"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (divisible and ragged vs the block size) and
value scales; every kernel must agree with its `ref.py` oracle to float
tolerance. Failures here are tiling/BlockSpec bugs by construction.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    fp8_gemm_pallas,
    lowrank_apply_fp8_pallas,
    lowrank_apply_pallas,
    matmul_pallas,
    range_sketch_pallas,
)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

DIMS = st.integers(min_value=1, max_value=70)
SMALL_BLOCK = 32  # keep interpret-mode grids small but multi-step


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# matmul_pallas
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_on_arbitrary_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = matmul_pallas(a, b, block=SMALL_BLOCK)
    np.testing.assert_allclose(got, ref.ref_matmul(a, b), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 1, 1), (32, 32, 32), (64, 32, 96), (33, 65, 31)])
def test_matmul_block_boundary_shapes(shape):
    m, k, n = shape
    rng = np.random.default_rng(0)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = matmul_pallas(a, b, block=SMALL_BLOCK)
    np.testing.assert_allclose(got, ref.ref_matmul(a, b), rtol=1e-5, atol=1e-4)


def test_matmul_large_scale_values():
    # f32 accumulation must survive big magnitudes without overflow.
    # Summation *order* differs between the tiled kernel and one flat
    # jnp.dot, so elements that suffer catastrophic cancellation can
    # disagree at rtol 1e-5 while both are individually correct — bound
    # the error relative to the problem scale (‖a‖·‖b‖·ulp-ish) instead.
    rng = np.random.default_rng(1)
    a, b = rand(rng, 48, 48, scale=1e4), rand(rng, 48, 48, scale=1e4)
    got = matmul_pallas(a, b, block=SMALL_BLOCK)
    want = ref.ref_matmul(a, b)
    scale = float(jnp.max(jnp.abs(a))) * float(jnp.max(jnp.abs(b))) * 48
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6 * scale)
    assert bool(jnp.all(jnp.isfinite(got)))


def test_matmul_rejects_bad_shapes():
    a = jnp.zeros((4, 5))
    b = jnp.zeros((6, 4))
    with pytest.raises(ValueError):
        matmul_pallas(a, b)
    with pytest.raises(ValueError):
        matmul_pallas(jnp.zeros((4,)), jnp.zeros((4, 4)))


def test_matmul_dtype_override():
    rng = np.random.default_rng(2)
    a, b = rand(rng, 16, 16), rand(rng, 16, 16)
    out = matmul_pallas(a, b, out_dtype=jnp.bfloat16)
    assert out.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# fp8_gemm_pallas
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_fp8_gemm_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = fp8_gemm_pallas(a, b, block=SMALL_BLOCK)
    want = ref.ref_fp8_gemm(a, b)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_fp8_gemm_error_band_vs_exact():
    # §5.4: percent-level relative error vs exact, not garbage.
    rng = np.random.default_rng(3)
    a, b = rand(rng, 64, 64), rand(rng, 64, 64)
    got = fp8_gemm_pallas(a, b, block=SMALL_BLOCK)
    exact = ref.ref_matmul(a, b)
    rel = float(jnp.linalg.norm(got - exact) / jnp.linalg.norm(exact))
    assert 1e-4 < rel < 0.15, rel


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(min_value=1e-3, max_value=1e3), seed=st.integers(0, 2**31 - 1))
def test_fp8_gemm_scaling_compensation(scale, seed):
    # Per-tensor amax scaling must make the error scale-invariant.
    rng = np.random.default_rng(seed)
    a, b = rand(rng, 32, 32), rand(rng, 32, 32)
    base = fp8_gemm_pallas(a, b, block=SMALL_BLOCK)
    scaled = fp8_gemm_pallas(a * scale, b, block=SMALL_BLOCK)
    np.testing.assert_allclose(scaled, base * scale, rtol=2e-2, atol=2e-2 * scale)


def test_fp8_gemm_zero_inputs():
    z = jnp.zeros((16, 16), jnp.float32)
    out = fp8_gemm_pallas(z, z, block=SMALL_BLOCK)
    assert float(jnp.max(jnp.abs(out))) == 0.0


# ---------------------------------------------------------------------------
# lowrank_apply_pallas (+fp8)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    m=DIMS,
    n=DIMS,
    ra=st.integers(1, 24),
    rb=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_lowrank_apply_matches_ref(m, n, ra, rb, seed):
    rng = np.random.default_rng(seed)
    u, core, vt = rand(rng, m, ra), rand(rng, ra, rb), rand(rng, rb, n)
    got = lowrank_apply_pallas(u, core, vt, block=SMALL_BLOCK)
    np.testing.assert_allclose(got, ref.ref_lowrank_apply(u, core, vt), rtol=1e-4, atol=1e-3)


@settings(max_examples=12, deadline=None)
@given(m=DIMS, n=DIMS, r=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_lowrank_apply_fp8_matches_ref(m, n, r, seed):
    # The kernel folds the dequant scales into the f32 accumulator once
    # per tile; the oracle divides per element in the compute dtype. Both
    # are valid fp8 pipelines with slightly different rounding, so the
    # comparison is norm-relative (single tiny-magnitude elements may
    # disagree at percent level while the product is equally accurate).
    rng = np.random.default_rng(seed)
    u, core, vt = rand(rng, m, r), rand(rng, r, r), rand(rng, r, n)
    got = lowrank_apply_fp8_pallas(u, core, vt, block=SMALL_BLOCK)
    want = ref.ref_lowrank_apply_fp8(u, core, vt)
    denom = float(jnp.linalg.norm(want)) + 1e-6
    rel = float(jnp.linalg.norm(got - want)) / denom
    assert rel < 3e-2, rel
    # And both stay within the fp8 band of the exact factor chain.
    exact = ref.ref_lowrank_apply(u, core, vt)
    rel_exact = float(jnp.linalg.norm(got - exact)) / (float(jnp.linalg.norm(exact)) + 1e-6)
    assert rel_exact < 0.12, rel_exact


def test_lowrank_apply_shape_validation():
    with pytest.raises(ValueError):
        lowrank_apply_pallas(jnp.zeros((8, 4)), jnp.zeros((5, 5)), jnp.zeros((5, 8)))


def test_lowrank_chain_equals_full_product():
    # U (core) Vᵀ must equal the dense product of the reconstruction.
    rng = np.random.default_rng(4)
    u, core, vt = rand(rng, 40, 6), rand(rng, 6, 6), rand(rng, 6, 36)
    got = lowrank_apply_pallas(u, core, vt, block=SMALL_BLOCK)
    dense = (u @ core) @ vt
    np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# range_sketch_pallas
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(m=DIMS, k=DIMS, l=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_range_sketch_matches_ref(m, k, l, seed):
    rng = np.random.default_rng(seed)
    a, om = rand(rng, m, k), rand(rng, k, l)
    got = range_sketch_pallas(a, om, block=SMALL_BLOCK)
    np.testing.assert_allclose(got, ref.ref_range_sketch(a, om), rtol=1e-5, atol=1e-4)


def test_range_sketch_shape_validation():
    with pytest.raises(ValueError):
        range_sketch_pallas(jnp.zeros((8, 4)), jnp.zeros((5, 3)))
