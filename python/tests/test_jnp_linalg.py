"""Custom-call-free linear algebra vs jnp.linalg (LAPACK) references.

These routines exist because LAPACK custom calls cannot execute in the
Rust PJRT client; they must nonetheless match LAPACK quality on the
sketch-sized problems they serve.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.jnp_linalg import jacobi_eigh, mgs_qr, rsvd_custom, svd_small_rows


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# mgs_qr
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(4, 120),
    l=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_mgs_qr_orthonormal_and_reconstructs(m, l, seed):
    if l > m:
        l = m
    rng = np.random.default_rng(seed)
    y = rand(rng, m, l)
    q, r = mgs_qr(y)
    np.testing.assert_allclose(q.T @ q, jnp.eye(l), atol=5e-5)
    np.testing.assert_allclose(q @ r, y, atol=5e-5 * float(jnp.max(jnp.abs(y))) * m)


def test_mgs_qr_r_is_upper_triangular():
    rng = np.random.default_rng(1)
    _, r = mgs_qr(rand(rng, 40, 8))
    assert float(jnp.max(jnp.abs(jnp.tril(r, -1)))) < 1e-5


def test_mgs_qr_rank_deficient_input():
    # Duplicate columns: dead directions must yield zero q columns, not NaN.
    rng = np.random.default_rng(2)
    col = rand(rng, 30, 1)
    y = jnp.concatenate([col, col, rand(rng, 30, 2)], axis=1)
    q, r = mgs_qr(y)
    assert bool(jnp.all(jnp.isfinite(q)))
    np.testing.assert_allclose(q @ r, y, atol=1e-4)


# ---------------------------------------------------------------------------
# jacobi_eigh
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(l=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_jacobi_eigh_matches_lapack(l, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, l, l)
    a = x @ x.T + jnp.eye(l)  # SPD, well-separated enough
    w_got, v_got = jacobi_eigh(a)
    w_ref = jnp.linalg.eigvalsh(a)[::-1]  # descending
    np.testing.assert_allclose(w_got, w_ref, rtol=1e-3, atol=1e-3)
    # Eigenvector quality: A v ≈ w v.
    resid = jnp.linalg.norm(a @ v_got - v_got * w_got[None, :])
    assert float(resid) < 1e-2 * float(jnp.linalg.norm(a)), float(resid)


def test_jacobi_eigh_diagonal_is_fixed_point():
    a = jnp.diag(jnp.asarray([5.0, 3.0, 1.0], jnp.float32))
    w, v = jacobi_eigh(a)
    np.testing.assert_allclose(w, jnp.asarray([5.0, 3.0, 1.0]), atol=1e-6)
    np.testing.assert_allclose(jnp.abs(v), jnp.eye(3), atol=1e-6)


def test_jacobi_eigh_rejects_nonsquare():
    with pytest.raises(ValueError):
        jacobi_eigh(jnp.zeros((3, 4)))


# ---------------------------------------------------------------------------
# svd_small_rows / rsvd_custom
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    l=st.integers(2, 20),
    n=st.integers(24, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_svd_small_rows_matches_lapack_spectrum(l, n, seed):
    rng = np.random.default_rng(seed)
    b = rand(rng, l, n)
    u, s, vt = svd_small_rows(b)
    s_ref = jnp.linalg.svd(b, compute_uv=False)
    np.testing.assert_allclose(s, s_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose((u * s[None, :]) @ vt, b, atol=2e-3 * n)


def test_rsvd_custom_recovers_low_rank_exactly():
    rng = np.random.default_rng(7)
    a = jnp.asarray(
        rng.standard_normal((90, 12)) @ rng.standard_normal((12, 75)), jnp.float32
    )
    omega = rand(rng, 75, 20)
    u, s, vt = rsvd_custom(a, omega)
    rec = (u * s[None, :]) @ vt
    rel = float(jnp.linalg.norm(rec - a) / jnp.linalg.norm(a))
    assert rel < 1e-4, rel
    # Trailing (l - true rank) singular values collapse to ~0.
    assert float(s[12]) < 1e-3 * float(s[0])


def test_rsvd_custom_truncation_error_tracks_eckart_young():
    # On a known decaying spectrum, the rank-r sketch error must sit near
    # the optimal tail energy.
    rng = np.random.default_rng(8)
    l_edge = 60
    sv = jnp.asarray([0.8**j for j in range(l_edge)], jnp.float32)
    q1, _ = mgs_qr(rand(rng, l_edge, l_edge))
    q2, _ = mgs_qr(rand(rng, l_edge, l_edge))
    a = (q1 * sv[None, :]) @ q2.T
    r = 12
    omega = rand(rng, l_edge, r + 8)
    u, s, vt = rsvd_custom(a, omega)
    rec = (u[:, :r] * s[None, :r]) @ vt[:r, :]
    err = float(jnp.linalg.norm(rec - a))
    opt = float(jnp.sqrt(jnp.sum(sv[r:] ** 2)))
    assert err < 3.0 * opt + 1e-5, (err, opt)
