"""AOT lowering tests: lattice construction, manifest shape, HLO sanity.

Full-lattice lowering is exercised by `make artifacts`; here we lower the
quick (sentinel) lattice into a tmpdir and validate the contract the Rust
manifest parser and runtime rely on.
"""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    sentinel = str(out / "model.hlo.txt")
    manifest = aot.lower_all(str(out), sentinel, quick=True, verbose=False)
    return out, sentinel, manifest


def test_quick_lattice_contains_sentinel_graph(quick_artifacts):
    out, sentinel, manifest = quick_artifacts
    assert os.path.exists(sentinel)
    names = [e["name"] for e in manifest["entries"]]
    assert "lowrank_e2e_n128_r16" in names


def test_manifest_json_roundtrips(quick_artifacts):
    out, _, manifest = quick_artifacts
    on_disk = json.loads((out / "manifest.json").read_text())
    assert on_disk == manifest
    assert on_disk["version"] == 1
    assert on_disk["oversample"] == aot.OVERSAMPLE


def test_manifest_entry_contract(quick_artifacts):
    out, _, manifest = quick_artifacts
    e = manifest["entries"][0]
    # The exact fields the Rust parser requires.
    for field in ["name", "op", "file", "n", "rank", "inputs", "outputs"]:
        assert field in e, field
    assert (out / e["file"]).exists()
    # e2e graph: a, b, omega_a, omega_b -> c.
    n, r = e["n"], e["rank"]
    assert e["inputs"] == [[n, n], [n, n], [n, r + 8], [n, r + 8]]
    assert e["outputs"] == [[n, n]]


def test_hlo_text_is_parseable_hlo(quick_artifacts):
    out, sentinel, _ = quick_artifacts
    text = open(sentinel).read()
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text
    # Tuple return (return_tuple=True): root is a tuple op.
    assert "tuple(" in text
    # No LAPACK custom-calls may leak into artifacts (the Rust client
    # cannot execute them) — the whole point of jnp_linalg.
    assert "lapack" not in text.lower()
    assert "custom-call" not in text.lower()


def test_full_lattice_covers_all_ops():
    entries = aot.build_lattice(quick=False)
    ops = {e["op"] for e in entries}
    assert {
        "dense_f32",
        "dense_f16",
        "dense_fp8",
        "lowrank_apply",
        "lowrank_apply_fp8",
        "rsvd",
        "lowrank_gemm",
        "lowrank_gemm_fp8",
        "lowrank_e2e",
    } <= ops
    # No rank exceeding n/2 on the lattice (aot.py's own constraint).
    for e in entries:
        if e["rank"]:
            assert e["rank"] * 2 <= e["n"], e["name"]


def test_lattice_names_are_unique():
    entries = aot.build_lattice(quick=False)
    names = [e["name"] for e in entries]
    assert len(names) == len(set(names))
