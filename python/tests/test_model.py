"""L2 graph tests: the model functions the artifacts are lowered from."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


def lowrank_matrix(rng, m, n, r):
    return jnp.asarray(
        rng.standard_normal((m, r)) @ rng.standard_normal((r, n)), jnp.float32
    )


def test_dense_gemm_f32_exact():
    rng = np.random.default_rng(0)
    a, b = rand(rng, 96, 96), rand(rng, 96, 96)
    np.testing.assert_allclose(
        model.dense_gemm_f32(a, b), ref.ref_matmul(a, b), rtol=1e-5, atol=1e-4
    )


def test_dense_gemm_f16_storage_rounding():
    rng = np.random.default_rng(1)
    a, b = rand(rng, 64, 64), rand(rng, 64, 64)
    got = model.dense_gemm_f16(a, b)
    exact = ref.ref_matmul(a, b)
    rel = float(jnp.linalg.norm(got - exact) / jnp.linalg.norm(exact))
    # f16 storage: small but visible error, far below fp8's.
    assert 1e-6 < rel < 5e-3, rel


def test_dense_gemm_fp8_band():
    rng = np.random.default_rng(2)
    a, b = rand(rng, 64, 64), rand(rng, 64, 64)
    got = model.dense_gemm_fp8(a, b)
    exact = ref.ref_matmul(a, b)
    rel = float(jnp.linalg.norm(got - exact) / jnp.linalg.norm(exact))
    assert 1e-3 < rel < 0.15, rel


def test_lowrank_core_matches_ref():
    rng = np.random.default_rng(3)
    s_a = jnp.abs(rand(rng, 6)) + 0.1
    s_b = jnp.abs(rand(rng, 5)) + 0.1
    vt_a, u_b = rand(rng, 6, 80), rand(rng, 80, 5)
    np.testing.assert_allclose(
        model.lowrank_core(s_a, vt_a, u_b, s_b),
        ref.ref_lowrank_core(s_a, vt_a, u_b, s_b),
        rtol=1e-4,
        atol=1e-4,
    )


@pytest.mark.parametrize("fp8", [False, True])
def test_lowrank_gemm_full_chain(fp8):
    # Factor two genuinely low-rank matrices exactly, run Eq. (1), compare
    # against the dense product.
    rng = np.random.default_rng(4)
    n, r = 72, 6
    a = lowrank_matrix(rng, n, n, r)
    b = lowrank_matrix(rng, n, n, r)
    oa, ob = rand(rng, n, r + 8), rand(rng, n, r + 8)
    u_a, s_a, vt_a = model.rsvd_factorize(a, oa, rank=r)
    u_b, s_b, vt_b = model.rsvd_factorize(b, ob, rank=r)
    got = model.lowrank_gemm(u_a, s_a, vt_a, u_b, s_b, vt_b, fp8=fp8)
    exact = a @ b
    rel = float(jnp.linalg.norm(got - exact) / jnp.linalg.norm(exact))
    tol = 0.08 if fp8 else 1e-3
    assert rel < tol, rel


def test_rsvd_factorize_shapes_and_ordering():
    rng = np.random.default_rng(5)
    n, r = 64, 8
    a = rand(rng, n, n)
    u, s, vt = model.rsvd_factorize(a, rand(rng, n, r + 8), rank=r)
    assert u.shape == (n, r) and s.shape == (r,) and vt.shape == (r, n)
    assert bool(jnp.all(jnp.diff(s) <= 1e-5)), "singular values must descend"
    assert bool(jnp.all(s >= 0))


def test_lowrank_gemm_e2e_cold_path():
    rng = np.random.default_rng(6)
    n, r = 64, 8
    a = lowrank_matrix(rng, n, n, r)
    b = lowrank_matrix(rng, n, n, r)
    oa, ob = rand(rng, n, r + 8), rand(rng, n, r + 8)
    got = model.lowrank_gemm_e2e(a, b, oa, ob, rank=r)
    exact = a @ b
    rel = float(jnp.linalg.norm(got - exact) / jnp.linalg.norm(exact))
    assert rel < 1e-3, rel


def test_jit_wrappers_lower_and_agree():
    rng = np.random.default_rng(7)
    n, r = 48, 6
    a = lowrank_matrix(rng, n, n, r)
    om = rand(rng, n, r + 8)
    u1, s1, v1 = model.rsvd_factorize(a, om, rank=r)
    u2, s2, v2 = model.rsvd_factorize_jit(a, om, rank=r)
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-5)
    rec1 = (u1 * s1[None, :]) @ v1
    rec2 = (u2 * s2[None, :]) @ v2
    np.testing.assert_allclose(rec1, rec2, rtol=1e-4, atol=1e-4)


def test_error_grows_as_rank_shrinks():
    # §5.4 qualitative claim, at L2: truncation error is monotone in rank.
    rng = np.random.default_rng(8)
    n = 64
    sv = jnp.asarray([0.75**j for j in range(n)], jnp.float32)
    q1, _ = jnp.linalg.qr(rand(rng, n, n))
    q2, _ = jnp.linalg.qr(rand(rng, n, n))
    a = (q1 * sv[None, :]) @ q2.T
    b = (q2 * sv[None, :]) @ q1.T
    exact = a @ b
    prev = 0.0
    for r in [32, 16, 8, 4]:
        oa, ob = rand(rng, n, r + 8), rand(rng, n, r + 8)
        got = model.lowrank_gemm_e2e(a, b, oa, ob, rank=r)
        rel = float(jnp.linalg.norm(got - exact) / jnp.linalg.norm(exact))
        assert rel + 1e-5 >= prev, (r, rel, prev)
        prev = rel
    assert prev > 1e-3  # rank-4 truncation must be visible
