"""Bit-level validation of the E4M3 path against an independent model.

The kernels quantize through jax's `float8_e4m3fn` dtype; here we model
OCP E4M3 (1-4-3, no inf, max 448, round-to-nearest-even) from first
principles in Python and require exact agreement. This is the oracle the
Rust `fp8::codec` is also written against, so the two substrates share a
single numerical definition.
"""

import math

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.common import (
    E4M3_MAX,
    dequantize_e4m3,
    e4m3_scale_for,
    quantize_e4m3,
    saturate_e4m3,
)


def e4m3_reference(x: float) -> float:
    """Independent E4M3FN round-trip: round to 3-bit mantissa, RNE,
    clamp to ±448, denormals at 2^-9 granularity, bias 7."""
    if math.isnan(x):
        return math.nan
    if x == 0.0:
        return math.copysign(0.0, x)
    sign = math.copysign(1.0, x)
    a = abs(x)
    if a > E4M3_MAX:
        return sign * E4M3_MAX  # our kernels saturate before casting
    # Smallest normal is 2^-6; denormal lsb is 2^-9.
    if a < 2.0**-6:
        q = round(a / 2.0**-9)  # python round = RNE
        return sign * q * 2.0**-9
    e = math.floor(math.log2(a))
    # Guard boundary: log2 may land on e+1's edge after rounding below.
    lsb = 2.0**e / 8.0
    q = round(a / lsb)
    if q == 16:  # rounded up into the next binade
        e += 1
        lsb = 2.0**e / 8.0
        q = round(a / lsb)
    v = q * lsb
    return sign * min(v, E4M3_MAX)


@settings(max_examples=300, deadline=None)
@given(
    st.floats(
        min_value=-600.0, max_value=600.0, allow_nan=False, allow_infinity=False
    )
)
def test_e4m3_cast_matches_reference_model(x):
    got = float(jnp.float32(saturate_e4m3(jnp.float32(x)).astype(jnp.float8_e4m3fn)))
    want = e4m3_reference(x)
    assert got == want or (math.isnan(got) and math.isnan(want)), f"{x}: {got} != {want}"


def test_e4m3_exact_values_survive():
    # Every value with ≤3 mantissa bits in range must round-trip exactly.
    exact = [0.0, 1.0, -1.0, 0.5, 1.5, 2.0, 3.5, 448.0, -448.0, 0.015625]
    for x in exact:
        rt = float(jnp.float32(jnp.float32(x).astype(jnp.float8_e4m3fn)))
        assert rt == x, f"{x} -> {rt}"


def test_e4m3_max_is_448():
    # 448 = 0x7E; values just above saturate via our clamp.
    assert float(jnp.float32(saturate_e4m3(jnp.float32(1e6)).astype(jnp.float8_e4m3fn))) == 448.0


def test_e4m3_rne_tie_breaks():
    # Between 1.0 (q=8) and 1.125 (q=9) the tie 1.0625 rounds to even (8).
    assert float(jnp.float32(jnp.float32(1.0625).astype(jnp.float8_e4m3fn))) == 1.0
    # Between 1.125 (q=9) and 1.25 (q=10) the tie 1.1875 rounds to 1.25.
    assert float(jnp.float32(jnp.float32(1.1875).astype(jnp.float8_e4m3fn))) == 1.25


@settings(max_examples=50, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    scale_exp=st.integers(-20, 20),
)
def test_quantize_dequantize_bounded_error(seed, scale_exp):
    # With amax scaling, each element is bounded by the larger of the
    # 3-bit mantissa half-ulp (|x|·2⁻⁴, normal range) and the denormal
    # granularity (amax·2⁻¹⁰·(2⁹/448)·safety — elements far below amax
    # land in E4M3's denormal band where the error is absolute).
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((32, 32)) * 2.0**scale_exp, jnp.float32)
    s = e4m3_scale_for(x)
    rt = dequantize_e4m3(quantize_e4m3(x, s), s)
    amax = float(jnp.max(jnp.abs(x)))
    tol = jnp.maximum(jnp.abs(x) * 2.0**-4, amax * (2.0**-10 / 448.0) * 2.0**9)
    assert bool(jnp.all(jnp.abs(rt - x) <= tol + 1e-30)), float(
        jnp.max(jnp.abs(rt - x) / tol)
    )


def test_zero_tensor_scale_is_identity():
    z = jnp.zeros((8, 8), jnp.float32)
    s = e4m3_scale_for(z)
    assert float(s) == 1.0
    rt = dequantize_e4m3(quantize_e4m3(z, s), s)
    assert float(jnp.max(jnp.abs(rt))) == 0.0
