"""Pure-jnp oracles for every L1 kernel (the pytest correctness bar).

Each `ref_*` computes the same mathematical result as its Pallas
counterpart using nothing but jax.numpy — no tiling, no BlockSpecs — so
any disagreement beyond float tolerance is a kernel bug, not a
modelling choice. The FP8 reference reuses the *same* quantization
helpers as the kernel on purpose: the oracle checks the tiled matmul
structure, while quantization itself is validated bit-level in
`tests/test_fp8_numerics.py` against an independent Python
implementation of E4M3 rounding.
"""

from __future__ import annotations

import jax.numpy as jnp

from .common import dequantize_e4m3, e4m3_scale_for, quantize_e4m3


def ref_matmul(a, b):
    """Exact f32 GEMM."""
    return jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32), preferred_element_type=jnp.float32
    )


def ref_fp8_gemm(a, b, compute_dtype=jnp.bfloat16):
    """Quantize both operands to scaled E4M3, multiply in compute_dtype,
    accumulate f32 — the exact pipeline fp8_gemm_pallas implements."""
    sa = e4m3_scale_for(a)
    sb = e4m3_scale_for(b)
    ad = dequantize_e4m3(quantize_e4m3(a, sa), sa, compute_dtype)
    bd = dequantize_e4m3(quantize_e4m3(b, sb), sb, compute_dtype)
    return jnp.matmul(ad, bd, preferred_element_type=jnp.float32).astype(jnp.float32)


def ref_lowrank_core(s_a, vt_a, u_b, s_b):
    """core = diag(s_a) (V_A^T U_B) diag(s_b) — rank-sized, f32."""
    t = jnp.matmul(vt_a.astype(jnp.float32), u_b.astype(jnp.float32))
    return s_a[:, None] * t * s_b[None, :]


def ref_lowrank_apply(u, core, vt):
    """C = U @ core @ V^T, evaluated inside-out (rank-sized middle)."""
    t = jnp.matmul(core.astype(jnp.float32), vt.astype(jnp.float32))
    return jnp.matmul(u.astype(jnp.float32), t)


def ref_lowrank_apply_fp8(u, core, vt, compute_dtype=jnp.bfloat16):
    """fp8-storage variant of ref_lowrank_apply (U/V^T through E4M3)."""
    su = e4m3_scale_for(u)
    sv = e4m3_scale_for(vt)
    ud = dequantize_e4m3(quantize_e4m3(u, su), su, compute_dtype)
    vd = dequantize_e4m3(quantize_e4m3(vt, sv), sv, compute_dtype)
    t = jnp.matmul(core.astype(compute_dtype), vd, preferred_element_type=jnp.float32)
    return jnp.matmul(ud, t.astype(compute_dtype), preferred_element_type=jnp.float32).astype(
        jnp.float32
    )


def ref_range_sketch(a, omega):
    """Y = A @ Omega in f32."""
    return jnp.matmul(a.astype(jnp.float32), omega.astype(jnp.float32))


def ref_rsvd(a, rank: int, seed: int = 0, oversample: int = 8, power_iters: int = 2):
    """Plain-jnp Halko randomized SVD (truncated to `rank`).

    The oracle for model.rsvd_factorize: sketch, (optional) power
    iterations for spectral sharpening, thin-QR, small exact SVD on the
    projected panel.
    """
    import jax

    m, k = a.shape
    l = min(rank + oversample, min(m, k))
    omega = jax.random.normal(jax.random.PRNGKey(seed), (k, l), dtype=jnp.float32)
    y = a @ omega
    for _ in range(power_iters):
        y = a @ (a.T @ y)
    q, _ = jnp.linalg.qr(y)
    bsmall = q.T @ a
    u_s, s, vt = jnp.linalg.svd(bsmall, full_matrices=False)
    u = q @ u_s
    return u[:, :rank], s[:rank], vt[:rank, :]
