"""L1 Pallas kernel: randomized range-finder sketch (Halko step 1).

The bandwidth-dominant step of randomized SVD is the sketch

    Y = A @ Omega          (m x l, l = rank + oversampling)

— a single streaming pass over A against a skinny random matrix. This
kernel tiles A over a (m/bm, k/bk) grid with the k axis innermost; the
skinny Omega panel (bk x l) and the Y accumulator block (bm x l) are
VMEM-resident, so A is read from HBM exactly once (the property that
makes rSVD viable at the paper's scales).

The orthonormalization (QR) and the small-SVD that follow are
rank-sized and live at L2 (`model.rsvd_factorize`) as plain jnp ops —
they are O(r^2)-shaped and not worth a custom kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_BLOCK, cdiv, pad2d, pick_block, round_up


def _sketch_kernel(a_ref, om_ref, y_ref):
    """y[i] (+)= a[i,k] @ omega[k] with f32 accumulation."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(
        a_ref[...], om_ref[...], preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)


@functools.partial(jax.named_call, name="range_sketch_pallas")
def range_sketch_pallas(a, omega, *, block: int = DEFAULT_BLOCK):
    """Y = A @ Omega, A streamed once, Omega panels VMEM-resident."""
    m, k = a.shape
    k2, l = omega.shape
    if k != k2:
        raise ValueError(f"sketch inner-dim mismatch: {a.shape} @ {omega.shape}")

    bm = pick_block(m, block)
    bk = pick_block(k, block)
    mp, kp = round_up(m, bm), round_up(k, bk)
    a_p = pad2d(a.astype(jnp.float32), mp, kp)
    om_p = pad2d(omega.astype(jnp.float32), kp, l)

    grid = (cdiv(mp, bm), cdiv(kp, bk))
    out = pl.pallas_call(
        _sketch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, kk: (i, kk)),
            pl.BlockSpec((bk, l), lambda i, kk: (kk, 0)),
        ],
        out_specs=pl.BlockSpec((bm, l), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, l), jnp.float32),
        interpret=True,
    )(a_p, om_p)

    return out[:m, :]
