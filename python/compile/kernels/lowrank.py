"""L1 Pallas kernel: low-rank factor-chain application (paper Eq. 1).

Given A ~= U_A diag(s_A) V_A^T and B ~= U_B diag(s_B) V_B^T, the L2
graph merges everything rank-sized into one small core

    core = diag(s_A) . (V_A^T U_B) . diag(s_B)        (r_a x r_b)

and this kernel evaluates the only large-output step,

    C = U_A @ core @ V_B^T                            (m x n)

on a (m/bm, n/bn) grid. The core is tiny (r^2 floats) and its BlockSpec
index map is constant, so it stays **VMEM-resident across the whole
grid** — the TPU analogue of the paper's "compact factorized
representations move fewer bytes": HBM traffic per output tile is one
(bm x r) U-panel + one (r x bn) V-panel instead of full (bm x k)/(k x bn)
panels.

The fp8 variant streams U/V^T as `float8_e4m3fn` (1 byte/elem) and
up-casts tiles in VMEM, mirroring fp8_gemm.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (
    DEFAULT_BLOCK,
    cdiv,
    e4m3_scale_for,
    pad2d,
    pick_block,
    quantize_e4m3,
    round_up,
)


def _lowrank_apply_kernel(u_ref, core_ref, vt_ref, o_ref, *, compute_dtype):
    """o[i,j] = u[i,:] @ core @ vt[:,j] — rank-sized intermediate only."""
    u_tile = u_ref[...].astype(compute_dtype)
    vt_tile = vt_ref[...].astype(compute_dtype)
    core = core_ref[...].astype(compute_dtype)
    # (r_a x bn) intermediate: rank-sized, stays in VMEM/registers.
    t = jnp.dot(core, vt_tile, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(
        u_tile, t.astype(compute_dtype), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _lowrank_apply_fp8_kernel(u_ref, core_ref, vt_ref, inv_ref, o_ref, *, compute_dtype):
    """fp8-storage variant: dequantize the U/V^T tiles in VMEM."""
    u_tile = u_ref[...].astype(compute_dtype)
    vt_tile = vt_ref[...].astype(compute_dtype)
    core = core_ref[...].astype(compute_dtype)
    t = jnp.dot(core, vt_tile, preferred_element_type=jnp.float32)
    acc = jnp.dot(u_tile, t.astype(compute_dtype), preferred_element_type=jnp.float32)
    o_ref[...] = (acc * (inv_ref[0, 0] * inv_ref[0, 1])).astype(o_ref.dtype)


def _apply_grid(m, n, ra, rb, block):
    bm = pick_block(m, block)
    bn = pick_block(n, block)
    mp, np_ = round_up(m, bm), round_up(n, bn)
    return bm, bn, mp, np_, (cdiv(mp, bm), cdiv(np_, bn))


@functools.partial(jax.named_call, name="lowrank_apply_pallas")
def lowrank_apply_pallas(u, core, vt, *, block: int = DEFAULT_BLOCK, out_dtype=jnp.float32):
    """C = U @ core @ V^T with the core VMEM-resident across the grid."""
    m, ra = u.shape
    ra2, rb = core.shape
    rb2, n = vt.shape
    if ra != ra2 or rb != rb2:
        raise ValueError(f"factor-chain shape mismatch: {u.shape} @ {core.shape} @ {vt.shape}")

    bm, bn, mp, np_, grid = _apply_grid(m, n, ra, rb, block)
    u_p = pad2d(u.astype(jnp.float32), mp, ra)
    vt_p = pad2d(vt.astype(jnp.float32), rb, np_)

    out = pl.pallas_call(
        functools.partial(_lowrank_apply_kernel, compute_dtype=jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, ra), lambda i, j: (i, 0)),
            pl.BlockSpec((ra, rb), lambda i, j: (0, 0)),  # resident core
            pl.BlockSpec((rb, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(u_p, core.astype(jnp.float32), vt_p)

    return out[:m, :n].astype(out_dtype)


@functools.partial(jax.named_call, name="lowrank_apply_fp8_pallas")
def lowrank_apply_fp8_pallas(
    u,
    core,
    vt,
    *,
    block: int = DEFAULT_BLOCK,
    compute_dtype=jnp.bfloat16,
    out_dtype=jnp.float32,
):
    """fp8-storage factor-chain: U/V^T streamed as E4M3, f32 accumulate.

    The core stays f32 — it is r^2 scalars ("keep the spectrum exact",
    same discipline as the Rust LowRankFactor keeping s in f32).
    """
    m, ra = u.shape
    ra2, rb = core.shape
    rb2, n = vt.shape
    if ra != ra2 or rb != rb2:
        raise ValueError(f"factor-chain shape mismatch: {u.shape} @ {core.shape} @ {vt.shape}")

    su = e4m3_scale_for(u)
    sv = e4m3_scale_for(vt)
    uq = quantize_e4m3(u, su)
    vq = quantize_e4m3(vt, sv)
    inv = jnp.stack([1.0 / su, 1.0 / sv]).reshape(1, 2).astype(jnp.float32)

    bm, bn, mp, np_, grid = _apply_grid(m, n, ra, rb, block)
    u_p = pad2d(uq, mp, ra)
    vt_p = pad2d(vq, rb, np_)

    out = pl.pallas_call(
        functools.partial(_lowrank_apply_fp8_kernel, compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, ra), lambda i, j: (i, 0)),
            pl.BlockSpec((ra, rb), lambda i, j: (0, 0)),
            pl.BlockSpec((rb, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(u_p, core.astype(jnp.float32), vt_p, inv)

    return out[:m, :n].astype(out_dtype)
