"""L1 Pallas kernel: tiled dense matmul with f32 accumulation.

The workhorse of every dense baseline ("PyTorch FP32" / "TorchCompile
FP16" rows of Table 1) and of the factor-chain reconstruction step. The
HBM<->VMEM schedule is expressed with a (m/bm, n/bn, k/bk) grid —
k innermost so the output block stays resident in VMEM while the
reduction streams A- and B-panels past it (the BlockSpec analogue of
the paper's threadblock tiling through shared memory).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical, real-TPU perf is estimated
structurally (see common.gemm_vmem_bytes / mxu_utilization_estimate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_BLOCK, cdiv, gemm_block_shapes, pad2d, round_up


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """One grid step: o[i,j] (+)= x[i,k] @ y[k,j], f32 accumulation.

    The output BlockSpec ignores the k grid axis, so the same VMEM block
    is revisited across the k loop — zero it on the first step, keep
    accumulating afterwards.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.named_call, name="matmul_pallas")
def matmul_pallas(
    a,
    b,
    *,
    block: int = DEFAULT_BLOCK,
    out_dtype=jnp.float32,
):
    """C = A @ B via the tiled Pallas kernel.

    Shapes need not be multiples of the block: operands are zero-padded
    up to the grid and the result is sliced back. Accumulation is f32
    regardless of input dtype (the paper's FP32-accumulation discipline,
    §3.3.1).
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul_pallas expects 2-D operands, got {a.shape} @ {b.shape}")
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner-dim mismatch: {a.shape} @ {b.shape}")

    bm, bk, bn = gemm_block_shapes(m, k, n, block)
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    a_p = pad2d(a.astype(jnp.float32), mp, kp)
    b_p = pad2d(b.astype(jnp.float32), kp, np_)

    nk = cdiv(kp, bk)
    grid = (cdiv(mp, bm), cdiv(np_, bn), nk)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)

    return out[:m, :n].astype(out_dtype)
