"""Shared helpers for the Pallas kernel layer (L1).

Everything here is build-time-only Python: these functions run inside
`jax.jit`-traced graphs that are lowered once by `compile/aot.py` and
then executed from Rust through PJRT. Nothing in this package is
imported on the request path.

Hardware adaptation note (DESIGN.md §Hardware-Adaptation): the paper's
kernels are CUDA/TensorCore kernels. On a TPU-shaped machine the same
insight is expressed as

  - threadblock tiles      -> `pl.BlockSpec` grids over (m/bm, n/bn, k/bk)
  - shared-memory staging  -> VMEM residency of each block
  - WMMA fp16*fp16+fp32    -> MXU `jnp.dot(..., preferred_element_type=f32)`
  - hardware FP8 storage   -> `float8_e4m3fn` casts (bit-exact E4M3)
"""

from __future__ import annotations

import math

import jax.numpy as jnp

# Default square tile edge. 128 is the MXU-native lane width; a
# (128, 128) f32 block is 64 KiB, so a 3-operand matmul tile set is well
# inside the ~16 MiB VMEM budget even with double buffering.
DEFAULT_BLOCK = 128

# E4M3 (OCP FP8, no infinities) saturation bound.
E4M3_MAX = 448.0

# VMEM budget used by the block-shape planner (bytes). Slightly under
# the physical 16 MiB to leave room for Mosaic's own scratch.
VMEM_BUDGET = 14 * 1024 * 1024


def cdiv(a: int, b: int) -> int:
    """Ceiling division."""
    return -(-a // b)


def round_up(x: int, mult: int) -> int:
    """Round `x` up to a multiple of `mult`."""
    return cdiv(x, mult) * mult


def pick_block(dim: int, preferred: int = DEFAULT_BLOCK) -> int:
    """Choose a block edge for a dimension of size `dim`.

    Small dims use the whole dim (one grid step); large dims use the
    preferred MXU-aligned edge. Always a power-of-two-ish divisor-free
    choice — the L2 wrappers pad to a multiple of the block, so the
    block never has to divide `dim` exactly.
    """
    if dim <= 0:
        raise ValueError(f"dimension must be positive, got {dim}")
    return min(preferred, max(8, 1 << (dim - 1).bit_length()) if dim < preferred else preferred)


def gemm_block_shapes(m: int, k: int, n: int, block: int = DEFAULT_BLOCK):
    """(bm, bk, bn) for a tiled GEMM over an (m, k) x (k, n) problem."""
    return pick_block(m, block), pick_block(k, block), pick_block(n, block)


def gemm_vmem_bytes(bm: int, bk: int, bn: int, in_bytes: int = 4, acc_bytes: int = 4) -> int:
    """Resident VMEM bytes for one grid step of the tiled matmul.

    One A block, one B block, one accumulator/output block. This is what
    DESIGN.md §9 reports as the kernel's VMEM footprint estimate.
    """
    return bm * bk * in_bytes + bk * bn * in_bytes + bm * bn * acc_bytes


def mxu_utilization_estimate(bm: int, bk: int, bn: int, lane: int = 128) -> float:
    """Fraction of MXU lanes kept busy by a (bm, bk, bn) tile.

    The MXU is a 128x128 systolic array; tiles smaller than the lane
    width in any contracted/output dim leave lanes idle. This is the
    structural estimate recorded in DESIGN.md (interpret=True gives no
    real hardware timing).
    """
    eff = (min(bm, lane) / lane) * (min(bk, lane) / lane) * (min(bn, lane) / lane)
    return float(eff)


def saturate_e4m3(x):
    """Clamp to the E4M3 representable range so the cast saturates
    instead of producing NaN (OCP behaviour: no inf encoding)."""
    return jnp.clip(x, -E4M3_MAX, E4M3_MAX)


def quantize_e4m3(x, scale):
    """f32 -> scaled, saturating E4M3. Returns the fp8 payload.

    `scale` maps the tensor's dynamic range onto [-448, 448]; the
    matching `dequantize_e4m3` divides it back out. Bit-exact: goes
    through the real `float8_e4m3fn` dtype.
    """
    return saturate_e4m3(x * scale).astype(jnp.float8_e4m3fn)


def dequantize_e4m3(q, scale, dtype=jnp.float32):
    """Scaled E4M3 -> `dtype` (compute precision)."""
    return q.astype(dtype) / scale


def e4m3_scale_for(x):
    """Per-tensor scale: map max|x| to the E4M3 saturation bound.

    Mirrors `rust/src/fp8/quantize.rs`: amax-based per-tensor scaling
    (the paper's 'scaling compensation' for FP8's narrow range).
    """
    amax = jnp.max(jnp.abs(x))
    # Guard zero tensors; scale 1.0 keeps them exactly zero.
    return jnp.where(amax > 0, E4M3_MAX / amax, 1.0)


def pad2d(x, rows: int, cols: int):
    """Zero-pad a 2-D array up to (rows, cols)."""
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


def flops_gemm(m: int, k: int, n: int) -> float:
    """Model FLOPs of a dense (m,k)x(k,n) GEMM."""
    return 2.0 * m * k * n


def log2_spaced(lo: int, hi: int) -> list[int]:
    """The paper's sqrt(2)-geometric size sweep (§4.3)."""
    sizes = []
    x = float(lo)
    while x <= hi * 1.0001:
        n = int(round(x / 64.0) * 64)  # keep MXU-friendly multiples
        if not sizes or n != sizes[-1]:
            sizes.append(n)
        x *= math.sqrt(2.0)
    return sizes
