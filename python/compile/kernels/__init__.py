"""L1: Pallas kernels for the paper's compute hot-spots.

All kernels run with `interpret=True` (the CPU PJRT plugin cannot
execute Mosaic custom-calls); see each module's docstring for the
TPU-structural reasoning (BlockSpec schedules, VMEM residency, MXU
accumulation) that replaces the paper's CUDA threadblock design.
"""

from .common import (
    DEFAULT_BLOCK,
    E4M3_MAX,
    VMEM_BUDGET,
    cdiv,
    dequantize_e4m3,
    e4m3_scale_for,
    gemm_block_shapes,
    gemm_vmem_bytes,
    mxu_utilization_estimate,
    pick_block,
    quantize_e4m3,
    round_up,
)
from .fp8_gemm import fp8_gemm_pallas
from .lowrank import lowrank_apply_fp8_pallas, lowrank_apply_pallas
from .matmul import matmul_pallas
from .range_finder import range_sketch_pallas

__all__ = [
    "DEFAULT_BLOCK",
    "E4M3_MAX",
    "VMEM_BUDGET",
    "cdiv",
    "dequantize_e4m3",
    "e4m3_scale_for",
    "fp8_gemm_pallas",
    "gemm_block_shapes",
    "gemm_vmem_bytes",
    "lowrank_apply_fp8_pallas",
    "lowrank_apply_pallas",
    "matmul_pallas",
    "mxu_utilization_estimate",
    "pick_block",
    "quantize_e4m3",
    "range_sketch_pallas",
    "round_up",
]
