"""L1 Pallas kernel: FP8-storage GEMM (quantize -> fp8 tiles -> f32 acc).

This is the paper's §3.3 pipeline made explicit:

  1. per-tensor amax scaling maps each operand onto the E4M3 range,
  2. operands are stored/streamed as `float8_e4m3fn` (1 byte/elem — the
     bandwidth win the paper's §6.2 roofline argument relies on),
  3. inside the kernel each VMEM tile is up-cast to the compute
     precision (bf16 by default, the MXU analogue of the paper's "FP16
     compute"), multiplied on the MXU,
  4. partial sums accumulate in f32 ("FP32 accumulation").

The dequantize-inside-the-kernel placement matters: the HBM traffic is
fp8 bytes, only the VMEM-resident tile is ever widened.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (
    DEFAULT_BLOCK,
    cdiv,
    e4m3_scale_for,
    gemm_block_shapes,
    pad2d,
    quantize_e4m3,
    round_up,
)


def _fp8_gemm_kernel(x_ref, y_ref, inv_ref, o_ref, *, compute_dtype):
    """o[i,j] (+)= dequant(x_fp8[i,k]) @ dequant(y_fp8[k,j]).

    `inv_ref` carries the two dequantization scales (1/sa, 1/sb) as a
    (1, 2) f32 block broadcast to every grid step; folding the product
    of both scales into the f32 accumulator once per step is cheaper
    than scaling each operand tile.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x_tile = x_ref[...].astype(compute_dtype)
    y_tile = y_ref[...].astype(compute_dtype)
    acc = jnp.dot(x_tile, y_tile, preferred_element_type=jnp.float32)
    o_ref[...] += acc * (inv_ref[0, 0] * inv_ref[0, 1])


@functools.partial(jax.named_call, name="fp8_gemm_pallas")
def fp8_gemm_pallas(
    a,
    b,
    *,
    block: int = DEFAULT_BLOCK,
    compute_dtype=jnp.bfloat16,
    out_dtype=jnp.float32,
):
    """C ~= A @ B with FP8 (E4M3) storage and f32 accumulation.

    Inputs are f32; quantization happens here (per-tensor amax scaling)
    so the lowered HLO contains the full storage pipeline the Rust
    roofline model charges bytes for.
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"fp8_gemm_pallas expects 2-D operands, got {a.shape} @ {b.shape}")
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner-dim mismatch: {a.shape} @ {b.shape}")

    sa = e4m3_scale_for(a)
    sb = e4m3_scale_for(b)
    aq = quantize_e4m3(a, sa)
    bq = quantize_e4m3(b, sb)

    bm, bk, bn = gemm_block_shapes(m, k, n, block)
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    aq = pad2d(aq, mp, kp)
    bq = pad2d(bq, kp, np_)
    inv = jnp.stack([1.0 / sa, 1.0 / sb]).reshape(1, 2).astype(jnp.float32)

    nk = cdiv(kp, bk)
    grid = (cdiv(mp, bm), cdiv(np_, bn), nk)

    out = pl.pallas_call(
        functools.partial(_fp8_gemm_kernel, compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 2), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(aq, bq, inv)

    return out[:m, :n].astype(out_dtype)
