"""L2: the paper's compute graphs, built on the L1 Pallas kernels.

Each public function here is a pure, jittable graph with static shapes;
`compile/aot.py` lowers a lattice of them to HLO text once, and the
Rust runtime (`rust/src/runtime/`) loads + executes the artifacts on
the request path. Python never runs at serving time.

Graphs (paper §3 / §4.4 method list):

  dense_gemm_f32      exact GEMM              -> "PyTorch FP32" analogue
  dense_gemm_f16      f16-storage GEMM        -> "TorchCompile FP16"
  dense_gemm_fp8      E4M3-storage GEMM       -> "cuBLAS Optimized FP8"
  rsvd_factorize      Halko factorization     -> offline decomposition
  lowrank_core        rank-sized core merge   -> Eq. (1) inner product
  lowrank_apply[.fp8] factor-chain apply      -> "LowRank FP8/Auto"
  lowrank_gemm        core + apply in one     -> full Eq. (1)
  lowrank_gemm_e2e    factorize + chain       -> cold-path (cache miss)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .jnp_linalg import rsvd_custom
from .kernels import (
    fp8_gemm_pallas,
    lowrank_apply_fp8_pallas,
    lowrank_apply_pallas,
    matmul_pallas,
    range_sketch_pallas,
)


def dense_gemm_f32(a, b):
    """Exact f32 GEMM through the tiled Pallas kernel."""
    return matmul_pallas(a, b)


def dense_gemm_f16(a, b):
    """f16-storage GEMM: operands round-trip through IEEE binary16
    before the f32-accumulating kernel (the 'TorchCompile FP16' row —
    half-width storage, full-precision accumulate)."""
    a16 = a.astype(jnp.float16).astype(jnp.float32)
    b16 = b.astype(jnp.float16).astype(jnp.float32)
    return matmul_pallas(a16, b16)


def dense_gemm_fp8(a, b):
    """E4M3-storage GEMM with bf16 compute / f32 accumulation."""
    return fp8_gemm_pallas(a, b)


def lowrank_core(s_a, vt_a, u_b, s_b):
    """core = diag(s_a) (V_A^T U_B) diag(s_b) — the k-contraction of
    Eq. (1), the only place the inner dimension k is touched.

    V_A^T (r x k) @ U_B (k x r) routes through the Pallas matmul: it is
    the rank-sized-output, k-streaming product."""
    t = matmul_pallas(vt_a, u_b)
    return s_a[:, None] * t * s_b[None, :]


def lowrank_apply(u_a, core, vt_b):
    """C = U_A @ core @ V_B^T (f32 factors)."""
    return lowrank_apply_pallas(u_a, core, vt_b)


def lowrank_apply_fp8(u_a, core, vt_b):
    """C = U_A @ core @ V_B^T with E4M3-stored U/V^T."""
    return lowrank_apply_fp8_pallas(u_a, core, vt_b)


def lowrank_gemm(u_a, s_a, vt_a, u_b, s_b, vt_b, *, fp8: bool = False):
    """Full Eq. (1): merge the core, then the factor-chain apply."""
    core = lowrank_core(s_a, vt_a, u_b, s_b)
    if fp8:
        return lowrank_apply_fp8_pallas(u_a, core, vt_b)
    return lowrank_apply_pallas(u_a, core, vt_b)


def rsvd_factorize(a, omega, *, rank: int, power_iters: int = 2):
    """Rank-r randomized SVD of `a` with caller-supplied sketch `omega`.

    The m x k streaming products go through the Pallas sketch/matmul
    kernels; the l-sized orthonormalization and small SVD use the
    custom-call-free routines in jnp_linalg (LAPACK custom calls cannot
    execute in the Rust PJRT client — see jnp_linalg docstring).
    """
    u, s, vt = rsvd_custom(
        a,
        omega,
        power_iters=power_iters,
        matmul=lambda x, y: (
            range_sketch_pallas(x, y) if y.shape[1] <= 256 else matmul_pallas(x, y)
        ),
    )
    return u[:, :rank], s[:rank], vt[:rank, :]


def lowrank_gemm_e2e(a, b, omega_a, omega_b, *, rank: int, fp8: bool = False):
    """Cold path: factorize both operands, then the factor chain.

    This is what a cache miss costs in the serving system; the warm
    path skips straight to `lowrank_gemm` with cached factors.
    """
    u_a, s_a, vt_a = rsvd_factorize(a, omega_a, rank=rank)
    u_b, s_b, vt_b = rsvd_factorize(b, omega_b, rank=rank)
    return lowrank_gemm(u_a, s_a, vt_a, u_b, s_b, vt_b, fp8=fp8)


# ---------------------------------------------------------------------------
# Jit wrappers with static configuration, used by aot.py and the tests.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("rank", "power_iters"))
def rsvd_factorize_jit(a, omega, rank: int, power_iters: int = 2):
    return rsvd_factorize(a, omega, rank=rank, power_iters=power_iters)


@functools.partial(jax.jit, static_argnames=("fp8",))
def lowrank_gemm_jit(u_a, s_a, vt_a, u_b, s_b, vt_b, fp8: bool = False):
    return lowrank_gemm(u_a, s_a, vt_a, u_b, s_b, vt_b, fp8=fp8)
