"""AOT lowering: L2 graphs -> HLO text artifacts + manifest.json.

Emits HLO **text**, not `.serialize()`: jax >= 0.5 writes HloModuleProto
with 64-bit instruction ids which the Rust side's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once via `make artifacts`; the Rust binary is self-contained
afterwards. Usage:

    python -m compile.aot --out ../artifacts/model.hlo.txt [--quick]

`--quick` lowers only the sentinel e2e graph (used by fast CI loops);
the full lattice is what the serving runtime expects.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Oversampling used for every rsvd sketch (matches RsvdOptions::default
# on the Rust side — keep in sync or cold-path shapes won't line up).
OVERSAMPLE = 8

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, whatever the arity).

    `as_hlo_text(True)` = print_large_constants: the default printer
    elides big literals as `constant({...})`, which xla_extension 0.5.1's
    text parser silently reads back as **zeros** — any graph with an
    embedded table (one-hot rotation schedules, iota-free masks) would
    quietly produce garbage on the Rust side. Discovered via the probe
    harness; see DESIGN.md §AOT-gotchas.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def _tuple1(fn):
    """Wrap a single-output graph so every artifact returns a tuple."""

    @functools.wraps(fn)
    def wrapped(*args):
        return (fn(*args),)

    return wrapped


def build_lattice(quick: bool = False):
    """The artifact lattice: (name, op, fn, input_specs, output_shapes, meta).

    Shapes are static in HLO, so the runtime serves this lattice
    directly and falls back to the Rust linalg substrate for any other
    shape (DESIGN.md §8) — mirroring the paper's 'automatic fallback'.
    """
    entries = []

    def add(name, op, fn, in_specs, out_shapes, n, rank=0):
        entries.append(
            {
                "name": name,
                "op": op,
                "fn": fn,
                "in_specs": in_specs,
                "out_shapes": out_shapes,
                "n": n,
                "rank": rank,
            }
        )

    # Sentinel / end-to-end graph: cold-path lowrank GEMM at N=128, r=16.
    n, r = 128, 16
    l = r + OVERSAMPLE
    add(
        "lowrank_e2e_n128_r16",
        "lowrank_e2e",
        _tuple1(
            functools.partial(
                lambda a, b, oa, ob, rank: model.lowrank_gemm_e2e(a, b, oa, ob, rank=rank),
                rank=r,
            )
        ),
        [spec(n, n), spec(n, n), spec(n, l), spec(n, l)],
        [(n, n)],
        n,
        r,
    )
    if quick:
        return entries

    sizes = [64, 128, 256]
    ranks = [8, 16, 32]

    for n in sizes:
        add(
            f"dense_f32_n{n}",
            "dense_f32",
            _tuple1(model.dense_gemm_f32),
            [spec(n, n), spec(n, n)],
            [(n, n)],
            n,
        )
        add(
            f"dense_f16_n{n}",
            "dense_f16",
            _tuple1(model.dense_gemm_f16),
            [spec(n, n), spec(n, n)],
            [(n, n)],
            n,
        )
        add(
            f"dense_fp8_n{n}",
            "dense_fp8",
            _tuple1(model.dense_gemm_fp8),
            [spec(n, n), spec(n, n)],
            [(n, n)],
            n,
        )
        for r in ranks:
            if r * 2 > n:
                continue
            add(
                f"lowrank_apply_n{n}_r{r}",
                "lowrank_apply",
                _tuple1(model.lowrank_apply),
                [spec(n, r), spec(r, r), spec(r, n)],
                [(n, n)],
                n,
                r,
            )
            add(
                f"lowrank_apply_fp8_n{n}_r{r}",
                "lowrank_apply_fp8",
                _tuple1(model.lowrank_apply_fp8),
                [spec(n, r), spec(r, r), spec(r, n)],
                [(n, n)],
                n,
                r,
            )

    # Cold factorization graphs + warm factor-chain with both factor sets.
    for n in [128, 256]:
        for r in [8, 16]:
            l = r + OVERSAMPLE
            add(
                f"rsvd_n{n}_r{r}",
                "rsvd",
                functools.partial(
                    lambda a, om, rank: model.rsvd_factorize(a, om, rank=rank), rank=r
                ),
                [spec(n, n), spec(n, l)],
                [(n, r), (r,), (r, n)],
                n,
                r,
            )
        r = 16
        for fp8 in [False, True]:
            suffix = "_fp8" if fp8 else ""
            add(
                f"lowrank_gemm{suffix}_n{n}_r{r}",
                f"lowrank_gemm{suffix}",
                _tuple1(
                    functools.partial(
                        lambda ua, sa, va, ub, sb, vb, fp8: model.lowrank_gemm(
                            ua, sa, va, ub, sb, vb, fp8=fp8
                        ),
                        fp8=fp8,
                    )
                ),
                [spec(n, r), spec(r), spec(r, n), spec(n, r), spec(r), spec(r, n)],
                [(n, n)],
                n,
                r,
            )

    return entries


def lower_all(out_dir: str, sentinel: str, quick: bool = False, verbose: bool = True):
    """Lower the lattice, write artifacts + manifest, return the manifest."""
    os.makedirs(out_dir, exist_ok=True)
    entries = build_lattice(quick=quick)
    manifest = {"version": 1, "oversample": OVERSAMPLE, "entries": []}

    for e in entries:
        t0 = time.time()
        lowered = jax.jit(e["fn"]).lower(*e["in_specs"])
        text = to_hlo_text(lowered)
        fname = f"{e['name']}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": e["name"],
                "op": e["op"],
                "file": fname,
                "n": e["n"],
                "rank": e["rank"],
                "inputs": [list(s.shape) for s in e["in_specs"]],
                "outputs": [list(s) for s in e["out_shapes"]],
            }
        )
        if verbose:
            print(
                f"  lowered {e['name']:>28s}  {len(text) / 1024:8.1f} KiB  "
                f"({time.time() - t0:.2f}s)"
            )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # The Makefile sentinel: a copy of the e2e graph.
    e2e = os.path.join(out_dir, "lowrank_e2e_n128_r16.hlo.txt")
    with open(e2e) as src, open(sentinel, "w") as dst:
        dst.write(src.read())
    if verbose:
        print(f"wrote {len(manifest['entries'])} artifacts + manifest to {out_dir}")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description="AOT-lower the Low-Rank GEMM artifact lattice")
    p.add_argument("--out", default="../artifacts/model.hlo.txt", help="sentinel HLO path")
    p.add_argument("--quick", action="store_true", help="sentinel graph only")
    args = p.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    lower_all(out_dir, os.path.abspath(args.out), quick=args.quick)


if __name__ == "__main__":
    main()
