"""Custom-call-free linear algebra for the AOT path.

`jnp.linalg.qr` / `svd` / `eigh` lower to LAPACK **custom calls**
(`lapack_sgeqrf`, `lapack_sgesdd`, ...) whose targets are registered by
jaxlib's Python runtime — the standalone `xla_extension` the Rust PJRT
client links against does not know them, so any artifact containing one
would fail to compile at load time. Every routine here is therefore
built from plain jnp/lax primitives only (dot/while/select/...), which
round-trip through HLO text and run anywhere.

The shapes these routines see are *sketch-sized* (l = rank +
oversampling, l << n), so O(l^3)-with-a-bad-constant is perfectly fine;
the bandwidth-heavy work stays in the Pallas kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def mgs_qr(y):
    """Thin QR of y (m x l) by two-pass modified Gram-Schmidt.

    Two MGS passes give orthogonality to ~machine precision ("twice is
    enough", Giraud et al.) — equivalent quality to Householder for the
    range-finder's purpose. Fully jittable: fori_loop over the l
    columns, no custom calls.

    Returns (q, r) with q: m x l orthonormal, r: l x l upper-triangular.
    Zero (or numerically dead) columns yield zero q-columns rather than
    NaN: the corresponding singular values come out ~0 downstream and
    are truncated away.
    """
    m, l = y.shape
    eps = jnp.asarray(1e-30, y.dtype)

    def one_pass(y_in):
        def body(j, state):
            q, r = state
            v = y_in[:, j] - q @ r[:, j]

            # Re-orthogonalize v against already-built columns (MGS step).
            proj = q.T @ v
            mask = (jnp.arange(l) < j).astype(y_in.dtype)
            proj = proj * mask
            v = v - q @ proj
            r = r.at[:, j].add(proj)

            nrm = jnp.sqrt(jnp.sum(v * v))
            qcol = jnp.where(nrm > eps, v / jnp.maximum(nrm, eps), jnp.zeros_like(v))
            q = q.at[:, j].set(qcol)
            r = r.at[j, j].set(nrm)
            return q, r

        q0 = jnp.zeros_like(y_in)
        r0 = jnp.zeros((l, l), y_in.dtype)
        return lax.fori_loop(0, l, body, (q0, r0))

    q1, r1 = one_pass(y)
    # Second pass on q1 to polish orthogonality; combine the triangular
    # factors (y = q2 (r2 r1)).
    q2, r2 = one_pass(q1)
    return q2, r2 @ r1


def _round_robin_pairings(l_pad: int):
    """Static tournament schedule: (l_pad - 1) rounds of l_pad/2 disjoint
    pairs covering every (p, q) pair exactly once. `l_pad` must be even
    (callers pad odd sizes with a phantom index that pairs harmlessly
    with itself-never — it just sits in rotations with zero off-diagonal).
    """
    import numpy as np

    assert l_pad % 2 == 0
    others = list(range(1, l_pad))
    rounds = []
    for _ in range(l_pad - 1):
        idx = [0] + others
        pairs = [(idx[i], idx[l_pad - 1 - i]) for i in range(l_pad // 2)]
        rounds.append([(min(p, q), max(p, q)) for p, q in pairs])
        others = others[-1:] + others[:-1]
    return np.asarray(rounds, dtype=np.int32)  # (l_pad-1, l_pad/2, 2)


def jacobi_eigh(a, sweeps: int = 12):
    """Symmetric eigendecomposition by **parallel round-robin Jacobi**.

    `a` is l x l symmetric (the Gram matrix of the projected panel).
    Each round applies l/2 disjoint rotations at once as one sparse
    rotation matrix G (built by scatter) and two l x l matmuls —
    A <- G^T A G, V <- V G. The loop body is a handful of ops, so the
    lowered HLO stays small and XLA compile time stays sane (the naive
    pairwise unroll produced multi-MiB graphs that took minutes to
    compile). A fixed sweep count keeps the graph static; 12 sweeps is
    far past convergence for l <= 128.

    Returns (eigenvalues desc, eigenvectors as columns). Plain jnp/lax
    ops only — no LAPACK custom calls.
    """
    l = a.shape[0]
    if a.shape != (l, l):
        raise ValueError(f"jacobi_eigh expects square input, got {a.shape}")
    if l == 1:
        return a[0], jnp.ones((1, 1), a.dtype)

    # Pad odd sizes with one inert dimension (zero row/col: its
    # off-diagonals are zero so every rotation involving it is identity).
    l_pad = l + (l % 2)
    if l_pad != l:
        a = jnp.pad(a, ((0, 1), (0, 1)))

    # AOT portability: everything below is matmul + elementwise only.
    # Diag-style ("pointwise 2-D") gathers like `a[p, p]` and scatters
    # like `g.at[p, q].set(s)` MISCOMPILE on the xla_extension 0.5.1
    # runtime the Rust client links (verified by the probe harness —
    # DESIGN.md §AOT-gotchas); single-axis takes and dots round-trip
    # fine. So each round's pair selection is expressed through constant
    # one-hot matrices Ph/Qh (l × l/2, Ph[p_i, i] = 1): row extraction is
    # `Phᵀ A`, diagonal reads are masked row-sums, and the rotation
    # matrix G is assembled as a sum of rank-(l/2) one-hot products.
    import numpy as np

    table = _round_robin_pairings(l_pad)  # numpy (rounds, l/2, 2)
    half = l_pad // 2
    onehots = []
    for ri in range(table.shape[0]):
        ph = np.zeros((l_pad, half), dtype=np.float32)
        qh = np.zeros((l_pad, half), dtype=np.float32)
        ph[table[ri, :, 0], np.arange(half)] = 1.0
        qh[table[ri, :, 1], np.arange(half)] = 1.0
        onehots.append((jnp.asarray(ph), jnp.asarray(qh)))

    def one_round(ph, qh, state):
        a_cur, v_cur = state
        pa = ph.T @ a_cur  # rows of A at the p indices
        qa = qh.T @ a_cur
        app = jnp.sum(pa * ph.T, axis=1)  # A[p, p]
        aqq = jnp.sum(qa * qh.T, axis=1)  # A[q, q]
        apq = jnp.sum(pa * qh.T, axis=1)  # A[p, q]

        # Classic Jacobi angle per pair; inert when already diagonal.
        active = jnp.abs(apq) > 1e-30
        tau = (aqq - app) / (2.0 * jnp.where(active, apq, 1.0))
        t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
        t = jnp.where(active, t, 0.0)
        c = 1.0 / jnp.sqrt(1.0 + t * t)
        s = t * c

        # G = Σ_i c_i(e_p e_pᵀ + e_q e_qᵀ) + s_i(e_p e_qᵀ − e_q e_pᵀ);
        # round-robin pairs cover every index, so no identity residual.
        g = (
            ph @ (c[:, None] * ph.T)
            + qh @ (c[:, None] * qh.T)
            + ph @ (s[:, None] * qh.T)
            - qh @ (s[:, None] * ph.T)
        )

        a_new = g.T @ a_cur @ g
        # Re-symmetrize to stop round-off drift across many rounds.
        a_new = 0.5 * (a_new + a_new.T)
        v_new = v_cur @ g
        return a_new, v_new

    def sweep(_, state):
        for ph, qh in onehots:
            state = one_round(ph, qh, state)
        return state

    a_final, v_final = lax.fori_loop(
        0, sweeps, sweep, (a, jnp.eye(l_pad, dtype=a.dtype))
    )
    eye = jnp.eye(l_pad, dtype=a.dtype)
    w = jnp.sum(a_final * eye, axis=1)[:l]  # diag without gather
    v_final = v_final[:l, :l]
    order = jnp.argsort(-w)
    return w[order], v_final[:, order]


def svd_small_rows(b, sweeps: int = 12):
    """SVD of a short-fat panel b (l x n, l small) via the l x l Gram
    matrix: b b^T = U diag(s^2) U^T, V^T = diag(1/s) U^T b.

    Squares the condition number — acceptable because the caller only
    keeps the leading `rank < l` triplets, and the trailing (inaccurate)
    directions are exactly the ones truncated. Returns (u, s, vt) with
    s descending and numerically-zero singular values mapped to zero
    rows of vt (not NaN).
    """
    l = b.shape[0]
    gram = b @ b.T
    w, u = jacobi_eigh(gram, sweeps=sweeps)
    w = jnp.maximum(w, 0.0)
    s = jnp.sqrt(w)
    safe = jnp.where(s > 1e-20, s, 1.0)
    vt = (u.T @ b) / safe[:, None]
    vt = jnp.where((s > 1e-20)[:, None], vt, 0.0)
    return u, s, vt


@functools.partial(jax.named_call, name="rsvd_jnp")
def rsvd_custom(a, omega, power_iters: int = 2, sweeps: int = 12, matmul=jnp.matmul):
    """Halko randomized SVD with an externally-supplied sketch matrix.

    `omega` (k x l) is passed in (not generated here) so the AOT graph
    is deterministic given its inputs and the Rust side controls the
    seed. `matmul` is injectable so the heavy products route through the
    Pallas kernel when lowering artifacts, or plain jnp in tests.

    Returns (u: m x l, s: l, vt: l x n) — caller truncates to rank.
    """
    # Sketch + LU-free subspace (power) iterations with re-orthonorm.
    y = matmul(a, omega)
    for _ in range(power_iters):
        q, _ = mgs_qr(y)
        z = matmul(a.T, q)
        q, _ = mgs_qr(z)
        y = matmul(a, q)
    q, _ = mgs_qr(y)

    b = matmul(q.T, a)  # l x n projected panel
    u_small, s, vt = svd_small_rows(b, sweeps=sweeps)
    u = q @ u_small
    return u, s, vt
