//! **End-to-end validation driver** (EXPERIMENTS.md §E2E).
//!
//! Boots the full three-layer stack — GemmService (L3) over AOT-compiled
//! XLA artifacts lowered from the Pallas kernels (L1/L2) — and serves a
//! realistic transformer-inference GEMM trace against offline-factorized
//! weights:
//!
//!   * per-layer shapes: QKV projection, attention output, MLP up/down,
//!   * weights preloaded into the factor cache (offline decomposition),
//!   * activations replayed as batched async requests,
//!   * reports throughput, latency p50/p99, per-backend counts, and
//!     end-to-end numerical error vs the exact product.
//!
//! Run: `make artifacts && cargo run --release --example transformer_serving`

use std::time::Instant;

use lowrank_gemm::coordinator::{BackendKind, GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::fp8::StorageFormat;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::lowrank::RankStrategy;
use lowrank_gemm::trace::transformer_model_trace;

fn main() {
    // Model configuration: a 4-layer toy transformer whose GEMM shapes sit
    // on the AOT lattice (d_model = 128) so the XLA path is exercised.
    let d_model = 128;
    let d_ff = 256;
    let layers = 4;
    let batch_tokens = 128;
    let steps = 24; // inference steps to replay
    let rank = 16;

    let mut cfg = ServiceConfig {
        workers: 2,
        max_batch: 4,
        ..Default::default()
    };
    cfg.router.rank_strategy = RankStrategy::Fixed(rank);
    cfg.router.storage = StorageFormat::F32; // isolate truncation error
    cfg.artifacts_dir = if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts".into())
    } else {
        eprintln!("note: artifacts/ missing — running CPU-substrate only (run `make artifacts`)");
        None
    };
    let svc = GemmService::start(cfg).expect("service start");

    // ---- Offline phase: factorize every weight once. --------------------
    let trace = transformer_model_trace(batch_tokens, d_model, d_ff, layers);
    let mut rng = Pcg64::seeded(2024);
    let mut weights = Vec::new();
    let t0 = Instant::now();
    for shape in &trace {
        let id = shape.weight_id.expect("trace weights have ids");
        let w = Matrix::low_rank_noisy(shape.k, shape.n, rank / 2, 1e-5, &mut rng);
        svc.preload_factor(id, &w).expect("preload");
        weights.push((id, w));
    }
    println!(
        "offline: factorized {} weights in {:.1} ms (cache: {} entries, {} KiB)",
        weights.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        svc.stats().cache.entries,
        svc.stats().cache.resident_bytes / 1024,
    );

    // ---- Serving phase: replay the trace asynchronously. ----------------
    let t1 = Instant::now();
    let mut inflight = Vec::new();
    for step in 0..steps {
        for (i, shape) in trace.iter().enumerate() {
            let (id, w) = &weights[i];
            let x = Matrix::gaussian(shape.m, shape.k, &mut rng);
            let exact = x.matmul(w);
            let mut req = GemmRequest::new(x, w.clone()).with_ids(None, Some(*id));
            // Mixed traffic: half auto-routed (at this toy scale the cost
            // model correctly picks dense — launch-overhead dominated),
            // half pinned to the low-rank path to exercise the cached
            // factored×dense serving pipeline end to end.
            if step % 2 == 1 {
                req = req.with_kernel(lowrank_gemm::kernels::KernelKind::LowRankAuto);
            }
            inflight.push((step, i, exact, svc.submit(req).expect("submit")));
        }
    }

    let mut total = 0usize;
    let mut xla_hits = 0usize;
    let mut worst_err = 0f32;
    let mut sum_err = 0f64;
    for (_step, _i, exact, rx) in inflight {
        let resp = rx.recv().expect("response").expect("gemm ok");
        if resp.backend == BackendKind::Xla {
            xla_hits += 1;
        }
        let err = resp.c.rel_frobenius_distance(&exact);
        worst_err = worst_err.max(err);
        sum_err += err as f64;
        total += 1;
    }
    let wall = t1.elapsed().as_secs_f64();

    // ---- Report. ---------------------------------------------------------
    let stats = svc.stats();
    println!("\nserved {total} GEMMs in {wall:.3} s  ->  {:.0} req/s", total as f64 / wall);
    println!(
        "backends: {} via XLA artifacts, {} via CPU substrate",
        xla_hits,
        total - xla_hits
    );
    println!(
        "error: mean {:.3e}, worst {:.3e} (tolerance was {:.2})",
        sum_err / total as f64,
        worst_err,
        0.05
    );
    println!(
        "cache: {} hits / {} misses, {} rejected by backpressure",
        stats.cache.hits, stats.cache.misses, stats.rejected
    );
    for (name, s) in svc.metrics().histogram_summaries() {
        println!(
            "  {name:<14} p50 {:>8.0}  p99 {:>8.0}  mean {:>8.0}  (n={})",
            s.p50, s.p99, s.mean, s.count
        );
    }
    for (name, v) in svc.metrics().counters() {
        println!("  {name:<24} {v}");
    }

    assert_eq!(total, steps * trace.len());
    assert!(worst_err < 0.05, "error out of band: {worst_err}");
    println!("\ntransformer_serving: OK");
}
