//! §5.4 validation: training *through* low-rank GEMM.
//!
//! Trains a two-layer MLP on a synthetic regression task twice — once with
//! exact f32 matmuls, once with every forward/backward weight product
//! routed through the factor-chain (weights re-factorized each step, the
//! worst case) — and compares loss curves. The paper's claims under test:
//!
//!   * "gradient flow preservation": 1-5% noise in activations/weights
//!     does not disrupt training,
//!   * "error consistency": per-layer approximation errors stay bounded
//!     instead of compounding step over step.
//!
//! Run: `cargo run --release --example mlp_training`

use lowrank_gemm::fp8::StorageFormat;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::lowrank::{
    factorize, lowrank_matmul_dense_rhs, LowRankConfig, RankStrategy,
};

const D_IN: usize = 64;
const D_HID: usize = 128;
const D_OUT: usize = 16;
const BATCH: usize = 64;
const STEPS: usize = 300;
const LR: f32 = 0.02;

struct Mlp {
    w1: Matrix, // d_in × d_hid
    w2: Matrix, // d_hid × d_out
}

/// y = relu(x·W1)·W2, all products optionally through low-rank factors.
fn forward(
    mlp: &Mlp,
    x: &Matrix,
    lowrank: Option<&LowRankConfig>,
) -> (Matrix, Matrix, Matrix) {
    let matmul = |a: &Matrix, w: &Matrix| -> Matrix {
        match lowrank {
            // Weight factored, activation dense — the serving/training
            // pattern (activations change every step; weights are the
            // structured operand). x·W = (Wᵀ factored applied to xᵀ)ᵀ,
            // but lowrank_matmul_dense_rhs already handles A-factored ×
            // B-dense, so factor W on the left of the transposed product:
            // (x·W)ᵀ = Wᵀ·xᵀ.
            Some(cfg) => {
                let wt = w.transpose();
                let f = factorize(&wt, cfg).expect("factorize weight");
                lowrank_matmul_dense_rhs(&f, &a.transpose()).transpose()
            }
            None => a.matmul(w),
        }
    };
    let z1 = matmul(x, &mlp.w1);
    let mut h = z1.clone();
    for v in h.data_mut() {
        *v = v.max(0.0); // relu
    }
    let y = matmul(&h, &mlp.w2);
    (z1, h, y)
}

fn train(lowrank: Option<&LowRankConfig>, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::seeded(seed);
    // Teacher network generates the targets; student must fit it.
    let teacher_w1 = Matrix::low_rank_noisy(D_IN, D_HID, 8, 1e-3, &mut rng);
    let teacher_w2 = Matrix::low_rank_noisy(D_HID, D_OUT, 8, 1e-3, &mut rng);

    let mut mlp = Mlp {
        w1: Matrix::uniform(D_IN, D_HID, -0.1, 0.1, &mut rng),
        w2: Matrix::uniform(D_HID, D_OUT, -0.1, 0.1, &mut rng),
    };

    let mut losses = Vec::with_capacity(STEPS);
    for _ in 0..STEPS {
        let x = Matrix::gaussian(BATCH, D_IN, &mut rng);
        // Teacher forward (exact) for targets.
        let mut th = x.matmul(&teacher_w1);
        for v in th.data_mut() {
            *v = v.max(0.0);
        }
        let target = th.matmul(&teacher_w2);

        // Student forward (possibly low-rank).
        let (z1, h, y) = forward(&mlp, &x, lowrank);

        // MSE loss + backward pass.
        let mut dy = y.sub(&target).expect("shape");
        let loss = dy.sq_frobenius_norm() / (BATCH * D_OUT) as f32;
        losses.push(loss);
        dy.scale_in_place(2.0 / (BATCH * D_OUT) as f32);

        // dW2 = hᵀ·dy ; dh = dy·W2ᵀ ; dz1 = dh ⊙ relu'(z1) ; dW1 = xᵀ·dz1.
        let dw2 = h.matmul_tn(&dy);
        let dh = dy.matmul_nt(&mlp.w2);
        let mut dz1 = dh;
        for (g, z) in dz1.data_mut().iter_mut().zip(z1.data()) {
            if *z <= 0.0 {
                *g = 0.0;
            }
        }
        let dw1 = x.matmul_tn(&dz1);

        mlp.w1.axpy_in_place(-LR, &dw1).expect("sgd w1");
        mlp.w2.axpy_in_place(-LR, &dw2).expect("sgd w2");
    }
    losses
}

fn main() {
    let lr_cfg = LowRankConfig {
        rank: RankStrategy::Fixed(16),
        storage: StorageFormat::Fp8(lowrank_gemm::fp8::Fp8Format::E4M3),
        ..Default::default()
    };

    println!("training 2-layer MLP ({D_IN}->{D_HID}->{D_OUT}), {STEPS} steps, batch {BATCH}");
    let exact = train(None, 31);
    let approx = train(Some(&lr_cfg), 31);

    println!("\nstep   exact-loss   lowrank-loss   ratio");
    for s in (0..STEPS).step_by(30).chain([STEPS - 1]) {
        println!(
            "{s:>4}   {:>10.5}   {:>12.5}   {:>5.2}",
            exact[s],
            approx[s],
            approx[s] / exact[s].max(1e-9)
        );
    }

    let final_exact = exact[STEPS - 1];
    let final_approx = approx[STEPS - 1];
    let start = exact[0];
    println!(
        "\nloss reduction: exact {:.1}x, low-rank {:.1}x",
        start / final_exact,
        start / final_approx
    );

    // The §5.4 acceptance gates: both runs converge (≥10x loss reduction)
    // and the low-rank run lands within 3x of the exact final loss.
    assert!(
        start / final_exact > 10.0,
        "exact baseline failed to converge"
    );
    assert!(
        start / final_approx > 10.0,
        "low-rank training failed to converge — gradient flow broken"
    );
    assert!(
        final_approx / final_exact < 3.0,
        "low-rank final loss too far from exact: {final_approx} vs {final_exact}"
    );
    println!("mlp_training: OK (gradient flow preserved through factor-chain GEMM)");
}
