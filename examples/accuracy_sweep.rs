//! §5.4 error study: approximation error vs rank, energy threshold, and
//! storage precision — measured end to end on real numerics.
//!
//! Run: `cargo run --release --example accuracy_sweep`

use lowrank_gemm::bench_harness::Table;
use lowrank_gemm::fp8::StorageFormat;
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::lowrank::{
    eckart_young_rel_error, energy_capture, factorize, LowRankConfig, RankStrategy,
};
use lowrank_gemm::trace::{matrix_with_spectrum, SpectrumKind};

fn error_vs_rank() {
    let n = 256;
    let mut rng = Pcg64::seeded(17);
    for kind in [SpectrumKind::ExponentialDecay, SpectrumKind::PowerLaw, SpectrumKind::Flat] {
        let a = matrix_with_spectrum(n, kind, &mut rng);
        let b = matrix_with_spectrum(n, kind, &mut rng);
        let exact = a.matmul(&b);
        let sv = kind.values(n);
        let mut table = Table::new(
            &format!("error vs rank — {} spectrum (N={n})", kind.name()),
            &["r", "EY bound (A)", "factor err", "product err", "energy kept"],
        );
        for r in [4usize, 8, 16, 32, 64, 128] {
            let cfg = LowRankConfig {
                rank: RankStrategy::Fixed(r),
                storage: StorageFormat::F32,
                ..Default::default()
            };
            let fa = factorize(&a, &cfg).unwrap();
            let fb = factorize(&b, &cfg).unwrap();
            let prod_err = lowrank_gemm::lowrank::lowrank_matmul(&fa, &fb)
                .rel_frobenius_distance(&exact);
            table.row(&[
                r.to_string(),
                format!("{:.3e}", eckart_young_rel_error(&sv, r)),
                format!("{:.3e}", fa.measured_error(&a)),
                format!("{prod_err:.3e}"),
                format!("{:.4}", energy_capture(&sv, r)),
            ]);
        }
        table.print();
        println!();
    }
}

fn energy_threshold_sweep() {
    let n = 256;
    let mut rng = Pcg64::seeded(18);
    let a = matrix_with_spectrum(n, SpectrumKind::ExponentialDecay, &mut rng);
    let mut table = Table::new(
        "energy threshold τ sweep (exp-decay spectrum, N=256)",
        &["τ", "selected rank", "measured err", "memory saving"],
    );
    for tau in [0.90f32, 0.95, 0.99, 0.999, 0.9999] {
        let cfg = LowRankConfig {
            rank: RankStrategy::EnergyFraction(tau),
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let f = factorize(&a, &cfg).unwrap();
        table.row(&[
            format!("{tau}"),
            f.rank().to_string(),
            format!("{:.3e}", f.measured_error(&a)),
            format!("{:5.1}%", 100.0 * f.memory_saving()),
        ]);
    }
    table.print();
    println!("(τ=0.99 is the paper's default — §3.2.)\n");
}

fn storage_precision_sweep() {
    let n = 192;
    let r = 24;
    let mut rng = Pcg64::seeded(19);
    let a = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);
    let b = Matrix::low_rank_noisy(n, n, r, 1e-4, &mut rng);
    let exact = a.matmul(&b);
    let mut table = Table::new(
        "storage precision sweep (N=192, r=24, low-rank-plus-noise input)",
        &["storage", "factor bytes", "product rel err"],
    );
    for fmt in [
        StorageFormat::F32,
        StorageFormat::Bf16,
        StorageFormat::F16,
        StorageFormat::Fp8(lowrank_gemm::fp8::Fp8Format::E4M3),
        StorageFormat::Fp8(lowrank_gemm::fp8::Fp8Format::E5M2),
    ] {
        let cfg = LowRankConfig {
            rank: RankStrategy::Fixed(r),
            storage: fmt,
            ..Default::default()
        };
        let fa = factorize(&a, &cfg).unwrap();
        let fb = factorize(&b, &cfg).unwrap();
        let err = lowrank_gemm::lowrank::lowrank_matmul(&fa, &fb).rel_frobenius_distance(&exact);
        table.row(&[
            fmt.name().to_string(),
            format!("{}", fa.storage_bytes()),
            format!("{err:.3e}"),
        ]);
    }
    table.print();
    println!("(paper §3.3: E4M3 at percent-level error with 4x smaller factors than f32.)");
}

fn main() {
    error_vs_rank();
    energy_threshold_sweep();
    storage_precision_sweep();
}
