//! Quickstart: the 30-second tour of the public API.
//!
//! Factorize two matrices, multiply them with the factor-chain GEMM,
//! compare against the exact product, and let the AutoKernelSelector
//! explain its routing decision.
//!
//! Run: `cargo run --release --example quickstart`

use std::time::Instant;

use lowrank_gemm::prelude::*;

fn main() {
    let n = 512;
    let rank_hint = n / 16;
    let mut rng = Pcg64::seeded(7);

    // Synthetic operands with a decaying spectrum (the paper's favorable
    // case: most real weight matrices look like this).
    let a = Matrix::low_rank_noisy(n, n, rank_hint, 1e-4, &mut rng);
    let b = Matrix::low_rank_noisy(n, n, rank_hint, 1e-4, &mut rng);

    // 1) Offline decomposition (paper §3.1/§6.5). Energy-based rank
    //    selection keeps 99% of the spectral energy.
    let cfg = LowRankConfig {
        rank: RankStrategy::EnergyFraction(0.99),
        ..Default::default()
    };
    let t0 = Instant::now();
    let fa = factorize(&a, &cfg).expect("factorize A");
    let fb = factorize(&b, &cfg).expect("factorize B");
    println!(
        "factorized two {n}x{n} matrices in {:.1} ms (ranks {} / {}, {:.0}% memory saving)",
        t0.elapsed().as_secs_f64() * 1e3,
        fa.rank(),
        fb.rank(),
        100.0 * fa.memory_saving(),
    );

    // 2) The factor-chain GEMM (paper Eq. 1) vs the dense product.
    let t1 = Instant::now();
    let c_lowrank = lowrank_matmul(&fa, &fb);
    let lowrank_ms = t1.elapsed().as_secs_f64() * 1e3;

    let t2 = Instant::now();
    let c_exact = a.matmul(&b);
    let dense_ms = t2.elapsed().as_secs_f64() * 1e3;

    println!(
        "low-rank GEMM: {lowrank_ms:.1} ms   dense GEMM: {dense_ms:.1} ms   speedup {:.1}x",
        dense_ms / lowrank_ms
    );
    println!(
        "relative error = {:.3e}  (paper §5.4 band: 1e-3 .. 2e-2)",
        c_lowrank.rel_frobenius_distance(&c_exact)
    );

    // 3) Ask the selector what it would route on the paper's hardware.
    let selector = AutoKernelSelector::new(DeviceProfile::rtx4090());
    for (label, sz, cached) in [("this size, cold", n, false), ("paper scale, cold", 20480, false)] {
        let choice = selector.select(&lowrank_gemm::kernels::SelectorInputs {
            m: sz,
            k: sz,
            n: sz,
            error_tolerance: 0.05,
            rank: (sz / 40).max(16),
            factors_cached: cached,
            factored_output_ok: false,
            decomp_amortization: 1.0,
            fp8_reencode: false,
        });
        println!(
            "selector @N={sz} ({label}): {} (predicted {:.2} ms, {:.1e} rel err)",
            choice.kind.paper_name(),
            choice.cost.time_s * 1e3,
            choice.predicted_error
        );
    }
}
