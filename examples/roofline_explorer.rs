//! §6.2 + Table 3 explorer: the paper's percent-of-peak arithmetic,
//! recomputed and audited, plus an interactive-ish sweep of where each
//! kernel wins on each device.
//!
//! Run: `cargo run --release --example roofline_explorer [-- --device h200]`

use lowrank_gemm::cli::parse_args;
use lowrank_gemm::gpu_sim::{DeviceProfile, Precision, Roofline};
use lowrank_gemm::kernels::{AutoKernelSelector, SelectorInputs};
use lowrank_gemm::trace::sqrt2_sweep;

fn section62(d: &DeviceProfile) {
    println!("== §6.2 arithmetic on {} ==", d.name);
    let measured = 378.0e12; // the paper's anchor measurement
    println!(
        "  compute peak (paper-quoted fp8): {:.0} TFLOPS",
        d.peak_fp8 / 1e12
    );
    println!(
        "  378 TFLOPS = {:.1}% of compute peak (paper: 28.6%)",
        100.0 * measured / d.peak_fp8
    );
    let stated = d.paper_stated_bw_ceiling_flops(Precision::Fp8);
    println!(
        "  paper's 'bandwidth ceiling' as stated: {:.0} TFLOPS -> 378 is {:.1}% (paper: 56.7%)",
        stated / 1e12,
        100.0 * measured / stated
    );
    let literal = d.bandwidth_limited_gemm_flops(Precision::Fp8);
    println!(
        "  AUDIT: the formula as printed gives {:.3} TFLOPS (667 GFLOPS — 1000x unit slip);",
        literal / 1e12
    );
    for n in [1024usize, 4096, 20480] {
        let phys = d.physical_bw_limited_gemm_flops(n, Precision::Fp8);
        println!(
            "  physical BW bound @N={n}: {:.0} TFLOPS ({})",
            phys / 1e12,
            if phys > d.peak_fp8 { "compute-bound" } else { "bandwidth-bound" }
        );
    }
    println!();
}

fn winner_map(d: &DeviceProfile) {
    println!("== kernel winner map on {} (cold, tol 5%, r = N/40) ==", d.name);
    let selector = AutoKernelSelector::new(d.clone());
    let rl = Roofline::new(d.clone());
    println!(
        "{:>7} {:>22} {:>12} {:>14} {:>12}",
        "N", "winner", "time", "TFLOPS", "pred err"
    );
    for n in sqrt2_sweep(1024, 46_336) {
        let inp = SelectorInputs {
            m: n,
            k: n,
            n,
            error_tolerance: 0.05,
            rank: (n / 40).max(16),
            factors_cached: false,
            factored_output_ok: false,
            decomp_amortization: 1.0,
            fp8_reencode: false,
        };
        let c = selector.select(&inp);
        let tflops = Roofline::achieved_flops(2.0 * (n as f64).powi(3), c.cost.time_s) / 1e12;
        println!(
            "{:>7} {:>22} {:>9.2} ms {:>11.0} {:>12.2e}",
            n,
            c.kind.paper_name(),
            c.cost.time_s * 1e3,
            tflops,
            c.predicted_error
        );
        // Memory gate: stop when three dense f32 matrices outgrow HBM.
        if 3 * n * n * 4 > d.memory_bytes as usize {
            println!("        (dense f32 working set exceeds {} memory here)", d.name);
            break;
        }
    }
    let _ = rl;
    println!();
}

fn table3_row(d: &DeviceProfile, anchor_tflops: f64, anchor_bw: f64) {
    println!(
        "  {:<9} {:>6.1} TB/s  projected {:>6.0} TFLOPS  ({}x bandwidth scaling)",
        d.name,
        d.bandwidth_bps / 1e12,
        anchor_tflops * d.bandwidth_bps / anchor_bw,
        (d.bandwidth_bps / anchor_bw) as i64
    );
}

fn main() {
    let args = parse_args(std::env::args().skip(1)).expect("args");
    let device = args.get("device").unwrap_or("rtx4090");
    let d = DeviceProfile::by_name(device).expect("known device");

    section62(&d);
    winner_map(&d);

    println!("== Table 3 extrapolation (paper §6.3 rule: scale 378 TFLOPS by BW) ==");
    let anchor = DeviceProfile::rtx4090();
    for dev in [DeviceProfile::rtx4090(), DeviceProfile::h200(), DeviceProfile::b200()] {
        table3_row(&dev, 378.0, anchor.bandwidth_bps);
    }
}
