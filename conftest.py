"""Pytest path shim: make `python/` importable whether the suite is run
as `pytest python/tests/` from the repo root or `pytest tests/` from
inside `python/` (the Makefile does the latter)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
