//! Offline stub of the `xla` crate (docs.rs/xla 0.1.6 API surface).
//!
//! The evaluation container has no PJRT/XLA shared library, so this stub
//! keeps `lowrank_gemm::runtime` compiling while every entry point returns
//! a descriptive error. The coordinator treats that exactly like running
//! with `use_xla = false`: all requests fall back to the native CPU
//! substrate, which implements every kernel the artifacts would serve.
//! Swapping this path dependency for the real crate re-enables the PJRT
//! path with no source changes.

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str =
    "xla runtime unavailable: built against the offline stub (no PJRT plugin); \
     run CPU-substrate-only or link the real `xla` crate";

/// Error type mirroring `xla::Error`'s `Display` surface.
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable() -> Error {
        Error {
            msg: UNAVAILABLE.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Host tensor literal.
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable())
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Synchronously transfer the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// Create the CPU PJRT client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

/// An XLA computation graph.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
