//! Offline stub of `anyhow`, providing only the `Error` surface the main
//! crate's error conversions need (`Display`, including the `{:#}`
//! alternate chain format). The real crate is a drop-in replacement.

use std::fmt;

/// Opaque error value carrying a message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Wrap a message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` on real anyhow prints the whole cause chain; the stub has
        // a single message either way.
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:#}"), "boom");
        assert_eq!(format!("{e:?}"), "boom");
    }
}
