//! Dependency-free CLI argument parsing (no clap on the offline set).
//!
//! Grammar: `lowrank-gemm <subcommand> [--key value] [--flag] [positional…]`.
//! Values may also be attached as `--key=value`. Unknown keys are an error
//! (catching typos beats silently ignoring them).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct CliArgs {
    /// First non-flag token (the subcommand).
    pub command: Option<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
}

/// Keys that take a value (everything else after `--` is a flag).
const VALUE_KEYS: &[&str] = &[
    "config", "device", "artifacts", "n", "rank", "size", "sizes", "kernel", "strategy",
    "method", "storage", "tolerance", "requests", "workers", "batch", "window-us", "seed",
    "out", "iters", "warmup", "shard-workers", "tile-m", "tile-n", "min-parallel-n",
    "autotune-alpha", "autotune-epsilon", "autotune-min-samples", "autotune-table",
    "cache-budget-mb", "cache-min-dim", "cache-amortize", "amortize",
    "kernel-mc", "kernel-kc", "kernel-nc", "naive-cutover",
    "trace-ring", "trace-slowest", "trace-max-spans", "trace-export",
    "accuracy-sample", "accuracy-probes", "accuracy-alpha", "accuracy-min-samples",
    "accuracy-table", "accuracy-seed",
    "sched-workers", "sched-queue-depth", "sched-tenant-quota",
    "fault-inject", "fault-breaker-window", "fault-breaker-threshold", "fault-breaker-cooldown",
    "listen", "router", "cluster-heartbeat-ms", "cluster-heartbeat-timeout-ms",
    "cluster-dead-after-ms", "cluster-connect-timeout-ms", "cluster-read-timeout-ms",
    "cluster-max-attempts", "cluster-backoff-base-ms", "cluster-backoff-cap-ms",
    "cluster-fill-cap", "cluster-affinity-min-dim", "cluster-seed", "run-ms",
    "last", "chrome-out", "prom-out", "json-out",
];

/// Parse an argv (excluding the program name).
pub fn parse_args<I: IntoIterator<Item = String>>(argv: I) -> Result<CliArgs> {
    let mut out = CliArgs::default();
    let mut it = argv.into_iter().peekable();

    while let Some(tok) = it.next() {
        if let Some(rest) = tok.strip_prefix("--") {
            if rest.is_empty() {
                // `--` terminator: everything after is positional.
                out.positional.extend(it);
                break;
            }
            if let Some((k, v)) = rest.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
                continue;
            }
            if VALUE_KEYS.contains(&rest) {
                let v = it
                    .next()
                    .ok_or_else(|| Error::Config(format!("--{rest} expects a value")))?;
                out.options.insert(rest.to_string(), v);
            } else {
                out.flags.push(rest.to_string());
            }
        } else if out.command.is_none() {
            out.command = Some(tok);
        } else {
            out.positional.push(tok);
        }
    }
    Ok(out)
}

impl CliArgs {
    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: cannot parse `{v}`"))),
        }
    }

    /// Is a bare flag present?
    pub fn has_flag(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    /// Comma-separated list of usize (e.g. `--sizes 256,512,1024`).
    pub fn get_usize_list(&self, key: &str) -> Result<Option<Vec<usize>>> {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .map_err(|_| Error::Config(format!("--{key}: bad entry `{s}`")))
                })
                .collect::<Result<Vec<_>>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> CliArgs {
        parse_args(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("serve --config conf.toml --workers 4 --verbose");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get("config"), Some("conf.toml"));
        assert_eq!(a.get_parse::<usize>("workers", 1).unwrap(), 4);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn key_equals_value() {
        let a = parse("bench --n=2048 --kernel=lowrank_auto");
        assert_eq!(a.get("n"), Some("2048"));
        assert_eq!(a.get("kernel"), Some("lowrank_auto"));
    }

    #[test]
    fn positional_after_doubledash() {
        let a = parse("run --n 8 -- --not-a-flag pos2");
        assert_eq!(a.positional, vec!["--not-a-flag", "pos2"]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse_args(["bench".into(), "--n".into()]).is_err());
    }

    #[test]
    fn size_list() {
        let a = parse("bench --sizes 128,256,512");
        assert_eq!(a.get_usize_list("sizes").unwrap(), Some(vec![128, 256, 512]));
        assert!(parse("bench --sizes 1,x").get_usize_list("sizes").is_err());
    }

    #[test]
    fn typed_default_when_absent() {
        let a = parse("bench");
        assert_eq!(a.get_parse::<f32>("tolerance", 0.05).unwrap(), 0.05);
    }
}
