//! Benchmark harness — criterion substitute for the offline environment.
//!
//! Implements the paper's §4.3 methodology directly: configurable warmup
//! iterations, measurement iterations, and summary statistics. Also ships
//! the table/series printers every `rust/benches/*.rs` target uses, so all
//! reproduced tables render in a consistent, diffable format that
//! EXPERIMENTS.md can embed verbatim.

use std::time::Instant;

/// Measurement settings (paper §4.3: 5 warmup + 5 measured).
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Untimed warmup iterations.
    pub warmup_iters: usize,
    /// Timed iterations.
    pub measure_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 5,
            measure_iters: 5,
        }
    }
}

impl BenchConfig {
    /// Faster settings for CI-ish runs.
    pub fn quick() -> Self {
        BenchConfig {
            warmup_iters: 1,
            measure_iters: 3,
        }
    }
}

/// Summary statistics over the measured iterations.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Minimum (best) seconds.
    pub min_s: f64,
    /// Maximum (worst) seconds.
    pub max_s: f64,
    /// Sample standard deviation.
    pub stddev_s: f64,
    /// Iterations measured.
    pub iters: usize,
}

impl Measurement {
    /// Throughput in "units/s" given units of work per iteration.
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        if self.mean_s <= 0.0 {
            0.0
        } else {
            units_per_iter / self.mean_s
        }
    }
}

/// Run `f` under the config and summarize.
pub fn bench(cfg: &BenchConfig, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.measure_iters);
    for _ in 0..cfg.measure_iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// Summarize raw samples.
pub fn summarize(samples: &[f64]) -> Measurement {
    let n = samples.len().max(1) as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples
        .iter()
        .map(|s| (s - mean) * (s - mean))
        .sum::<f64>()
        / (n - 1.0).max(1.0);
    Measurement {
        mean_s: mean,
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().copied().fold(0.0, f64::max),
        stddev_s: var.sqrt(),
        iters: samples.len(),
    }
}

/// Fixed-width table printer: renders rows like the paper's Tables 1/2/3.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table arity");
        self.rows.push(cells.to_vec());
    }

    /// Render to a string (also returned so benches can tee into files).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Environment-variable escape hatch so `cargo bench` can be run quick
/// (`LRG_BENCH_QUICK=1`) or full (default mirrors the paper's 5+5).
pub fn config_from_env() -> BenchConfig {
    if std::env::var("LRG_BENCH_QUICK").is_ok() {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut runs = 0;
        let cfg = BenchConfig {
            warmup_iters: 2,
            measure_iters: 3,
        };
        let m = bench(&cfg, || {
            runs += 1;
        });
        assert_eq!(runs, 5);
        assert_eq!(m.iters, 3);
        assert!(m.min_s <= m.mean_s && m.mean_s <= m.max_s);
    }

    #[test]
    fn summarize_stats() {
        let m = summarize(&[1.0, 2.0, 3.0]);
        assert!((m.mean_s - 2.0).abs() < 1e-12);
        assert_eq!(m.min_s, 1.0);
        assert_eq!(m.max_s, 3.0);
        assert!((m.stddev_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn throughput() {
        let m = summarize(&[0.5]);
        assert!((m.throughput(10.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "TFLOPS"]);
        t.row(&["PyTorch FP32".into(), "49".into()]);
        t.row(&["LowRank Auto".into(), "378".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| PyTorch FP32 |"));
        assert!(s.contains("| 378"));
    }

    #[test]
    #[should_panic(expected = "table arity")]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
