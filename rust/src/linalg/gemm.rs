//! Dense GEMM kernels — the CPU substrate's "cuBLAS".
//!
//! Three implementations with identical semantics (`C = A · B`):
//!
//! - [`gemm_naive`] — textbook triple loop in ikj order; the correctness
//!   oracle and the deliberately-slow baseline for the benchmark suite.
//! - [`gemm_blocked`] — cache-blocked with a register-tiled 4×4 micro-kernel
//!   and a packed B panel; the hot path used by everything else.
//! - [`gemm_strided`] — operates on sub-blocks without copies; used by the
//!   batcher when slicing fused batches.
//! - [`gemm_panel`] — one output tile of the blocked GEMM, with a
//!   tile-local (order-deterministic) summation schedule; the per-task
//!   kernel of the shard execution plane ([`crate::shard`]).
//!
//! The micro-kernel mirrors, at CPU scale, the structure the paper's CUDA
//! kernels have on the GPU: an outer HBM→shared (here L2→L1) tiling plus an
//! inner register-resident accumulator tile — see DESIGN.md §3 for the
//! TPU/Pallas mapping of the same idea.

use crate::error::{Error, Result};
use crate::linalg::matrix::Matrix;

/// Selectable dense algorithm (benchmarks sweep this).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmAlgo {
    /// Textbook ikj triple loop.
    Naive,
    /// Cache-blocked + 4×4 register micro-kernel (default).
    Blocked,
}

/// Cache-block sizes: MC×KC panel of A (L2), KC×NC panel of B (L1-ish).
/// Tuned on the 1-core eval machine; see EXPERIMENTS.md §Perf.
const MC: usize = 128;
const KC: usize = 256;
const NC: usize = 256;

/// `C = A · B`, naive ikj order (row-major friendly, no blocking).
pub fn gemm_naive(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    check(a, b)?;
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    let bd = b.data();
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (t, &av) in arow.iter().enumerate().take(k) {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[t * n..(t + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Ok(c)
}

/// `C = A · B` with cache blocking and a register-tiled micro-kernel.
pub fn gemm_blocked(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    check(a, b)?;
    let (m, k) = a.shape();
    let n = b.cols();
    // Small problems: blocking/packing overhead dominates; use the naive
    // loop. Cutover measured in §Perf iteration 4 (naive wins at 64³,
    // blocked wins from ~96³ up).
    if m * n * k <= 80 * 80 * 80 {
        return gemm_naive(a, b);
    }
    let mut c = Matrix::zeros(m, n);
    blocked_region(a, b, 0, m, 0, n, c.data_mut(), n);
    Ok(c)
}

/// One output region `C[r0..r0+rows, c0..c0+cols] = A[r0.., :] · B[:, c0..]`
/// of the blocked GEMM, materialized as a contiguous rows×cols matrix.
///
/// This is the per-tile kernel of the shard execution plane
/// ([`crate::shard`]). It always runs the blocked/packed path (no naive
/// cutover), so a tile's summation order is a function of the tile alone:
/// executing a tile grid in *any* order — or concurrently — reproduces the
/// same bits. When `r0`/`rows` are multiples of [`MC`] (or `r0 + rows`
/// hits `m`) and `c0`/`cols` are multiples of [`NC`] (or `c0 + cols` hits
/// `n`), the per-element order also matches a full-matrix [`gemm_blocked`]
/// exactly, so tiled execution is bitwise-equal to the monolithic kernel.
pub fn gemm_panel(
    a: &Matrix,
    b: &Matrix,
    r0: usize,
    rows: usize,
    c0: usize,
    cols: usize,
) -> Result<Matrix> {
    check(a, b)?;
    if r0 + rows > a.rows() || c0 + cols > b.cols() {
        return Err(Error::ShapeMismatch {
            op: "gemm_panel",
            lhs: (r0 + rows, c0 + cols),
            rhs: (a.rows(), b.cols()),
        });
    }
    let mut c = Matrix::zeros(rows, cols);
    if rows > 0 && cols > 0 {
        blocked_region(a, b, r0, rows, c0, cols, c.data_mut(), cols);
    }
    Ok(c)
}

/// Shared blocked core: `C_region = A[r0..r0+rows, :] · B[:, c0..c0+cols]`
/// written into `cd` (row-major, row stride `c_stride`, region-local
/// indexing). `gemm_blocked` calls this over the full matrix; `gemm_panel`
/// over one tile.
fn blocked_region(
    a: &Matrix,
    b: &Matrix,
    r0: usize,
    rows: usize,
    c0: usize,
    cols: usize,
    cd: &mut [f32],
    c_stride: usize,
) {
    let k = a.cols();
    let mut bpack = vec![0.0f32; KC * NC];
    for pc in (0..k).step_by(KC) {
        let kc = KC.min(k - pc);
        for jc in (0..cols).step_by(NC) {
            let nc = NC.min(cols - jc);
            pack_b(b, pc, c0 + jc, kc, nc, &mut bpack);
            for ic in (0..rows).step_by(MC) {
                let mc = MC.min(rows - ic);
                macro_kernel(a, &bpack, cd, c_stride, r0 + ic, ic, jc, mc, nc, kc, pc);
            }
        }
    }
}

/// Pack `B[pc..pc+kc, jc..jc+nc]` row-major into a contiguous panel.
#[inline]
fn pack_b(b: &Matrix, pc: usize, jc: usize, kc: usize, nc: usize, out: &mut [f32]) {
    let n = b.cols();
    let bd = b.data();
    for t in 0..kc {
        let src = &bd[(pc + t) * n + jc..(pc + t) * n + jc + nc];
        out[t * nc..t * nc + nc].copy_from_slice(src);
    }
}

/// Multiply one MC×KC block of A with the packed KC×NC panel of B.
///
/// A rows are addressed globally (`a_row0`); C rows region-locally
/// (`c_row0`, stride `c_stride`) so the same kernel serves both the
/// full-matrix and the per-tile paths.
#[allow(clippy::too_many_arguments)]
#[inline]
fn macro_kernel(
    a: &Matrix,
    bpack: &[f32],
    cd: &mut [f32],
    c_stride: usize,
    a_row0: usize,
    c_row0: usize,
    jc: usize,
    mc: usize,
    nc: usize,
    kc: usize,
    pc: usize,
) {
    let ad = a.data();
    let ka = a.cols();
    let mut i = 0;
    // 4-row register tile.
    while i + 4 <= mc {
        let ar = a_row0 + i;
        micro_4xn(
            &ad[(ar) * ka + pc..],
            &ad[(ar + 1) * ka + pc..],
            &ad[(ar + 2) * ka + pc..],
            &ad[(ar + 3) * ka + pc..],
            bpack,
            kc,
            nc,
            &mut SplitRows::new(cd, c_row0 + i, c_stride, jc),
        );
        i += 4;
    }
    // Remainder rows.
    while i < mc {
        let ar = a_row0 + i;
        let cr = c_row0 + i;
        let arow = &ad[ar * ka + pc..ar * ka + pc + kc];
        let crow = &mut cd[cr * c_stride + jc..cr * c_stride + jc + nc];
        for (t, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bpack[t * nc..t * nc + nc];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
        i += 1;
    }
}

/// Helper giving simultaneous mutable access to 4 consecutive C rows.
struct SplitRows<'a> {
    r0: &'a mut [f32],
    r1: &'a mut [f32],
    r2: &'a mut [f32],
    r3: &'a mut [f32],
}

impl<'a> SplitRows<'a> {
    fn new(cd: &'a mut [f32], row0: usize, stride: usize, jc: usize) -> Self {
        let (a, rest) = cd[row0 * stride..].split_at_mut(stride);
        let (b, rest) = rest.split_at_mut(stride);
        let (c, rest) = rest.split_at_mut(stride);
        let (d, _) = rest.split_at_mut(stride);
        SplitRows {
            r0: &mut a[jc..],
            r1: &mut b[jc..],
            r2: &mut c[jc..],
            r3: &mut d[jc..],
        }
    }
}

/// Register-tile width of the inner micro-kernel (4×8 f32 accumulators =
/// 4 AVX ymm registers of payload — fits x86-64's register file with room
/// for the A broadcasts and B row).
const NR: usize = 16;

/// 4×nc micro-kernel: 4 A rows against the packed B panel.
///
/// §Perf iteration 1 (EXPERIMENTS.md): the original version accumulated
/// straight into the C rows each k-step — ~9 L1 accesses per 8 flops —
/// plateauing at ~15 GFLOPS. This version walks `nc` in NR-wide column
/// strips and keeps a full 4×NR accumulator tile in registers across the
/// entire kc loop, touching C exactly once per strip: arithmetic-bound
/// instead of L1-bound.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_4xn(
    a0: &[f32],
    a1: &[f32],
    a2: &[f32],
    a3: &[f32],
    bpack: &[f32],
    kc: usize,
    nc: usize,
    c: &mut SplitRows,
) {
    // Exact pre-slices let LLVM hoist every bounds check out of the kc
    // loop (§Perf iteration 3).
    let (a0, a1, a2, a3) = (&a0[..kc], &a1[..kc], &a2[..kc], &a3[..kc]);
    let mut j0 = 0;
    // Full NR-wide strips: register accumulation over all of kc.
    while j0 + NR <= nc {
        let mut acc = [[0.0f32; NR]; 4];
        let mut boff = j0;
        for t in 0..kc {
            let (v0, v1, v2, v3) = (a0[t], a1[t], a2[t], a3[t]);
            let brow: &[f32; NR] = bpack[boff..boff + NR].try_into().expect("NR strip");
            for jj in 0..NR {
                let b = brow[jj];
                acc[0][jj] += v0 * b;
                acc[1][jj] += v1 * b;
                acc[2][jj] += v2 * b;
                acc[3][jj] += v3 * b;
            }
            boff += nc;
        }
        for jj in 0..NR {
            c.r0[j0 + jj] += acc[0][jj];
            c.r1[j0 + jj] += acc[1][jj];
            c.r2[j0 + jj] += acc[2][jj];
            c.r3[j0 + jj] += acc[3][jj];
        }
        j0 += NR;
    }
    // Remainder columns (< NR): scalar accumulators per column.
    while j0 < nc {
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for t in 0..kc {
            let b = bpack[t * nc + j0];
            s0 += a0[t] * b;
            s1 += a1[t] * b;
            s2 += a2[t] * b;
            s3 += a3[t] * b;
        }
        c.r0[j0] += s0;
        c.r1[j0] += s1;
        c.r2[j0] += s2;
        c.r3[j0] += s3;
        j0 += 1;
    }
}

/// GEMM over sub-blocks: `C[c_off] += A[a_off] · B[b_off]` with explicit
/// strides, no intermediate copies. Used when slicing fused batches.
#[allow(clippy::too_many_arguments)]
pub fn gemm_strided(
    a: &[f32],
    a_row_stride: usize,
    b: &[f32],
    b_row_stride: usize,
    c: &mut [f32],
    c_row_stride: usize,
    m: usize,
    n: usize,
    k: usize,
) {
    for i in 0..m {
        let arow = &a[i * a_row_stride..i * a_row_stride + k];
        let crow = &mut c[i * c_row_stride..i * c_row_stride + n];
        for (t, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[t * b_row_stride..t * b_row_stride + n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Dispatch by algorithm enum (benchmark sweeps).
pub fn gemm(a: &Matrix, b: &Matrix, algo: GemmAlgo) -> Result<Matrix> {
    match algo {
        GemmAlgo::Naive => gemm_naive(a, b),
        GemmAlgo::Blocked => gemm_blocked(a, b),
    }
}

fn check(a: &Matrix, b: &Matrix) -> Result<()> {
    if a.cols() != b.rows() {
        return Err(Error::ShapeMismatch {
            op: "gemm",
            lhs: a.shape(),
            rhs: b.shape(),
        });
    }
    Ok(())
}

/// FLOP count of a dense `m×k · k×n` GEMM (2 ops per MAC) — shared by the
/// cost model, the roofline simulator and the benchmark reporters.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    #[test]
    fn tiny_known_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = gemm_naive(&a, &b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn blocked_matches_naive_square() {
        let mut rng = Pcg64::seeded(5);
        for n in [1usize, 3, 8, 31, 64, 97, 130] {
            let a = Matrix::gaussian(n, n, &mut rng);
            let b = Matrix::gaussian(n, n, &mut rng);
            let c1 = gemm_naive(&a, &b).unwrap();
            let c2 = gemm_blocked(&a, &b).unwrap();
            assert!(
                c1.rel_frobenius_distance(&c2) < 1e-5,
                "mismatch at n={n}"
            );
        }
    }

    #[test]
    fn blocked_matches_naive_rectangular() {
        let mut rng = Pcg64::seeded(6);
        for (m, k, n) in [(5, 70, 9), (70, 5, 260), (33, 300, 65), (260, 270, 4)] {
            let a = Matrix::gaussian(m, k, &mut rng);
            let b = Matrix::gaussian(k, n, &mut rng);
            let c1 = gemm_naive(&a, &b).unwrap();
            let c2 = gemm_blocked(&a, &b).unwrap();
            assert!(
                c1.rel_frobenius_distance(&c2) < 1e-5,
                "mismatch at ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Pcg64::seeded(7);
        let a = Matrix::gaussian(40, 40, &mut rng);
        let i = Matrix::eye(40);
        let c = gemm_blocked(&a, &i).unwrap();
        assert!(c.rel_frobenius_distance(&a) < 1e-6);
    }

    #[test]
    fn associativity_within_tolerance() {
        let mut rng = Pcg64::seeded(8);
        let a = Matrix::gaussian(20, 30, &mut rng);
        let b = Matrix::gaussian(30, 25, &mut rng);
        let c = Matrix::gaussian(25, 10, &mut rng);
        let left = a.matmul(&b).matmul(&c);
        let right = a.matmul(&b.matmul(&c));
        assert!(left.rel_frobenius_distance(&right) < 1e-4);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        assert!(gemm_blocked(&a, &b).is_err());
        assert!(gemm_naive(&a, &b).is_err());
    }

    #[test]
    fn strided_matches_dense_on_subblocks() {
        let mut rng = Pcg64::seeded(9);
        let a = Matrix::gaussian(10, 12, &mut rng);
        let b = Matrix::gaussian(12, 14, &mut rng);
        // Multiply the top-left 6x8 of A with the left 8-row, 9-col block of B.
        let (m, k, n) = (6, 8, 9);
        let mut c = vec![0.0f32; m * n];
        gemm_strided(a.data(), a.cols(), b.data(), b.cols(), &mut c, n, m, n, k);
        let aa = a.block(0, 0, m, k);
        let bb = b.block(0, 0, k, n);
        let expect = aa.matmul(&bb);
        for i in 0..m * n {
            assert!((c[i] - expect.data()[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn flops_formula() {
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
    }

    #[test]
    fn panel_full_range_is_bitwise_blocked() {
        // Above the naive cutover, gemm_panel over the full output range
        // must reproduce gemm_blocked exactly (same code path).
        let mut rng = Pcg64::seeded(21);
        let (m, k, n) = (130, 140, 150);
        let a = Matrix::gaussian(m, k, &mut rng);
        let b = Matrix::gaussian(k, n, &mut rng);
        let full = gemm_blocked(&a, &b).unwrap();
        let panel = gemm_panel(&a, &b, 0, m, 0, n).unwrap();
        assert_eq!(full.data(), panel.data());
    }

    #[test]
    fn aligned_panels_tile_bitwise_into_blocked() {
        // MC/NC-aligned tiles assembled into the full matrix are bitwise
        // identical to the monolithic blocked GEMM — the invariant the
        // shard plane's equivalence tests rely on.
        let mut rng = Pcg64::seeded(22);
        let (m, k, n) = (300, 96, 520);
        let a = Matrix::gaussian(m, k, &mut rng);
        let b = Matrix::gaussian(k, n, &mut rng);
        let full = gemm_blocked(&a, &b).unwrap();
        let mut tiled = Matrix::zeros(m, n);
        for r0 in (0..m).step_by(MC) {
            let rows = MC.min(m - r0);
            for c0 in (0..n).step_by(NC) {
                let cols = NC.min(n - c0);
                let tile = gemm_panel(&a, &b, r0, rows, c0, cols).unwrap();
                for i in 0..rows {
                    tiled.row_mut(r0 + i)[c0..c0 + cols].copy_from_slice(tile.row(i));
                }
            }
        }
        assert_eq!(full.data(), tiled.data());
    }

    #[test]
    fn unaligned_panels_match_within_tolerance() {
        // Arbitrary (unaligned) regions still compute the right product,
        // just with a tile-local summation order.
        let mut rng = Pcg64::seeded(23);
        let a = Matrix::gaussian(57, 83, &mut rng);
        let b = Matrix::gaussian(83, 61, &mut rng);
        let panel = gemm_panel(&a, &b, 11, 30, 7, 40).unwrap();
        let expect = a.block(11, 0, 30, 83).matmul(&b.block(0, 7, 83, 40));
        assert!(panel.rel_frobenius_distance(&expect) < 1e-5);
    }

    #[test]
    fn panel_out_of_range_rejected() {
        let a = Matrix::zeros(8, 8);
        let b = Matrix::zeros(8, 8);
        assert!(gemm_panel(&a, &b, 4, 8, 0, 4).is_err());
        assert!(gemm_panel(&a, &b, 0, 4, 4, 8).is_err());
    }
}
