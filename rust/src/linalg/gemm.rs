//! Dense GEMM kernels — the CPU substrate's "cuBLAS".
//!
//! Implementations with identical semantics (`C = A · B`):
//!
//! - [`gemm_naive`] — textbook triple loop in ikj order; the correctness
//!   oracle and the deliberately-slow baseline for the benchmark suite.
//! - [`gemm_blocked`] — the hot path: cache-blocked over **packed
//!   operands** ([`crate::linalg::pack`]) with register-tiled 8×NR / 4×NR
//!   micro-kernels.
//! - [`gemm_blocked_unpacked`] — the legacy blocked kernel (per-panel B
//!   pack, strided A reads); kept as the bitwise reference the packed path
//!   is asserted against, and as the `hotpath_micro` baseline.
//! - [`gemm_strided`] — operates on sub-blocks without copies; used by the
//!   batcher when slicing fused batches.
//! - [`gemm_panel`] / [`gemm_panel_packed`] — one output tile of the
//!   blocked GEMM, with a tile-local (order-deterministic) summation
//!   schedule; the per-task kernels of the shard execution plane
//!   ([`crate::shard`]). The packed variant reads shared [`PackedA`] /
//!   [`PackedB`] operands so the panels are packed once per GEMM instead
//!   of once per tile.
//!
//! # Packed layouts (the hot path's memory shape)
//!
//! ```text
//!   A (m×k, row-major)                PackedA block (MC×KC, micro-panel-major)
//!   ┌──────────────┐                  ┌ t→                                  ┐
//!   │ row 0  ────▶ │   pack           │ a00 a10 .. a70 │ a01 a11 .. a71 │ … │  8-row
//!   │ row 1  ────▶ │  ─────▶          │ (8 rows interleaved per k-step)     │  micro-panels
//!   │   ⋮          │                  ├─────────────────────────────────────┤
//!   └──────────────┘                  │ 4-row panel │ then <4 scalar rows   │
//!                                     └─────────────────────────────────────┘
//!   B (k×n, row-major)                PackedB panel (KC×NC, row-major)
//!   — packed once per GEMM, each panel byte-identical to the legacy
//!   per-tile `pack_b`, shared read-only across tiles and shard workers.
//! ```
//!
//! The micro-kernel keeps a full R×NR accumulator tile in registers across
//! the entire KC loop and touches C exactly once per column strip. A C
//! element's additions therefore depend only on (its coordinates, the KC
//! grouping, the NR strip schedule) — never on which micro-tile width
//! covers its row or whether the operands were packed — which is why the
//! packed, unpacked, 8-row and 4-row paths are all **bitwise identical**
//! (asserted exhaustively by `rust/tests/pack_equivalence.rs`).
//!
//! Geometry (MC/KC/NC and the naive cutover) is runtime-tunable via
//! [`set_kernel_params`] (the `[kernel]` config section), so the autotune
//! plane can calibrate the blocking per host. The defaults reproduce the
//! historical constants bit-for-bit.
//!
//! The micro-kernel mirrors, at CPU scale, the structure the paper's CUDA
//! kernels have on the GPU: an outer HBM→shared (here L2→L1) tiling plus an
//! inner register-resident accumulator tile — see DESIGN.md §3 for the
//! TPU/Pallas mapping of the same idea.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::error::{Error, Result};
use crate::linalg::matrix::Matrix;
use crate::linalg::pack::{self, PackedA, PackedB, MR, MR_WIDE};

/// Selectable dense algorithm (benchmarks sweep this).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmAlgo {
    /// Textbook ikj triple loop.
    Naive,
    /// Cache-blocked + register micro-kernel over packed operands (default).
    Blocked,
}

/// Default cache-block sizes: MC×KC panel of A (L2), KC×NC panel of B
/// (L1-ish). Tuned on the 1-core eval machine; see EXPERIMENTS.md §Perf.
const MC: usize = 128;
const KC: usize = 256;
const NC: usize = 256;

/// Default naive cutover: `m·n·k` at/below this runs the naive loop
/// (measured in §Perf iteration 4 — naive wins at 64³, blocked from ~96³).
const NAIVE_CUTOVER: usize = 80 * 80 * 80;

/// Runtime-tunable blocked-kernel geometry (the `[kernel]` config plane).
///
/// `kc`/`nc` participate in the summation *grouping*, so two runs only
/// produce identical bits when they use identical params — the shard
/// plane's bitwise guarantees additionally need its tile grid aligned to
/// `mc`/`nc` (see `[shard]` docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelParams {
    /// A-block height (rows per packed A block).
    pub mc: usize,
    /// Shared inner blocking depth of PackedA blocks and PackedB panels.
    pub kc: usize,
    /// B-panel width.
    pub nc: usize,
    /// `m·n·k` at/below which the naive loop runs (0 = never).
    pub naive_cutover: usize,
}

impl Default for KernelParams {
    fn default() -> Self {
        KernelParams {
            mc: MC,
            kc: KC,
            nc: NC,
            naive_cutover: NAIVE_CUTOVER,
        }
    }
}

impl KernelParams {
    /// Range-check the geometry — the single validator shared by every
    /// input path (TOML, CLI, programmatic [`set_kernel_params`]).
    pub fn validate(&self) -> Result<()> {
        if self.mc == 0 || self.kc == 0 || self.nc == 0 {
            return Err(Error::Config(format!(
                "kernel mc/kc/nc must be positive (got {}/{}/{})",
                self.mc, self.kc, self.nc
            )));
        }
        Ok(())
    }
}

static PARAM_MC: AtomicUsize = AtomicUsize::new(MC);
static PARAM_KC: AtomicUsize = AtomicUsize::new(KC);
static PARAM_NC: AtomicUsize = AtomicUsize::new(NC);
static PARAM_CUTOVER: AtomicUsize = AtomicUsize::new(NAIVE_CUTOVER);

/// The process-wide kernel geometry (set once at service boot).
pub fn kernel_params() -> KernelParams {
    KernelParams {
        mc: PARAM_MC.load(Ordering::Relaxed),
        kc: PARAM_KC.load(Ordering::Relaxed),
        nc: PARAM_NC.load(Ordering::Relaxed),
        naive_cutover: PARAM_CUTOVER.load(Ordering::Relaxed),
    }
}

/// Install process-wide kernel geometry. Intended to be called once at
/// boot from the `[kernel]` config section; changing params mid-flight is
/// safe but changes result bits of concurrent GEMMs (the grouping moves).
pub fn set_kernel_params(p: &KernelParams) -> Result<()> {
    p.validate()?;
    PARAM_MC.store(p.mc, Ordering::Relaxed);
    PARAM_KC.store(p.kc, Ordering::Relaxed);
    PARAM_NC.store(p.nc, Ordering::Relaxed);
    PARAM_CUTOVER.store(p.naive_cutover, Ordering::Relaxed);
    Ok(())
}

/// `C = A · B`, naive ikj order (row-major friendly, no blocking).
pub fn gemm_naive(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    check(a, b)?;
    let (m, k) = a.shape();
    let n = b.cols();
    // Output from the arena: the rank-sized factor-chain products land
    // here, and recycling them is what makes the chain allocation-free.
    let mut data = pack::checkout_zeroed(m * n);
    let bd = b.data();
    for i in 0..m {
        let arow = a.row(i);
        let crow = &mut data[i * n..(i + 1) * n];
        for (t, &av) in arow.iter().enumerate().take(k) {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[t * n..(t + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    Matrix::from_vec(m, n, data)
}

/// `C = A · B` on the packed hot path: both operands are packed once
/// (A into micro-panel-major blocks, B into row-major panels), then the
/// register-tiled micro-kernels run entirely from the packed buffers.
/// Bitwise identical to [`gemm_blocked_unpacked`] at equal [`KernelParams`].
pub fn gemm_blocked(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    gemm_blocked_with(a, b, &kernel_params())
}

/// [`gemm_blocked`] with explicit geometry (tests / calibration sweeps).
pub fn gemm_blocked_with(a: &Matrix, b: &Matrix, p: &KernelParams) -> Result<Matrix> {
    check(a, b)?;
    let (m, k) = a.shape();
    let n = b.cols();
    // Small problems: blocking/packing overhead dominates; use the naive
    // loop.
    if m * n * k <= p.naive_cutover {
        return gemm_naive(a, b);
    }
    let pa = PackedA::pack(a, p.mc, p.kc);
    let pb = PackedB::pack(b, p.kc, p.nc);
    let mut data = pack::checkout_zeroed(m * n);
    packed_region(&pa, &pb, 0, m, 0, n, &mut data, n);
    pa.recycle();
    pb.recycle();
    Matrix::from_vec(m, n, data)
}

/// Legacy blocked GEMM (per-panel B pack, strided A reads) — the bitwise
/// reference for the packed hot path and the `hotpath_micro` baseline.
pub fn gemm_blocked_unpacked(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    gemm_blocked_unpacked_with(a, b, &kernel_params())
}

/// [`gemm_blocked_unpacked`] with explicit geometry.
pub fn gemm_blocked_unpacked_with(a: &Matrix, b: &Matrix, p: &KernelParams) -> Result<Matrix> {
    check(a, b)?;
    let (m, k) = a.shape();
    let n = b.cols();
    if m * n * k <= p.naive_cutover {
        return gemm_naive(a, b);
    }
    let mut c = Matrix::zeros(m, n);
    blocked_region(a, b, 0, m, 0, n, c.data_mut(), n, p);
    Ok(c)
}

/// One output region `C[r0..r0+rows, c0..c0+cols] = A[r0.., :] · B[:, c0..]`
/// of the blocked GEMM, materialized as a contiguous rows×cols matrix.
///
/// This is the per-tile kernel of the shard execution plane's *fallback*
/// path (unaligned grids): it re-packs the B panels it needs per tile. It
/// always runs the blocked path (no naive cutover), so a tile's summation
/// order is a function of the tile alone: executing a tile grid in *any*
/// order — or concurrently — reproduces the same bits. When `r0`/`rows`
/// are multiples of MC (or `r0 + rows` hits `m`) and `c0`/`cols` are
/// multiples of NC (or `c0 + cols` hits `n`), the per-element order also
/// matches a full-matrix [`gemm_blocked`] exactly, so tiled execution is
/// bitwise-equal to the monolithic kernel.
pub fn gemm_panel(
    a: &Matrix,
    b: &Matrix,
    r0: usize,
    rows: usize,
    c0: usize,
    cols: usize,
) -> Result<Matrix> {
    check(a, b)?;
    if r0 + rows > a.rows() || c0 + cols > b.cols() {
        return Err(Error::ShapeMismatch {
            op: "gemm_panel",
            lhs: (r0 + rows, c0 + cols),
            rhs: (a.rows(), b.cols()),
        });
    }
    let mut data = pack::checkout_zeroed(rows * cols);
    if rows > 0 && cols > 0 {
        blocked_region(a, b, r0, rows, c0, cols, &mut data, cols, &kernel_params());
    }
    Matrix::from_vec(rows, cols, data)
}

/// [`gemm_panel`] over pre-packed operands: the shard plane's hot path.
/// The shared [`PackedA`]/[`PackedB`] are packed once per GEMM and read
/// concurrently by every worker, so per-tile re-packing disappears.
///
/// The region must be pack-aligned (`r0 % mc == 0`, `c0 % nc == 0`, and
/// each extent either a block multiple or flush with the matrix edge) so
/// region-local panels coincide with the globally packed ones; unaligned
/// regions are rejected — callers fall back to [`gemm_panel`].
pub fn gemm_panel_packed(
    pa: &PackedA,
    pb: &PackedB,
    r0: usize,
    rows: usize,
    c0: usize,
    cols: usize,
) -> Result<Matrix> {
    if pa.k() != pb.k() || pa.kc() != pb.kc() {
        return Err(Error::ShapeMismatch {
            op: "gemm_panel_packed",
            lhs: (pa.k(), pa.kc()),
            rhs: (pb.k(), pb.kc()),
        });
    }
    let aligned = r0 % pa.mc() == 0
        && c0 % pb.nc() == 0
        && (rows % pa.mc() == 0 || r0 + rows == pa.m())
        && (cols % pb.nc() == 0 || c0 + cols == pb.n());
    if r0 + rows > pa.m() || c0 + cols > pb.n() || !aligned {
        return Err(Error::ShapeMismatch {
            op: "gemm_panel_packed",
            lhs: (r0 + rows, c0 + cols),
            rhs: (pa.m(), pb.n()),
        });
    }
    let mut data = pack::checkout_zeroed(rows * cols);
    if rows > 0 && cols > 0 {
        packed_region(pa, pb, r0, rows, c0, cols, &mut data, cols);
    }
    Matrix::from_vec(rows, cols, data)
}

/// Full-range product over pre-packed operands (no naive cutover — the
/// caller decides; see [`gemm_blocked_with`] for the cutover rule).
pub fn gemm_packed(pa: &PackedA, pb: &PackedB) -> Result<Matrix> {
    gemm_panel_packed(pa, pb, 0, pa.m(), 0, pb.n())
}

/// Shared packed core: `C_region = A[r0..r0+rows, :] · B[:, c0..c0+cols]`
/// written into `cd` (row-major, row stride `c_stride`, region-local
/// indexing), reading both operands from their packed layouts.
#[allow(clippy::too_many_arguments)]
pub(crate) fn packed_region(
    pa: &PackedA,
    pb: &PackedB,
    r0: usize,
    rows: usize,
    c0: usize,
    cols: usize,
    cd: &mut [f32],
    c_stride: usize,
) {
    debug_assert_eq!(pa.k(), pb.k(), "packed operands share k");
    debug_assert_eq!(pa.kc(), pb.kc(), "packed operands share kc");
    let k = pa.k();
    let (mc, kc, nc) = (pa.mc(), pa.kc(), pb.nc());
    for pc in (0..k).step_by(kc) {
        let kcur = kc.min(k - pc);
        for jc in (0..cols).step_by(nc) {
            let ncur = nc.min(cols - jc);
            let bpanel = pb.panel(pc, c0 + jc);
            debug_assert_eq!(bpanel.len(), kcur * ncur, "region/panel agree");
            for ic in (0..rows).step_by(mc) {
                let mcur = mc.min(rows - ic);
                let ablock = pa.block(r0 + ic, pc);
                debug_assert_eq!(ablock.len(), mcur * kcur, "region/block agree");
                macro_kernel_packed(ablock, bpanel, cd, c_stride, ic, jc, mcur, ncur, kcur);
            }
        }
    }
}

/// Shared legacy blocked core (strided A, per-call B panel scratch).
#[allow(clippy::too_many_arguments)]
fn blocked_region(
    a: &Matrix,
    b: &Matrix,
    r0: usize,
    rows: usize,
    c0: usize,
    cols: usize,
    cd: &mut [f32],
    c_stride: usize,
    p: &KernelParams,
) {
    let k = a.cols();
    let (mc, kc, nc) = (p.mc, p.kc, p.nc);
    // Arena scratch: fully (re)written by `pack_b` before every read.
    let mut bpack = pack::checkout_stale(kc * nc);
    for pc in (0..k).step_by(kc) {
        let kcur = kc.min(k - pc);
        for jc in (0..cols).step_by(nc) {
            let ncur = nc.min(cols - jc);
            pack_b(b, pc, c0 + jc, kcur, ncur, &mut bpack);
            for ic in (0..rows).step_by(mc) {
                let mcur = mc.min(rows - ic);
                macro_kernel(a, &bpack, cd, c_stride, r0 + ic, ic, jc, mcur, ncur, kcur, pc);
            }
        }
    }
    pack::recycle(bpack);
}

/// Pack `B[pc..pc+kc, jc..jc+nc]` row-major into a contiguous panel.
#[inline]
fn pack_b(b: &Matrix, pc: usize, jc: usize, kc: usize, nc: usize, out: &mut [f32]) {
    let n = b.cols();
    let bd = b.data();
    for t in 0..kc {
        let src = &bd[(pc + t) * n + jc..(pc + t) * n + jc + nc];
        out[t * nc..t * nc + nc].copy_from_slice(src);
    }
}

/// Multiply one packed MC×KC block of A with one packed KC×NC panel of B,
/// region-local C rows (`c_row0`, stride `c_stride`). Zone traversal
/// mirrors the packed block layout exactly: wide micro-panels, then at
/// most one narrow one, then the `< MR` scalar remainder rows.
#[allow(clippy::too_many_arguments)]
#[inline]
fn macro_kernel_packed(
    ablock: &[f32],
    bpanel: &[f32],
    cd: &mut [f32],
    c_stride: usize,
    c_row0: usize,
    jc: usize,
    mc: usize,
    nc: usize,
    kc: usize,
) {
    let mut i = 0;
    while i + MR_WIDE <= mc {
        let ap = &ablock[i * kc..(i + MR_WIDE) * kc];
        let mut rows = split_rows_mut::<MR_WIDE>(cd, c_row0 + i, c_stride, jc, nc);
        micro_rxn::<MR_WIDE>(ap, bpanel, kc, nc, &mut rows);
        i += MR_WIDE;
    }
    if i + MR <= mc {
        let ap = &ablock[i * kc..(i + MR) * kc];
        let mut rows = split_rows_mut::<MR>(cd, c_row0 + i, c_stride, jc, nc);
        micro_rxn::<MR>(ap, bpanel, kc, nc, &mut rows);
        i += MR;
    }
    while i < mc {
        // Scalar remainder rows (< MR): same direct-accumulation order and
        // zero-skip as the legacy remainder path.
        let arow = &ablock[i * kc..i * kc + kc];
        let crow = &mut cd[(c_row0 + i) * c_stride + jc..(c_row0 + i) * c_stride + jc + nc];
        for (t, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bpanel[t * nc..t * nc + nc];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
        i += 1;
    }
}

/// Multiply one MC×KC block of A with the packed KC×NC panel of B
/// (legacy strided-A path).
///
/// A rows are addressed globally (`a_row0`); C rows region-locally
/// (`c_row0`, stride `c_stride`) so the same kernel serves both the
/// full-matrix and the per-tile paths.
#[allow(clippy::too_many_arguments)]
#[inline]
fn macro_kernel(
    a: &Matrix,
    bpack: &[f32],
    cd: &mut [f32],
    c_stride: usize,
    a_row0: usize,
    c_row0: usize,
    jc: usize,
    mc: usize,
    nc: usize,
    kc: usize,
    pc: usize,
) {
    let ad = a.data();
    let ka = a.cols();
    let mut i = 0;
    // 4-row register tile.
    while i + 4 <= mc {
        let ar = a_row0 + i;
        micro_4xn(
            &ad[(ar) * ka + pc..],
            &ad[(ar + 1) * ka + pc..],
            &ad[(ar + 2) * ka + pc..],
            &ad[(ar + 3) * ka + pc..],
            bpack,
            kc,
            nc,
            &mut SplitRows::new(cd, c_row0 + i, c_stride, jc),
        );
        i += 4;
    }
    // Remainder rows.
    while i < mc {
        let ar = a_row0 + i;
        let cr = c_row0 + i;
        let arow = &ad[ar * ka + pc..ar * ka + pc + kc];
        let crow = &mut cd[cr * c_stride + jc..cr * c_stride + jc + nc];
        for (t, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bpack[t * nc..t * nc + nc];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
        i += 1;
    }
}

/// Helper giving simultaneous mutable access to 4 consecutive C rows
/// (legacy micro-kernel).
struct SplitRows<'a> {
    r0: &'a mut [f32],
    r1: &'a mut [f32],
    r2: &'a mut [f32],
    r3: &'a mut [f32],
}

impl<'a> SplitRows<'a> {
    fn new(cd: &'a mut [f32], row0: usize, stride: usize, jc: usize) -> Self {
        let (a, rest) = cd[row0 * stride..].split_at_mut(stride);
        let (b, rest) = rest.split_at_mut(stride);
        let (c, rest) = rest.split_at_mut(stride);
        let (d, _) = rest.split_at_mut(stride);
        SplitRows {
            r0: &mut a[jc..],
            r1: &mut b[jc..],
            r2: &mut c[jc..],
            r3: &mut d[jc..],
        }
    }
}

/// Simultaneous mutable access to `R` consecutive C rows, each trimmed to
/// the `width`-column window at `jc` (the packed micro-kernels' C view).
fn split_rows_mut<'a, const R: usize>(
    cd: &'a mut [f32],
    row0: usize,
    stride: usize,
    jc: usize,
    width: usize,
) -> [&'a mut [f32]; R] {
    let mut rest: &'a mut [f32] = cd.split_at_mut(row0 * stride).1;
    std::array::from_fn(|_| {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(stride);
        rest = tail;
        &mut head[jc..jc + width]
    })
}

/// Register-tile width of the inner micro-kernels (NR-wide f32 column
/// strips; with the 8-row wide tile this is an 8×16 accumulator block —
/// 8 AVX-512 zmm registers of payload, or a spill-free 4×16 on AVX2 via
/// the narrow tile).
const NR: usize = 16;

/// R×nc micro-kernel over a packed A micro-panel (`ap[t·R + j]`) and a
/// packed B panel.
///
/// §Perf iteration 1 (EXPERIMENTS.md): accumulating straight into C each
/// k-step was L1-bound (~9 accesses per 8 flops); this walks `nc` in
/// NR-wide column strips and keeps a full R×NR accumulator tile in
/// registers across the entire kc loop, touching C exactly once per strip.
/// The packed-operand iteration (PR 5) additionally makes every A load
/// come from the contiguous micro-panel instead of R strided rows.
#[inline]
fn micro_rxn<const R: usize>(
    ap: &[f32],
    bpack: &[f32],
    kc: usize,
    nc: usize,
    c: &mut [&mut [f32]; R],
) {
    // Exact pre-slice lets LLVM hoist the bounds checks out of the kc loop.
    let ap = &ap[..kc * R];
    let mut j0 = 0;
    // Full NR-wide strips: register accumulation over all of kc.
    while j0 + NR <= nc {
        let mut acc = [[0.0f32; NR]; R];
        let mut boff = j0;
        for t in 0..kc {
            let brow: &[f32; NR] = bpack[boff..boff + NR].try_into().expect("NR strip");
            let avals = &ap[t * R..t * R + R];
            for (accj, &av) in acc.iter_mut().zip(avals) {
                for (acv, &bv) in accj.iter_mut().zip(brow) {
                    *acv += av * bv;
                }
            }
            boff += nc;
        }
        for (cj, accj) in c.iter_mut().zip(&acc) {
            for (cv, &av) in cj[j0..j0 + NR].iter_mut().zip(accj) {
                *cv += av;
            }
        }
        j0 += NR;
    }
    // Remainder columns (< NR): scalar accumulators per column.
    while j0 < nc {
        let mut s = [0.0f32; R];
        for t in 0..kc {
            let b = bpack[t * nc + j0];
            let avals = &ap[t * R..t * R + R];
            for (sj, &av) in s.iter_mut().zip(avals) {
                *sj += av * b;
            }
        }
        for (cj, &sj) in c.iter_mut().zip(&s) {
            cj[j0] += sj;
        }
        j0 += 1;
    }
}

/// 4×nc micro-kernel over strided A rows (legacy unpacked path; see
/// [`micro_rxn`] for the strip scheme — the per-element arithmetic order
/// is identical).
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_4xn(
    a0: &[f32],
    a1: &[f32],
    a2: &[f32],
    a3: &[f32],
    bpack: &[f32],
    kc: usize,
    nc: usize,
    c: &mut SplitRows,
) {
    // Exact pre-slices let LLVM hoist every bounds check out of the kc
    // loop (§Perf iteration 3).
    let (a0, a1, a2, a3) = (&a0[..kc], &a1[..kc], &a2[..kc], &a3[..kc]);
    let mut j0 = 0;
    // Full NR-wide strips: register accumulation over all of kc.
    while j0 + NR <= nc {
        let mut acc = [[0.0f32; NR]; 4];
        let mut boff = j0;
        for t in 0..kc {
            let (v0, v1, v2, v3) = (a0[t], a1[t], a2[t], a3[t]);
            let brow: &[f32; NR] = bpack[boff..boff + NR].try_into().expect("NR strip");
            for jj in 0..NR {
                let b = brow[jj];
                acc[0][jj] += v0 * b;
                acc[1][jj] += v1 * b;
                acc[2][jj] += v2 * b;
                acc[3][jj] += v3 * b;
            }
            boff += nc;
        }
        for jj in 0..NR {
            c.r0[j0 + jj] += acc[0][jj];
            c.r1[j0 + jj] += acc[1][jj];
            c.r2[j0 + jj] += acc[2][jj];
            c.r3[j0 + jj] += acc[3][jj];
        }
        j0 += NR;
    }
    // Remainder columns (< NR): scalar accumulators per column.
    while j0 < nc {
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for t in 0..kc {
            let b = bpack[t * nc + j0];
            s0 += a0[t] * b;
            s1 += a1[t] * b;
            s2 += a2[t] * b;
            s3 += a3[t] * b;
        }
        c.r0[j0] += s0;
        c.r1[j0] += s1;
        c.r2[j0] += s2;
        c.r3[j0] += s3;
        j0 += 1;
    }
}

/// GEMM over sub-blocks: `C[c_off] += A[a_off] · B[b_off]` with explicit
/// strides, no intermediate copies. Used when slicing fused batches.
#[allow(clippy::too_many_arguments)]
pub fn gemm_strided(
    a: &[f32],
    a_row_stride: usize,
    b: &[f32],
    b_row_stride: usize,
    c: &mut [f32],
    c_row_stride: usize,
    m: usize,
    n: usize,
    k: usize,
) {
    for i in 0..m {
        let arow = &a[i * a_row_stride..i * a_row_stride + k];
        let crow = &mut c[i * c_row_stride..i * c_row_stride + n];
        for (t, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[t * b_row_stride..t * b_row_stride + n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Dispatch by algorithm enum (benchmark sweeps).
pub fn gemm(a: &Matrix, b: &Matrix, algo: GemmAlgo) -> Result<Matrix> {
    match algo {
        GemmAlgo::Naive => gemm_naive(a, b),
        GemmAlgo::Blocked => gemm_blocked(a, b),
    }
}

fn check(a: &Matrix, b: &Matrix) -> Result<()> {
    if a.cols() != b.rows() {
        return Err(Error::ShapeMismatch {
            op: "gemm",
            lhs: a.shape(),
            rhs: b.shape(),
        });
    }
    Ok(())
}

/// FLOP count of a dense `m×k · k×n` GEMM (2 ops per MAC) — shared by the
/// cost model, the roofline simulator and the benchmark reporters.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    #[test]
    fn tiny_known_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = gemm_naive(&a, &b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn blocked_matches_naive_square() {
        let mut rng = Pcg64::seeded(5);
        for n in [1usize, 3, 8, 31, 64, 97, 130] {
            let a = Matrix::gaussian(n, n, &mut rng);
            let b = Matrix::gaussian(n, n, &mut rng);
            let c1 = gemm_naive(&a, &b).unwrap();
            let c2 = gemm_blocked(&a, &b).unwrap();
            assert!(
                c1.rel_frobenius_distance(&c2) < 1e-5,
                "mismatch at n={n}"
            );
        }
    }

    #[test]
    fn blocked_matches_naive_rectangular() {
        let mut rng = Pcg64::seeded(6);
        for (m, k, n) in [(5, 70, 9), (70, 5, 260), (33, 300, 65), (260, 270, 4)] {
            let a = Matrix::gaussian(m, k, &mut rng);
            let b = Matrix::gaussian(k, n, &mut rng);
            let c1 = gemm_naive(&a, &b).unwrap();
            let c2 = gemm_blocked(&a, &b).unwrap();
            assert!(
                c1.rel_frobenius_distance(&c2) < 1e-5,
                "mismatch at ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn packed_is_bitwise_identical_to_unpacked() {
        // The tentpole invariant: the packed hot path reproduces the
        // legacy kernel's bits exactly — odd shapes, every micro-tile
        // zone (8/4/scalar rows), remainder columns, 1×N / N×1 edges.
        let mut rng = Pcg64::seeded(41);
        for (m, k, n) in [
            (97, 83, 101),
            (130, 257, 259),
            (256, 96, 520),
            (129, 300, 17),
            (1, 300, 257),
            (300, 257, 1),
            (83, 1, 83),
        ] {
            let a = Matrix::gaussian(m, k, &mut rng);
            let b = Matrix::gaussian(k, n, &mut rng);
            let packed = gemm_blocked(&a, &b).unwrap();
            let unpacked = gemm_blocked_unpacked(&a, &b).unwrap();
            assert_eq!(packed.data(), unpacked.data(), "({m},{k},{n})");
        }
    }

    #[test]
    fn packed_matches_unpacked_with_custom_params() {
        // Explicit-params variants: geometry changes change bits, but the
        // packed/unpacked pair must stay bit-identical at any geometry.
        let mut rng = Pcg64::seeded(42);
        let a = Matrix::gaussian(150, 170, &mut rng);
        let b = Matrix::gaussian(170, 190, &mut rng);
        for p in [
            KernelParams { mc: 64, kc: 96, nc: 112, naive_cutover: 0 },
            KernelParams { mc: 32, kc: 512, nc: 48, naive_cutover: 0 },
            KernelParams::default(),
        ] {
            let packed = gemm_blocked_with(&a, &b, &p).unwrap();
            let unpacked = gemm_blocked_unpacked_with(&a, &b, &p).unwrap();
            assert_eq!(packed.data(), unpacked.data(), "{p:?}");
        }
    }

    #[test]
    fn kernel_params_validate_and_default() {
        assert_eq!(kernel_params(), KernelParams::default());
        assert!(set_kernel_params(&KernelParams { mc: 0, ..Default::default() }).is_err());
        assert!(set_kernel_params(&KernelParams { kc: 0, ..Default::default() }).is_err());
        assert!(set_kernel_params(&KernelParams { nc: 0, ..Default::default() }).is_err());
        // A failed set must not have mutated the installed params.
        assert_eq!(kernel_params(), KernelParams::default());
        set_kernel_params(&KernelParams::default()).unwrap();
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Pcg64::seeded(7);
        let a = Matrix::gaussian(40, 40, &mut rng);
        let i = Matrix::eye(40);
        let c = gemm_blocked(&a, &i).unwrap();
        assert!(c.rel_frobenius_distance(&a) < 1e-6);
    }

    #[test]
    fn associativity_within_tolerance() {
        let mut rng = Pcg64::seeded(8);
        let a = Matrix::gaussian(20, 30, &mut rng);
        let b = Matrix::gaussian(30, 25, &mut rng);
        let c = Matrix::gaussian(25, 10, &mut rng);
        let left = a.matmul(&b).matmul(&c);
        let right = a.matmul(&b.matmul(&c));
        assert!(left.rel_frobenius_distance(&right) < 1e-4);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        assert!(gemm_blocked(&a, &b).is_err());
        assert!(gemm_naive(&a, &b).is_err());
        assert!(gemm_blocked_unpacked(&a, &b).is_err());
    }

    #[test]
    fn strided_matches_dense_on_subblocks() {
        let mut rng = Pcg64::seeded(9);
        let a = Matrix::gaussian(10, 12, &mut rng);
        let b = Matrix::gaussian(12, 14, &mut rng);
        // Multiply the top-left 6x8 of A with the left 8-row, 9-col block of B.
        let (m, k, n) = (6, 8, 9);
        let mut c = vec![0.0f32; m * n];
        gemm_strided(a.data(), a.cols(), b.data(), b.cols(), &mut c, n, m, n, k);
        let aa = a.block(0, 0, m, k);
        let bb = b.block(0, 0, k, n);
        let expect = aa.matmul(&bb);
        for i in 0..m * n {
            assert!((c[i] - expect.data()[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn flops_formula() {
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
    }

    #[test]
    fn panel_full_range_is_bitwise_blocked() {
        // Above the naive cutover, gemm_panel over the full output range
        // must reproduce gemm_blocked exactly.
        let mut rng = Pcg64::seeded(21);
        let (m, k, n) = (130, 140, 150);
        let a = Matrix::gaussian(m, k, &mut rng);
        let b = Matrix::gaussian(k, n, &mut rng);
        let full = gemm_blocked(&a, &b).unwrap();
        let panel = gemm_panel(&a, &b, 0, m, 0, n).unwrap();
        assert_eq!(full.data(), panel.data());
    }

    #[test]
    fn packed_panel_full_range_is_bitwise_blocked() {
        let mut rng = Pcg64::seeded(24);
        let (m, k, n) = (130, 140, 150);
        let a = Matrix::gaussian(m, k, &mut rng);
        let b = Matrix::gaussian(k, n, &mut rng);
        let p = kernel_params();
        let pa = PackedA::pack(&a, p.mc, p.kc);
        let pb = PackedB::pack(&b, p.kc, p.nc);
        let full = gemm_blocked(&a, &b).unwrap();
        let panel = gemm_panel_packed(&pa, &pb, 0, m, 0, n).unwrap();
        assert_eq!(full.data(), panel.data());
        let whole = gemm_packed(&pa, &pb).unwrap();
        assert_eq!(full.data(), whole.data());
    }

    #[test]
    fn aligned_panels_tile_bitwise_into_blocked() {
        // MC/NC-aligned tiles assembled into the full matrix are bitwise
        // identical to the monolithic blocked GEMM — the invariant the
        // shard plane's equivalence tests rely on — on both the unpacked
        // fallback and the shared-packed tile kernels.
        let mut rng = Pcg64::seeded(22);
        let (m, k, n) = (300, 96, 520);
        let a = Matrix::gaussian(m, k, &mut rng);
        let b = Matrix::gaussian(k, n, &mut rng);
        let full = gemm_blocked(&a, &b).unwrap();
        let p = kernel_params();
        let pa = PackedA::pack(&a, p.mc, p.kc);
        let pb = PackedB::pack(&b, p.kc, p.nc);
        for packed in [false, true] {
            let mut tiled = Matrix::zeros(m, n);
            for r0 in (0..m).step_by(MC) {
                let rows = MC.min(m - r0);
                for c0 in (0..n).step_by(NC) {
                    let cols = NC.min(n - c0);
                    let tile = if packed {
                        gemm_panel_packed(&pa, &pb, r0, rows, c0, cols).unwrap()
                    } else {
                        gemm_panel(&a, &b, r0, rows, c0, cols).unwrap()
                    };
                    for i in 0..rows {
                        tiled.row_mut(r0 + i)[c0..c0 + cols].copy_from_slice(tile.row(i));
                    }
                }
            }
            assert_eq!(full.data(), tiled.data(), "packed={packed}");
        }
    }

    #[test]
    fn unaligned_panels_match_within_tolerance() {
        // Arbitrary (unaligned) regions still compute the right product,
        // just with a tile-local summation order.
        let mut rng = Pcg64::seeded(23);
        let a = Matrix::gaussian(57, 83, &mut rng);
        let b = Matrix::gaussian(83, 61, &mut rng);
        let panel = gemm_panel(&a, &b, 11, 30, 7, 40).unwrap();
        let expect = a.block(11, 0, 30, 83).matmul(&b.block(0, 7, 83, 40));
        assert!(panel.rel_frobenius_distance(&expect) < 1e-5);
    }

    #[test]
    fn panel_out_of_range_rejected() {
        let a = Matrix::zeros(8, 8);
        let b = Matrix::zeros(8, 8);
        assert!(gemm_panel(&a, &b, 4, 8, 0, 4).is_err());
        assert!(gemm_panel(&a, &b, 0, 4, 4, 8).is_err());
    }

    #[test]
    fn packed_panel_rejects_unaligned_regions() {
        let mut rng = Pcg64::seeded(25);
        let a = Matrix::gaussian(300, 64, &mut rng);
        let b = Matrix::gaussian(64, 300, &mut rng);
        let p = kernel_params();
        let pa = PackedA::pack(&a, p.mc, p.kc);
        let pb = PackedB::pack(&b, p.kc, p.nc);
        // Unaligned offset / interior non-multiple extents are refused.
        assert!(gemm_panel_packed(&pa, &pb, 64, 128, 0, 256).is_err());
        assert!(gemm_panel_packed(&pa, &pb, 0, 100, 0, 256).is_err());
        assert!(gemm_panel_packed(&pa, &pb, 0, 128, 0, 100).is_err());
        // Flush-with-edge remainders are fine.
        assert!(gemm_panel_packed(&pa, &pb, 128, 172, 256, 44).is_ok());
        // Out of range rejected.
        assert!(gemm_panel_packed(&pa, &pb, 256, 128, 0, 256).is_err());
    }
}
