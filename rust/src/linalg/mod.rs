//! Dense linear-algebra substrate.
//!
//! This module is the reproduction's stand-in for cuBLAS/LAPACK: a
//! from-scratch, dependency-free dense matrix library providing everything
//! the paper's pipeline needs —
//!
//! - [`Matrix`]: row-major `f32` dense matrices with structured generators
//!   (the paper's experiments are on synthetic matrices with controlled
//!   spectra),
//! - [`gemm`]: naive, blocked and register-blocked GEMM (the "cuBLAS"
//!   comparator and the CPU hot path for shapes not covered by AOT
//!   artifacts),
//! - [`pack`]: the packed-operand plane — BLIS-style micro-panel packing
//!   of A/B (with fused FP8 decode-into-pack) plus the per-thread scratch
//!   arena the hot path allocates from,
//! - [`qr`]: Householder QR (used by randomized SVD's orthonormalization),
//! - [`svd`]: one-sided Jacobi SVD (the exact truncated-SVD reference),
//! - [`rsvd`]: Halko–Martinsson–Tropp randomized SVD with power iterations,
//! - [`lanczos`]: Golub–Kahan–Lanczos bidiagonalization for truncated SVD,
//! - [`rng`]: a PCG-family PRNG (no `rand` crate offline).

pub mod gemm;
pub mod lanczos;
pub mod matrix;
pub mod norms;
pub mod pack;
pub mod qr;
pub mod rng;
pub mod rsvd;
pub mod svd;

pub use gemm::{
    gemm_blocked, gemm_blocked_unpacked, gemm_flops, gemm_naive, kernel_params,
    set_kernel_params, GemmAlgo, KernelParams,
};
pub use pack::{PackedA, PackedB};
pub use lanczos::lanczos_svd;
pub use matrix::Matrix;
pub use qr::{qr_thin, QrFactors};
pub use rng::Pcg64;
pub use rsvd::{rsvd, RsvdOptions};
pub use svd::{jacobi_svd, Svd};
