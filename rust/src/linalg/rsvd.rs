//! Randomized SVD (Halko–Martinsson–Tropp 2011).
//!
//! The paper's fast decomposition path: a Gaussian range finder with
//! oversampling and optional power iterations, then an exact SVD of the
//! small projected matrix. Cost is `O(mn(r+p))` for the sketch plus
//! `O((m+n)(r+p)²)` for the small factorizations — the `(m+k)r²`-style
//! term quoted in the paper's §3.1.

use crate::error::{Error, Result};
use crate::linalg::matrix::Matrix;
use crate::linalg::qr::qr_thin;
use crate::linalg::rng::Pcg64;
use crate::linalg::svd::{jacobi_svd, Svd};

/// Tuning knobs for randomized SVD.
#[derive(Clone, Copy, Debug)]
pub struct RsvdOptions {
    /// Oversampling columns added to the target rank (Halko recommends 5–10).
    pub oversample: usize,
    /// Power iterations (0–2 typical; each sharpens the spectrum at the
    /// cost of two extra passes over A).
    pub power_iters: usize,
    /// PRNG seed (decompositions are deterministic given the seed).
    pub seed: u64,
}

impl Default for RsvdOptions {
    fn default() -> Self {
        RsvdOptions {
            oversample: 8,
            power_iters: 1,
            seed: 0x5eed,
        }
    }
}

/// Randomized truncated SVD of `a` at rank `r`.
pub fn rsvd(a: &Matrix, r: usize, opts: &RsvdOptions) -> Result<Svd> {
    let (m, n) = a.shape();
    let kmax = m.min(n);
    if r == 0 || r > kmax {
        return Err(Error::InvalidRank {
            requested: r,
            max: kmax,
        });
    }
    let l = (r + opts.oversample).min(kmax);
    let mut rng = Pcg64::seeded(opts.seed);

    // Stage A: range finder. Y = A Ω, Ω ∈ R^{n×l} Gaussian.
    let omega = Matrix::gaussian(n, l, &mut rng);
    let mut y = a.matmul(&omega); // m×l
    let mut q = qr_thin(&y).q;

    // Power iterations with re-orthonormalization each half-step
    // (subspace iteration): Q ← orth(A · orth(Aᵀ Q)).
    for _ in 0..opts.power_iters {
        let z = a.matmul_tn(&q); // n×l
        let qz = qr_thin(&z).q;
        y = a.matmul(&qz); // m×l
        q = qr_thin(&y).q;
    }

    // Stage B: B = Qᵀ A (l×n), small exact SVD of B.
    let b = q.matmul_tn(a);
    let small = jacobi_svd(&b)?;

    // U = Q · U_B, truncate to r.
    let u = q.matmul(&small.u.take_cols(r.min(small.s.len())));
    Ok(Svd {
        u,
        s: small.s[..r.min(small.s.len())].to_vec(),
        vt: small.vt.take_rows(r),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::orthonormality_defect;

    #[test]
    fn recovers_exact_low_rank() {
        let mut rng = Pcg64::seeded(41);
        let a = Matrix::low_rank(40, 30, 5, &mut rng);
        let f = rsvd(&a, 5, &RsvdOptions::default()).unwrap();
        let err = f.reconstruct().rel_frobenius_distance(&a);
        assert!(err < 1e-4, "err {err}");
    }

    #[test]
    fn near_optimal_on_decaying_spectrum() {
        let mut rng = Pcg64::seeded(42);
        let sv: Vec<f32> = (0..16).map(|i| (2.0f32).powi(-(i as i32))).collect();
        let a = Matrix::with_spectrum(32, 32, &sv, &mut rng);
        let r = 6;
        let f = rsvd(&a, r, &RsvdOptions::default()).unwrap();
        let err = f.reconstruct().sub(&a).unwrap().frobenius_norm();
        let opt: f32 = sv[r..].iter().map(|s| s * s).sum::<f32>().sqrt();
        // Within 2x of Eckart-Young optimum (Halko-type bound with power iter).
        assert!(err < 2.0 * opt + 1e-5, "err {err} opt {opt}");
    }

    #[test]
    fn factors_orthonormal() {
        let mut rng = Pcg64::seeded(43);
        let a = Matrix::gaussian(30, 20, &mut rng);
        let f = rsvd(&a, 8, &RsvdOptions::default()).unwrap();
        assert!(orthonormality_defect(&f.u) < 1e-3);
        assert!(orthonormality_defect(&f.vt.transpose()) < 1e-3);
        for w in f.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Pcg64::seeded(44);
        let a = Matrix::gaussian(20, 20, &mut rng);
        let f1 = rsvd(&a, 4, &RsvdOptions::default()).unwrap();
        let f2 = rsvd(&a, 4, &RsvdOptions::default()).unwrap();
        assert_eq!(f1.s, f2.s);
        assert_eq!(f1.u.data(), f2.u.data());
    }

    #[test]
    fn power_iterations_improve_accuracy() {
        let mut rng = Pcg64::seeded(45);
        // Slowly decaying spectrum — the hard case for plain sketching.
        let sv: Vec<f32> = (1..=24).map(|i| 1.0 / (i as f32).sqrt()).collect();
        let a = Matrix::with_spectrum(48, 48, &sv, &mut rng);
        let e0 = rsvd(&a, 6, &RsvdOptions { power_iters: 0, ..Default::default() })
            .unwrap()
            .reconstruct()
            .rel_frobenius_distance(&a);
        let e2 = rsvd(&a, 6, &RsvdOptions { power_iters: 2, ..Default::default() })
            .unwrap()
            .reconstruct()
            .rel_frobenius_distance(&a);
        assert!(e2 <= e0 * 1.05, "power iters should not hurt: {e2} vs {e0}");
    }

    #[test]
    fn rank_bounds_checked() {
        let a = Matrix::eye(4);
        assert!(rsvd(&a, 0, &RsvdOptions::default()).is_err());
        assert!(rsvd(&a, 9, &RsvdOptions::default()).is_err());
    }

    #[test]
    fn wide_matrix() {
        let mut rng = Pcg64::seeded(46);
        let a = Matrix::low_rank(12, 40, 3, &mut rng);
        let f = rsvd(&a, 3, &RsvdOptions::default()).unwrap();
        assert!(f.reconstruct().rel_frobenius_distance(&a) < 1e-3);
    }
}
