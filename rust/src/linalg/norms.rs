//! Vector and matrix norm helpers shared across the decomposition routines.

use crate::linalg::matrix::Matrix;

/// Euclidean norm of a vector, accumulated in f64 for stability.
pub fn norm2(x: &[f32]) -> f32 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32
}

/// Dot product, accumulated in f64.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| a as f64 * b as f64).sum::<f64>() as f32
}

/// Normalize a vector in place; returns the original norm. Vectors with
/// norm below `eps` are zeroed (caller decides how to handle breakdown).
pub fn normalize(x: &mut [f32], eps: f32) -> f32 {
    let n = norm2(x);
    if n > eps {
        let inv = 1.0 / n;
        for v in x.iter_mut() {
            *v *= inv;
        }
    } else {
        for v in x.iter_mut() {
            *v = 0.0;
        }
    }
    n
}

/// Spectral-norm estimate via power iteration on `AᵀA` (used by error
/// reporting; exact SVD is overkill there).
pub fn spectral_norm_est(a: &Matrix, iters: usize, seed: u64) -> f32 {
    let mut rng = crate::linalg::rng::Pcg64::seeded(seed);
    let mut v: Vec<f32> = (0..a.cols()).map(|_| rng.gaussian()).collect();
    normalize(&mut v, 1e-30);
    let mut sigma = 0.0f32;
    for _ in 0..iters.max(1) {
        let u = a.matvec(&v);
        let mut w = a.matvec_t(&u);
        sigma = normalize(&mut w, 1e-30).sqrt();
        v = w;
        if sigma == 0.0 {
            break;
        }
    }
    sigma
}

/// Column-orthonormality defect `‖QᵀQ − I‖_F` — a property checked by the
/// QR/rSVD tests and the integration suite.
pub fn orthonormality_defect(q: &Matrix) -> f32 {
    let gram = q.matmul_tn(q);
    let k = gram.rows();
    let mut acc = 0.0f64;
    for i in 0..k {
        for j in 0..k {
            let want = if i == j { 1.0 } else { 0.0 };
            let d = (gram[(i, j)] - want) as f64;
            acc += d * d;
        }
    }
    acc.sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    #[test]
    fn norm2_known() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert_eq!(norm2(&[]), 0.0);
    }

    #[test]
    fn dot_known() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn normalize_unit() {
        let mut v = vec![1.0, 2.0, 2.0];
        let n = normalize(&mut v, 1e-12);
        assert!((n - 3.0).abs() < 1e-6);
        assert!((norm2(&v) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn normalize_zero_vector() {
        let mut v = vec![0.0, 0.0];
        let n = normalize(&mut v, 1e-12);
        assert_eq!(n, 0.0);
        assert_eq!(v, vec![0.0, 0.0]);
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        let mut m = Matrix::zeros(4, 4);
        m[(0, 0)] = 7.0;
        m[(1, 1)] = 3.0;
        m[(2, 2)] = 1.0;
        let est = spectral_norm_est(&m, 50, 42);
        assert!((est - 7.0).abs() < 1e-2, "est {est}");
    }

    #[test]
    fn orthonormality_defect_of_identity_block() {
        let mut rng = Pcg64::seeded(2);
        let g = Matrix::gaussian(30, 5, &mut rng);
        let q = crate::linalg::qr::qr_thin(&g).q;
        assert!(orthonormality_defect(&q) < 1e-4);
    }
}
