//! PCG-family pseudo-random number generator.
//!
//! The offline vendor set has no `rand` crate, so the substrate ships its
//! own generator: PCG-XSL-RR 128/64 (O'Neill 2014), the same construction
//! used by `rand_pcg::Pcg64`. It is deterministic, seedable, splittable and
//! fast — all the properties the benchmark harness and the randomized SVD
//! need. Gaussian variates come from the Box–Muller transform.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

/// Default multiplier from the PCG reference implementation.
const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn seeded(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id; distinct streams are
    /// independent, which gives cheap "split" semantics for worker threads.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 {
            state: 0,
            inc,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Derive an independent generator (new stream) from this one.
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg64::with_stream(seed, stream)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal variate (Box–Muller; one value per call, the pair's
    /// second half is discarded to keep the generator state simple).
    pub fn gaussian(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            return (r * theta.cos()) as f32;
        }
    }

    /// Fill a slice with standard normal variates.
    pub fn fill_gaussian(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gaussian();
        }
    }

    /// Fill a slice with uniform `[lo, hi)` variates.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform(lo, hi);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Pcg64::seeded(7);
        let mut c = a.split();
        let same = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::seeded(11);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let g = rng.gaussian() as f64;
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg64::seeded(13);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
