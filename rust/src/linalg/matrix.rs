//! Row-major dense `f32` matrix.
//!
//! The workhorse type of the CPU substrate. Storage is a flat `Vec<f32>` in
//! row-major order (`data[r * cols + c]`), matching both the XLA literal
//! layout used by the runtime bridge and the paper's PyTorch baseline.

use crate::error::{Error, Result};
use crate::linalg::rng::Pcg64;

/// Dense row-major `f32` matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let rshow = self.rows.min(6);
        let cshow = self.cols.min(8);
        for r in 0..rshow {
            write!(f, "  ")?;
            for c in 0..cshow {
                write!(f, "{:>10.4} ", self[(r, c)])?;
            }
            writeln!(f, "{}", if cshow < self.cols { "…" } else { "" })?;
        }
        if rshow < self.rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl Matrix {
    // ------------------------------------------------------------------
    // Constructors
    // ------------------------------------------------------------------

    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Matrix from an explicit row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::ShapeMismatch {
                op: "from_vec",
                lhs: (rows, cols),
                rhs: (data.len(), 1),
            });
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix built from a function of `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    /// I.i.d. standard-normal entries.
    pub fn gaussian(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_gaussian(&mut m.data);
        m
    }

    /// I.i.d. uniform entries in `[lo, hi)`.
    pub fn uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Pcg64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_uniform(&mut m.data, lo, hi);
        m
    }

    /// Exactly rank-`r` matrix: product of two Gaussian factors, scaled so
    /// the Frobenius norm is O(sqrt(rows*cols)).
    pub fn low_rank(rows: usize, cols: usize, rank: usize, rng: &mut Pcg64) -> Self {
        let rank = rank.max(1).min(rows.min(cols));
        let g1 = Matrix::gaussian(rows, rank, rng);
        let g2 = Matrix::gaussian(rank, cols, rng);
        let mut m = g1.matmul(&g2);
        let scale = 1.0 / (rank as f32).sqrt();
        m.scale_in_place(scale);
        m
    }

    /// Rank-`r` signal plus i.i.d. Gaussian noise of amplitude
    /// `noise * signal_rms` — the structured generator used throughout the
    /// benchmark suite (the paper evaluates on matrices with rapidly
    /// decaying spectra; this is the simplest such family).
    pub fn low_rank_noisy(
        rows: usize,
        cols: usize,
        rank: usize,
        noise: f32,
        rng: &mut Pcg64,
    ) -> Self {
        let mut m = Matrix::low_rank(rows, cols, rank, rng);
        if noise > 0.0 {
            let rms = (m.sq_frobenius_norm() / (rows * cols) as f32).sqrt();
            for v in m.data.iter_mut() {
                *v += noise * rms * rng.gaussian();
            }
        }
        m
    }

    /// Matrix with an explicit singular-value profile: `A = U diag(s) Vᵀ`
    /// with Haar-ish random orthonormal `U`, `V` (QR of Gaussian).
    /// Used by the error-analysis experiments to generate exponential-decay
    /// and heavy-tail spectra.
    pub fn with_spectrum(rows: usize, cols: usize, sv: &[f32], rng: &mut Pcg64) -> Self {
        let k = sv.len().min(rows.min(cols));
        let gu = Matrix::gaussian(rows, k, rng);
        let gv = Matrix::gaussian(cols, k, rng);
        let u = crate::linalg::qr::qr_thin(&gu).q;
        let v = crate::linalg::qr::qr_thin(&gv).q;
        // A = U * diag(sv) * Vᵀ
        let mut us = u;
        for c in 0..k {
            let s = sv[c];
            for r in 0..rows {
                us[(r, c)] *= s;
            }
        }
        us.matmul_nt(&v)
    }

    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Flat row-major data.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat row-major data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutably borrow row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy column `c` out.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    // ------------------------------------------------------------------
    // Elementwise / structural ops
    // ------------------------------------------------------------------

    /// Transpose (allocates).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        t
    }

    /// `self + other`.
    pub fn add(&self, other: &Matrix) -> Result<Matrix> {
        self.check_same_shape("add", other)?;
        let mut out = self.clone();
        for (o, x) in out.data.iter_mut().zip(&other.data) {
            *o += x;
        }
        Ok(out)
    }

    /// `self - other`.
    pub fn sub(&self, other: &Matrix) -> Result<Matrix> {
        self.check_same_shape("sub", other)?;
        let mut out = self.clone();
        for (o, x) in out.data.iter_mut().zip(&other.data) {
            *o -= x;
        }
        Ok(out)
    }

    /// In-place `self += alpha * other` (axpy).
    pub fn axpy_in_place(&mut self, alpha: f32, other: &Matrix) -> Result<()> {
        self.check_same_shape("axpy", other)?;
        for (o, x) in self.data.iter_mut().zip(&other.data) {
            *o += alpha * x;
        }
        Ok(())
    }

    /// In-place scalar multiply.
    pub fn scale_in_place(&mut self, alpha: f32) {
        for v in self.data.iter_mut() {
            *v *= alpha;
        }
    }

    /// Scale each column `c` by `s[c]` (i.e. `self * diag(s)`), in place.
    pub fn scale_cols_in_place(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.cols, "scale_cols length");
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (v, &sc) in row.iter_mut().zip(s) {
                *v *= sc;
            }
        }
    }

    /// Scale each row `r` by `s[r]` (i.e. `diag(s) * self`), in place.
    pub fn scale_rows_in_place(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.rows, "scale_rows length");
        for r in 0..self.rows {
            let sc = s[r];
            for v in &mut self.data[r * self.cols..(r + 1) * self.cols] {
                *v *= sc;
            }
        }
    }

    /// Copy a sub-block `[r0..r0+h, c0..c0+w]`.
    pub fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        let mut out = Matrix::zeros(h, w);
        for r in 0..h {
            let src = &self.data[(r0 + r) * self.cols + c0..(r0 + r) * self.cols + c0 + w];
            out.row_mut(r).copy_from_slice(src);
        }
        out
    }

    /// Keep only the first `k` columns.
    pub fn take_cols(&self, k: usize) -> Matrix {
        self.block(0, 0, self.rows, k.min(self.cols))
    }

    /// Keep only the first `k` rows.
    pub fn take_rows(&self, k: usize) -> Matrix {
        self.block(0, 0, k.min(self.rows), self.cols)
    }

    // ------------------------------------------------------------------
    // Products (thin wrappers over `gemm`)
    // ------------------------------------------------------------------

    /// `self · other` using the fastest available dense kernel.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        crate::linalg::gemm::gemm_blocked(self, other)
            .expect("matmul: inner dimensions must agree")
    }

    /// `self · otherᵀ` without materializing the transpose.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt inner dim");
        let m = self.rows;
        let n = other.rows;
        let k = self.cols;
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for (j, o) in out_row.iter_mut().enumerate().take(n) {
                let b_row = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for t in 0..k {
                    acc += a_row[t] * b_row[t];
                }
                *o = acc;
            }
        }
        out
    }

    /// `selfᵀ · other` without materializing the transpose.
    pub fn matmul_tn(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_tn inner dim");
        let m = self.cols;
        let n = other.cols;
        let k = self.rows;
        let mut out = Matrix::zeros(m, n);
        for t in 0..k {
            let a_row = self.row(t);
            let b_row = other.row(t);
            for i in 0..m {
                let a = a_row[i];
                if a == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[i * n..(i + 1) * n];
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols, "matvec dim");
        (0..self.rows)
            .map(|r| {
                self.row(r)
                    .iter()
                    .zip(x)
                    .map(|(a, b)| a * b)
                    .sum::<f32>()
            })
            .collect()
    }

    /// `selfᵀ x` without materializing the transpose.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.rows, "matvec_t dim");
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(r)) {
                *o += xr * a;
            }
        }
        out
    }

    // ------------------------------------------------------------------
    // Norms / comparisons
    // ------------------------------------------------------------------

    /// Squared Frobenius norm.
    pub fn sq_frobenius_norm(&self) -> f32 {
        // Accumulate in f64: the N=2048 benches overflow f32 granularity.
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() as f32
    }

    /// Frobenius norm.
    pub fn frobenius_norm(&self) -> f32 {
        self.sq_frobenius_norm().sqrt()
    }

    /// `‖self − other‖_F / ‖other‖_F` — the relative-error metric used in
    /// the paper's §5.4.
    pub fn rel_frobenius_distance(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape(), "rel_frobenius_distance shape");
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            let d = (*a - *b) as f64;
            num += d * d;
            den += (*b as f64) * (*b as f64);
        }
        if den == 0.0 {
            return if num == 0.0 { 0.0 } else { f32::INFINITY };
        }
        (num / den).sqrt() as f32
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// True if every entry is finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }

    fn check_same_shape(&self, op: &'static str, other: &Matrix) -> Result<()> {
        if self.shape() != other.shape() {
            return Err(Error::ShapeMismatch {
                op,
                lhs: self.shape(),
                rhs: other.shape(),
            });
        }
        Ok(())
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg64 {
        Pcg64::seeded(1234)
    }

    #[test]
    fn zeros_and_eye() {
        let z = Matrix::zeros(3, 4);
        assert_eq!(z.shape(), (3, 4));
        assert!(z.data().iter().all(|&v| v == 0.0));
        let i = Matrix::eye(3);
        assert_eq!(i[(0, 0)], 1.0);
        assert_eq!(i[(0, 1)], 0.0);
    }

    #[test]
    fn from_vec_shape_check() {
        assert!(Matrix::from_vec(2, 2, vec![1.0; 4]).is_ok());
        assert!(Matrix::from_vec(2, 2, vec![1.0; 5]).is_err());
    }

    #[test]
    fn transpose_round_trip() {
        let m = Matrix::gaussian(17, 33, &mut rng());
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
    }

    #[test]
    fn transpose_entries() {
        let m = Matrix::from_fn(3, 2, |r, c| (r * 10 + c) as f32);
        let t = m.transpose();
        assert_eq!(t.shape(), (2, 3));
        assert_eq!(t[(1, 2)], m[(2, 1)]);
    }

    #[test]
    fn add_sub_axpy() {
        let mut r = rng();
        let a = Matrix::gaussian(5, 7, &mut r);
        let b = Matrix::gaussian(5, 7, &mut r);
        let s = a.add(&b).unwrap().sub(&b).unwrap();
        assert!(s.rel_frobenius_distance(&a) < 1e-6);
        let mut c = a.clone();
        c.axpy_in_place(2.0, &b).unwrap();
        let expect = a.add(&b).unwrap().add(&b).unwrap();
        assert!(c.rel_frobenius_distance(&expect) < 1e-6);
    }

    #[test]
    fn shape_mismatch_errors() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(3, 2);
        assert!(a.add(&b).is_err());
        assert!(a.sub(&b).is_err());
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let mut r = rng();
        let a = Matrix::gaussian(8, 6, &mut r);
        let b = Matrix::gaussian(9, 6, &mut r);
        let fast = a.matmul_nt(&b);
        let slow = a.matmul(&b.transpose());
        assert!(fast.rel_frobenius_distance(&slow) < 1e-5);
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let mut r = rng();
        let a = Matrix::gaussian(6, 8, &mut r);
        let b = Matrix::gaussian(6, 9, &mut r);
        let fast = a.matmul_tn(&b);
        let slow = a.transpose().matmul(&b);
        assert!(fast.rel_frobenius_distance(&slow) < 1e-5);
    }

    #[test]
    fn matvec_consistency() {
        let mut r = rng();
        let a = Matrix::gaussian(5, 4, &mut r);
        let x: Vec<f32> = (0..4).map(|i| i as f32 + 1.0).collect();
        let y = a.matvec(&x);
        let xm = Matrix::from_vec(4, 1, x.clone()).unwrap();
        let ym = a.matmul(&xm);
        for i in 0..5 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-5);
        }
        // matvec_t
        let z = a.matvec_t(&y);
        let zm = a.transpose().matvec(&y);
        for i in 0..4 {
            assert!((z[i] - zm[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn low_rank_has_given_rank() {
        let mut r = rng();
        let m = Matrix::low_rank(32, 24, 4, &mut r);
        let svd = crate::linalg::svd::jacobi_svd(&m).unwrap();
        // singular values beyond index 3 should be ~0
        assert!(svd.s[3] > 1e-3);
        assert!(svd.s[4] < 1e-3 * svd.s[0]);
    }

    #[test]
    fn with_spectrum_matches_requested_singular_values() {
        let mut r = rng();
        let sv = [8.0, 4.0, 2.0, 1.0];
        let m = Matrix::with_spectrum(20, 16, &sv, &mut r);
        let svd = crate::linalg::svd::jacobi_svd(&m).unwrap();
        for (i, &want) in sv.iter().enumerate() {
            assert!(
                (svd.s[i] - want).abs() / want < 1e-3,
                "sv[{i}] = {} want {want}",
                svd.s[i]
            );
        }
    }

    #[test]
    fn block_and_take() {
        let m = Matrix::from_fn(6, 6, |r, c| (r * 6 + c) as f32);
        let b = m.block(1, 2, 2, 3);
        assert_eq!(b.shape(), (2, 3));
        assert_eq!(b[(0, 0)], m[(1, 2)]);
        assert_eq!(b[(1, 2)], m[(2, 4)]);
        assert_eq!(m.take_cols(2).shape(), (6, 2));
        assert_eq!(m.take_rows(2).shape(), (2, 6));
    }

    #[test]
    fn scale_rows_cols() {
        let mut m = Matrix::from_fn(2, 3, |_, _| 1.0);
        m.scale_cols_in_place(&[1.0, 2.0, 3.0]);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        m.scale_rows_in_place(&[1.0, 10.0]);
        assert_eq!(m.row(1), &[10.0, 20.0, 30.0]);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_vec(1, 2, vec![3.0, 4.0]).unwrap();
        assert!((m.frobenius_norm() - 5.0).abs() < 1e-6);
        assert_eq!(m.max_abs(), 4.0);
        assert!(m.all_finite());
    }

    #[test]
    fn rel_distance_zero_for_equal() {
        let m = Matrix::gaussian(4, 4, &mut rng());
        assert_eq!(m.rel_frobenius_distance(&m), 0.0);
    }
}
