//! Thin (economy) QR factorization via Householder reflections.
//!
//! `A (m×n, m ≥ n)  =  Q (m×n, orthonormal columns) · R (n×n, upper
//! triangular)`. This is the orthonormalization primitive inside the
//! randomized SVD range finder and the Lanczos reorthogonalization — the
//! reproduction's equivalent of LAPACK `geqrf`/`orgqr`.

use crate::linalg::matrix::Matrix;

/// Result of a thin QR factorization.
pub struct QrFactors {
    /// m×n with orthonormal columns.
    pub q: Matrix,
    /// n×n upper triangular.
    pub r: Matrix,
}

/// Thin QR of `a` (requires `rows ≥ cols`; callers shrink first otherwise).
///
/// Implementation: in-place Householder on a working copy, then explicit
/// back-accumulation of Q applied to the first n columns of the identity.
pub fn qr_thin(a: &Matrix) -> QrFactors {
    let (m, n) = a.shape();
    assert!(m >= n, "qr_thin requires rows >= cols (got {m}x{n})");
    let mut work = a.clone();
    // Householder vectors are stored below the diagonal of `work`; betas here.
    let mut betas = vec![0.0f32; n];

    for j in 0..n {
        // Build the Householder vector for column j from work[j.., j].
        let mut sigma = 0.0f64;
        for i in j..m {
            let v = work[(i, j)] as f64;
            sigma += v * v;
        }
        let norm = sigma.sqrt() as f32;
        let x0 = work[(j, j)];
        if norm == 0.0 {
            betas[j] = 0.0;
            continue;
        }
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        // v = x - alpha * e1, normalized so v[0] = 1.
        let v0 = x0 - alpha;
        betas[j] = if v0 == 0.0 { 0.0 } else { -v0 / alpha };
        if v0 != 0.0 {
            let inv = 1.0 / v0;
            for i in (j + 1)..m {
                work[(i, j)] *= inv;
            }
        }
        work[(j, j)] = alpha;

        // Apply H = I - beta v vᵀ to the trailing columns.
        if betas[j] != 0.0 {
            for c in (j + 1)..n {
                // w = vᵀ * work[:, c]
                let mut w = work[(j, c)] as f64;
                for i in (j + 1)..m {
                    w += work[(i, j)] as f64 * work[(i, c)] as f64;
                }
                let bw = betas[j] as f64 * w;
                work[(j, c)] -= bw as f32;
                for i in (j + 1)..m {
                    let vij = work[(i, j)];
                    work[(i, c)] -= (bw * vij as f64) as f32;
                }
            }
        }
    }

    // Extract R.
    let mut r = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r[(i, j)] = work[(i, j)];
        }
    }

    // Accumulate Q = H_0 H_1 … H_{n-1} applied to I(:, 0..n), by applying
    // reflections in reverse order.
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for j in (0..n).rev() {
        let beta = betas[j];
        if beta == 0.0 {
            continue;
        }
        for c in 0..n {
            let mut w = q[(j, c)] as f64;
            for i in (j + 1)..m {
                w += work[(i, j)] as f64 * q[(i, c)] as f64;
            }
            let bw = beta as f64 * w;
            q[(j, c)] -= bw as f32;
            for i in (j + 1)..m {
                let vij = work[(i, j)];
                q[(i, c)] -= (bw * vij as f64) as f32;
            }
        }
    }

    QrFactors { q, r }
}

/// Orthonormalize the columns of `a` (returns only Q). Handles the
/// rows < cols case by truncating to the first `rows` columns.
pub fn orthonormalize(a: &Matrix) -> Matrix {
    if a.rows() >= a.cols() {
        qr_thin(a).q
    } else {
        qr_thin(&a.take_cols(a.rows())).q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::orthonormality_defect;
    use crate::linalg::rng::Pcg64;

    #[test]
    fn reconstructs_a() {
        let mut rng = Pcg64::seeded(21);
        for (m, n) in [(8, 8), (20, 5), (50, 50), (33, 17)] {
            let a = Matrix::gaussian(m, n, &mut rng);
            let QrFactors { q, r } = qr_thin(&a);
            let qr = q.matmul(&r);
            assert!(
                qr.rel_frobenius_distance(&a) < 1e-4,
                "reconstruction failed at {m}x{n}"
            );
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Pcg64::seeded(22);
        for (m, n) in [(10, 10), (40, 7), (64, 32)] {
            let a = Matrix::gaussian(m, n, &mut rng);
            let q = qr_thin(&a).q;
            assert!(
                orthonormality_defect(&q) < 1e-4,
                "Q not orthonormal at {m}x{n}"
            );
        }
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Pcg64::seeded(23);
        let a = Matrix::gaussian(12, 6, &mut rng);
        let r = qr_thin(&a).r;
        for i in 0..6 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn handles_rank_deficiency() {
        let mut rng = Pcg64::seeded(24);
        // rank-2 matrix, 10x4
        let a = Matrix::low_rank(10, 4, 2, &mut rng);
        let QrFactors { q, r } = qr_thin(&a);
        let qr = q.matmul(&r);
        assert!(qr.rel_frobenius_distance(&a) < 1e-4);
        assert!(q.all_finite());
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(5, 3);
        let QrFactors { q, r } = qr_thin(&a);
        assert!(q.all_finite());
        assert!(r.all_finite());
        assert!(q.matmul(&r).frobenius_norm() < 1e-6);
    }

    #[test]
    fn orthonormalize_wide_truncates() {
        let mut rng = Pcg64::seeded(25);
        let a = Matrix::gaussian(4, 9, &mut rng);
        let q = orthonormalize(&a);
        assert_eq!(q.shape(), (4, 4));
        assert!(orthonormality_defect(&q) < 1e-4);
    }
}
