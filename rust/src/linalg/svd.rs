//! Exact SVD via the one-sided Jacobi method.
//!
//! `A (m×n) = U (m×k) · diag(s) (k) · Vᵀ (k×n)`, `k = min(m, n)`, singular
//! values in non-increasing order. One-sided Jacobi is chosen over
//! Golub–Kahan because it is simple, numerically robust (it computes small
//! singular values to high relative accuracy) and needs no bidiagonal QR
//! machinery. It is O(mn²) per sweep — fine as the *exact* reference the
//! paper's "SVD" decomposition option maps to; the fast path at scale is
//! [`crate::linalg::rsvd`].

use crate::error::{Error, Result};
use crate::linalg::matrix::Matrix;

/// Singular value decomposition result (thin form).
pub struct Svd {
    /// m×k left singular vectors (orthonormal columns).
    pub u: Matrix,
    /// k singular values, non-increasing.
    pub s: Vec<f32>,
    /// k×n — this is Vᵀ, not V.
    pub vt: Matrix,
}

impl Svd {
    /// Reconstruct `U diag(s) Vᵀ` (testing / error analysis).
    pub fn reconstruct(&self) -> Matrix {
        let mut us = self.u.clone();
        us.scale_cols_in_place(&self.s);
        us.matmul(&self.vt)
    }

    /// Truncate to the leading `r` components.
    pub fn truncate(&self, r: usize) -> Svd {
        let r = r.min(self.s.len());
        Svd {
            u: self.u.take_cols(r),
            s: self.s[..r].to_vec(),
            vt: self.vt.take_rows(r),
        }
    }
}

/// Maximum Jacobi sweeps before declaring non-convergence.
const MAX_SWEEPS: usize = 60;

/// One-sided Jacobi SVD.
///
/// Works on `G = A` (m ≥ n) or `G = Aᵀ` (m < n, result transposed back).
/// Repeatedly applies Givens rotations on column pairs of `G` until all
/// pairs are numerically orthogonal; then `‖g_j‖ = σ_j`, `g_j/σ_j = u_j`,
/// and the accumulated rotations form `V`.
pub fn jacobi_svd(a: &Matrix) -> Result<Svd> {
    let (m, n) = a.shape();
    if m < n {
        // Decompose Aᵀ = U Σ Vᵀ  ⇒  A = V Σ Uᵀ.
        let t = jacobi_svd(&a.transpose())?;
        return Ok(Svd {
            u: t.vt.transpose(),
            s: t.s,
            vt: t.u.transpose(),
        });
    }

    let mut g = a.clone(); // m×n, columns will converge to σ_j u_j
    let mut v = Matrix::eye(n);
    let eps = 1e-7_f64;

    // Frobenius scale for the convergence threshold.
    let scale = (a.sq_frobenius_norm() as f64 / (n.max(1) as f64)).sqrt() + 1e-30;
    let tol = eps * scale * scale;

    let mut converged = false;
    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram block for columns p, q.
                let mut app = 0.0f64;
                let mut aqq = 0.0f64;
                let mut apq = 0.0f64;
                for i in 0..m {
                    let gp = g[(i, p)] as f64;
                    let gq = g[(i, q)] as f64;
                    app += gp * gp;
                    aqq += gq * gq;
                    apq += gp * gq;
                }
                off = off.max(apq.abs());
                if apq.abs() <= tol || apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation that annihilates apq.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let gp = g[(i, p)];
                    let gq = g[(i, q)];
                    g[(i, p)] = cf * gp - sf * gq;
                    g[(i, q)] = sf * gp + cf * gq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = cf * vp - sf * vq;
                    v[(i, q)] = sf * vp + cf * vq;
                }
            }
        }
        if off <= tol {
            converged = true;
            break;
        }
    }
    if !converged {
        // One-sided Jacobi degrades gracefully; treat near-convergence as ok
        // unless the residual is egregious.
        let mut worst = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let mut apq = 0.0f64;
                for i in 0..m {
                    apq += g[(i, p)] as f64 * g[(i, q)] as f64;
                }
                worst = worst.max(apq.abs());
            }
        }
        if worst > 1e-3 * scale * scale {
            return Err(Error::NoConvergence {
                what: "jacobi_svd",
                iters: MAX_SWEEPS,
            });
        }
    }

    // Extract singular values and U.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigmas = vec![0.0f32; n];
    for (j, s) in sigmas.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for i in 0..m {
            let gij = g[(i, j)] as f64;
            acc += gij * gij;
        }
        *s = acc.sqrt() as f32;
    }
    order.sort_by(|&a, &b| sigmas[b].partial_cmp(&sigmas[a]).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut s_sorted = vec![0.0f32; n];
    let mut vt = Matrix::zeros(n, n);
    for (dst, &src) in order.iter().enumerate() {
        let sv = sigmas[src];
        s_sorted[dst] = sv;
        if sv > 1e-30 {
            let inv = 1.0 / sv;
            for i in 0..m {
                u[(i, dst)] = g[(i, src)] * inv;
            }
        }
        for i in 0..n {
            vt[(dst, i)] = v[(i, src)];
        }
    }

    Ok(Svd {
        u,
        s: s_sorted,
        vt,
    })
}

/// Truncated exact SVD: the best rank-`r` approximation (Eckart–Young).
pub fn truncated_svd(a: &Matrix, r: usize) -> Result<Svd> {
    let k = a.rows().min(a.cols());
    if r == 0 || r > k {
        return Err(Error::InvalidRank {
            requested: r,
            max: k,
        });
    }
    Ok(jacobi_svd(a)?.truncate(r))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::orthonormality_defect;
    use crate::linalg::rng::Pcg64;

    #[test]
    fn reconstructs_exactly() {
        let mut rng = Pcg64::seeded(31);
        for (m, n) in [(6, 6), (12, 5), (5, 12), (20, 20)] {
            let a = Matrix::gaussian(m, n, &mut rng);
            let svd = jacobi_svd(&a).unwrap();
            assert!(
                svd.reconstruct().rel_frobenius_distance(&a) < 1e-4,
                "reconstruction failed at {m}x{n}"
            );
        }
    }

    #[test]
    fn factors_are_orthonormal() {
        let mut rng = Pcg64::seeded(32);
        let a = Matrix::gaussian(15, 9, &mut rng);
        let svd = jacobi_svd(&a).unwrap();
        assert!(orthonormality_defect(&svd.u) < 1e-4);
        assert!(orthonormality_defect(&svd.vt.transpose()) < 1e-4);
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let mut rng = Pcg64::seeded(33);
        let a = Matrix::gaussian(10, 14, &mut rng);
        let svd = jacobi_svd(&a).unwrap();
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(svd.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = Matrix::zeros(4, 4);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 9.0;
        a[(2, 2)] = 1.0;
        a[(3, 3)] = 5.0;
        let svd = jacobi_svd(&a).unwrap();
        let want = [9.0, 5.0, 3.0, 1.0];
        for (got, want) in svd.s.iter().zip(want) {
            assert!((got - want).abs() < 1e-4, "got {got} want {want}");
        }
    }

    #[test]
    fn eckart_young_optimality() {
        // Truncated SVD must beat any other rank-r factorization we can
        // easily construct (here: the first r columns/rows outer product).
        let mut rng = Pcg64::seeded(34);
        let sv = [10.0, 6.0, 3.0, 1.5, 0.8, 0.3];
        let a = Matrix::with_spectrum(16, 12, &sv, &mut rng);
        let r = 3;
        let t = truncated_svd(&a, r).unwrap();
        let err = t.reconstruct().sub(&a).unwrap().frobenius_norm();
        // Theoretical optimum: sqrt(sum of squared discarded svs).
        let opt = (1.5f32 * 1.5 + 0.8 * 0.8 + 0.3 * 0.3).sqrt();
        assert!((err - opt).abs() / opt < 0.02, "err {err} vs opt {opt}");
    }

    #[test]
    fn truncate_rank_bounds() {
        let a = Matrix::eye(4);
        assert!(truncated_svd(&a, 0).is_err());
        assert!(truncated_svd(&a, 5).is_err());
        assert!(truncated_svd(&a, 4).is_ok());
    }

    #[test]
    fn zero_matrix_is_fine() {
        let a = Matrix::zeros(6, 4);
        let svd = jacobi_svd(&a).unwrap();
        assert!(svd.s.iter().all(|&s| s == 0.0));
        assert!(svd.reconstruct().frobenius_norm() < 1e-6);
    }

    #[test]
    fn rank_one_matrix() {
        let mut rng = Pcg64::seeded(35);
        let a = Matrix::low_rank(10, 8, 1, &mut rng);
        let svd = jacobi_svd(&a).unwrap();
        assert!(svd.s[0] > 0.0);
        assert!(svd.s[1] < 1e-4 * svd.s[0]);
    }
}
