//! Golub–Kahan–Lanczos bidiagonalization for truncated SVD.
//!
//! The paper's §3.1 mentions "randomized SVD or Lanczos methods" as the
//! truncated-decomposition options; this is the Lanczos one. We run k + q
//! bidiagonalization steps with full reorthogonalization (the matrices here
//! are small enough that the O(mk²) reorthogonalization is cheap and it
//! removes the classic ghost-eigenvalue pathology), then take the SVD of
//! the small bidiagonal matrix via the existing Jacobi kernel.

use crate::error::{Error, Result};
use crate::linalg::matrix::Matrix;
use crate::linalg::norms::{dot, normalize};
use crate::linalg::rng::Pcg64;
use crate::linalg::svd::{jacobi_svd, Svd};

/// Truncated SVD via Lanczos bidiagonalization.
///
/// `extra` is the number of additional Lanczos steps beyond the target rank
/// (analogous to rSVD oversampling; 4–8 is plenty for decaying spectra).
pub fn lanczos_svd(a: &Matrix, r: usize, extra: usize, seed: u64) -> Result<Svd> {
    let (m, n) = a.shape();
    let kmax = m.min(n);
    if r == 0 || r > kmax {
        return Err(Error::InvalidRank {
            requested: r,
            max: kmax,
        });
    }
    let steps = (r + extra).min(kmax);

    // Lanczos vectors: U (m × steps), V (n × steps); bidiagonal alphas/betas.
    let mut us: Vec<Vec<f32>> = Vec::with_capacity(steps);
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(steps);
    let mut alphas = Vec::with_capacity(steps);
    let mut betas = Vec::with_capacity(steps);

    let mut rng = Pcg64::seeded(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_gaussian(&mut v);
    normalize(&mut v, 1e-30);

    let mut beta = 0.0f32;
    let mut u_prev: Vec<f32> = vec![0.0; m];

    for j in 0..steps {
        // u_j = A v_j - beta_{j-1} u_{j-1}
        let mut u = a.matvec(&v);
        if j > 0 {
            for (ui, &pi) in u.iter_mut().zip(&u_prev) {
                *ui -= beta * pi;
            }
        }
        // Full reorthogonalization against previous u's.
        for prev in &us {
            let c = dot(&u, prev);
            for (ui, &pi) in u.iter_mut().zip(prev) {
                *ui -= c * pi;
            }
        }
        let alpha = normalize(&mut u, 1e-30);
        if alpha == 0.0 {
            break; // invariant subspace found
        }
        us.push(u.clone());
        vs.push(v.clone());
        alphas.push(alpha);

        // v_{j+1} = Aᵀ u_j - alpha_j v_j
        let mut vnext = a.matvec_t(&u);
        for (vi, &ci) in vnext.iter_mut().zip(&v) {
            *vi -= alpha * ci;
        }
        for prev in &vs {
            let c = dot(&vnext, prev);
            for (vi, &pi) in vnext.iter_mut().zip(prev) {
                *vi -= c * pi;
            }
        }
        beta = normalize(&mut vnext, 1e-30);
        betas.push(beta);
        if beta == 0.0 {
            break;
        }
        u_prev = u;
        v = vnext;
    }

    let k = alphas.len();
    if k == 0 {
        // A is (numerically) zero.
        return Ok(Svd {
            u: Matrix::zeros(m, r),
            s: vec![0.0; r],
            vt: Matrix::zeros(r, n),
        });
    }

    // Build the small upper-bidiagonal matrix B (k×k): the recurrence
    // `u_j = A v_j − β_{j−1} u_{j−1}`, `v_{j+1} = Aᵀ u_j − α_j v_j` yields
    // A V_k = U_k B with B[j,j] = alpha_j and B[j,j+1] = beta_j.
    let mut b = Matrix::zeros(k, k);
    for j in 0..k {
        b[(j, j)] = alphas[j];
        if j + 1 < k {
            b[(j, j + 1)] = betas[j];
        }
    }
    let small = jacobi_svd(&b)?;

    // Assemble U = Us · U_B, Vt = V_Bᵀ · Vsᵀ, truncated to r.
    let rr = r.min(k);
    let mut u_out = Matrix::zeros(m, rr);
    for c in 0..rr {
        for (j, uj) in us.iter().enumerate() {
            let w = small.u[(j, c)];
            if w == 0.0 {
                continue;
            }
            for i in 0..m {
                u_out[(i, c)] += w * uj[i];
            }
        }
    }
    let mut vt_out = Matrix::zeros(rr, n);
    for rrow in 0..rr {
        for (j, vj) in vs.iter().enumerate() {
            let w = small.vt[(rrow, j)];
            if w == 0.0 {
                continue;
            }
            for i in 0..n {
                vt_out[(rrow, i)] += w * vj[i];
            }
        }
    }
    let mut s = small.s[..rr].to_vec();
    s.resize(r.min(kmax), 0.0);

    Ok(Svd {
        u: u_out,
        s,
        vt: vt_out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::orthonormality_defect;

    #[test]
    fn recovers_exact_low_rank() {
        let mut rng = Pcg64::seeded(51);
        let a = Matrix::low_rank(36, 28, 4, &mut rng);
        let f = lanczos_svd(&a, 4, 6, 7).unwrap();
        let err = f.reconstruct().rel_frobenius_distance(&a);
        assert!(err < 1e-3, "err {err}");
    }

    #[test]
    fn matches_jacobi_on_leading_singular_values() {
        let mut rng = Pcg64::seeded(52);
        let sv = [9.0, 5.0, 2.5, 1.2, 0.6, 0.3, 0.1];
        let a = Matrix::with_spectrum(30, 24, &sv, &mut rng);
        let f = lanczos_svd(&a, 3, 8, 11).unwrap();
        for (i, &want) in sv[..3].iter().enumerate() {
            assert!(
                (f.s[i] - want).abs() / want < 0.02,
                "sv[{i}] got {} want {want}",
                f.s[i]
            );
        }
    }

    #[test]
    fn factors_orthonormal() {
        let mut rng = Pcg64::seeded(53);
        let a = Matrix::gaussian(25, 18, &mut rng);
        let f = lanczos_svd(&a, 6, 6, 13).unwrap();
        assert!(orthonormality_defect(&f.u) < 1e-2);
        assert!(orthonormality_defect(&f.vt.transpose()) < 1e-2);
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(10, 6);
        let f = lanczos_svd(&a, 3, 2, 17).unwrap();
        assert!(f.s.iter().all(|&s| s < 1e-6));
    }

    #[test]
    fn rank_bounds() {
        let a = Matrix::eye(5);
        assert!(lanczos_svd(&a, 0, 2, 1).is_err());
        assert!(lanczos_svd(&a, 6, 2, 1).is_err());
    }

    #[test]
    fn early_breakdown_on_exact_rank() {
        // rank-2 matrix with steps > 2: Lanczos must stop gracefully.
        let mut rng = Pcg64::seeded(54);
        let a = Matrix::low_rank(16, 16, 2, &mut rng);
        let f = lanczos_svd(&a, 5, 5, 19).unwrap();
        assert!(f.s[0] > 0.0);
        assert!(f.s[2] < 1e-3 * f.s[0].max(1e-9));
        assert!(f.u.all_finite() && f.vt.all_finite());
    }
}
