//! Packed-operand plane: BLIS-style panel packing + a per-thread scratch
//! arena — the memory side of the blocked GEMM hot path.
//!
//! Three pieces:
//!
//! - [`PackedA`]: the whole A operand re-laid-out into MC×KC blocks whose
//!   interior is *micro-panel-major* — for each k-step `t`, the MR values
//!   `A[i..i+MR][t]` sit contiguously, so the micro-kernel's row broadcasts
//!   all come from one cache line instead of MR strided ones.
//! - [`PackedB`]: the whole B operand as KC×NC row-major panels (byte-wise
//!   the layout the legacy per-call `pack_b` produced), packed **once** and
//!   then shared read-only — across the K loop, across output tiles, and
//!   across shard workers ([`crate::shard`]). Reuse is observable via
//!   [`PackedB::reuse`] and surfaces as the `pack.reuse` metric.
//! - the **arena** (`checkout_zeroed` / `checkout_stale` / `recycle`): a
//!   per-thread recycling pool of `f32` buffers so steady-state serving
//!   re-uses pack buffers, factor-chain intermediates and kernel outputs
//!   instead of allocating on every request. [`stats`] exposes per-thread
//!   counters for the allocation-free tests.
//!
//! Both packed types also have `pack_quantized` constructors that decode
//! FP8/F16/BF16 payloads **directly into the packed layout** (fused
//! decode-into-pack): one pass over the codec bytes, no full-matrix f32
//! materialization in between. The decoded values are bit-identical to
//! [`crate::fp8::dequantize`]'s, so fused and unfused paths produce the
//! same product bits.
//!
//! Packing is a pure re-layout: the kernels read identical values in an
//! identical order from the packed buffers, so every packed path is
//! bitwise-equal to its unpacked counterpart by construction (asserted by
//! `rust/tests/pack_equivalence.rs`).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::fp8::quantize::{decode_row_segment, QuantizedTensor};
use crate::linalg::matrix::Matrix;

/// Rows per narrow micro-panel (the legacy 4-row register tile).
pub const MR: usize = 4;

/// Rows per wide micro-panel (the widened 8×NR register tile; see
/// [`crate::linalg::gemm`] for why widening preserves bitwise results).
pub const MR_WIDE: usize = 8;

// ---------------------------------------------------------------------------
// Per-thread scratch arena
// ---------------------------------------------------------------------------

/// Per-thread arena counters (see [`stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Checkouts served by growing or freshly allocating a buffer.
    pub fresh_allocs: u64,
    /// Total checkouts (zeroed + stale).
    pub checkouts: u64,
    /// Buffers returned via [`recycle`].
    pub recycled: u64,
}

struct Arena {
    free: Vec<Vec<f32>>,
    stats: ArenaStats,
}

impl Arena {
    /// Pop the best-fitting free buffer (smallest capacity ≥ `len`), or a
    /// fresh one. Growing an undersized buffer counts as a fresh alloc.
    fn take(&mut self, len: usize) -> Vec<f32> {
        self.stats.checkouts += 1;
        let mut best: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            if b.capacity() >= len && best.is_none_or(|j| b.capacity() < self.free[j].capacity()) {
                best = Some(i);
            }
        }
        match best {
            Some(i) => self.free.swap_remove(i),
            None => {
                self.stats.fresh_allocs += 1;
                // Grow a free buffer's storage if one exists (growing
                // beats leaking it), else start fresh. `reserve` is
                // relative to `len()`, so clear first to guarantee the
                // resulting capacity covers the request.
                match self.free.pop() {
                    Some(mut b) => {
                        b.clear();
                        b.reserve(len);
                        b
                    }
                    None => Vec::with_capacity(len),
                }
            }
        }
    }
}

thread_local! {
    static ARENA: RefCell<Arena> = RefCell::new(Arena {
        free: Vec::new(),
        stats: ArenaStats::default(),
    });
}

/// Check out a buffer of exactly `len` zeros. Allocation-free when a
/// recycled buffer with enough capacity exists (the zero-fill is a memset,
/// not an allocation).
pub fn checkout_zeroed(len: usize) -> Vec<f32> {
    let mut b = ARENA.with(|a| a.borrow_mut().take(len));
    b.clear();
    b.resize(len, 0.0);
    b
}

/// Check out a buffer of exactly `len` **unspecified** (stale) contents.
/// Only for outputs that are provably fully written before being read —
/// in debug builds the buffer is poisoned with NaN so a violated contract
/// shows up in the equivalence tests instead of silently reusing stale
/// data.
pub fn checkout_stale(len: usize) -> Vec<f32> {
    let mut b = ARENA.with(|a| a.borrow_mut().take(len));
    // Stale contents are *initialized* memory from a previous checkout —
    // safe to expose; only its values are unspecified.
    if b.len() > len {
        b.truncate(len);
    } else {
        b.resize(len, 0.0);
    }
    if cfg!(debug_assertions) {
        b.fill(f32::NAN);
    }
    b
}

/// Max buffers a thread's arena retains (burst-of-odd-shapes bound).
const ARENA_MAX_BUFFERS: usize = 16;

/// Max total capacity a thread's arena retains, in f32 elements (256 MiB).
/// Idle scratch beyond this is released largest-first: a thread that once
/// served huge GEMMs must not pin their buffers forever after traffic
/// shifts to small shapes. Under *sustained* large traffic the big
/// buffers are checked out (not in the free list) most of the time, so
/// steady-state reuse is unaffected.
const ARENA_MAX_ELEMS: usize = 64 << 20;

/// Return a buffer to this thread's arena for reuse.
pub fn recycle(buf: Vec<f32>) {
    ARENA.with(|a| {
        let mut a = a.borrow_mut();
        a.stats.recycled += 1;
        a.free.push(buf);
        // Count bound: drop the smallest buffers beyond the cap (they are
        // the cheapest to re-create).
        if a.free.len() > ARENA_MAX_BUFFERS {
            a.free.sort_by_key(|b| b.capacity());
            let excess = a.free.len() - ARENA_MAX_BUFFERS;
            a.free.drain(..excess);
        }
        // Byte bound: release largest-first until under the cap.
        let mut total: usize = a.free.iter().map(|b| b.capacity()).sum();
        while total > ARENA_MAX_ELEMS {
            let largest = a
                .free
                .iter()
                .enumerate()
                .max_by_key(|(_, b)| b.capacity())
                .map(|(i, _)| i)
                .expect("non-empty while over budget");
            total -= a.free.swap_remove(largest).capacity();
        }
    });
}

/// Snapshot this thread's arena counters.
pub fn stats() -> ArenaStats {
    ARENA.with(|a| a.borrow().stats)
}

// ---------------------------------------------------------------------------
// PackedB: KC×NC panels, packed once, shared read-only
// ---------------------------------------------------------------------------

/// The B operand packed into KC×NC row-major panels (pack-once/reuse-many).
///
/// Panel `(pc, jc)` (element offsets, multiples of `kc`/`nc`) lives at
/// buffer offset `pc·n + kc_actual·jc` and holds `kc_actual × nc_actual`
/// values row-major — byte-identical to what the legacy per-tile `pack_b`
/// produced for the same panel, which is what makes packed and unpacked
/// kernels bitwise-equal.
pub struct PackedB {
    k: usize,
    n: usize,
    kc: usize,
    nc: usize,
    buf: Vec<f32>,
    uses: AtomicU64,
}

impl PackedB {
    /// Pack all of `b` (one pass). The buffer comes from the arena.
    pub fn pack(b: &Matrix, kc: usize, nc: usize) -> PackedB {
        let (k, n) = b.shape();
        let mut out = Self::shell(k, n, kc, nc);
        let bd = b.data();
        for pc in (0..k).step_by(kc) {
            let kcur = kc.min(k - pc);
            for jc in (0..n).step_by(nc) {
                let ncur = nc.min(n - jc);
                let off = pc * n + kcur * jc;
                for t in 0..kcur {
                    let src = &bd[(pc + t) * n + jc..(pc + t) * n + jc + ncur];
                    out.buf[off + t * ncur..off + t * ncur + ncur].copy_from_slice(src);
                }
            }
        }
        out
    }

    /// Fused decode-into-pack: decode `q`'s codec bytes straight into the
    /// panel layout (one pass, no dense f32 intermediate). Panel values
    /// are bit-identical to `pack(&dequantize(q), kc, nc)`.
    pub fn pack_quantized(q: &QuantizedTensor, kc: usize, nc: usize) -> PackedB {
        let (k, n) = q.shape;
        let mut out = Self::shell(k, n, kc, nc);
        for pc in (0..k).step_by(kc) {
            let kcur = kc.min(k - pc);
            for jc in (0..n).step_by(nc) {
                let ncur = nc.min(n - jc);
                let off = pc * n + kcur * jc;
                for t in 0..kcur {
                    decode_row_segment(q, pc + t, jc, &mut out.buf[off + t * ncur..off + t * ncur + ncur]);
                }
            }
        }
        out
    }

    fn shell(k: usize, n: usize, kc: usize, nc: usize) -> PackedB {
        assert!(kc > 0 && nc > 0, "PackedB: kc/nc must be positive");
        PackedB {
            k,
            n,
            kc,
            nc,
            buf: checkout_stale(k * n),
            uses: AtomicU64::new(0),
        }
    }

    /// Inner dimension (B rows).
    pub fn k(&self) -> usize {
        self.k
    }

    /// B columns.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Panel height (the KC cache block).
    pub fn kc(&self) -> usize {
        self.kc
    }

    /// Panel width (the NC cache block).
    pub fn nc(&self) -> usize {
        self.nc
    }

    /// Number of packed panels.
    pub fn panels(&self) -> usize {
        self.k.div_ceil(self.kc) * self.n.div_ceil(self.nc)
    }

    /// Borrow panel `(pc, jc)` (element offsets; `pc % kc == 0`,
    /// `jc % nc == 0`). Counts one use for reuse accounting.
    pub fn panel(&self, pc: usize, jc: usize) -> &[f32] {
        debug_assert!(pc % self.kc == 0 && jc % self.nc == 0, "unaligned panel");
        debug_assert!(pc < self.k && jc < self.n, "panel out of range");
        self.uses.fetch_add(1, Ordering::Relaxed);
        let kcur = self.kc.min(self.k - pc);
        let ncur = self.nc.min(self.n - jc);
        let off = pc * self.n + kcur * jc;
        &self.buf[off..off + kcur * ncur]
    }

    /// Panel fetches so far.
    pub fn uses(&self) -> u64 {
        self.uses.load(Ordering::Relaxed)
    }

    /// Panel fetches beyond the first per panel — the packs a repacking
    /// implementation would have paid again (the `pack.reuse` metric).
    pub fn reuse(&self) -> u64 {
        self.uses().saturating_sub(self.panels() as u64)
    }

    /// Give the buffer back to this thread's arena (optional; dropping is
    /// also fine, the memory is just not reused then).
    pub fn recycle(self) {
        recycle(self.buf);
    }

    /// Trim the backing buffer to exactly `k·n` elements. Call before
    /// storing a packed operand long-term (e.g. a cache entry): the
    /// arena hands out best-fit buffers whose *capacity* can exceed the
    /// panels' size, and a resident entry must not pin that slack.
    pub fn shrink_to_fit(&mut self) {
        self.buf.shrink_to_fit();
    }

    /// Bytes of heap this packing actually pins: the buffer *capacity*
    /// (which [`shrink_to_fit`](PackedB::shrink_to_fit) trims toward
    /// `k·n`), not the `k·n` estimate. Byte-budgeted caches must charge
    /// this — the estimate undercounts whenever arena slack survives.
    pub fn resident_bytes(&self) -> usize {
        self.buf.capacity() * std::mem::size_of::<f32>()
    }
}

// ---------------------------------------------------------------------------
// PackedA: MC×KC blocks, micro-panel-major
// ---------------------------------------------------------------------------

/// The A operand packed into MC×KC blocks, micro-panel-major.
///
/// Block `(r, pc)` lives at buffer offset `r·k + mc_actual·pc`. Its rows
/// decompose into zones mirroring the macro-kernel's traversal — as many
/// [`MR_WIDE`]-row micro-panels as fit, then at most one [`MR`]-row panel,
/// then the `< MR` remainder rows stored row-major. Micro-panel layout is
/// `panel[t·R + j] = A[row0 + j][pc + t]`; the uniform arithmetic makes
/// every zone addressable as `block[i·kc_actual ..]` for local row `i`.
pub struct PackedA {
    m: usize,
    k: usize,
    mc: usize,
    kc: usize,
    buf: Vec<f32>,
    uses: AtomicU64,
}

impl PackedA {
    /// Pack all of `a` (one pass). The buffer comes from the arena.
    pub fn pack(a: &Matrix, mc: usize, kc: usize) -> PackedA {
        let (m, k) = a.shape();
        let mut out = Self::shell(m, k, mc, kc);
        let ad = a.data();
        for r0 in (0..m).step_by(mc) {
            let mcur = mc.min(m - r0);
            for pc in (0..k).step_by(kc) {
                let kcur = kc.min(k - pc);
                let off = r0 * k + mcur * pc;
                let block = &mut out.buf[off..off + mcur * kcur];
                pack_a_block(block, mcur, kcur, |i, dest| {
                    let row = &ad[(r0 + i) * k + pc..(r0 + i) * k + pc + kcur];
                    dest.copy_from_slice(row);
                });
            }
        }
        out
    }

    /// Fused decode-into-pack for a quantized A (see
    /// [`PackedB::pack_quantized`]).
    pub fn pack_quantized(q: &QuantizedTensor, mc: usize, kc: usize) -> PackedA {
        let (m, k) = q.shape;
        let mut out = Self::shell(m, k, mc, kc);
        for r0 in (0..m).step_by(mc) {
            let mcur = mc.min(m - r0);
            for pc in (0..k).step_by(kc) {
                let kcur = kc.min(k - pc);
                let off = r0 * k + mcur * pc;
                let block = &mut out.buf[off..off + mcur * kcur];
                pack_a_block(block, mcur, kcur, |i, dest| {
                    decode_row_segment(q, r0 + i, pc, dest);
                });
            }
        }
        out
    }

    fn shell(m: usize, k: usize, mc: usize, kc: usize) -> PackedA {
        assert!(mc > 0 && kc > 0, "PackedA: mc/kc must be positive");
        PackedA {
            m,
            k,
            mc,
            kc,
            buf: checkout_stale(m * k),
            uses: AtomicU64::new(0),
        }
    }

    /// A rows.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Inner dimension (A columns).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Block height (the MC cache block).
    pub fn mc(&self) -> usize {
        self.mc
    }

    /// Block depth (the KC cache block).
    pub fn kc(&self) -> usize {
        self.kc
    }

    /// Number of packed blocks.
    pub fn blocks(&self) -> usize {
        self.m.div_ceil(self.mc) * self.k.div_ceil(self.kc)
    }

    /// Borrow block `(r, pc)` (element offsets; `r % mc == 0`,
    /// `pc % kc == 0`). Counts one use for reuse accounting.
    pub fn block(&self, r: usize, pc: usize) -> &[f32] {
        debug_assert!(r % self.mc == 0 && pc % self.kc == 0, "unaligned block");
        debug_assert!(r < self.m && pc < self.k, "block out of range");
        self.uses.fetch_add(1, Ordering::Relaxed);
        let mcur = self.mc.min(self.m - r);
        let kcur = self.kc.min(self.k - pc);
        let off = r * self.k + mcur * pc;
        &self.buf[off..off + mcur * kcur]
    }

    /// Block fetches so far.
    pub fn uses(&self) -> u64 {
        self.uses.load(Ordering::Relaxed)
    }

    /// Block fetches beyond the first per block.
    pub fn reuse(&self) -> u64 {
        self.uses().saturating_sub(self.blocks() as u64)
    }

    /// Give the buffer back to this thread's arena.
    pub fn recycle(self) {
        recycle(self.buf);
    }
}

/// Write one MC×KC block in the zoned micro-panel-major layout. `fetch`
/// copies `A[row0 + i][pc .. pc + kcur]` into its destination; the scalar
/// remainder zone writes rows in place, the micro zones scatter through a
/// stack row buffer.
fn pack_a_block(block: &mut [f32], mcur: usize, kcur: usize, mut fetch: impl FnMut(usize, &mut [f32])) {
    let mut rowbuf = checkout_stale(kcur);
    let mut scatter = |block: &mut [f32], i0: usize, r: usize, rowbuf: &mut [f32]| {
        for j in 0..r {
            fetch(i0 + j, rowbuf);
            for (t, &v) in rowbuf.iter().enumerate() {
                block[i0 * kcur + t * r + j] = v;
            }
        }
    };
    let mut i = 0;
    while i + MR_WIDE <= mcur {
        scatter(block, i, MR_WIDE, &mut rowbuf);
        i += MR_WIDE;
    }
    if i + MR <= mcur {
        scatter(block, i, MR, &mut rowbuf);
        i += MR;
    }
    while i < mcur {
        fetch(i, &mut block[i * kcur..(i + 1) * kcur]);
        i += 1;
    }
    recycle(rowbuf);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::{dequantize, quantize, StorageFormat};
    use crate::linalg::rng::Pcg64;

    #[test]
    fn arena_recycles_buffers() {
        let before = stats();
        let b = checkout_zeroed(1000);
        assert!(b.iter().all(|&v| v == 0.0));
        recycle(b);
        let b2 = checkout_zeroed(900);
        assert!(b2.capacity() >= 1000, "recycled buffer reused");
        assert!(b2.iter().all(|&v| v == 0.0));
        recycle(b2);
        let after = stats();
        assert_eq!(after.checkouts - before.checkouts, 2);
        assert_eq!(after.recycled - before.recycled, 2);
        // The second checkout was served from the free list.
        assert_eq!(after.fresh_allocs - before.fresh_allocs, 1);
    }

    #[test]
    fn stale_checkout_has_exact_len() {
        let b = checkout_stale(123);
        assert_eq!(b.len(), 123);
        recycle(b);
        let b = checkout_stale(7);
        assert_eq!(b.len(), 7);
        recycle(b);
    }

    #[test]
    fn packed_b_panels_match_source_rows() {
        let mut rng = Pcg64::seeded(11);
        let b = Matrix::gaussian(70, 90, &mut rng);
        let (kc, nc) = (32, 48);
        let pb = PackedB::pack(&b, kc, nc);
        assert_eq!(pb.panels(), 3 * 2);
        for pc in (0..70).step_by(kc) {
            let kcur = kc.min(70 - pc);
            for jc in (0..90).step_by(nc) {
                let ncur = nc.min(90 - jc);
                let panel = pb.panel(pc, jc);
                for t in 0..kcur {
                    assert_eq!(
                        &panel[t * ncur..t * ncur + ncur],
                        &b.row(pc + t)[jc..jc + ncur],
                        "panel ({pc},{jc}) row {t}"
                    );
                }
            }
        }
        assert_eq!(pb.uses(), 6);
        assert_eq!(pb.reuse(), 0);
        let _ = pb.panel(0, 0);
        assert_eq!(pb.reuse(), 1);
        pb.recycle();
    }

    #[test]
    fn packed_a_blocks_are_micro_panel_major() {
        let mut rng = Pcg64::seeded(12);
        // 23 rows: two 8-panels, one 4-panel, 3 scalar rows.
        let a = Matrix::gaussian(23, 40, &mut rng);
        let (mc, kc) = (23, 16);
        let pa = PackedA::pack(&a, mc, kc);
        let block = pa.block(0, 16);
        let kcur = 16; // min(kc, 40 - 16)
        // 8-panel 1, row 9, t=2:
        assert_eq!(block[8 * kcur + 2 * 8 + 1], a[(9, 18)]);
        // 4-panel (rows 16..20), row 17, t=0:
        assert_eq!(block[16 * kcur + 4 * 0 + 1], a[(17, 16)]);
        // scalar zone (rows 20..23), row 21, t=5:
        assert_eq!(block[21 * kcur + 5], a[(21, 21)]);
        assert_eq!(pa.blocks(), 3);
        pa.recycle();
    }

    #[test]
    fn fused_quantized_pack_matches_dequantize_then_pack() {
        let mut rng = Pcg64::seeded(13);
        let b = Matrix::gaussian(67, 53, &mut rng);
        for fmt in [
            StorageFormat::Fp8(crate::fp8::Fp8Format::E4M3),
            StorageFormat::Fp8(crate::fp8::Fp8Format::E5M2),
            StorageFormat::F16,
            StorageFormat::Bf16,
            StorageFormat::F32,
        ] {
            let q = quantize(&b, fmt);
            let dense = dequantize(&q);
            let fused = PackedB::pack_quantized(&q, 32, 32);
            let unfused = PackedB::pack(&dense, 32, 32);
            assert_eq!(fused.buf, unfused.buf, "{fmt:?} B");
            let fa = PackedA::pack_quantized(&q, 32, 32);
            let ua = PackedA::pack(&dense, 32, 32);
            assert_eq!(fa.buf, ua.buf, "{fmt:?} A");
        }
    }
}
