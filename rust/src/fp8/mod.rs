//! Software floating-point codecs: FP8 (E4M3 / E5M2), FP16, BF16.
//!
//! The evaluation machine has no FP8 hardware, so the paper's "FP8 storage,
//! FP16 compute, FP32 accumulate" pipeline is emulated **bit-exactly**:
//! encode/decode round-trips go through the real bit layouts with
//! round-to-nearest-even, saturation and NaN handling matching the
//! OCP FP8 specification (and IEEE 754 binary16 / bfloat16 for the 16-bit
//! types). Throughput effects of the narrower types are modeled separately
//! in [`crate::gpu_sim`] from byte counts; *numerical* effects come from
//! here and are therefore real, not simulated.

pub mod codec;
pub mod quantize;

pub use codec::{
    bf16_decode, bf16_encode, e4m3_decode, e4m3_encode, e5m2_decode, e5m2_encode, f16_decode,
    f16_encode, Fp8Format,
};
pub use quantize::{
    decode_row_segment, dequantize, dequantize_into, quant_stats, quantize, quantized_matmul,
    quantized_matmul_fused, QuantStats, QuantizedTensor, StorageFormat,
};
