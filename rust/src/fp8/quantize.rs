//! Per-tensor scaled quantization — the paper's "scaling compensation".
//!
//! FP8's dynamic range is tiny (E4M3: ±448 with 3 mantissa bits), so
//! tensors are stored as `bytes = encode(x / scale)` with
//! `scale = max|x| / (margin · max_finite)`. Dequantization multiplies the
//! scale back. This is exactly the per-tensor "delayed scaling" scheme of
//! NVIDIA's Transformer Engine, minus the history heuristics (our tensors
//! are static at quantization time).

use crate::fp8::codec::{f16_decode, f16_encode, Fp8Format};
use crate::linalg::matrix::Matrix;

/// Storage precision of a quantized tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StorageFormat {
    /// 8-bit float (either layout).
    Fp8(Fp8Format),
    /// IEEE binary16.
    F16,
    /// bfloat16.
    Bf16,
    /// Plain f32 (identity codec; lets the pipeline be precision-generic).
    F32,
}

impl StorageFormat {
    /// Bytes per element — the number the roofline model charges for traffic.
    pub fn bytes_per_element(self) -> usize {
        match self {
            StorageFormat::Fp8(_) => 1,
            StorageFormat::F16 | StorageFormat::Bf16 => 2,
            StorageFormat::F32 => 4,
        }
    }

    /// Short human name used by reports/configs.
    pub fn name(self) -> &'static str {
        match self {
            StorageFormat::Fp8(Fp8Format::E4M3) => "fp8_e4m3",
            StorageFormat::Fp8(Fp8Format::E5M2) => "fp8_e5m2",
            StorageFormat::F16 => "f16",
            StorageFormat::Bf16 => "bf16",
            StorageFormat::F32 => "f32",
        }
    }

    /// Parse the name back (config files).
    pub fn parse(s: &str) -> Option<StorageFormat> {
        Some(match s {
            "fp8_e4m3" | "fp8" => StorageFormat::Fp8(Fp8Format::E4M3),
            "fp8_e5m2" => StorageFormat::Fp8(Fp8Format::E5M2),
            "f16" | "fp16" => StorageFormat::F16,
            "bf16" => StorageFormat::Bf16,
            "f32" | "fp32" => StorageFormat::F32,
            _ => return None,
        })
    }
}

/// A tensor stored in reduced precision with a per-tensor scale.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    /// Storage layout.
    pub format: StorageFormat,
    /// Shape (rows, cols).
    pub shape: (usize, usize),
    /// Dequantization scale: `x ≈ decode(byte) * scale`.
    pub scale: f32,
    /// Packed payload (1 or 2 bytes per element, little-endian for 16-bit).
    pub bytes: Vec<u8>,
}

/// Headroom left below the format max to absorb accumulation growth.
const SCALE_MARGIN: f32 = 1.0;

/// Quantize a matrix to the requested storage format.
pub fn quantize(m: &Matrix, format: StorageFormat) -> QuantizedTensor {
    let amax = m.max_abs();
    let (scale, inv_scale) = match format {
        StorageFormat::Fp8(f) => {
            let target = f.max_finite() * SCALE_MARGIN;
            if amax > 0.0 {
                (amax / target, target / amax)
            } else {
                (1.0, 1.0)
            }
        }
        // 16/32-bit types have enough range; store unscaled.
        _ => (1.0, 1.0),
    };

    let n = m.rows() * m.cols();
    let bytes = match format {
        StorageFormat::Fp8(f) => {
            let mut out = Vec::with_capacity(n);
            for &v in m.data() {
                out.push(f.encode(v * inv_scale));
            }
            out
        }
        StorageFormat::F16 => {
            let mut out = Vec::with_capacity(2 * n);
            for &v in m.data() {
                out.extend_from_slice(&f16_encode(v).to_le_bytes());
            }
            out
        }
        StorageFormat::Bf16 => {
            let mut out = Vec::with_capacity(2 * n);
            for &v in m.data() {
                out.extend_from_slice(&crate::fp8::codec::bf16_encode(v).to_le_bytes());
            }
            out
        }
        StorageFormat::F32 => {
            let mut out = Vec::with_capacity(4 * n);
            for &v in m.data() {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out
        }
    };

    QuantizedTensor {
        format,
        shape: m.shape(),
        scale,
        bytes,
    }
}

/// Dequantize back to a dense f32 matrix.
pub fn dequantize(q: &QuantizedTensor) -> Matrix {
    let (rows, cols) = q.shape;
    let mut data = vec![0.0f32; rows * cols];
    dequantize_into(q, &mut data);
    Matrix::from_vec(rows, cols, data).expect("quantized payload length")
}

/// Decode a contiguous element range `[e0, e0 + out.len())` of `q`'s
/// payload into `out`, applying the tensor scale. The single scalar
/// decode site every dequantization path shares, so fused and unfused
/// consumers are bit-identical per element by construction.
fn decode_range(q: &QuantizedTensor, e0: usize, out: &mut [f32]) {
    match q.format {
        StorageFormat::Fp8(f) => {
            for (o, &b) in out.iter_mut().zip(&q.bytes[e0..e0 + out.len()]) {
                *o = f.decode(b) * q.scale;
            }
        }
        StorageFormat::F16 => {
            let src = &q.bytes[2 * e0..2 * (e0 + out.len())];
            for (o, ch) in out.iter_mut().zip(src.chunks_exact(2)) {
                *o = f16_decode(u16::from_le_bytes([ch[0], ch[1]])) * q.scale;
            }
        }
        StorageFormat::Bf16 => {
            let src = &q.bytes[2 * e0..2 * (e0 + out.len())];
            for (o, ch) in out.iter_mut().zip(src.chunks_exact(2)) {
                *o = crate::fp8::codec::bf16_decode(u16::from_le_bytes([ch[0], ch[1]])) * q.scale;
            }
        }
        StorageFormat::F32 => {
            let src = &q.bytes[4 * e0..4 * (e0 + out.len())];
            for (o, ch) in out.iter_mut().zip(src.chunks_exact(4)) {
                *o = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]) * q.scale;
            }
        }
    }
}

/// Dequantize the whole tensor into a caller-provided buffer (row-major,
/// `rows·cols` elements) — the arena-friendly variant of [`dequantize`].
pub fn dequantize_into(q: &QuantizedTensor, out: &mut [f32]) {
    let (rows, cols) = q.shape;
    assert_eq!(out.len(), rows * cols, "dequantize_into buffer length");
    decode_range(q, 0, out);
}

/// Decode the row segment `q[row][c0 .. c0 + out.len()]` into `out` — the
/// fused decode-into-pack primitive ([`crate::linalg::pack`] decodes
/// codec bytes straight into packed panel layout through this, one pass,
/// no full-matrix f32 intermediate). Values are bit-identical to the same
/// elements of [`dequantize`].
pub fn decode_row_segment(q: &QuantizedTensor, row: usize, c0: usize, out: &mut [f32]) {
    let (rows, cols) = q.shape;
    debug_assert!(row < rows && c0 + out.len() <= cols, "segment in range");
    decode_range(q, row * cols + c0, out);
}

/// Quantization error statistics (feeds the §5.4 error analysis).
#[derive(Clone, Copy, Debug, Default)]
pub struct QuantStats {
    /// Mean relative elementwise error over non-tiny entries.
    pub mean_rel_err: f32,
    /// Max relative elementwise error over non-tiny entries.
    pub max_rel_err: f32,
    /// Relative Frobenius error of the whole tensor.
    pub frob_rel_err: f32,
}

/// Measure round-trip error of quantizing `m` to `format`.
pub fn quant_stats(m: &Matrix, format: StorageFormat) -> QuantStats {
    let q = quantize(m, format);
    let d = dequantize(&q);
    let thresh = 1e-3 * m.max_abs().max(f32::MIN_POSITIVE);
    let mut n = 0usize;
    let mut sum = 0.0f64;
    let mut max = 0.0f32;
    for (&a, &b) in m.data().iter().zip(d.data()) {
        if a.abs() > thresh {
            let rel = ((b - a) / a).abs();
            sum += rel as f64;
            max = max.max(rel);
            n += 1;
        }
    }
    QuantStats {
        mean_rel_err: if n > 0 { (sum / n as f64) as f32 } else { 0.0 },
        max_rel_err: max,
        frob_rel_err: d.rel_frobenius_distance(m),
    }
}

/// "FP8 storage, FP32 accumulate" GEMM: both operands round-trip through
/// the codec (with per-tensor scaling) and the product is computed in f32 —
/// the numerical pipeline of the paper's §3.3.1, minus the hardware.
pub fn quantized_matmul(a: &Matrix, b: &Matrix, format: StorageFormat) -> Matrix {
    let qa = dequantize(&quantize(a, format));
    let qb = dequantize(&quantize(b, format));
    qa.matmul(&qb)
}

/// [`quantized_matmul`] on the fused hot path: the decode side of the
/// codec round-trip lands **directly in the packed panel layout** (one
/// pass over the codec bytes; the dense f32 intermediates of the unfused
/// path are never materialized). Bit-identical to [`quantized_matmul`]:
/// the decoded values are the same and the packed kernel reproduces the
/// blocked kernel's summation order exactly.
pub fn quantized_matmul_fused(a: &Matrix, b: &Matrix, format: StorageFormat) -> Matrix {
    use crate::linalg::gemm::{gemm_packed, kernel_params};
    use crate::linalg::pack::{PackedA, PackedB};

    let (m, k) = a.shape();
    let n = b.cols();
    let p = kernel_params();
    // Below the blocked cutover the unfused path never packs (naive
    // loop); mirror it exactly to keep bit-parity.
    if m * n * k <= p.naive_cutover {
        return quantized_matmul(a, b, format);
    }
    let qa = quantize(a, format);
    let qb = quantize(b, format);
    let pa = PackedA::pack_quantized(&qa, p.mc, p.kc);
    let pb = PackedB::pack_quantized(&qb, p.kc, p.nc);
    let c = gemm_packed(&pa, &pb).expect("quantized_matmul_fused: inner dimensions must agree");
    pa.recycle();
    pb.recycle();
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    fn mat(seed: u64) -> Matrix {
        Matrix::gaussian(24, 18, &mut Pcg64::seeded(seed))
    }

    #[test]
    fn f32_roundtrip_is_exact() {
        let m = mat(1);
        let q = quantize(&m, StorageFormat::F32);
        assert_eq!(dequantize(&q), m);
    }

    #[test]
    fn fp8_roundtrip_bounded_error() {
        let m = mat(2);
        let s = quant_stats(&m, StorageFormat::Fp8(Fp8Format::E4M3));
        // 3-bit mantissa + scaling: mean rel err well under 4%, max under ~7%.
        assert!(s.mean_rel_err < 0.04, "mean {}", s.mean_rel_err);
        assert!(s.max_rel_err < 0.08, "max {}", s.max_rel_err);
        assert!(s.frob_rel_err < 0.04, "frob {}", s.frob_rel_err);
    }

    #[test]
    fn f16_much_tighter_than_fp8() {
        let m = mat(3);
        let s8 = quant_stats(&m, StorageFormat::Fp8(Fp8Format::E4M3));
        let s16 = quant_stats(&m, StorageFormat::F16);
        assert!(s16.frob_rel_err < s8.frob_rel_err / 4.0);
    }

    #[test]
    fn scaling_handles_large_magnitudes() {
        // Without scaling these would all saturate at 448.
        let mut m = mat(4);
        m.scale_in_place(1e6);
        let s = quant_stats(&m, StorageFormat::Fp8(Fp8Format::E4M3));
        assert!(s.frob_rel_err < 0.04, "frob {}", s.frob_rel_err);
    }

    #[test]
    fn scaling_handles_tiny_magnitudes() {
        let mut m = mat(5);
        m.scale_in_place(1e-6);
        let s = quant_stats(&m, StorageFormat::Fp8(Fp8Format::E4M3));
        assert!(s.frob_rel_err < 0.04, "frob {}", s.frob_rel_err);
    }

    #[test]
    fn zero_tensor() {
        let m = Matrix::zeros(4, 4);
        let q = quantize(&m, StorageFormat::Fp8(Fp8Format::E4M3));
        let d = dequantize(&q);
        assert_eq!(d, m);
    }

    #[test]
    fn bytes_per_element_accounting() {
        let m = mat(6);
        let n = m.rows() * m.cols();
        assert_eq!(quantize(&m, StorageFormat::Fp8(Fp8Format::E4M3)).bytes.len(), n);
        assert_eq!(quantize(&m, StorageFormat::F16).bytes.len(), 2 * n);
        assert_eq!(quantize(&m, StorageFormat::F32).bytes.len(), 4 * n);
    }

    #[test]
    fn quantized_matmul_error_scales_with_format() {
        let mut rng = Pcg64::seeded(7);
        let a = Matrix::gaussian(20, 20, &mut rng);
        let b = Matrix::gaussian(20, 20, &mut rng);
        let exact = a.matmul(&b);
        let e8 = quantized_matmul(&a, &b, StorageFormat::Fp8(Fp8Format::E4M3))
            .rel_frobenius_distance(&exact);
        let e16 = quantized_matmul(&a, &b, StorageFormat::F16).rel_frobenius_distance(&exact);
        assert!(e8 < 0.08, "fp8 err {e8}");
        assert!(e16 < e8);
    }

    #[test]
    fn format_name_parse_roundtrip() {
        for f in [
            StorageFormat::Fp8(Fp8Format::E4M3),
            StorageFormat::Fp8(Fp8Format::E5M2),
            StorageFormat::F16,
            StorageFormat::Bf16,
            StorageFormat::F32,
        ] {
            assert_eq!(StorageFormat::parse(f.name()), Some(f));
        }
        assert_eq!(StorageFormat::parse("int4"), None);
    }

    #[test]
    fn fused_quantized_matmul_is_bitwise_identical() {
        let mut rng = Pcg64::seeded(9);
        // Above the blocked cutover so the fused pack path actually runs.
        let a = Matrix::gaussian(130, 140, &mut rng);
        let b = Matrix::gaussian(140, 150, &mut rng);
        for fmt in [
            StorageFormat::Fp8(Fp8Format::E4M3),
            StorageFormat::Fp8(Fp8Format::E5M2),
            StorageFormat::F16,
            StorageFormat::Bf16,
            StorageFormat::F32,
        ] {
            let fused = quantized_matmul_fused(&a, &b, fmt);
            let unfused = quantized_matmul(&a, &b, fmt);
            assert_eq!(fused.data(), unfused.data(), "{}", fmt.name());
        }
        // Below the cutover both take the naive path.
        let a = Matrix::gaussian(24, 24, &mut rng);
        let b = Matrix::gaussian(24, 24, &mut rng);
        let fmt = StorageFormat::Fp8(Fp8Format::E4M3);
        assert_eq!(
            quantized_matmul_fused(&a, &b, fmt).data(),
            quantized_matmul(&a, &b, fmt).data()
        );
    }

    #[test]
    fn row_segment_decode_matches_dequantize() {
        let m = mat(11);
        for fmt in [
            StorageFormat::Fp8(Fp8Format::E4M3),
            StorageFormat::F16,
            StorageFormat::Bf16,
            StorageFormat::F32,
        ] {
            let q = quantize(&m, fmt);
            let dense = dequantize(&q);
            let mut seg = vec![0.0f32; 7];
            decode_row_segment(&q, 5, 3, &mut seg);
            assert_eq!(&seg, &dense.row(5)[3..10], "{}", fmt.name());
            let mut all = vec![0.0f32; m.rows() * m.cols()];
            dequantize_into(&q, &mut all);
            assert_eq!(&all, dense.data(), "{}", fmt.name());
        }
    }

    #[test]
    fn e5m2_storage_works_too() {
        let m = mat(8);
        let s = quant_stats(&m, StorageFormat::Fp8(Fp8Format::E5M2));
        // 2-bit mantissa: coarser than E4M3 but bounded.
        assert!(s.frob_rel_err < 0.09, "frob {}", s.frob_rel_err);
    }
}
