//! Bit-exact scalar codecs for FP8 E4M3/E5M2 (OCP spec), IEEE binary16 and
//! bfloat16.
//!
//! All encoders use round-to-nearest-even. E4M3 follows the OCP "FN"
//! variant used by NVIDIA hardware: no infinities, exponent bias 7, max
//! finite 448, NaN = 0x7F/0xFF. E5M2 is IEEE-like: bias 15, max finite
//! 57344, has infinities.

/// Which 8-bit float layout a tensor uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Fp8Format {
    /// 4 exponent bits, 3 mantissa bits — more precision, less range.
    E4M3,
    /// 5 exponent bits, 2 mantissa bits — more range, less precision.
    E5M2,
}

impl Fp8Format {
    /// Largest finite representable magnitude.
    pub fn max_finite(self) -> f32 {
        match self {
            Fp8Format::E4M3 => 448.0,
            Fp8Format::E5M2 => 57344.0,
        }
    }

    /// Mantissa bits (for error models: ulp ≈ 2^-mbits).
    pub fn mantissa_bits(self) -> u32 {
        match self {
            Fp8Format::E4M3 => 3,
            Fp8Format::E5M2 => 2,
        }
    }

    /// Encode one value.
    pub fn encode(self, x: f32) -> u8 {
        match self {
            Fp8Format::E4M3 => e4m3_encode(x),
            Fp8Format::E5M2 => e5m2_encode(x),
        }
    }

    /// Decode one byte.
    pub fn decode(self, b: u8) -> f32 {
        match self {
            Fp8Format::E4M3 => e4m3_decode(b),
            Fp8Format::E5M2 => e5m2_decode(b),
        }
    }
}

/// Generic binary-float encoder: `ebits` exponent bits, `mbits` mantissa
/// bits, bias, saturating at `max_finite`, round-to-nearest-even, flushing
/// to (sub)normals below the normal range. `ieee_inf` selects whether the
/// top exponent encodes inf/NaN (E5M2, f16) or is used for finite values
/// except the all-ones mantissa (E4M3-FN).
fn encode_small(x: f32, ebits: u32, mbits: u32, bias: i32, max_finite: f32, ieee_inf: bool) -> u8 {
    let bits = x.to_bits();
    let sign = ((bits >> 31) as u8) << 7;
    if x.is_nan() {
        return sign | 0x7f; // canonical NaN (all ones exp+mantissa for E4M3; qNaN for others)
    }
    let ax = x.abs();
    if ax > max_finite {
        if ieee_inf {
            // Infinity encoding: exponent all ones, mantissa 0.
            let exp_all = ((1u8 << ebits) - 1) << mbits;
            return sign | exp_all;
        }
        // Saturate (E4M3-FN has no inf).
        return sign | max_byte(ebits, mbits, ieee_inf);
    }
    if ax == 0.0 {
        return sign;
    }

    // Decompose |x| = m * 2^e with m in [1, 2).
    let e = ax.log2().floor() as i32;
    let e = e.clamp(-149, 127);
    let mut exp = e + bias;
    // Subnormal range: exp <= 0 → effective exponent is 1 - bias.
    let (mant_f, is_sub) = if exp <= 0 {
        (ax / f32::powi(2.0, 1 - bias), true)
    } else {
        (ax / f32::powi(2.0, e) - 1.0, false)
    };
    // Round mantissa to mbits with round-to-nearest-even.
    let scale = (1u32 << mbits) as f32;
    let mut mant = round_ties_even(mant_f * scale);
    if is_sub {
        exp = 0;
        if mant >= scale {
            // Rounded up into the normal range.
            exp = 1;
            mant = 0.0;
        }
    } else if mant >= scale {
        // Mantissa overflow: bump exponent.
        exp += 1;
        mant = 0.0;
    }
    let max_exp = (1i32 << ebits) - 1;
    let enc_max = max_byte(ebits, mbits, ieee_inf);
    if ieee_inf {
        if exp >= max_exp {
            return sign | enc_max; // saturate below inf
        }
    } else if exp > max_exp || (exp == max_exp && mant as u32 >= (1 << mbits) - 1) {
        // E4M3-FN: exp=15, mant=7 is NaN; largest finite is exp=15, mant=6.
        return sign | enc_max;
    }
    sign | (((exp as u8) << mbits) | mant as u8)
}

/// Largest finite encoding for the format.
fn max_byte(ebits: u32, mbits: u32, ieee_inf: bool) -> u8 {
    let max_exp = (1u8 << ebits) - 1;
    if ieee_inf {
        // exp = max-1, mantissa all ones.
        ((max_exp - 1) << mbits) | ((1 << mbits) - 1)
    } else {
        // exp = max, mantissa all ones minus one (all-ones = NaN).
        (max_exp << mbits) | (((1u8 << mbits) - 1) - 1)
    }
}

fn round_ties_even(x: f32) -> f32 {
    let f = x.floor();
    let d = x - f;
    if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

fn decode_small(b: u8, ebits: u32, mbits: u32, bias: i32, ieee_inf: bool) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let emask = (1u8 << ebits) - 1;
    let exp = (b >> mbits) & emask;
    let mant = b & ((1 << mbits) - 1);
    let max_exp = emask;
    if exp == max_exp {
        if ieee_inf {
            return if mant == 0 {
                sign * f32::INFINITY
            } else {
                f32::NAN
            };
        }
        // E4M3-FN: all-ones mantissa is NaN, otherwise finite.
        if mant == (1 << mbits) - 1 {
            return f32::NAN;
        }
    }
    let scale = (1u32 << mbits) as f32;
    if exp == 0 {
        // Subnormal: mant/2^mbits * 2^(1-bias)
        sign * (mant as f32 / scale) * f32::powi(2.0, 1 - bias)
    } else {
        sign * (1.0 + mant as f32 / scale) * f32::powi(2.0, exp as i32 - bias)
    }
}

// ---------------------------------------------------------------------------
// Fast paths (§Perf iteration 5). The float-math reference implementations
// (`encode_small`/`decode_small`, `log2`-based) stay as the test oracles;
// the public functions below are integer bit manipulation + tiny LUTs,
// asserted bit-identical to the references over exhaustive/boundary sweeps
// in the tests at the bottom of this file.
// ---------------------------------------------------------------------------

/// Generic fast encoder: RNE by integer mantissa rounding for normal
/// targets, one exact power-of-two multiply for subnormal targets.
#[inline]
fn encode_fast(x: f32, ebits: u32, mbits: u32, bias: i32, max_finite: f32, ieee_inf: bool) -> u8 {
    let bits = x.to_bits();
    let sign = ((bits >> 24) & 0x80) as u8;
    if x.is_nan() {
        return sign | 0x7f;
    }
    let abits = bits & 0x7fff_ffff;
    let ax = f32::from_bits(abits);
    if ax > max_finite {
        if ieee_inf {
            return sign | (((1u8 << ebits) - 1) << mbits);
        }
        return sign | max_byte(ebits, mbits, ieee_inf);
    }
    if abits == 0 {
        return sign;
    }

    let e_unb = ((abits >> 23) & 0xff) as i32 - 127; // f32 subnormals → -127, handled below
    let exp_t = e_unb + bias;
    if exp_t >= 1 && e_unb > -127 {
        // Normal target: round the f32 mantissa down to `mbits` with RNE.
        let drop = 23 - mbits;
        let m = abits & 0x7f_ffff;
        let mut keep = m >> drop;
        let rest = m & ((1u32 << drop) - 1);
        let half = 1u32 << (drop - 1);
        if rest > half || (rest == half && keep & 1 == 1) {
            keep += 1;
        }
        let mut exp_t = exp_t as u32;
        if keep == 1 << mbits {
            keep = 0;
            exp_t += 1;
        }
        debug_assert!(exp_t < (1 << ebits) + ieee_inf as u32);
        sign | ((exp_t as u8) << mbits) | keep as u8
    } else {
        // Subnormal target: q = RNE(ax · 2^(bias-1+mbits)); the scale is a
        // power of two so the product is exact (no double rounding).
        let scale = f32::from_bits((((bias - 1 + mbits as i32) + 127) as u32) << 23);
        let q = round_ties_even(ax * scale);
        if q >= (1u32 << mbits) as f32 {
            return sign | (1 << mbits); // rounded up into the first normal
        }
        sign | q as u8
    }
}

/// Lazily built 256-entry decode tables (exact by construction: filled
/// from the reference decoder).
fn fp8_lut(ebits: u32, mbits: u32, bias: i32, ieee_inf: bool) -> [f32; 256] {
    let mut t = [0.0f32; 256];
    for (b, slot) in t.iter_mut().enumerate() {
        *slot = decode_small(b as u8, ebits, mbits, bias, ieee_inf);
    }
    t
}

fn e4m3_lut() -> &'static [f32; 256] {
    static LUT: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| fp8_lut(4, 3, 7, false))
}

fn e5m2_lut() -> &'static [f32; 256] {
    static LUT: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| fp8_lut(5, 2, 15, true))
}

/// Encode f32 → E4M3 byte.
pub fn e4m3_encode(x: f32) -> u8 {
    encode_fast(x, 4, 3, 7, 448.0, false)
}

/// Decode E4M3 byte → f32.
pub fn e4m3_decode(b: u8) -> f32 {
    e4m3_lut()[b as usize]
}

/// Encode f32 → E5M2 byte.
pub fn e5m2_encode(x: f32) -> u8 {
    encode_fast(x, 5, 2, 15, 57344.0, true)
}

/// Decode E5M2 byte → f32.
pub fn e5m2_decode(b: u8) -> f32 {
    e5m2_lut()[b as usize]
}

/// Encode f32 → IEEE binary16 bits (round-to-nearest-even).
///
/// Integer fast path (§Perf iteration 5); bit-identical to
/// [`f16_encode_ref`] (asserted exhaustively in tests).
pub fn f16_encode(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    if x.is_nan() {
        return sign | 0x7e00;
    }
    let abits = bits & 0x7fff_ffff;
    let ax = f32::from_bits(abits);
    if ax > 65504.0 {
        return sign | 0x7c00; // inf
    }
    if abits == 0 {
        return sign;
    }
    let e_unb = ((abits >> 23) & 0xff) as i32 - 127;
    let exp_t = e_unb + 15;
    if exp_t >= 1 && e_unb > -127 {
        let m = abits & 0x7f_ffff;
        let mut keep = m >> 13;
        let rest = m & 0x1fff;
        if rest > 0x1000 || (rest == 0x1000 && keep & 1 == 1) {
            keep += 1;
        }
        let mut exp_t = exp_t as u32;
        if keep == 1 << 10 {
            keep = 0;
            exp_t += 1;
        }
        sign | ((exp_t as u16) << 10) | keep as u16
    } else {
        // Subnormal target: q = RNE(ax · 2^24), exact power-of-two scale.
        let q = round_ties_even(ax * f32::from_bits((24 + 127) << 23));
        if q >= 1024.0 {
            return sign | (1 << 10);
        }
        sign | q as u16
    }
}

/// Reference (float-math) f16 encoder — the oracle the fast path is
/// validated against.
pub fn f16_encode_ref(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let ax = x.abs();
    if x.is_nan() {
        return sign | 0x7e00;
    }
    if ax > 65504.0 {
        return sign | 0x7c00; // inf
    }
    if ax < f32::powi(2.0, -24) / 2.0 {
        return sign; // underflow to zero
    }
    let e = ax.log2().floor() as i32;
    let mut exp = e + 15;
    let (mant_f, is_sub) = if exp <= 0 {
        (ax / f32::powi(2.0, -14), true)
    } else {
        (ax / f32::powi(2.0, e) - 1.0, false)
    };
    let mut mant = round_ties_even(mant_f * 1024.0);
    if is_sub {
        exp = 0;
        if mant >= 1024.0 {
            exp = 1;
            mant = 0.0;
        }
    } else if mant >= 1024.0 {
        exp += 1;
        mant = 0.0;
    }
    if exp >= 31 {
        return sign | 0x7c00;
    }
    sign | ((exp as u16) << 10) | mant as u16
}

/// Decode IEEE binary16 bits → f32.
pub fn f16_decode(h: u16) -> f32 {
    // 64 Ki-entry LUT (256 KiB, L2-resident) built from the reference
    // decoder — exact by construction (§Perf iteration 5).
    static LUT: std::sync::OnceLock<Vec<f32>> = std::sync::OnceLock::new();
    let lut = LUT.get_or_init(|| (0..=u16::MAX).map(f16_decode_ref).collect());
    lut[h as usize]
}

/// Reference (float-math) f16 decoder.
pub fn f16_decode_ref(h: u16) -> f32 {
    let sign = if h & 0x8000 != 0 { -1.0f32 } else { 1.0 };
    let exp = (h >> 10) & 0x1f;
    let mant = h & 0x3ff;
    match exp {
        0 => sign * (mant as f32 / 1024.0) * f32::powi(2.0, -14),
        31 => {
            if mant == 0 {
                sign * f32::INFINITY
            } else {
                f32::NAN
            }
        }
        _ => sign * (1.0 + mant as f32 / 1024.0) * f32::powi(2.0, exp as i32 - 15),
    }
}

/// Encode f32 → bfloat16 bits (round-to-nearest-even on the dropped 16).
pub fn bf16_encode(x: f32) -> u16 {
    if x.is_nan() {
        return ((x.to_bits() >> 16) as u16) | 0x0040; // force quiet
    }
    let bits = x.to_bits();
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7fff + lsb);
    (rounded >> 16) as u16
}

/// Decode bfloat16 bits → f32 (exact: bf16 is a truncated f32).
pub fn bf16_decode(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The fast integer encoders must be bit-identical to the float-math
    /// references: exhaustive over every f16 value (as f32 inputs), every
    /// fp8 decode point and its neighborhoods, binade boundaries, ties,
    /// and a large PRNG sweep of raw f32 bit patterns.
    #[test]
    fn fast_paths_match_references_exhaustively() {
        let check = |x: f32| {
            assert_eq!(
                e4m3_encode(x),
                encode_small(x, 4, 3, 7, 448.0, false),
                "e4m3 {x} ({:#x})",
                x.to_bits()
            );
            assert_eq!(
                e5m2_encode(x),
                encode_small(x, 5, 2, 15, 57344.0, true),
                "e5m2 {x} ({:#x})",
                x.to_bits()
            );
            let (fast, slow) = (f16_encode(x), f16_encode_ref(x));
            // NaNs may differ in payload only, never in NaN-ness.
            if x.is_nan() {
                assert_eq!(fast & 0x7c00, 0x7c00);
                assert_ne!(fast & 0x3ff, 0);
            } else {
                assert_eq!(fast, slow, "f16 {x} ({:#x})", x.to_bits());
            }
        };

        // Every f16-representable value and its f32 neighbours.
        for h in 0..=u16::MAX {
            let x = f16_decode_ref(h);
            if x.is_finite() {
                check(x);
                check(x * (1.0 + f32::EPSILON));
                check(x * (1.0 - f32::EPSILON));
                check(-x);
            }
        }
        // Every fp8 decode point, its midpoints (the RNE ties) and ulps.
        for b in 0..=u8::MAX {
            for v in [
                decode_small(b, 4, 3, 7, false),
                decode_small(b, 5, 2, 15, true),
            ] {
                if v.is_finite() {
                    for f in [1.0f32, 1.0 + 1e-7, 1.0 - 1e-7, 1.0625, 0.9375] {
                        check(v * f);
                        check(-v * f);
                    }
                }
            }
        }
        // PRNG sweep over raw bit patterns (includes NaNs/infs/subnormals).
        let mut state = 0x243F_6A88_85A3_08D3u64;
        for _ in 0..200_000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            check(f32::from_bits((state >> 32) as u32));
        }
    }

    #[test]
    fn f16_decode_lut_matches_reference() {
        for h in 0..=u16::MAX {
            let (lut, r) = (f16_decode(h), f16_decode_ref(h));
            assert!(lut == r || (lut.is_nan() && r.is_nan()), "{h:#x}");
        }
    }

    fn roundtrip_exact_e4m3(x: f32) {
        let d = e4m3_decode(e4m3_encode(x));
        assert_eq!(d, x, "E4M3 {x} -> {d}");
    }

    #[test]
    fn e4m3_exact_values() {
        // Powers of two and small integers are exactly representable.
        for x in [0.0f32, 1.0, -1.0, 2.0, 0.5, 0.25, 3.5, -12.0, 448.0, -448.0] {
            roundtrip_exact_e4m3(x);
        }
    }

    #[test]
    fn e4m3_saturates() {
        assert_eq!(e4m3_decode(e4m3_encode(10000.0)), 448.0);
        assert_eq!(e4m3_decode(e4m3_encode(-10000.0)), -448.0);
        assert_eq!(e4m3_decode(e4m3_encode(449.0)), 448.0);
    }

    #[test]
    fn e4m3_nan() {
        assert!(e4m3_decode(e4m3_encode(f32::NAN)).is_nan());
        assert!(e4m3_decode(0x7f).is_nan());
        assert!(e4m3_decode(0xff).is_nan());
    }

    #[test]
    fn e4m3_subnormals() {
        // Smallest subnormal: 2^-9 ≈ 0.001953125
        let tiny = f32::powi(2.0, -9);
        assert_eq!(e4m3_decode(e4m3_encode(tiny)), tiny);
        // Below half the smallest subnormal rounds to zero.
        assert_eq!(e4m3_decode(e4m3_encode(tiny / 4.0)), 0.0);
    }

    #[test]
    fn e4m3_relative_error_bound() {
        // For normal range values, rel err ≤ 2^-4 (half ulp of 3-bit mantissa).
        let mut x = 0.02f32;
        while x < 400.0 {
            let d = e4m3_decode(e4m3_encode(x));
            let rel = (d - x).abs() / x;
            assert!(rel <= 1.0 / 16.0 + 1e-6, "x={x} d={d} rel={rel}");
            x *= 1.37;
        }
    }

    #[test]
    fn e4m3_monotone() {
        // Encoding must be monotone on positives.
        let mut prev = e4m3_decode(e4m3_encode(0.01));
        let mut x = 0.011f32;
        while x < 440.0 {
            let d = e4m3_decode(e4m3_encode(x));
            assert!(d >= prev, "monotonicity broke at {x}");
            prev = d;
            x *= 1.1;
        }
    }

    #[test]
    fn e5m2_range_and_inf() {
        assert_eq!(e5m2_decode(e5m2_encode(57344.0)), 57344.0);
        assert_eq!(e5m2_decode(e5m2_encode(1e8)), f32::INFINITY);
        assert_eq!(e5m2_decode(e5m2_encode(-1e8)), f32::NEG_INFINITY);
        assert!(e5m2_decode(e5m2_encode(f32::NAN)).is_nan());
    }

    #[test]
    fn e5m2_exact_values() {
        for x in [0.0f32, 1.0, -2.0, 0.75, 6.0, 1024.0] {
            assert_eq!(e5m2_decode(e5m2_encode(x)), x, "E5M2 {x}");
        }
    }

    #[test]
    fn e5m2_coarser_than_e4m3_in_core_range() {
        // 2 mantissa bits vs 3: E4M3 must be at least as accurate around 1.
        let x = 1.3f32;
        let e4 = (e4m3_decode(e4m3_encode(x)) - x).abs();
        let e5 = (e5m2_decode(e5m2_encode(x)) - x).abs();
        assert!(e4 <= e5);
    }

    #[test]
    fn f16_roundtrip_exact() {
        for x in [0.0f32, 1.0, -1.5, 0.333251953125, 65504.0, -65504.0] {
            assert_eq!(f16_decode(f16_encode(x)), x, "f16 {x}");
        }
    }

    #[test]
    fn f16_inf_nan_subnormal() {
        assert_eq!(f16_decode(f16_encode(1e6)), f32::INFINITY);
        assert!(f16_decode(f16_encode(f32::NAN)).is_nan());
        let sub = f32::powi(2.0, -24); // smallest f16 subnormal
        assert_eq!(f16_decode(f16_encode(sub)), sub);
    }

    #[test]
    fn f16_rel_error_bound() {
        let mut x = 1e-3f32;
        while x < 6e4 {
            let d = f16_decode(f16_encode(x));
            assert!(((d - x) / x).abs() <= f32::powi(2.0, -11) + 1e-7, "x={x}");
            x *= 1.7;
        }
    }

    #[test]
    fn bf16_roundtrip() {
        for x in [0.0f32, 1.0, -3.140625, 1e30, -1e-30] {
            let d = bf16_decode(bf16_encode(x));
            assert!(((d - x) / x.abs().max(1e-38)).abs() < 0.01 || d == x, "bf16 {x} -> {d}");
        }
        assert!(bf16_decode(bf16_encode(f32::NAN)).is_nan());
    }

    #[test]
    fn bf16_round_to_nearest_even() {
        // 1.0 + 2^-9 lies halfway between two bf16 values (mantissa 7 bits);
        // round-to-even keeps 1.0's neighbor with even mantissa.
        let x = f32::from_bits(0x3f80_8000); // 1.00390625
        let d = bf16_decode(bf16_encode(x));
        assert_eq!(d.to_bits() & 0xffff, 0);
    }

    #[test]
    fn all_e4m3_bytes_decode_finite_or_nan() {
        for b in 0u8..=255 {
            let v = e4m3_decode(b);
            assert!(v.is_finite() || v.is_nan(), "byte {b:#x} -> {v}");
            if v.is_finite() {
                assert!(v.abs() <= 448.0);
            }
        }
    }

    #[test]
    fn e4m3_decode_encode_identity_on_bytes() {
        // decode→encode must reproduce every non-NaN byte (canonical codes).
        for b in 0u8..=255 {
            let v = e4m3_decode(b);
            if v.is_nan() {
                continue;
            }
            if v == 0.0 && b == 0x80 {
                continue; // -0 encodes to 0x80; f32 -0.0 keeps the sign, check:
            }
            assert_eq!(e4m3_encode(v), b, "byte {b:#x} via {v}");
        }
    }
}
