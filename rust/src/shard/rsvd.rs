//! Panel-parallel randomized SVD and sharded factorization.
//!
//! The two data-parallel passes of Halko's method dominate its cost and
//! shard cleanly by output row panels:
//!
//! - the range sketch `Y = A·Ω` (and its power-iteration refreshes) runs
//!   on the tile plane's dense GEMM,
//! - the projections `Z = Aᵀ·Q` and `B = Qᵀ·A` run on the row-panel
//!   [`ShardExecutor::matmul_tn`] primitive.
//!
//! The sequential stages — thin QR re-orthonormalization and the exact
//! SVD of the small `l×n` projection — stay on the caller thread; they
//! are `O((m+n) l²)` against the sketches' `O(m n l)`.
//!
//! Structure (sketch seed, oversampling, iteration count, truncation)
//! mirrors [`crate::linalg::rsvd::rsvd`] exactly, so with the tile plane's
//! deterministic kernels the factorization is bitwise-reproducible at any
//! worker count.

use crate::error::{Error, Result};
use crate::linalg::matrix::Matrix;
use crate::linalg::qr::qr_thin;
use crate::linalg::rng::Pcg64;
use crate::linalg::rsvd::RsvdOptions;
use crate::linalg::svd::{jacobi_svd, Svd};
use crate::lowrank::factor::{DecompMethod, LowRankConfig, LowRankFactor};
use crate::lowrank::rank::{select_rank, RankStrategy};
use crate::shard::executor::ShardExecutor;

/// Randomized truncated SVD of `a` at rank `r`, with the range sketch and
/// projections executed on the shard plane.
pub fn rsvd_sharded(
    exec: &ShardExecutor,
    a: &Matrix,
    r: usize,
    opts: &RsvdOptions,
) -> Result<Svd> {
    let (m, n) = a.shape();
    let kmax = m.min(n);
    if r == 0 || r > kmax {
        return Err(Error::InvalidRank {
            requested: r,
            max: kmax,
        });
    }
    let l = (r + opts.oversample).min(kmax);
    let mut rng = Pcg64::seeded(opts.seed);

    // Stage A: range finder. Y = A Ω, Ω ∈ R^{n×l} Gaussian — the sketch is
    // drawn on the caller thread (same seed ⇒ same Ω as the serial path);
    // the m×l pass over A is row-panel-sharded.
    let omega = Matrix::gaussian(n, l, &mut rng);
    let mut y = exec.gemm(a, &omega)?;
    let mut q = qr_thin(&y).q;

    // Power iterations with re-orthonormalization each half-step.
    for _ in 0..opts.power_iters {
        let z = exec.matmul_tn(a, &q)?; // n×l, row-panel-sharded
        let qz = qr_thin(&z).q;
        y = exec.gemm(a, &qz)?;
        q = qr_thin(&y).q;
    }

    // Stage B: B = Qᵀ A (l×n, row-panel-sharded), small exact SVD of B.
    let b = exec.matmul_tn(&q, a)?;
    let small = jacobi_svd(&b)?;

    // U = Q · U_B, truncate to r (rank-sized product: routed serial).
    let u = exec.gemm(&q, &small.u.take_cols(r.min(small.s.len())))?;
    Ok(Svd {
        u,
        s: small.s[..r.min(small.s.len())].to_vec(),
        vt: small.vt.take_rows(r),
    })
}

/// Decompose a dense matrix under `cfg` with panel-parallel randomized
/// SVD. Mirrors [`crate::lowrank::factorize`] (including the spectrum
/// probe for the adaptive rank strategies); the exact-SVD and Lanczos
/// methods are inherently sequential and delegate to the serial path.
pub fn factorize_sharded(
    exec: &ShardExecutor,
    a: &Matrix,
    cfg: &LowRankConfig,
) -> Result<LowRankFactor> {
    if cfg.method != DecompMethod::RandomizedSvd {
        return crate::lowrank::factorize(a, cfg);
    }
    let (m, n) = a.shape();
    let kmax = m.min(n);

    let rank = match cfg.rank {
        RankStrategy::Fixed(_)
        | RankStrategy::FixedFraction(_)
        | RankStrategy::HardwareAware { .. } => select_rank(
            &cfg.rank,
            m,
            n,
            &[],
            &crate::gpu_sim::profile::DeviceProfile::rtx4090(),
        ),
        RankStrategy::EnergyFraction(_) | RankStrategy::ErrorBound(_) => {
            let probe_rank = (kmax / 4).clamp(1, kmax.min(64).max(1));
            let probe = rsvd_sharded(exec, a, probe_rank, &cfg.rsvd)?;
            select_rank(
                &cfg.rank,
                m,
                n,
                &probe.s,
                &crate::gpu_sim::profile::DeviceProfile::rtx4090(),
            )
        }
    };
    let rank = rank.clamp(1, kmax);

    let svd = rsvd_sharded(exec, a, rank, &cfg.rsvd)?;
    Ok(LowRankFactor::from_svd(
        &svd.u,
        svd.s,
        &svd.vt,
        cfg.storage,
        a.shape(),
        cfg.method,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::StorageFormat;
    use crate::linalg::rsvd::rsvd;
    use crate::shard::plan::{ShardPlan, TileGrid};

    fn exec(workers: usize) -> ShardExecutor {
        ShardExecutor::new(ShardPlan {
            grid: TileGrid::default(),
            workers,
            min_parallel_n: 64,
        })
    }

    #[test]
    fn sharded_rsvd_is_bitwise_serial_rsvd() {
        // Large enough that the sketch and both projections actually run
        // on the tile plane (see the FLOP gate), on an MC/NC-aligned grid.
        let mut rng = Pcg64::seeded(401);
        let a = Matrix::low_rank_noisy(1536, 512, 24, 1e-4, &mut rng);
        let opts = RsvdOptions::default();
        let serial = rsvd(&a, 24, &opts).unwrap();
        let sharded = rsvd_sharded(&exec(4), &a, 24, &opts).unwrap();
        assert_eq!(serial.s, sharded.s);
        assert_eq!(serial.u.data(), sharded.u.data());
        assert_eq!(serial.vt.data(), sharded.vt.data());
    }

    #[test]
    fn worker_count_invariant_factorization() {
        let mut rng = Pcg64::seeded(402);
        let a = Matrix::low_rank_noisy(768, 640, 12, 1e-4, &mut rng);
        let cfg = LowRankConfig {
            rank: RankStrategy::Fixed(12),
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let f1 = factorize_sharded(&exec(1), &a, &cfg).unwrap();
        let f4 = factorize_sharded(&exec(4), &a, &cfg).unwrap();
        assert_eq!(f1.s, f4.s);
        assert_eq!(f1.u.bytes, f4.u.bytes);
        assert_eq!(f1.vt.bytes, f4.vt.bytes);
    }

    #[test]
    fn sharded_factorization_matches_serial_factorize() {
        let mut rng = Pcg64::seeded(403);
        let a = Matrix::low_rank_noisy(640, 512, 8, 1e-4, &mut rng);
        let cfg = LowRankConfig {
            rank: RankStrategy::Fixed(8),
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let serial = crate::lowrank::factorize(&a, &cfg).unwrap();
        let sharded = factorize_sharded(&exec(3), &a, &cfg).unwrap();
        assert_eq!(serial.s, sharded.s);
        assert_eq!(serial.u.bytes, sharded.u.bytes);
        assert_eq!(serial.vt.bytes, sharded.vt.bytes);
        assert!(sharded.measured_error(&a) < 2e-3);
    }

    #[test]
    fn adaptive_rank_probe_works_sharded() {
        let mut rng = Pcg64::seeded(404);
        let a = Matrix::low_rank_noisy(600, 600, 6, 1e-5, &mut rng);
        let cfg = LowRankConfig {
            rank: RankStrategy::EnergyFraction(0.99),
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let f = factorize_sharded(&exec(4), &a, &cfg).unwrap();
        assert!(f.rank() >= 1);
        assert!(f.measured_error(&a) < 0.05);
    }

    #[test]
    fn non_rsvd_methods_delegate() {
        let mut rng = Pcg64::seeded(405);
        let a = Matrix::low_rank(96, 80, 5, &mut rng);
        let cfg = LowRankConfig {
            rank: RankStrategy::Fixed(5),
            method: DecompMethod::ExactSvd,
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let serial = crate::lowrank::factorize(&a, &cfg).unwrap();
        let sharded = factorize_sharded(&exec(2), &a, &cfg).unwrap();
        assert_eq!(serial.s, sharded.s);
    }

    #[test]
    fn rank_bounds_still_checked() {
        let a = Matrix::eye(16);
        let ex = exec(2);
        assert!(rsvd_sharded(&ex, &a, 0, &RsvdOptions::default()).is_err());
        assert!(rsvd_sharded(&ex, &a, 17, &RsvdOptions::default()).is_err());
    }
}
