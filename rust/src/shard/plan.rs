//! Tile grids and the sharding decision.
//!
//! A [`ShardPlan`] describes how the tile-execution plane partitions one
//! GEMM: the output is cut into an MC×NC-aligned [`TileGrid`], each tile
//! becomes one independent task, and `workers` claim jobs race over the
//! task list. `min_parallel_n` plus a flat FLOP floor gate the plane so
//! small requests never pay tiling overhead.
//!
//! The default tile (256×256) is a multiple of the blocked kernel's MC/NC
//! cache blocks, which makes tiled execution **bitwise-equal** to the
//! monolithic [`crate::linalg::gemm::gemm_blocked`] (see
//! [`crate::linalg::gemm::gemm_panel`] for the argument). Changing the
//! tile to non-multiples keeps results correct to float tolerance but
//! gives up the bitwise guarantee against the monolithic kernel; the
//! guarantee *between worker counts* holds for any tile shape, because the
//! per-tile summation order never depends on who executes the tile.

use crate::config::schema::ShardSettings;

/// Work floor (2·m·k·n FLOPs) below which tiling is pure overhead even
/// when the shapes clear `min_parallel_n` — roughly a millisecond of
/// single-core GEMM.
pub const MIN_PARALLEL_FLOPS: f64 = (1u64 << 24) as f64;

/// One output tile: rows `r0..r1`, columns `c0..c1` of C.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    /// First output row.
    pub r0: usize,
    /// One past the last output row.
    pub r1: usize,
    /// First output column.
    pub c0: usize,
    /// One past the last output column.
    pub c1: usize,
}

impl Tile {
    /// Tile height.
    pub fn rows(&self) -> usize {
        self.r1 - self.r0
    }

    /// Tile width.
    pub fn cols(&self) -> usize {
        self.c1 - self.c0
    }
}

/// Regular output tiling (last row/column of tiles absorbs remainders).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileGrid {
    /// Tile height (output rows per task).
    pub tile_m: usize,
    /// Tile width (output columns per task).
    pub tile_n: usize,
}

impl Default for TileGrid {
    fn default() -> Self {
        TileGrid {
            tile_m: 256,
            tile_n: 256,
        }
    }
}

impl TileGrid {
    /// Grid with the given tile shape (clamped to ≥ 1).
    pub fn new(tile_m: usize, tile_n: usize) -> Self {
        TileGrid {
            tile_m: tile_m.max(1),
            tile_n: tile_n.max(1),
        }
    }

    /// Enumerate the tiles of an `m×n` output, row-major.
    pub fn tiles(&self, m: usize, n: usize) -> Vec<Tile> {
        let mut out = Vec::with_capacity(self.tile_count(m, n));
        let mut r0 = 0;
        while r0 < m {
            let r1 = (r0 + self.tile_m.max(1)).min(m);
            let mut c0 = 0;
            while c0 < n {
                let c1 = (c0 + self.tile_n.max(1)).min(n);
                out.push(Tile { r0, r1, c0, c1 });
                c0 = c1;
            }
            r0 = r1;
        }
        out
    }

    /// Number of tiles an `m×n` output decomposes into.
    pub fn tile_count(&self, m: usize, n: usize) -> usize {
        m.div_ceil(self.tile_m.max(1)) * n.div_ceil(self.tile_n.max(1))
    }
}

/// The tile-execution plan: grid shape, worker count, and the size gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Output tiling.
    pub grid: TileGrid,
    /// Worker threads in the shard pool.
    pub workers: usize,
    /// Requests with `max(m, n)` below this stay single-threaded.
    pub min_parallel_n: usize,
}

impl Default for ShardPlan {
    fn default() -> Self {
        ShardPlan {
            grid: TileGrid::default(),
            workers: 4,
            min_parallel_n: 512,
        }
    }
}

impl ShardPlan {
    /// Should an `m_out×n_out` product over inner dimension `k` run on the
    /// tile plane? Deliberately independent of `workers`, so the same plan
    /// routes identically at any pool size — the worker-count bitwise
    /// equivalence the tests assert.
    pub fn should_parallelize(&self, m_out: usize, n_out: usize, k: usize) -> bool {
        m_out.max(n_out) >= self.min_parallel_n
            && 2.0 * m_out as f64 * k as f64 * n_out as f64 >= MIN_PARALLEL_FLOPS
    }
}

impl From<&ShardSettings> for ShardPlan {
    fn from(s: &ShardSettings) -> ShardPlan {
        ShardPlan {
            grid: TileGrid::new(s.tile_m, s.tile_n),
            workers: s.workers.max(1),
            min_parallel_n: s.min_parallel_n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiles_cover_exactly_with_remainders() {
        let g = TileGrid::new(256, 256);
        let tiles = g.tiles(300, 520);
        assert_eq!(tiles.len(), g.tile_count(300, 520));
        assert_eq!(tiles.len(), 2 * 3);
        // Coverage: every cell in exactly one tile.
        let mut hit = vec![0u8; 300 * 520];
        for t in &tiles {
            assert!(t.r1 <= 300 && t.c1 <= 520);
            assert!(t.rows() > 0 && t.cols() > 0);
            for r in t.r0..t.r1 {
                for c in t.c0..t.c1 {
                    hit[r * 520 + c] += 1;
                }
            }
        }
        assert!(hit.iter().all(|&h| h == 1));
        // Remainder tiles exist.
        assert!(tiles.iter().any(|t| t.rows() == 44));
        assert!(tiles.iter().any(|t| t.cols() == 8));
    }

    #[test]
    fn tile_count_empty_and_exact() {
        let g = TileGrid::new(128, 128);
        assert_eq!(g.tile_count(0, 256), 0);
        assert_eq!(g.tile_count(256, 256), 4);
        assert!(g.tiles(0, 256).is_empty());
    }

    #[test]
    fn should_parallelize_gates() {
        let p = ShardPlan {
            grid: TileGrid::default(),
            workers: 4,
            min_parallel_n: 512,
        };
        // Big square: yes.
        assert!(p.should_parallelize(1024, 1024, 1024));
        // Below the size gate: no.
        assert!(!p.should_parallelize(256, 256, 4096));
        // Tall-skinny with a large side and real work: yes.
        assert!(p.should_parallelize(4096, 64, 1024));
        // Clears the size gate but trivial work (thin k): no.
        assert!(!p.should_parallelize(4096, 8, 8));
        // Degenerate: no.
        assert!(!p.should_parallelize(0, 0, 128));
    }

    #[test]
    fn plan_from_settings_clamps() {
        let s = ShardSettings {
            workers: 0,
            tile_m: 0,
            tile_n: 512,
            min_parallel_n: 300,
        };
        let p = ShardPlan::from(&s);
        assert_eq!(p.workers, 1);
        assert_eq!(p.grid.tile_m, 1);
        assert_eq!(p.grid.tile_n, 512);
        assert_eq!(p.min_parallel_n, 300);
    }

    #[test]
    fn default_tile_is_cache_block_aligned() {
        // The bitwise-vs-monolithic guarantee needs tile_m % MC == 0 and
        // tile_n % NC == 0 (MC = 128, NC = 256 in linalg::gemm).
        let g = TileGrid::default();
        assert_eq!(g.tile_m % 128, 0);
        assert_eq!(g.tile_n % 256, 0);
    }
}
