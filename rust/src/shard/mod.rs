//! The shard execution plane: block-partitioned parallel GEMM.
//!
//! The paper's throughput headline comes from memory-bandwidth-aware
//! tiling; this module is the serving-side equivalent for the CPU
//! substrate: every large `C = A·B` is partitioned into an output tile
//! grid, each tile becomes one dependency-free task (tiles of C are
//! disjoint, and each task reads only its A row panel and B column
//! panel), and the task set executes across a dedicated
//! [`crate::exec::ThreadPool`] with atomic work-claiming.
//!
//! ```text
//!              ShardPlan { grid, workers, min_parallel_n }
//!                               │
//!   A (m×k) ──┐      ┌──────────┴──────────┐
//!             ├──▶   │ tile grid over C    │   claim jobs (atomic cursor)
//!   B (k×n) ──┘      │ ┌────┬────┬────┐    │   ┌──────────┐
//!                    │ │T0  │T1  │T2  │    ├──▶│ worker 0 │─┐
//!                    │ ├────┼────┼────┤    │   ├──────────┤ ├─▶ assemble C
//!                    │ │T3  │T4  │T5  │    ├──▶│ worker 1 │─┘  + shard.tile_us
//!                    │ └────┴────┴────┘    │   └──────────┘
//!                    └─────────────────────┘
//! ```
//!
//! Covered hot paths, all behind one [`ShardExecutor`]:
//!
//! - **dense blocked GEMM** — per-tile [`crate::linalg::gemm::gemm_panel`]
//!   (same packing and micro-kernel as the monolithic kernel),
//! - **FP8 dense GEMM** — codec round-trip, then the sharded f32 product,
//! - **the low-rank factor chain** — every constituent product routed
//!   through the plane, including **panel-parallel randomized SVD**
//!   ([`rsvd_sharded`]): the `A·Ω` range sketch and the `Qᵀ·A` / `Aᵀ·Q`
//!   projections are row-panel-sharded across workers.
//!
//! Determinism: a tile's bits depend only on the tile, never on which
//! worker computes it or when, so results are bitwise identical across
//! worker counts — and, with the default MC/NC-aligned grid, bitwise
//! identical to the single-threaded kernels. The equivalence tests assert
//! both properties exactly.

pub mod executor;
pub mod plan;
pub mod rsvd;

pub use executor::ShardExecutor;
pub use plan::{ShardPlan, Tile, TileGrid};
pub use rsvd::{factorize_sharded, rsvd_sharded};
