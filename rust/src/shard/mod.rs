//! The shard execution plane: block-partitioned parallel GEMM.
//!
//! The paper's throughput headline comes from memory-bandwidth-aware
//! tiling; this module is the serving-side equivalent for the CPU
//! substrate: every large `C = A·B` is partitioned into an output tile
//! grid, each tile becomes one dependency-free task (tiles of C are
//! disjoint, and each task reads only its A row panel and B column
//! panel), and the task set executes across a dedicated
//! [`crate::exec::ThreadPool`] with atomic work-claiming.
//!
//! ```text
//!              ShardPlan { grid, workers, min_parallel_n }
//!                               │
//!   A (m×k) ──┐      ┌──────────┴──────────┐
//!             ├──▶   │ tile grid over C    │   claim jobs (atomic cursor)
//!   B (k×n) ──┘      │ ┌────┬────┬────┐    │   ┌──────────┐
//!                    │ │T0  │T1  │T2  │    ├──▶│ worker 0 │─┐
//!                    │ ├────┼────┼────┤    │   ├──────────┤ ├─▶ assemble C
//!                    │ │T3  │T4  │T5  │    ├──▶│ worker 1 │─┘  + shard.tile_us
//!                    │ └────┴────┴────┘    │   └──────────┘
//!                    └─────────────────────┘
//! ```
//!
//! Covered hot paths, all behind one [`ShardExecutor`]:
//!
//! - **dense blocked GEMM** — operands packed **once**
//!   ([`crate::linalg::pack`]) and shared read-only across workers; each
//!   tile runs [`crate::linalg::gemm::gemm_panel_packed`] (same
//!   micro-kernel and summation order as the monolithic kernel; grids
//!   not aligned to the kernel blocking fall back to per-tile
//!   [`crate::linalg::gemm::gemm_panel`] re-packing),
//! - **FP8 dense GEMM** — fused decode-into-pack: quantize once, decode
//!   the codec bytes straight into the shared packed panels, shard the
//!   product (no full-matrix f32 intermediates),
//! - **the low-rank factor chain** — every constituent product routed
//!   through the plane with arena-recycled intermediates (and optionally
//!   a pre-packed cached `Vᵀ_B`), including **panel-parallel randomized
//!   SVD** ([`rsvd_sharded`]): the `A·Ω` range sketch and the `Qᵀ·A` /
//!   `Aᵀ·Q` projections are row-panel-sharded across workers.
//!
//! Determinism: a tile's bits depend only on the tile, never on which
//! worker computes it or when, so results are bitwise identical across
//! worker counts — and, with the default MC/NC-aligned grid, bitwise
//! identical to the single-threaded kernels. The equivalence tests assert
//! both properties exactly.

pub mod executor;
pub mod plan;
pub mod rsvd;

pub use executor::ShardExecutor;
pub use plan::{ShardPlan, Tile, TileGrid};
pub use rsvd::{factorize_sharded, rsvd_sharded};
