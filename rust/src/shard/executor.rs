//! The tile executor: atomic work-claiming over [`crate::exec::ThreadPool`].
//!
//! One GEMM becomes `tile_count` independent tasks (one per output tile —
//! no inter-task dependencies, since C tiles are disjoint). The executor
//! submits `min(workers, tasks)` *claim jobs* to its dedicated pool; each
//! claim job races an atomic cursor over the task list, computes every
//! tile it wins with [`gemm_panel`] (packing the B panel it needs per
//! tile, exactly like the monolithic kernel), and streams the finished
//! tile back over a channel. The caller assembles tiles into C in arrival
//! order — legal because tiles are disjoint and each tile's bits are
//! fixed by the tile alone.
//!
//! Determinism contract: for a fixed [`ShardPlan`] grid, results are
//! **bitwise identical for every worker count** (the per-tile summation
//! order never depends on who computes the tile or when). With the
//! default MC/NC-aligned grid, dense results are additionally bitwise
//! identical to single-threaded [`gemm_blocked`] whenever the monolithic
//! kernel takes its blocked path.
//!
//! The pool is *owned* by the executor and separate from the coordinator's
//! request-level worker pool: a request worker blocks in [`ShardExecutor`]
//! while its tiles run here, which would deadlock on a shared FIFO pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::exec::ThreadPool;
use crate::fp8::{dequantize, quantize, StorageFormat};
use crate::linalg::gemm::{gemm_blocked, gemm_panel};
use crate::linalg::matrix::Matrix;
use crate::lowrank::factor::LowRankFactor;
use crate::metrics::MetricsRegistry;
use crate::shard::plan::{ShardPlan, Tile};

/// Executes GEMM-shaped work over a tile grid on a dedicated worker pool.
pub struct ShardExecutor {
    plan: ShardPlan,
    pool: ThreadPool,
    metrics: Option<Arc<MetricsRegistry>>,
}

impl ShardExecutor {
    /// Executor with a fresh pool of `plan.workers` threads, no metrics.
    pub fn new(plan: ShardPlan) -> Self {
        ShardExecutor {
            pool: ThreadPool::new(plan.workers),
            plan,
            metrics: None,
        }
    }

    /// Executor reporting per-shard timings into `metrics`
    /// (`shard.tile_us` histogram, `shard.*` counters).
    pub fn with_metrics(plan: ShardPlan, metrics: Arc<MetricsRegistry>) -> Self {
        ShardExecutor {
            pool: ThreadPool::new(plan.workers),
            plan,
            metrics: Some(metrics),
        }
    }

    /// The plan this executor runs.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Claim jobs submitted to the pool but not yet started (other GEMMs
    /// in flight ahead of ours).
    pub fn pending_jobs(&self) -> u64 {
        self.pool.pending()
    }

    fn count(&self, name: &str) {
        if let Some(m) = &self.metrics {
            m.count(name, 1);
        }
    }

    /// `C = A · B`. Routes to the tile plane when the plan's gates pass,
    /// to the single-threaded blocked kernel otherwise.
    pub fn gemm(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        if a.cols() != b.rows() {
            return Err(Error::ShapeMismatch {
                op: "shard gemm",
                lhs: a.shape(),
                rhs: b.shape(),
            });
        }
        let (m, k) = a.shape();
        let n = b.cols();
        if !self.plan.should_parallelize(m, n, k) {
            self.count("shard.gemm.serial");
            return gemm_blocked(a, b);
        }
        self.count("shard.gemm.parallel");
        self.mm_sharded(a, b)
    }

    /// FP8/F16 dense GEMM: both operands round-trip the storage codec
    /// (per-tensor scale computed over the whole operand, matching the
    /// single-threaded [`crate::fp8::quantized_matmul`] bit-for-bit), then
    /// the f32 product runs on the tile plane.
    pub fn quantized_matmul(
        &self,
        a: &Matrix,
        b: &Matrix,
        format: StorageFormat,
    ) -> Result<Matrix> {
        let qa = dequantize(&quantize(a, format));
        let qb = dequantize(&quantize(b, format));
        self.gemm(&qa, &qb)
    }

    /// `C = Aᵀ · B` with the output row-panel-sharded (the rSVD projection
    /// primitive). Bitwise identical to [`Matrix::matmul_tn`] at every
    /// worker count: each output row accumulates over `t` in the same
    /// order on both paths.
    pub fn matmul_tn(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        if a.rows() != b.rows() {
            return Err(Error::ShapeMismatch {
                op: "shard matmul_tn",
                lhs: a.shape(),
                rhs: b.shape(),
            });
        }
        let m = a.cols();
        let n = b.cols();
        let k = a.rows();
        if !self.plan.should_parallelize(m, n, k) {
            return Ok(a.matmul_tn(b));
        }
        // Row panels only: the projection shapes are thin on one side, so
        // column-splitting would just shrink the per-task row sweep.
        let tile_m = self.plan.grid.tile_m.max(1);
        let tiles: Vec<Tile> = (0..m)
            .step_by(tile_m)
            .map(|r0| Tile {
                r0,
                r1: (r0 + tile_m).min(m),
                c0: 0,
                c1: n,
            })
            .collect();
        let a = Arc::new(a.clone());
        let b = Arc::new(b.clone());
        let ntasks = tiles.len();
        let tiles = Arc::new(tiles);
        let work: WorkFn = Arc::new(move |i| {
            let t = tiles[i];
            Ok((t, tn_panel(&a, &b, t.r0, t.r1)))
        });
        let parts = self.run_claimed(ntasks, work)?;
        Ok(assemble(m, n, parts))
    }

    /// Factor-chain GEMM (`C ≈ U_A Σ_A V_Aᵀ U_B Σ_B V_Bᵀ`), every dense
    /// product routed through the tile plane. Mirrors
    /// [`crate::lowrank::lowrank_matmul`] including its contraction-order
    /// choice; the rank-sized inner products fall under the parallel gates
    /// and run single-threaded, the m×n-sized reconstruction shards.
    pub fn lowrank_matmul(&self, fa: &LowRankFactor, fb: &LowRankFactor) -> Result<Matrix> {
        if fa.orig_shape.1 != fb.orig_shape.0 {
            return Err(Error::ShapeMismatch {
                op: "shard lowrank gemm",
                lhs: fa.orig_shape,
                rhs: fb.orig_shape,
            });
        }
        let ua = fa.u_dense();
        let vat = fa.vt_dense();
        let ub = fb.u_dense();
        let vbt = fb.vt_dense();

        let mut t2 = self.gemm(&vat, &ub)?;
        t2.scale_rows_in_place(&fa.s);
        t2.scale_cols_in_place(&fb.s);

        let (m, _) = fa.orig_shape;
        let (_, n) = fb.orig_shape;
        if m <= n {
            let t3 = self.gemm(&ua, &t2)?;
            self.gemm(&t3, &vbt)
        } else {
            let t3 = self.gemm(&t2, &vbt)?;
            self.gemm(&ua, &t3)
        }
    }

    /// Factor × dense GEMM (`A` factored, `B` dense) on the tile plane.
    pub fn lowrank_matmul_dense_rhs(&self, fa: &LowRankFactor, b: &Matrix) -> Result<Matrix> {
        if fa.orig_shape.1 != b.rows() {
            return Err(Error::ShapeMismatch {
                op: "shard lowrank×dense",
                lhs: fa.orig_shape,
                rhs: b.shape(),
            });
        }
        let vat = fa.vt_dense();
        let mut t = self.gemm(&vat, b)?;
        t.scale_rows_in_place(&fa.s);
        self.gemm(&fa.u_dense(), &t)
    }

    /// Dense × factor GEMM (`B` factored) on the tile plane.
    pub fn lowrank_matmul_dense_lhs(&self, a: &Matrix, fb: &LowRankFactor) -> Result<Matrix> {
        if a.cols() != fb.orig_shape.0 {
            return Err(Error::ShapeMismatch {
                op: "shard dense×lowrank",
                lhs: a.shape(),
                rhs: fb.orig_shape,
            });
        }
        let ub = fb.u_dense();
        let mut t = self.gemm(a, &ub)?;
        t.scale_cols_in_place(&fb.s);
        self.gemm(&t, &fb.vt_dense())
    }

    /// The sharded dense product: tile grid → claim jobs → assembly.
    ///
    /// The operands are cloned into `Arc`s so the claim jobs are
    /// `'static` for the pool. That copy is O(m·k + k·n) against the
    /// product's O(m·k·n) — under the FLOP gate it is < 1% of the work —
    /// but it does hold a second transient copy of A/B; a zero-copy
    /// scoped-execution pool is the known follow-up if memory headroom
    /// ever matters at N ≳ 16k.
    fn mm_sharded(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let m = a.rows();
        let n = b.cols();
        let tiles = self.plan.grid.tiles(m, n);
        let ntasks = tiles.len();
        let a = Arc::new(a.clone());
        let b = Arc::new(b.clone());
        let tiles = Arc::new(tiles);
        let work: WorkFn = Arc::new(move |i| {
            let t = tiles[i];
            gemm_panel(&a, &b, t.r0, t.rows(), t.c0, t.cols()).map(|p| (t, p.into_vec()))
        });
        let parts = self.run_claimed(ntasks, work)?;
        Ok(assemble(m, n, parts))
    }

    /// Fan `ntasks` out to `min(workers, ntasks)` claim jobs and collect
    /// every task's result. Tasks are claimed with an atomic cursor, so
    /// load-balancing is automatic: a worker stuck on a heavy remainder
    /// tile simply claims fewer tiles.
    fn run_claimed(&self, ntasks: usize, work: WorkFn) -> Result<Vec<(Tile, Vec<f32>)>> {
        let next = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel::<Result<(Tile, Vec<f32>)>>();
        let nworkers = self.plan.workers.clamp(1, ntasks.max(1));
        for w in 0..nworkers {
            let work = work.clone();
            let next = next.clone();
            let tx = tx.clone();
            let metrics = self.metrics.clone();
            self.pool.execute(move || {
                let mut claimed = 0u64;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= ntasks {
                        break;
                    }
                    let t0 = Instant::now();
                    let res = work(i);
                    if let Some(m) = &metrics {
                        m.observe("shard.tile_us", t0.elapsed().as_micros() as f64);
                    }
                    claimed += 1;
                    if tx.send(res).is_err() {
                        break; // caller bailed on an earlier error
                    }
                }
                if claimed > 0 {
                    if let Some(m) = &metrics {
                        m.count(&format!("shard.worker.{w}.tiles"), claimed);
                    }
                }
            });
        }
        drop(tx);
        let mut out = Vec::with_capacity(ntasks);
        for msg in rx {
            out.push(msg?);
        }
        if out.len() != ntasks {
            return Err(Error::Service(format!(
                "shard executor lost tiles: {}/{ntasks} arrived",
                out.len()
            )));
        }
        if let Some(m) = &self.metrics {
            m.count("shard.tasks", ntasks as u64);
        }
        Ok(out)
    }
}

/// A claimable task: tile index → (tile, row-major tile payload).
type WorkFn = Arc<dyn Fn(usize) -> Result<(Tile, Vec<f32>)> + Send + Sync>;

/// Scatter disjoint tiles into the m×n output.
fn assemble(m: usize, n: usize, parts: Vec<(Tile, Vec<f32>)>) -> Matrix {
    let mut c = Matrix::zeros(m, n);
    for (t, buf) in parts {
        let w = t.cols();
        for (ri, r) in (t.r0..t.r1).enumerate() {
            c.row_mut(r)[t.c0..t.c1].copy_from_slice(&buf[ri * w..(ri + 1) * w]);
        }
    }
    c
}

/// One row panel of `out = Aᵀ · B`: rows `i0..i1` of the m×n output
/// (`m = A.cols`). Per-element accumulation order (ascending `t`, with the
/// same zero-skip) is identical to [`Matrix::matmul_tn`], so panels are
/// bitwise-exact fragments of the single-threaded result.
fn tn_panel(a: &Matrix, b: &Matrix, i0: usize, i1: usize) -> Vec<f32> {
    let n = b.cols();
    let k = a.rows();
    let w = i1 - i0;
    let mut out = vec![0.0f32; w * n];
    for t in 0..k {
        let a_row = a.row(t);
        let b_row = b.row(t);
        for i in i0..i1 {
            let av = a_row[i];
            if av == 0.0 {
                continue;
            }
            let o = &mut out[(i - i0) * n..(i - i0 + 1) * n];
            for (ov, &bv) in o.iter_mut().zip(b_row) {
                *ov += av * bv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::quantized_matmul;
    use crate::linalg::rng::Pcg64;
    use crate::lowrank::factor::LowRankConfig;
    use crate::lowrank::factorize;
    use crate::lowrank::rank::RankStrategy;
    use crate::shard::plan::TileGrid;

    fn exec(workers: usize) -> ShardExecutor {
        ShardExecutor::new(ShardPlan {
            grid: TileGrid::default(),
            workers,
            min_parallel_n: 64,
        })
    }

    #[test]
    fn sharded_dense_is_bitwise_blocked_square() {
        let mut rng = Pcg64::seeded(301);
        let a = Matrix::gaussian(320, 320, &mut rng);
        let b = Matrix::gaussian(320, 320, &mut rng);
        let serial = gemm_blocked(&a, &b).unwrap();
        let sharded = exec(3).gemm(&a, &b).unwrap();
        assert_eq!(serial.data(), sharded.data());
    }

    #[test]
    fn sharded_dense_is_bitwise_blocked_tall_skinny() {
        let mut rng = Pcg64::seeded(302);
        // Tall output with a non-divisible row remainder (648 = 2·256+136).
        let a = Matrix::gaussian(648, 320, &mut rng);
        let b = Matrix::gaussian(320, 96, &mut rng);
        let serial = gemm_blocked(&a, &b).unwrap();
        let sharded = exec(4).gemm(&a, &b).unwrap();
        assert_eq!(serial.data(), sharded.data());
    }

    #[test]
    fn sharded_dense_handles_remainder_tiles() {
        let mut rng = Pcg64::seeded(303);
        // Both dimensions off the tile grid: 300×520 output.
        let a = Matrix::gaussian(300, 96, &mut rng);
        let b = Matrix::gaussian(96, 520, &mut rng);
        let serial = gemm_blocked(&a, &b).unwrap();
        let sharded = exec(3).gemm(&a, &b).unwrap();
        assert_eq!(serial.data(), sharded.data());
    }

    #[test]
    fn worker_count_never_changes_bits() {
        let mut rng = Pcg64::seeded(304);
        let a = Matrix::gaussian(520, 200, &mut rng);
        let b = Matrix::gaussian(200, 330, &mut rng);
        let one = exec(1).gemm(&a, &b).unwrap();
        for workers in [2, 3, 8] {
            let many = exec(workers).gemm(&a, &b).unwrap();
            assert_eq!(one.data(), many.data(), "workers={workers}");
        }
    }

    #[test]
    fn small_requests_stay_serial() {
        let mut rng = Pcg64::seeded(305);
        let a = Matrix::gaussian(32, 32, &mut rng);
        let b = Matrix::gaussian(32, 32, &mut rng);
        let metrics = Arc::new(MetricsRegistry::new());
        let ex = ShardExecutor::with_metrics(ShardPlan::default(), metrics.clone());
        let c = ex.gemm(&a, &b).unwrap();
        assert!(c.rel_frobenius_distance(&a.matmul(&b)) < 1e-6);
        let counters = metrics.counters();
        assert_eq!(counters.get("shard.gemm.serial"), Some(&1));
        assert_eq!(counters.get("shard.gemm.parallel"), None);
    }

    #[test]
    fn fp8_sharded_is_bitwise_quantized_matmul() {
        let mut rng = Pcg64::seeded(306);
        let a = Matrix::gaussian(256, 192, &mut rng);
        let b = Matrix::gaussian(192, 320, &mut rng);
        let fmt = StorageFormat::Fp8(crate::fp8::Fp8Format::E4M3);
        let serial = quantized_matmul(&a, &b, fmt);
        let sharded = exec(4).quantized_matmul(&a, &b, fmt).unwrap();
        assert_eq!(serial.data(), sharded.data());
    }

    #[test]
    fn matmul_tn_sharded_is_bitwise_serial() {
        let mut rng = Pcg64::seeded(307);
        // out is 640×40 (row panels), k = 1024 — the rSVD projection shape.
        let a = Matrix::gaussian(1024, 640, &mut rng);
        let b = Matrix::gaussian(1024, 40, &mut rng);
        let serial = a.matmul_tn(&b);
        let sharded = exec(3).matmul_tn(&a, &b).unwrap();
        assert_eq!(serial.data(), sharded.data());
    }

    #[test]
    fn factor_chain_matches_serial_chain() {
        let mut rng = Pcg64::seeded(308);
        let a = Matrix::low_rank(768, 512, 16, &mut rng);
        let b = Matrix::low_rank(512, 768, 16, &mut rng);
        let cfg = LowRankConfig {
            rank: RankStrategy::Fixed(16),
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let fa = factorize(&a, &cfg).unwrap();
        let fb = factorize(&b, &cfg).unwrap();
        let serial = crate::lowrank::lowrank_matmul(&fa, &fb);
        // Bitwise across worker counts…
        let c1 = exec(1).lowrank_matmul(&fa, &fb).unwrap();
        let c4 = exec(4).lowrank_matmul(&fa, &fb).unwrap();
        assert_eq!(c1.data(), c4.data());
        // …and bitwise against the monolithic chain (aligned default grid,
        // every constituent product lands on the same kernel path).
        assert_eq!(serial.data(), c4.data());
    }

    #[test]
    fn dense_rhs_and_lhs_paths_match_serial() {
        let mut rng = Pcg64::seeded(309);
        let w = Matrix::low_rank(640, 512, 12, &mut rng);
        let x = Matrix::gaussian(512, 640, &mut rng);
        let cfg = LowRankConfig {
            rank: RankStrategy::Fixed(12),
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let fw = factorize(&w, &cfg).unwrap();
        let serial_rhs = crate::lowrank::lowrank_matmul_dense_rhs(&fw, &x);
        let sharded_rhs = exec(4).lowrank_matmul_dense_rhs(&fw, &x).unwrap();
        assert_eq!(serial_rhs.data(), sharded_rhs.data());

        let y = Matrix::gaussian(640, 640, &mut rng);
        let fw2 = factorize(&Matrix::low_rank(640, 512, 12, &mut rng), &cfg).unwrap();
        let serial_lhs = crate::lowrank::lowrank_matmul_dense_lhs(&y, &fw2);
        let sharded_lhs = exec(4).lowrank_matmul_dense_lhs(&y, &fw2).unwrap();
        assert_eq!(serial_lhs.data(), sharded_lhs.data());
    }

    #[test]
    fn per_shard_metrics_recorded() {
        let mut rng = Pcg64::seeded(310);
        let a = Matrix::gaussian(512, 128, &mut rng);
        let b = Matrix::gaussian(128, 512, &mut rng);
        let metrics = Arc::new(MetricsRegistry::new());
        let ex = ShardExecutor::with_metrics(
            ShardPlan {
                grid: TileGrid::default(),
                workers: 4,
                min_parallel_n: 64,
            },
            metrics.clone(),
        );
        ex.gemm(&a, &b).unwrap();
        let counters = metrics.counters();
        assert_eq!(counters.get("shard.gemm.parallel"), Some(&1));
        assert_eq!(counters.get("shard.tasks"), Some(&4)); // 2×2 grid
        let worker_tiles: u64 = counters
            .iter()
            .filter(|(k, _)| k.starts_with("shard.worker."))
            .map(|(_, v)| *v)
            .sum();
        assert_eq!(worker_tiles, 4, "every tile attributed to a worker");
        let hists = metrics.histogram_summaries();
        assert_eq!(hists.get("shard.tile_us").map(|h| h.count), Some(4));
    }

    #[test]
    fn shape_mismatches_rejected() {
        let ex = exec(2);
        let a = Matrix::zeros(8, 9);
        let b = Matrix::zeros(10, 8);
        assert!(ex.gemm(&a, &b).is_err());
        assert!(ex.matmul_tn(&a, &b).is_err());
    }

    #[test]
    fn pending_jobs_observable() {
        let ex = exec(2);
        // Nothing queued at rest.
        assert_eq!(ex.pending_jobs(), 0);
    }
}
