//! The tile executor: atomic work-claiming over [`crate::exec::ThreadPool`].
//!
//! One GEMM becomes `tile_count` independent tasks (one per output tile —
//! no inter-task dependencies, since C tiles are disjoint). The executor
//! packs both operands **once** ([`crate::linalg::pack`]) and shares the
//! read-only [`PackedA`]/[`PackedB`] across every claim job; each claim
//! job races an atomic cursor over the task list, computes every tile it
//! wins with [`gemm_panel_packed`], and streams the finished tile back
//! over a channel. The caller assembles tiles into C in arrival order —
//! legal because tiles are disjoint and each tile's bits are fixed by the
//! tile alone. Panel fetches beyond the first per panel surface as the
//! `pack.reuse` counter — exactly the per-tile re-packs the pre-packed
//! plane no longer pays. Grids not aligned to the kernel's MC/NC blocking
//! fall back to the legacy per-tile [`gemm_panel`] path (counted as
//! `pack.unaligned_fallback`).
//!
//! The FP8 dense path is *fused*: operands are quantized once and the
//! codec bytes are decoded straight into the packed panel layout — the
//! full-matrix f32 intermediates of the old round-trip are never
//! materialized. The low-rank factor chain threads its rank-sized
//! intermediates (and the dequantized factor panels) through the pack
//! arena, so a steady-state chain does no hot-path allocation beyond the
//! result itself; with a pre-packed cached Vᵀ_B
//! ([`lowrank_matmul_prepacked`]) even the reconstruction operand's
//! decode+pack is skipped.
//!
//! Determinism contract: for a fixed [`ShardPlan`] grid, results are
//! **bitwise identical for every worker count** (the per-tile summation
//! order never depends on who computes the tile or when). With the
//! default MC/NC-aligned grid, dense results are additionally bitwise
//! identical to single-threaded [`gemm_blocked`] whenever the monolithic
//! kernel takes its blocked path — and the packed, fused and prepacked
//! variants reproduce those same bits (`rust/tests/pack_equivalence.rs`).
//!
//! Two pool layouts:
//!
//! - **Owned** (the default): a dedicated [`ThreadPool`] separate from the
//!   coordinator's request-level pool. A request worker blocks in
//!   [`ShardExecutor`] while its tiles run here, which would deadlock on a
//!   shared FIFO pool — the historical rationale for the split.
//! - **Shared** (`[scheduler]`): tiles run on the coordinator's unified
//!   work-stealing [`StealPool`], as stealable leaves next to request
//!   jobs. The FIFO deadlock argument is overturned by *caller
//!   participation*: the requesting job claims tiles off the atomic
//!   cursor itself (helpers it spawns are an acceleration, not a
//!   prerequisite), and it only ever waits on tiles a live helper already
//!   claimed — progress at any pool size, including one. A lone huge GEMM
//!   fans its tiles across every core; tiles of queued requests
//!   load-balance by stealing. Results stay bitwise identical to the
//!   owned layout: the claim discipline decides only *who* computes a
//!   tile, never what its bits are.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::exec::ThreadPool;
use crate::fault::{FaultPlane, TileFault};
use crate::fp8::quantize::QuantizedTensor;
use crate::fp8::{dequantize, dequantize_into, quantize, quantized_matmul_fused, StorageFormat};
use crate::linalg::gemm::{
    gemm_blocked, gemm_packed, gemm_panel, gemm_panel_packed, kernel_params, KernelParams,
};
use crate::linalg::matrix::Matrix;
use crate::linalg::pack::{self, PackedA, PackedB};
use crate::lowrank::factor::LowRankFactor;
use crate::metrics::{Counter, HistogramHandle, MetricsRegistry};
use crate::sched::{self, task_was_stolen, StealPool};
use crate::shard::plan::{ShardPlan, Tile};
use crate::trace_plane;

/// Interned handles for every metric the tile plane emits, resolved once
/// at executor construction — the claim loop and pack paths never touch
/// the registry's name map again.
struct ShardMetrics {
    gemm_serial: Arc<Counter>,
    gemm_parallel: Arc<Counter>,
    tasks: Arc<Counter>,
    tile_us: Arc<HistogramHandle>,
    pack_panels: Arc<Counter>,
    pack_reuse: Arc<Counter>,
    pack_fused_decode: Arc<Counter>,
    pack_unaligned_fallback: Arc<Counter>,
    pack_prepacked_use: Arc<Counter>,
    /// `shard.worker.{w}.tiles`, indexed by claim-job ordinal.
    worker_tiles: Vec<Arc<Counter>>,
}

impl ShardMetrics {
    fn new(registry: &MetricsRegistry, workers: usize) -> Self {
        ShardMetrics {
            gemm_serial: registry.counter("shard.gemm.serial"),
            gemm_parallel: registry.counter("shard.gemm.parallel"),
            tasks: registry.counter("shard.tasks"),
            tile_us: registry.histogram("shard.tile_us"),
            pack_panels: registry.counter("pack.panels"),
            pack_reuse: registry.counter("pack.reuse"),
            pack_fused_decode: registry.counter("pack.fused_decode"),
            pack_unaligned_fallback: registry.counter("pack.unaligned_fallback"),
            pack_prepacked_use: registry.counter("pack.prepacked_use"),
            worker_tiles: (0..workers.max(1))
                .map(|w| registry.counter(&format!("shard.worker.{w}.tiles")))
                .collect(),
        }
    }
}

/// The pool tile claim jobs run on: owned (the dedicated two-pool
/// layout) or shared with the coordinator (the `[scheduler]` layout).
enum TilePool {
    Owned(ThreadPool),
    Shared(Arc<StealPool>),
}

/// Executes GEMM-shaped work over a tile grid — on a dedicated worker
/// pool by default, or on the coordinator's unified work-stealing pool
/// under `[scheduler]` (see the [module docs](self)).
pub struct ShardExecutor {
    plan: ShardPlan,
    pool: TilePool,
    metrics: Option<Arc<ShardMetrics>>,
    /// Fault plane: when set, every tile runs under the per-tile panic
    /// guard (plus injection), probes are backlog-bounded, and an owned
    /// pool carries the worker-loop panic hook. `None` (the default) is
    /// the historical behavior bit-for-bit.
    fault: Option<Arc<FaultPlane>>,
}

impl ShardExecutor {
    /// Executor with a fresh pool of `plan.workers` threads, no metrics.
    pub fn new(plan: ShardPlan) -> Self {
        ShardExecutor {
            pool: TilePool::Owned(ThreadPool::new(plan.workers)),
            metrics: None,
            fault: None,
            plan,
        }
    }

    /// Executor reporting per-shard timings into `metrics`
    /// (`shard.tile_us` histogram, `shard.*` counters, `pack.*` reuse).
    pub fn with_metrics(plan: ShardPlan, metrics: Arc<MetricsRegistry>) -> Self {
        ShardExecutor {
            pool: TilePool::Owned(ThreadPool::new(plan.workers)),
            metrics: Some(Arc::new(ShardMetrics::new(&metrics, plan.workers))),
            fault: None,
            plan,
        }
    }

    /// Attach the fault plane (builder, construction time only). An owned
    /// pool is rebuilt with the worker-loop panic hook so even a panic
    /// escaping the per-tile guard cannot kill a tile worker; a shared
    /// pool already carries the hook from its own construction.
    pub fn with_fault(mut self, fault: Arc<FaultPlane>) -> Self {
        if matches!(&self.pool, TilePool::Owned(_)) {
            self.pool = TilePool::Owned(ThreadPool::with_panic_hook(
                self.plan.workers,
                Some(fault.panic_exec_counter()),
            ));
        }
        self.fault = Some(fault);
        self
    }

    /// Executor running its tiles on the coordinator's unified
    /// work-stealing pool instead of an owned one. The per-worker tile
    /// counters get one extra slot (`shard.worker.{size}.tiles`) for the
    /// caller's own participating claim loop.
    pub fn with_shared_pool(
        plan: ShardPlan,
        pool: Arc<StealPool>,
        metrics: Arc<MetricsRegistry>,
    ) -> Self {
        let slots = pool.size() + 1;
        ShardExecutor {
            pool: TilePool::Shared(pool),
            metrics: Some(Arc::new(ShardMetrics::new(&metrics, slots))),
            fault: None,
            plan,
        }
    }

    /// The plan this executor runs.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Claim jobs submitted to the pool but not yet started (other GEMMs
    /// in flight ahead of ours).
    pub fn pending_jobs(&self) -> u64 {
        match &self.pool {
            TilePool::Owned(p) => p.pending(),
            TilePool::Shared(p) => p.pending(),
        }
    }

    /// Run an arbitrary job on the shard pool's spare cycles. The owned
    /// pool is FIFO, so the job queues *behind* every tile task already
    /// submitted — effectively low-priority background work (the accuracy
    /// plane's error probes ride here so they never block a serving
    /// request). On the shared pool the job lands on the injector, behind
    /// whatever is already queued there. The job must be self-contained:
    /// nothing waits on it.
    pub fn execute_background(&self, job: impl FnOnce() + Send + 'static) {
        match &self.pool {
            TilePool::Owned(p) => p.execute(job),
            TilePool::Shared(p) => p.spawn(job),
        }
    }

    /// [`execute_background`](Self::execute_background) with the fault
    /// plane's probe-backlog bound: at most `cap` such jobs in flight,
    /// returns `false` (job dropped, nothing scheduled) when the backlog
    /// is full — the caller counts the shed. Without a fault plane the
    /// job is always scheduled (the historical unbounded behavior).
    pub fn try_execute_background(&self, cap: usize, job: impl FnOnce() + Send + 'static) -> bool {
        let Some(plane) = &self.fault else {
            self.execute_background(job);
            return true;
        };
        if !plane.try_reserve_probe(cap) {
            return false;
        }
        // Drop guard: the slot is released even if the job panics (the
        // probe wrapper upstream contains it, but the slot accounting
        // must not depend on that).
        struct Slot(Arc<FaultPlane>);
        impl Drop for Slot {
            fn drop(&mut self) {
                self.0.release_probe();
            }
        }
        let slot = Slot(plane.clone());
        self.execute_background(move || {
            let _slot = slot;
            job();
        });
        true
    }

    /// Is the tile grid aligned to the kernel blocking, so tiles can read
    /// the shared packed operands (and stay bitwise-equal to the
    /// monolithic kernel)?
    fn grid_aligned(&self, p: &KernelParams) -> bool {
        self.plan.grid.tile_m % p.mc == 0 && self.plan.grid.tile_n % p.nc == 0
    }

    /// Report pack-once/reuse-many accounting for one sharded product
    /// over operands packed *by this request* (their `uses` counters
    /// started at zero, so lifetime reuse == this request's reuse). For
    /// cache-resident operands use [`note_prepacked_stats`] instead —
    /// re-emitting a long-lived panel's cumulative counters every request
    /// would inflate the metric quadratically.
    fn note_pack_stats(&self, pa: &PackedA, pb: &PackedB) {
        if let Some(m) = &self.metrics {
            m.pack_panels.add((pa.blocks() + pb.panels()) as u64);
            m.pack_reuse.add(pa.reuse() + pb.reuse());
        }
    }

    /// Accounting for a product over a freshly packed A and a long-lived
    /// (cache-resident) B: only A's panels were packed now, and every one
    /// of this request's B fetches (`uses` delta) is a decode+pack the
    /// prepacked entry saved.
    fn note_prepacked_stats(&self, pa: &PackedA, pb_fetches: u64) {
        if let Some(m) = &self.metrics {
            m.pack_panels.add(pa.blocks() as u64);
            m.pack_reuse.add(pa.reuse() + pb_fetches);
        }
    }

    /// Give a finished product's packed operands back to this thread's
    /// arena. No-op for operands still shared (e.g. cache-resident
    /// prepacked panels keep their Arc alive).
    fn recycle_packed(pa: Arc<PackedA>, pb: Arc<PackedB>) {
        if let Ok(pa) = Arc::try_unwrap(pa) {
            pa.recycle();
        }
        if let Ok(pb) = Arc::try_unwrap(pb) {
            pb.recycle();
        }
    }

    /// `C = A · B`. Routes to the tile plane when the plan's gates pass,
    /// to the single-threaded blocked kernel otherwise.
    pub fn gemm(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        if a.cols() != b.rows() {
            return Err(Error::ShapeMismatch {
                op: "shard gemm",
                lhs: a.shape(),
                rhs: b.shape(),
            });
        }
        let (m, k) = a.shape();
        let n = b.cols();
        if !self.plan.should_parallelize(m, n, k) {
            if let Some(sm) = &self.metrics {
                sm.gemm_serial.inc();
            }
            return gemm_blocked(a, b);
        }
        if let Some(sm) = &self.metrics {
            sm.gemm_parallel.inc();
        }
        self.mm_sharded(a, b)
    }

    /// FP8/F16 dense GEMM. On the packed plane the decode side of the
    /// codec round-trip is **fused into packing**: quantize once, decode
    /// the bytes straight into panel layout, shard the packed product —
    /// bit-for-bit the result of the old dequantize-then-multiply
    /// pipeline (per-tensor scale over the whole operand, f32 compute),
    /// without its full-matrix f32 intermediates.
    pub fn quantized_matmul(
        &self,
        a: &Matrix,
        b: &Matrix,
        format: StorageFormat,
    ) -> Result<Matrix> {
        if a.cols() != b.rows() {
            return Err(Error::ShapeMismatch {
                op: "shard gemm",
                lhs: a.shape(),
                rhs: b.shape(),
            });
        }
        let (m, k) = a.shape();
        let n = b.cols();
        let p = kernel_params();
        if self.plan.should_parallelize(m, n, k) && self.grid_aligned(&p) {
            if let Some(sm) = &self.metrics {
                sm.gemm_parallel.inc();
                sm.pack_fused_decode.inc();
            }
            let (pa, pb) = {
                let mut sp = trace_plane::span("pack");
                sp.attr_str("mode", "fused_decode");
                let qa = quantize(a, format);
                let qb = quantize(b, format);
                (
                    Arc::new(PackedA::pack_quantized(&qa, p.mc, p.kc)),
                    Arc::new(PackedB::pack_quantized(&qb, p.kc, p.nc)),
                )
            };
            let c = self.mm_sharded_packed(m, n, pa.clone(), pb.clone())?;
            self.note_pack_stats(&pa, &pb);
            Self::recycle_packed(pa, pb);
            return Ok(self.corrupt_if_injected(c));
        }
        if !self.plan.should_parallelize(m, n, k) {
            // Serial: the single-threaded fused path (falls back to the
            // naive round-trip itself below the blocked cutover) — bitwise
            // identical to the legacy dequantize-then-multiply pipeline.
            if let Some(sm) = &self.metrics {
                sm.gemm_serial.inc();
                if m * n * k > p.naive_cutover {
                    sm.pack_fused_decode.inc();
                }
            }
            return Ok(self.corrupt_if_injected(quantized_matmul_fused(a, b, format)));
        }
        // Parallel but unaligned grid: the legacy round-trip, sharded over
        // per-tile re-packing (the fused serial kernel would change the
        // unaligned grid's tile-local bits).
        let qa = dequantize(&quantize(a, format));
        let qb = dequantize(&quantize(b, format));
        self.gemm(&qa, &qb).map(|c| self.corrupt_if_injected(c))
    }

    /// Deterministic decode-corruption injection for the quantized paths:
    /// when the seeded draw fires, perturb one element of the finished
    /// product — silent wrong-answer corruption of exactly the kind the
    /// accuracy plane's probes exist to catch. No fault plane, or a
    /// non-firing draw, returns `c` untouched.
    fn corrupt_if_injected(&self, c: Matrix) -> Matrix {
        let Some(plane) = &self.fault else {
            return c;
        };
        if !plane.inject_corrupt_decode(plane.next_gemm_seq()) {
            return c;
        }
        let (m, n) = c.shape();
        let mut v = c.into_vec();
        if let Some(x) = v.first_mut() {
            *x = *x * 1.25 + 1.0;
        }
        Matrix::from_vec(m, n, v).expect("same payload length")
    }

    /// `C = Aᵀ · B` with the output row-panel-sharded (the rSVD projection
    /// primitive). Bitwise identical to [`Matrix::matmul_tn`] at every
    /// worker count: each output row accumulates over `t` in the same
    /// order on both paths.
    pub fn matmul_tn(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        if a.rows() != b.rows() {
            return Err(Error::ShapeMismatch {
                op: "shard matmul_tn",
                lhs: a.shape(),
                rhs: b.shape(),
            });
        }
        let m = a.cols();
        let n = b.cols();
        let k = a.rows();
        if !self.plan.should_parallelize(m, n, k) {
            return Ok(a.matmul_tn(b));
        }
        // Row panels only: the projection shapes are thin on one side, so
        // column-splitting would just shrink the per-task row sweep.
        let tile_m = self.plan.grid.tile_m.max(1);
        let tiles: Vec<Tile> = (0..m)
            .step_by(tile_m)
            .map(|r0| Tile {
                r0,
                r1: (r0 + tile_m).min(m),
                c0: 0,
                c1: n,
            })
            .collect();
        let a = Arc::new(a.clone());
        let b = Arc::new(b.clone());
        let ntasks = tiles.len();
        let tiles = Arc::new(tiles);
        let work: WorkFn = Arc::new(move |i| {
            let t = tiles[i];
            Ok((t, tn_panel(&a, &b, t.r0, t.r1)))
        });
        self.run_and_assemble(m, n, ntasks, work)
    }

    /// Factor-chain GEMM (`C ≈ U_A Σ_A V_Aᵀ U_B Σ_B V_Bᵀ`), every dense
    /// product routed through the tile plane. Mirrors
    /// [`crate::lowrank::lowrank_matmul`] including its contraction-order
    /// choice; the rank-sized inner products fall under the parallel gates
    /// and run single-threaded, the m×n-sized reconstruction shards.
    pub fn lowrank_matmul(&self, fa: &LowRankFactor, fb: &LowRankFactor) -> Result<Matrix> {
        self.lowrank_matmul_prepacked(fa, fb, None)
    }

    /// [`lowrank_matmul`](Self::lowrank_matmul) with an optional
    /// pre-packed `Vᵀ_B` (the factor-cache plane stores one per entry):
    /// the reconstruction product then reads the cached panels directly —
    /// no decode, no pack — and stays bitwise identical to the cold chain.
    /// All intermediates thread through the pack arena (no `Matrix::zeros`
    /// on the chain).
    pub fn lowrank_matmul_prepacked(
        &self,
        fa: &LowRankFactor,
        fb: &LowRankFactor,
        packed_vbt: Option<&Arc<PackedB>>,
    ) -> Result<Matrix> {
        if fa.orig_shape.1 != fb.orig_shape.0 {
            return Err(Error::ShapeMismatch {
                op: "shard lowrank gemm",
                lhs: fa.orig_shape,
                rhs: fb.orig_shape,
            });
        }
        let vat = self.dense_mat(&fa.vt);
        let ub = self.dense_mat(&fb.u);
        let mut t2 = self.gemm(&vat, &ub)?;
        pack::recycle(vat.into_vec());
        pack::recycle(ub.into_vec());
        t2.scale_rows_in_place(&fa.s);
        t2.scale_cols_in_place(&fb.s);

        let (m, _) = fa.orig_shape;
        let (_, n) = fb.orig_shape;
        let ua = self.dense_mat(&fa.u);
        let c = if m <= n {
            let t3 = self.gemm(&ua, &t2)?;
            pack::recycle(t2.into_vec());
            let c = self.gemm_b_factor(&t3, fb, packed_vbt)?;
            pack::recycle(t3.into_vec());
            c
        } else {
            let t3 = self.gemm_b_factor(&t2, fb, packed_vbt)?;
            pack::recycle(t2.into_vec());
            let c = self.gemm(&ua, &t3)?;
            pack::recycle(t3.into_vec());
            c
        };
        pack::recycle(ua.into_vec());
        Ok(c)
    }

    /// Factor × dense GEMM (`A` factored, `B` dense) on the tile plane,
    /// intermediates through the pack arena.
    pub fn lowrank_matmul_dense_rhs(&self, fa: &LowRankFactor, b: &Matrix) -> Result<Matrix> {
        if fa.orig_shape.1 != b.rows() {
            return Err(Error::ShapeMismatch {
                op: "shard lowrank×dense",
                lhs: fa.orig_shape,
                rhs: b.shape(),
            });
        }
        let vat = self.dense_mat(&fa.vt);
        let mut t = self.gemm(&vat, b)?;
        pack::recycle(vat.into_vec());
        t.scale_rows_in_place(&fa.s);
        let ua = self.dense_mat(&fa.u);
        let c = self.gemm(&ua, &t)?;
        pack::recycle(ua.into_vec());
        pack::recycle(t.into_vec());
        Ok(c)
    }

    /// Dense × factor GEMM (`B` factored) on the tile plane,
    /// intermediates through the pack arena.
    pub fn lowrank_matmul_dense_lhs(&self, a: &Matrix, fb: &LowRankFactor) -> Result<Matrix> {
        if a.cols() != fb.orig_shape.0 {
            return Err(Error::ShapeMismatch {
                op: "shard dense×lowrank",
                lhs: a.shape(),
                rhs: fb.orig_shape,
            });
        }
        let ub = self.dense_mat(&fb.u);
        let mut t = self.gemm(a, &ub)?;
        pack::recycle(ub.into_vec());
        t.scale_cols_in_place(&fb.s);
        let vbt = self.dense_mat(&fb.vt);
        let c = self.gemm(&t, &vbt)?;
        pack::recycle(vbt.into_vec());
        pack::recycle(t.into_vec());
        Ok(c)
    }

    /// Dequantize a factor tensor into an arena-backed matrix (recycled by
    /// the chain once consumed) — bit-identical values to
    /// [`LowRankFactor::u_dense`]/`vt_dense`, without their allocation.
    fn dense_mat(&self, q: &QuantizedTensor) -> Matrix {
        let (rows, cols) = q.shape;
        let mut buf = pack::checkout_stale(rows * cols);
        dequantize_into(q, &mut buf);
        Matrix::from_vec(rows, cols, buf).expect("decoded payload length")
    }

    /// `a · Vᵀ_B`, reading `Vᵀ_B` from the pre-packed panels when they fit
    /// this kernel geometry and routing (otherwise decode + the normal
    /// path). Every branch reproduces `self.gemm(a, vt_dense)` bit-for-bit,
    /// so prepacked cache hits equal cold fills exactly.
    fn gemm_b_factor(
        &self,
        a: &Matrix,
        fb: &LowRankFactor,
        prepacked: Option<&Arc<PackedB>>,
    ) -> Result<Matrix> {
        let p = kernel_params();
        let (m, k) = a.shape();
        let n = fb.vt.shape.1;
        if let Some(pb) = prepacked {
            let parallel = self.plan.should_parallelize(m, n, k);
            let usable = pb.k() == k
                && pb.n() == n
                && pb.kc() == p.kc
                && pb.nc() == p.nc
                && m * n * k > p.naive_cutover
                && (!parallel || self.grid_aligned(&p));
            if usable {
                if let Some(sm) = &self.metrics {
                    sm.pack_prepacked_use.inc();
                }
                // Delta, not lifetime: pb's uses counter spans every
                // request that ever hit this cache entry. Concurrent
                // requests sharing the entry can land fetches inside each
                // other's windows, so the per-request attribution is
                // approximate — the documented trade-off for not
                // threading a counter through the tile loop; the metric
                // stays linear in traffic either way.
                let pb_uses_before = pb.uses();
                if parallel {
                    if let Some(sm) = &self.metrics {
                        sm.gemm_parallel.inc();
                    }
                    let pa = {
                        let mut sp = trace_plane::span("pack");
                        sp.attr_str("mode", "prepacked_b");
                        Arc::new(PackedA::pack(a, p.mc, p.kc))
                    };
                    let c = self.mm_sharded_packed(m, n, pa.clone(), pb.clone())?;
                    self.note_prepacked_stats(&pa, pb.uses() - pb_uses_before);
                    if let Ok(pa) = Arc::try_unwrap(pa) {
                        pa.recycle();
                    }
                    return Ok(c);
                }
                if let Some(sm) = &self.metrics {
                    sm.gemm_serial.inc();
                }
                let pa = PackedA::pack(a, p.mc, p.kc);
                let c = gemm_packed(&pa, pb)?;
                self.note_prepacked_stats(&pa, pb.uses() - pb_uses_before);
                pa.recycle();
                return Ok(c);
            }
        }
        let vbt = self.dense_mat(&fb.vt);
        let c = self.gemm(a, &vbt)?;
        pack::recycle(vbt.into_vec());
        Ok(c)
    }

    /// The sharded dense product. On MC/NC-aligned grids both operands
    /// are packed once and shared read-only across the claim jobs (the
    /// pack-once/reuse-many path); unaligned grids keep the legacy
    /// per-tile packing.
    fn mm_sharded(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let p = kernel_params();
        if !self.grid_aligned(&p) {
            if let Some(sm) = &self.metrics {
                sm.pack_unaligned_fallback.inc();
            }
            return self.mm_sharded_unpacked(a, b);
        }
        let m = a.rows();
        let n = b.cols();
        let (pa, pb) = {
            let mut sp = trace_plane::span("pack");
            sp.attr_str("mode", "shared");
            (
                Arc::new(PackedA::pack(a, p.mc, p.kc)),
                Arc::new(PackedB::pack(b, p.kc, p.nc)),
            )
        };
        let c = self.mm_sharded_packed(m, n, pa.clone(), pb.clone())?;
        self.note_pack_stats(&pa, &pb);
        Self::recycle_packed(pa, pb);
        Ok(c)
    }

    /// Tile grid → claim jobs over shared packed operands → assembly.
    fn mm_sharded_packed(
        &self,
        m: usize,
        n: usize,
        pa: Arc<PackedA>,
        pb: Arc<PackedB>,
    ) -> Result<Matrix> {
        let tiles = self.plan.grid.tiles(m, n);
        let ntasks = tiles.len();
        let tiles = Arc::new(tiles);
        let work: WorkFn = Arc::new(move |i| {
            let t = tiles[i];
            gemm_panel_packed(&pa, &pb, t.r0, t.rows(), t.c0, t.cols())
                .map(|p| (t, p.into_vec()))
        });
        self.run_and_assemble(m, n, ntasks, work)
    }

    /// Legacy sharded product (per-tile B re-pack inside [`gemm_panel`]) —
    /// the fallback for grids not aligned to the kernel blocking.
    ///
    /// The operands are cloned into `Arc`s so the claim jobs are
    /// `'static` for the pool. That copy is O(m·k + k·n) against the
    /// product's O(m·k·n) — under the FLOP gate it is < 1% of the work —
    /// but it does hold a second transient copy of A/B; a zero-copy
    /// scoped-execution pool is the known follow-up if memory headroom
    /// ever matters at N ≳ 16k.
    fn mm_sharded_unpacked(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let m = a.rows();
        let n = b.cols();
        let tiles = self.plan.grid.tiles(m, n);
        let ntasks = tiles.len();
        let a = Arc::new(a.clone());
        let b = Arc::new(b.clone());
        let tiles = Arc::new(tiles);
        let work: WorkFn = Arc::new(move |i| {
            let t = tiles[i];
            gemm_panel(&a, &b, t.r0, t.rows(), t.c0, t.cols()).map(|p| (t, p.into_vec()))
        });
        self.run_and_assemble(m, n, ntasks, work)
    }

    /// Fan `ntasks` out to claim jobs and collect every task's result.
    /// Tasks are claimed with an atomic cursor, so load-balancing is
    /// automatic: a worker stuck on a heavy remainder tile simply claims
    /// fewer tiles. Owned pool: `min(plan.workers, ntasks)` claim jobs,
    /// the caller only collects. Shared pool: the caller *participates*
    /// in the claim loop (see module docs for the deadlock-freedom
    /// argument).
    fn run_claimed(&self, ntasks: usize, work: WorkFn) -> Result<Vec<(Tile, Vec<f32>)>> {
        let work = match &self.fault {
            Some(plane) => Self::contained_work(plane.clone(), plane.next_gemm_seq(), work),
            None => work,
        };
        match &self.pool {
            TilePool::Owned(pool) => self.run_claimed_owned(pool, ntasks, work),
            TilePool::Shared(pool) => self.run_claimed_shared(pool, ntasks, work),
        }
    }

    /// Wrap a tile work function in the fault plane's per-tile guard:
    /// injected faults fire first (inside the guard, so an injected panic
    /// is contained exactly like a real one), then any panic out of the
    /// tile kernel is caught and converted into a typed per-tile error.
    /// The claim worker survives, the error flows through the normal
    /// result channel, and the owning request resolves with
    /// [`Error::KernelPanicked`] instead of hanging its collector on a
    /// tile that will never arrive.
    fn contained_work(plane: Arc<FaultPlane>, seq: u64, work: WorkFn) -> WorkFn {
        Arc::new(move |i| {
            let injected = plane.tile_fault(seq, i);
            catch_unwind(AssertUnwindSafe(|| {
                match injected {
                    Some(TileFault::Panic) => panic!("injected tile fault (seq {seq}, tile {i})"),
                    Some(TileFault::Stall(ms)) => {
                        std::thread::sleep(std::time::Duration::from_millis(ms));
                    }
                    None => {}
                }
                work(i)
            }))
            .unwrap_or_else(|_| {
                plane.note_panic_tile();
                Err(Error::KernelPanicked(format!("tile {i} of gemm {seq}")))
            })
        })
    }

    fn run_claimed_owned(
        &self,
        pool: &ThreadPool,
        ntasks: usize,
        work: WorkFn,
    ) -> Result<Vec<(Tile, Vec<f32>)>> {
        let next = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel::<Result<(Tile, Vec<f32>)>>();
        let nworkers = self.plan.workers.clamp(1, ntasks.max(1));
        // Pool threads never entered the request's trace scope; capture
        // the caller's context here so each claimed tile can attach a
        // `tile` span to the correct parent via `span_in`.
        let ctx = trace_plane::current();
        for w in 0..nworkers {
            let work = work.clone();
            let next = next.clone();
            let tx = tx.clone();
            let metrics = self.metrics.clone();
            let ctx = ctx.clone();
            pool.execute(move || {
                let mut claimed = 0u64;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= ntasks {
                        break;
                    }
                    let t0 = Instant::now();
                    let res = match &ctx {
                        Some(c) => {
                            let mut sp = trace_plane::span_in(c, "tile");
                            sp.attr_u64("tile", i as u64);
                            sp.attr_u64("worker", w as u64);
                            work(i)
                        }
                        None => work(i),
                    };
                    if let Some(m) = &metrics {
                        m.tile_us.observe(t0.elapsed().as_secs_f64() * 1e6);
                    }
                    claimed += 1;
                    if tx.send(res).is_err() {
                        break; // caller bailed on an earlier error
                    }
                }
                if claimed > 0 {
                    if let Some(m) = &metrics {
                        m.worker_tiles[w].add(claimed);
                    }
                }
            });
        }
        drop(tx);
        let mut out = Vec::with_capacity(ntasks);
        for msg in rx {
            out.push(msg?);
        }
        self.check_complete(ntasks, out)
    }

    /// The shared-pool claim loop. The caller spawns up to `pool.size()`
    /// helper claim jobs — onto its own deque when it is itself a pool
    /// worker (stealable by idle siblings), onto the injector otherwise —
    /// then claims tiles off the same cursor on its own thread. It stops
    /// collecting as soon as `ntasks` results are in: a helper job that
    /// never got picked up finds the cursor exhausted and no-ops, so the
    /// caller must *not* wait for the channel to close. Every `recv` that
    /// blocks corresponds to a tile a live helper has already claimed and
    /// is computing — progress at any pool size, including one.
    fn run_claimed_shared(
        &self,
        pool: &Arc<StealPool>,
        ntasks: usize,
        work: WorkFn,
    ) -> Result<Vec<(Tile, Vec<f32>)>> {
        let next = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel::<Result<(Tile, Vec<f32>)>>();
        let helpers = pool.size().min(ntasks.max(1).saturating_sub(1));
        let ctx = trace_plane::current();
        // Per-request tile accounting (the response's `stolen_tiles`):
        // TLS does not cross into pool workers, so capture the Arc here
        // and move clones into the helpers.
        let request = sched::current_request();
        for w in 0..helpers {
            let work = work.clone();
            let next = next.clone();
            let tx = tx.clone();
            let metrics = self.metrics.clone();
            let ctx = ctx.clone();
            let request = request.clone();
            pool.spawn(move || {
                // Whether *this helper job* was stolen off its home deque
                // — constant for every tile it claims.
                let stolen = task_was_stolen();
                let mut claimed = 0u64;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= ntasks {
                        break;
                    }
                    let t0 = Instant::now();
                    let res = match &ctx {
                        Some(c) => {
                            let mut sp = trace_plane::span_in(c, "tile");
                            sp.attr_u64("tile", i as u64);
                            sp.attr_u64("worker", w as u64);
                            sp.attr_u64("steal", stolen as u64);
                            work(i)
                        }
                        None => work(i),
                    };
                    if let Some(m) = &metrics {
                        m.tile_us.observe(t0.elapsed().as_secs_f64() * 1e6);
                    }
                    if let Some(r) = &request {
                        r.record(stolen);
                    }
                    claimed += 1;
                    if tx.send(res).is_err() {
                        break; // caller bailed on an earlier error
                    }
                }
                if claimed > 0 {
                    if let Some(m) = &metrics {
                        m.worker_tiles[w].add(claimed);
                    }
                }
            });
        }
        drop(tx);
        // Caller participation: claim tiles on this thread until the
        // cursor drains. The last worker_tiles slot is the caller's.
        let caller_slot = self
            .metrics
            .as_ref()
            .map(|m| m.worker_tiles.len() - 1)
            .unwrap_or(0);
        let mut out = Vec::with_capacity(ntasks);
        let mut caller_claimed = 0u64;
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= ntasks {
                break;
            }
            let t0 = Instant::now();
            let res = match &ctx {
                Some(c) => {
                    let mut sp = trace_plane::span_in(c, "tile");
                    sp.attr_u64("tile", i as u64);
                    sp.attr_u64("worker", caller_slot as u64);
                    sp.attr_u64("steal", 0);
                    work(i)
                }
                None => work(i),
            };
            if let Some(m) = &self.metrics {
                m.tile_us.observe(t0.elapsed().as_secs_f64() * 1e6);
            }
            if let Some(r) = &request {
                r.record(false);
            }
            caller_claimed += 1;
            out.push(res?);
        }
        if caller_claimed > 0 {
            if let Some(m) = &self.metrics {
                m.worker_tiles[caller_slot].add(caller_claimed);
            }
        }
        // Collect the helpers' tiles — counting to `ntasks`, not to
        // channel close (see the doc comment above).
        while out.len() < ntasks {
            match rx.recv() {
                Ok(msg) => out.push(msg?),
                Err(_) => break, // a helper died; caught below
            }
        }
        self.check_complete(ntasks, out)
    }

    /// Shared tail of the claim loops: the lost-tile invariant and the
    /// task counter.
    fn check_complete(
        &self,
        ntasks: usize,
        out: Vec<(Tile, Vec<f32>)>,
    ) -> Result<Vec<(Tile, Vec<f32>)>> {
        if out.len() != ntasks {
            return Err(Error::Service(format!(
                "shard executor lost tiles: {}/{ntasks} arrived",
                out.len()
            )));
        }
        if let Some(m) = &self.metrics {
            m.tasks.add(ntasks as u64);
        }
        Ok(out)
    }

    /// [`run_claimed`](Self::run_claimed) followed by tile assembly, the
    /// latter under an `assemble` span.
    fn run_and_assemble(&self, m: usize, n: usize, ntasks: usize, work: WorkFn) -> Result<Matrix> {
        let parts = self.run_claimed(ntasks, work)?;
        let mut sp = trace_plane::span("assemble");
        sp.attr_u64("tiles", ntasks as u64);
        Ok(assemble(m, n, parts))
    }
}

/// A claimable task: tile index → (tile, row-major tile payload).
type WorkFn = Arc<dyn Fn(usize) -> Result<(Tile, Vec<f32>)> + Send + Sync>;

/// Scatter disjoint tiles into the m×n output. The output buffer is an
/// uninit-safe arena checkout: every element is provably written because
/// the tile grid partitions the output (debug-asserted below), so the
/// zero-fill of `Matrix::zeros` would be dead stores.
fn assemble(m: usize, n: usize, parts: Vec<(Tile, Vec<f32>)>) -> Matrix {
    let mut data = pack::checkout_stale(m * n);
    let mut covered = 0usize;
    for (t, buf) in parts {
        let w = t.cols();
        covered += w * t.rows();
        for (ri, r) in (t.r0..t.r1).enumerate() {
            data[r * n + t.c0..r * n + t.c1].copy_from_slice(&buf[ri * w..(ri + 1) * w]);
        }
        // Tile payloads were checked out on worker-thread arenas; park
        // them in the caller's arena so the next request's packs reuse
        // the memory instead of churning the allocator.
        pack::recycle(buf);
    }
    debug_assert_eq!(covered, m * n, "tiles must cover the full output");
    Matrix::from_vec(m, n, data).expect("assembled size")
}

/// One row panel of `out = Aᵀ · B`: rows `i0..i1` of the m×n output
/// (`m = A.cols`). Per-element accumulation order (ascending `t`, with the
/// same zero-skip) is identical to [`Matrix::matmul_tn`], so panels are
/// bitwise-exact fragments of the single-threaded result.
fn tn_panel(a: &Matrix, b: &Matrix, i0: usize, i1: usize) -> Vec<f32> {
    let n = b.cols();
    let k = a.rows();
    let w = i1 - i0;
    let mut out = vec![0.0f32; w * n];
    for t in 0..k {
        let a_row = a.row(t);
        let b_row = b.row(t);
        for i in i0..i1 {
            let av = a_row[i];
            if av == 0.0 {
                continue;
            }
            let o = &mut out[(i - i0) * n..(i - i0 + 1) * n];
            for (ov, &bv) in o.iter_mut().zip(b_row) {
                *ov += av * bv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::quantized_matmul;
    use crate::linalg::rng::Pcg64;
    use crate::lowrank::factor::LowRankConfig;
    use crate::lowrank::factorize;
    use crate::lowrank::rank::RankStrategy;
    use crate::shard::plan::TileGrid;

    fn exec(workers: usize) -> ShardExecutor {
        ShardExecutor::new(ShardPlan {
            grid: TileGrid::default(),
            workers,
            min_parallel_n: 64,
        })
    }

    #[test]
    fn sharded_dense_is_bitwise_blocked_square() {
        let mut rng = Pcg64::seeded(301);
        let a = Matrix::gaussian(320, 320, &mut rng);
        let b = Matrix::gaussian(320, 320, &mut rng);
        let serial = gemm_blocked(&a, &b).unwrap();
        let sharded = exec(3).gemm(&a, &b).unwrap();
        assert_eq!(serial.data(), sharded.data());
    }

    #[test]
    fn sharded_dense_is_bitwise_blocked_tall_skinny() {
        let mut rng = Pcg64::seeded(302);
        // Tall output with a non-divisible row remainder (648 = 2·256+136).
        let a = Matrix::gaussian(648, 320, &mut rng);
        let b = Matrix::gaussian(320, 96, &mut rng);
        let serial = gemm_blocked(&a, &b).unwrap();
        let sharded = exec(4).gemm(&a, &b).unwrap();
        assert_eq!(serial.data(), sharded.data());
    }

    #[test]
    fn sharded_dense_handles_remainder_tiles() {
        let mut rng = Pcg64::seeded(303);
        // Both dimensions off the tile grid: 300×520 output.
        let a = Matrix::gaussian(300, 96, &mut rng);
        let b = Matrix::gaussian(96, 520, &mut rng);
        let serial = gemm_blocked(&a, &b).unwrap();
        let sharded = exec(3).gemm(&a, &b).unwrap();
        assert_eq!(serial.data(), sharded.data());
    }

    #[test]
    fn worker_count_never_changes_bits() {
        let mut rng = Pcg64::seeded(304);
        let a = Matrix::gaussian(520, 200, &mut rng);
        let b = Matrix::gaussian(200, 330, &mut rng);
        let one = exec(1).gemm(&a, &b).unwrap();
        for workers in [2, 3, 8] {
            let many = exec(workers).gemm(&a, &b).unwrap();
            assert_eq!(one.data(), many.data(), "workers={workers}");
        }
    }

    #[test]
    fn unaligned_grid_fallback_is_bitwise_stable() {
        // A grid off the MC/NC blocking loses the packed fast path but
        // must keep the worker-count determinism contract.
        let mut rng = Pcg64::seeded(311);
        let a = Matrix::gaussian(300, 128, &mut rng);
        let b = Matrix::gaussian(128, 300, &mut rng);
        let mk = |workers| {
            ShardExecutor::new(ShardPlan {
                grid: TileGrid::new(100, 100),
                workers,
                min_parallel_n: 64,
            })
        };
        let one = mk(1).gemm(&a, &b).unwrap();
        let four = mk(4).gemm(&a, &b).unwrap();
        assert_eq!(one.data(), four.data());
        assert!(one.rel_frobenius_distance(&a.matmul(&b)) < 1e-5);
    }

    #[test]
    fn small_requests_stay_serial() {
        let mut rng = Pcg64::seeded(305);
        let a = Matrix::gaussian(32, 32, &mut rng);
        let b = Matrix::gaussian(32, 32, &mut rng);
        let metrics = Arc::new(MetricsRegistry::new());
        let ex = ShardExecutor::with_metrics(ShardPlan::default(), metrics.clone());
        let c = ex.gemm(&a, &b).unwrap();
        assert!(c.rel_frobenius_distance(&a.matmul(&b)) < 1e-6);
        let counters = metrics.counters();
        assert_eq!(counters.get("shard.gemm.serial"), Some(&1));
        // Handles are pre-registered, so the parallel counter exists at 0.
        assert_eq!(counters.get("shard.gemm.parallel"), Some(&0));
    }

    #[test]
    fn fp8_sharded_is_bitwise_quantized_matmul() {
        let mut rng = Pcg64::seeded(306);
        let a = Matrix::gaussian(256, 192, &mut rng);
        let b = Matrix::gaussian(192, 320, &mut rng);
        let fmt = StorageFormat::Fp8(crate::fp8::Fp8Format::E4M3);
        let serial = quantized_matmul(&a, &b, fmt);
        for workers in [1, 4] {
            let sharded = exec(workers).quantized_matmul(&a, &b, fmt).unwrap();
            assert_eq!(serial.data(), sharded.data(), "workers={workers}");
        }
    }

    #[test]
    fn matmul_tn_sharded_is_bitwise_serial() {
        let mut rng = Pcg64::seeded(307);
        // out is 640×40 (row panels), k = 1024 — the rSVD projection shape.
        let a = Matrix::gaussian(1024, 640, &mut rng);
        let b = Matrix::gaussian(1024, 40, &mut rng);
        let serial = a.matmul_tn(&b);
        let sharded = exec(3).matmul_tn(&a, &b).unwrap();
        assert_eq!(serial.data(), sharded.data());
    }

    #[test]
    fn factor_chain_matches_serial_chain() {
        let mut rng = Pcg64::seeded(308);
        let a = Matrix::low_rank(768, 512, 16, &mut rng);
        let b = Matrix::low_rank(512, 768, 16, &mut rng);
        let cfg = LowRankConfig {
            rank: RankStrategy::Fixed(16),
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let fa = factorize(&a, &cfg).unwrap();
        let fb = factorize(&b, &cfg).unwrap();
        let serial = crate::lowrank::lowrank_matmul(&fa, &fb);
        // Bitwise across worker counts…
        let c1 = exec(1).lowrank_matmul(&fa, &fb).unwrap();
        let c4 = exec(4).lowrank_matmul(&fa, &fb).unwrap();
        assert_eq!(c1.data(), c4.data());
        // …and bitwise against the monolithic chain (aligned default grid,
        // every constituent product lands on the same kernel path).
        assert_eq!(serial.data(), c4.data());
    }

    #[test]
    fn prepacked_vbt_chain_is_bitwise_identical() {
        let mut rng = Pcg64::seeded(312);
        let a = Matrix::low_rank(640, 512, 12, &mut rng);
        let b = Matrix::low_rank(512, 640, 12, &mut rng);
        let cfg = LowRankConfig {
            rank: RankStrategy::Fixed(12),
            storage: StorageFormat::Fp8(crate::fp8::Fp8Format::E4M3),
            ..Default::default()
        };
        let fa = factorize(&a, &cfg).unwrap();
        let fb = factorize(&b, &cfg).unwrap();
        let p = kernel_params();
        let pb = Arc::new(PackedB::pack_quantized(&fb.vt, p.kc, p.nc));
        for workers in [1, 4] {
            let plain = exec(workers).lowrank_matmul(&fa, &fb).unwrap();
            let pre = exec(workers)
                .lowrank_matmul_prepacked(&fa, &fb, Some(&pb))
                .unwrap();
            assert_eq!(plain.data(), pre.data(), "workers={workers}");
        }
    }

    #[test]
    fn dense_rhs_and_lhs_paths_match_serial() {
        let mut rng = Pcg64::seeded(309);
        let w = Matrix::low_rank(640, 512, 12, &mut rng);
        let x = Matrix::gaussian(512, 640, &mut rng);
        let cfg = LowRankConfig {
            rank: RankStrategy::Fixed(12),
            storage: StorageFormat::F32,
            ..Default::default()
        };
        let fw = factorize(&w, &cfg).unwrap();
        let serial_rhs = crate::lowrank::lowrank_matmul_dense_rhs(&fw, &x);
        let sharded_rhs = exec(4).lowrank_matmul_dense_rhs(&fw, &x).unwrap();
        assert_eq!(serial_rhs.data(), sharded_rhs.data());

        let y = Matrix::gaussian(640, 640, &mut rng);
        let fw2 = factorize(&Matrix::low_rank(640, 512, 12, &mut rng), &cfg).unwrap();
        let serial_lhs = crate::lowrank::lowrank_matmul_dense_lhs(&y, &fw2);
        let sharded_lhs = exec(4).lowrank_matmul_dense_lhs(&y, &fw2).unwrap();
        assert_eq!(serial_lhs.data(), sharded_lhs.data());
    }

    #[test]
    fn per_shard_metrics_recorded() {
        let mut rng = Pcg64::seeded(310);
        let a = Matrix::gaussian(512, 128, &mut rng);
        let b = Matrix::gaussian(128, 512, &mut rng);
        let metrics = Arc::new(MetricsRegistry::new());
        let ex = ShardExecutor::with_metrics(
            ShardPlan {
                grid: TileGrid::default(),
                workers: 4,
                min_parallel_n: 64,
            },
            metrics.clone(),
        );
        ex.gemm(&a, &b).unwrap();
        let counters = metrics.counters();
        assert_eq!(counters.get("shard.gemm.parallel"), Some(&1));
        assert_eq!(counters.get("shard.tasks"), Some(&4)); // 2×2 grid
        let worker_tiles: u64 = counters
            .iter()
            .filter(|(k, _)| k.starts_with("shard.worker."))
            .map(|(_, v)| *v)
            .sum();
        assert_eq!(worker_tiles, 4, "every tile attributed to a worker");
        let hists = metrics.histogram_summaries();
        assert_eq!(hists.get("shard.tile_us").map(|h| h.count), Some(4));
    }

    #[test]
    fn pack_reuse_counted_on_multi_tile_runs() {
        // 512×512 over the default 256×256 grid: 4 tiles sharing the
        // packed panels — every fetch past the first per panel is a saved
        // re-pack and must show up in `pack.reuse`.
        let mut rng = Pcg64::seeded(313);
        let a = Matrix::gaussian(512, 512, &mut rng);
        let b = Matrix::gaussian(512, 512, &mut rng);
        let metrics = Arc::new(MetricsRegistry::new());
        let ex = ShardExecutor::with_metrics(
            ShardPlan {
                grid: TileGrid::default(),
                workers: 4,
                min_parallel_n: 64,
            },
            metrics.clone(),
        );
        ex.gemm(&a, &b).unwrap();
        let counters = metrics.counters();
        assert!(counters.get("pack.panels").copied().unwrap_or(0) > 0);
        // PackedA: 4×2 blocks fetched 2·2·2 times per tile-row/col;
        // PackedB: 2×2 panels fetched once per tile × k-step. Exact value
        // is geometry-dependent — the invariant is strictly positive.
        assert!(
            counters.get("pack.reuse").copied().unwrap_or(0) > 0,
            "multi-tile run must reuse shared panels: {counters:?}"
        );
        assert_eq!(counters.get("pack.unaligned_fallback"), Some(&0));
    }

    #[test]
    fn fused_fp8_counts_and_matches_unfused() {
        let mut rng = Pcg64::seeded(314);
        let a = Matrix::gaussian(512, 256, &mut rng);
        let b = Matrix::gaussian(256, 512, &mut rng);
        let fmt = StorageFormat::Fp8(crate::fp8::Fp8Format::E5M2);
        let metrics = Arc::new(MetricsRegistry::new());
        let ex = ShardExecutor::with_metrics(
            ShardPlan {
                grid: TileGrid::default(),
                workers: 2,
                min_parallel_n: 64,
            },
            metrics.clone(),
        );
        let fused = ex.quantized_matmul(&a, &b, fmt).unwrap();
        assert_eq!(fused.data(), quantized_matmul(&a, &b, fmt).data());
        assert_eq!(metrics.counters().get("pack.fused_decode"), Some(&1));
    }

    #[test]
    fn shape_mismatches_rejected() {
        let ex = exec(2);
        let a = Matrix::zeros(8, 9);
        let b = Matrix::zeros(10, 8);
        assert!(ex.gemm(&a, &b).is_err());
        assert!(ex.matmul_tn(&a, &b).is_err());
        assert!(ex
            .quantized_matmul(&a, &b, StorageFormat::F16)
            .is_err());
    }

    #[test]
    fn pending_jobs_observable() {
        let ex = exec(2);
        // Nothing queued at rest.
        assert_eq!(ex.pending_jobs(), 0);
    }
}
