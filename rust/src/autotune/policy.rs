//! ε-greedy exploration for the calibrated router.
//!
//! A selector that always exploits its current cost model starves the
//! calibration loop: a kernel whose (stale) prediction says "slow" is
//! never chosen, so no fresh samples ever correct the prediction. The
//! classic fix is ε-greedy sampling — with small probability ε, serve a
//! request on a deliberately non-optimal kernel. The router restricts
//! exploration to kernels whose *predicted error* still fits the
//! request's tolerance, so exploration trades latency, never accuracy.

use std::sync::Mutex;

use crate::linalg::Pcg64;

/// Seeded ε-greedy chooser. Thread-safe: the RNG sits behind a mutex
/// (one lock per routing decision, and only when autotuning is on).
#[derive(Debug)]
pub struct ExplorePolicy {
    epsilon: f64,
    rng: Mutex<Pcg64>,
}

impl ExplorePolicy {
    /// Policy exploring with probability `epsilon` (clamped to [0, 1]).
    /// Deterministic for a given `seed` — tests pin the sequence.
    pub fn new(epsilon: f64, seed: u64) -> Self {
        ExplorePolicy {
            epsilon: epsilon.clamp(0.0, 1.0),
            rng: Mutex::new(Pcg64::seeded(seed)),
        }
    }

    /// The configured exploration rate.
    pub fn epsilon(&self) -> f64 {
        self.epsilon
    }

    /// Roll the ε dice: should this request explore? Callers roll
    /// *before* computing the (more expensive) alternative set, so at
    /// small ε the exploitation path pays only this one RNG draw.
    pub fn roll(&self) -> bool {
        if self.epsilon <= 0.0 {
            return false;
        }
        self.rng.lock().unwrap().next_f64() < self.epsilon
    }

    /// Uniform choice among `alternatives` (no ε roll — pair with
    /// [`roll`](ExplorePolicy::roll)). `None` when there is nothing to
    /// explore.
    pub fn choose<T: Copy>(&self, alternatives: &[T]) -> Option<T> {
        if alternatives.is_empty() {
            return None;
        }
        let i = self.rng.lock().unwrap().below(alternatives.len() as u64) as usize;
        Some(alternatives[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_epsilon_never_rolls_true() {
        let p = ExplorePolicy::new(0.0, 7);
        for _ in 0..100 {
            assert!(!p.roll());
        }
    }

    #[test]
    fn unit_epsilon_always_rolls_and_choose_covers_all_arms() {
        let p = ExplorePolicy::new(1.0, 7);
        let mut seen = [false; 3];
        for _ in 0..100 {
            assert!(p.roll(), "ε=1 must explore");
            let arm = p.choose(&[0usize, 1, 2]).expect("non-empty choose");
            seen[arm] = true;
        }
        assert!(seen.iter().all(|&s| s), "all arms sampled: {seen:?}");
    }

    #[test]
    fn exploration_rate_tracks_epsilon() {
        let p = ExplorePolicy::new(0.25, 42);
        let trials = 4000;
        let explored = (0..trials).filter(|_| p.roll()).count();
        let rate = explored as f64 / trials as f64;
        assert!((rate - 0.25).abs() < 0.05, "rate {rate}");
    }

    #[test]
    fn seeded_sequences_are_deterministic() {
        let a = ExplorePolicy::new(0.5, 99);
        let b = ExplorePolicy::new(0.5, 99);
        let sa: Vec<_> = (0..64)
            .map(|_| a.roll().then(|| a.choose(&[1, 2, 3, 4])))
            .collect();
        let sb: Vec<_> = (0..64)
            .map(|_| b.roll().then(|| b.choose(&[1, 2, 3, 4])))
            .collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn empty_alternatives_are_safe() {
        let p = ExplorePolicy::new(1.0, 1);
        assert_eq!(p.choose::<u32>(&[]), None);
    }
}
