//! The online autotuning plane: measured-latency calibration of the
//! kernel selector.
//!
//! The paper's claim that the system "automatically adapts to hardware
//! capabilities" (§3.3.2, Listing 1) needs a feedback loop, not just a
//! frozen analytic roofline: the cost model describes the device profile
//! it was *configured* for, while requests execute on whatever substrate
//! is actually serving. This module closes the loop:
//!
//! ```text
//!             ┌────────────────────────────────────────────────┐
//!             │  AutoKernelSelector::estimate                  │
//!   request ─▶│   analytic roofline × shard speedup            │
//!             │   × CalibrationTable::correction  ◀─────────┐  │
//!             └───────────────┬────────────────────────────┼──┘
//!                             ▼                             │
//!               Router (ε-greedy ExplorePolicy)             │
//!                             ▼                             │
//!               Backend::execute  ──(observed exec time)──▶ │
//!                             CalibrationTable::record ─────┘
//!                     (EWMA of observed/predicted, per
//!                      (kernel kind, log2 size-class))
//! ```
//!
//! - [`CalibrationTable`] holds one EWMA ratio of observed/predicted wall
//!   time per [`crate::coordinator::BucketKey`] — the same (kernel kind,
//!   log2 size-class) key the dynamic batcher buckets by, so calibration
//!   granularity matches batching granularity. A confidence-weighted
//!   blend walks each cell from the analytic prior (correction 1.0)
//!   toward the measured posterior as samples accumulate.
//! - [`ExplorePolicy`] is the ε-greedy leg: with probability ε the router
//!   serves a request on a non-optimal (but in-tolerance) kernel so that
//!   rarely-chosen kernels keep receiving fresh samples instead of
//!   starving on a stale prediction.
//! - The table persists as JSON ([`CalibrationTable::save`] /
//!   [`CalibrationTable::load`]) so a tuned instance warm-starts after a
//!   restart.
//!
//! Everything is default-off: with `[autotune]` disabled the selector's
//! output is bit-identical to the static analytic model.

pub mod policy;
pub mod table;

pub use policy::ExplorePolicy;
pub use table::{CalibrationEntry, CalibrationTable};
