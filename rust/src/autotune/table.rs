//! The calibration table: per-(kernel, size-class) EWMA correction factors.
//!
//! Each cell tracks the ratio `observed / predicted` of wall-clock
//! execution time for one [`BucketKey`] (kernel kind × log2 size-class).
//! The selector multiplies its analytic prediction by
//! [`CalibrationTable::correction`], a confidence-weighted blend that
//! starts at the analytic prior (1.0, zero samples) and approaches the
//! measured EWMA as samples accumulate — LRAMM-style measured routing
//! layered over the paper's roofline model.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::batcher::BucketKey;
use crate::error::{Error, Result};
use crate::kernels::KernelKind;
use crate::runtime::json::{parse_json, Json};

/// Ratios outside this band are treated as degenerate measurements and
/// clamped: wide enough to express a roofline model that is off by six
/// orders of magnitude (a GPU profile serving on a CPU substrate), tight
/// enough that a zero-duration or garbage sample cannot poison a cell
/// with `inf`/`0`.
pub const RATIO_MIN: f64 = 1e-6;
/// Upper clamp for observed/predicted ratios (see [`RATIO_MIN`]).
pub const RATIO_MAX: f64 = 1e6;

/// One cell of the table.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalibrationEntry {
    /// EWMA of observed/predicted wall-time ratios.
    pub ratio: f64,
    /// How many samples have been folded into `ratio`.
    pub samples: u64,
}

/// Concurrent table of measured corrections to the analytic cost model.
///
/// Shared between the router's selector (reads on every routing decision)
/// and the service's dispatch loop (one write per completed request), so
/// all state sits behind a single mutex — the critical sections are a
/// hash-map probe plus a few flops, far below the cost of the GEMMs being
/// routed.
#[derive(Debug)]
pub struct CalibrationTable {
    /// EWMA smoothing factor in (0, 1]: weight of the newest sample.
    ewma_alpha: f64,
    /// Prior strength of the analytic model, in samples: a cell with this
    /// many observations sits halfway between the analytic prediction and
    /// its measured EWMA (`min_samples` in the `[autotune]` config).
    prior_samples: f64,
    cells: Mutex<HashMap<BucketKey, CalibrationEntry>>,
    /// Periodic persistence: `(path, every)` flushes the table after each
    /// `every`-th recorded sample, so an abrupt kill loses at most
    /// `every - 1` samples of a long calibration run instead of all of
    /// them (the shutdown save on `GemmService::drop` stays the final
    /// word). `None` = save only when explicitly asked.
    autosave: Option<(String, u64)>,
    /// Samples recorded since construction (drives the autosave cadence).
    recorded: AtomicU64,
    /// Serializes concurrent [`save`](CalibrationTable::save) calls: the
    /// tmp+rename dance is atomic per writer, but two workers autosaving
    /// at once must not interleave writes to the same tmp file.
    io_lock: Mutex<()>,
}

impl CalibrationTable {
    /// New empty table. `ewma_alpha` is clamped into (0, 1];
    /// `min_samples` is the analytic prior's strength in samples.
    pub fn new(ewma_alpha: f64, min_samples: u64) -> Self {
        CalibrationTable {
            ewma_alpha: ewma_alpha.clamp(f64::MIN_POSITIVE, 1.0),
            prior_samples: min_samples as f64,
            cells: Mutex::new(HashMap::new()),
            autosave: None,
            recorded: AtomicU64::new(0),
            io_lock: Mutex::new(()),
        }
    }

    /// Enable periodic persistence: flush to `path` after every
    /// `every`-th recorded sample (clamped to ≥ 1), through the same
    /// atomic tmp+rename path as [`save`](CalibrationTable::save).
    /// Flush failures are swallowed, like the shutdown save — losing a
    /// periodic checkpoint must never fail the serving path.
    pub fn set_autosave(&mut self, path: &str, every: u64) {
        self.autosave = Some((path.to_string(), every.max(1)));
    }

    /// Fold one completed request into the table and return the cell's
    /// updated correction factor. Non-finite or non-positive inputs are
    /// discarded (`None`): a sub-microsecond GEMM that rounds to zero
    /// observed time must not drive a cell toward `ratio = 0`.
    pub fn record(
        &self,
        kind: KernelKind,
        m: usize,
        k: usize,
        n: usize,
        predicted_s: f64,
        observed_s: f64,
    ) -> Option<f64> {
        if !predicted_s.is_finite()
            || !observed_s.is_finite()
            || predicted_s <= 0.0
            || observed_s <= 0.0
        {
            return None;
        }
        let ratio = (observed_s / predicted_s).clamp(RATIO_MIN, RATIO_MAX);
        let key = BucketKey::of(kind, m, k, n);
        let blended = {
            let mut cells = self.cells.lock().unwrap();
            let e = cells.entry(key).or_insert(CalibrationEntry {
                ratio,
                samples: 0,
            });
            if e.samples > 0 {
                e.ratio = self.ewma_alpha * ratio + (1.0 - self.ewma_alpha) * e.ratio;
            }
            e.samples += 1;
            self.blend(e)
        };
        if let Some((path, every)) = &self.autosave {
            // Cells lock released above: the flush re-acquires it only
            // for the snapshot. try_lock keeps the cadence best-effort —
            // if another worker is mid-flush, this sample's checkpoint is
            // simply skipped rather than stalling the recording thread.
            if (self.recorded.fetch_add(1, Ordering::Relaxed) + 1) % every == 0 {
                if let Ok(_io) = self.io_lock.try_lock() {
                    let _ = self.write_to(path);
                }
            }
        }
        Some(blended)
    }

    /// Correction factor for one request: the confidence-weighted blend
    /// of the analytic prior (1.0) and the cell's measured EWMA. 1.0 when
    /// the cell has never been sampled.
    pub fn correction(&self, kind: KernelKind, m: usize, k: usize, n: usize) -> f64 {
        let key = BucketKey::of(kind, m, k, n);
        self.cells
            .lock()
            .unwrap()
            .get(&key)
            .map(|e| self.blend(e))
            .unwrap_or(1.0)
    }

    /// `prior·1.0 + samples·ratio` over `prior + samples`: with
    /// `samples == prior_samples` the cell trusts measurements exactly as
    /// much as the analytic model.
    fn blend(&self, e: &CalibrationEntry) -> f64 {
        let n = e.samples as f64;
        (self.prior_samples + n * e.ratio) / (self.prior_samples + n)
    }

    /// Number of populated cells.
    pub fn len(&self) -> usize {
        self.cells.lock().unwrap().len()
    }

    /// Has any cell been populated?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Point-in-time copy of every cell.
    pub fn snapshot(&self) -> Vec<(BucketKey, CalibrationEntry)> {
        self.cells
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (*k, *v))
            .collect()
    }

    /// Serialize to the persistence format (deterministic cell order).
    /// `f64` values use Rust's round-trip `Display`, so save → load
    /// reproduces every ratio bit-exactly.
    pub fn to_json(&self) -> String {
        let mut entries = self.snapshot();
        entries.sort_by_key(|(k, _)| (k.kind.id(), k.size_class));
        let rows: Vec<String> = entries
            .iter()
            .map(|(k, e)| {
                format!(
                    "{{\"kernel\":\"{}\",\"size_class\":{},\"ratio\":{},\"samples\":{}}}",
                    k.kind.id(),
                    k.size_class,
                    e.ratio,
                    e.samples
                )
            })
            .collect();
        format!("{{\"version\":1,\"entries\":[{}]}}\n", rows.join(","))
    }

    /// Write the table to `path` atomically (temp file + rename): a
    /// crash mid-save must never leave a truncated table behind, because
    /// a corrupt file deliberately fails the next service start.
    /// Concurrent savers (periodic autosave from worker threads, the
    /// shutdown save) are serialized on an internal lock.
    pub fn save(&self, path: &str) -> Result<()> {
        let _io = self.io_lock.lock().unwrap();
        self.write_to(path)
    }

    /// The tmp+rename write itself; callers hold (or deliberately
    /// skipped) the io_lock. The temp file is fsynced before the rename:
    /// without it a crash can journal the rename ahead of the data and
    /// leave an *atomically installed* empty or truncated table — exactly
    /// the corruption the tmp+rename dance exists to prevent.
    fn write_to(&self, path: &str) -> Result<()> {
        use std::io::Write;
        let tmp = format!("{path}.tmp");
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(self.to_json().as_bytes())?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Replace the table's contents from a file written by [`save`].
    /// Returns the number of cells loaded. The smoothing/prior knobs stay
    /// as configured — only measurements persist.
    ///
    /// [`save`]: CalibrationTable::save
    pub fn load(&self, path: &str) -> Result<usize> {
        let text = std::fs::read_to_string(path)?;
        self.load_json(&text)
            .map_err(|e| Error::Config(format!("calibration table {path}: {e}")))
    }

    /// [`load`](CalibrationTable::load) from already-read JSON text.
    pub fn load_json(&self, text: &str) -> Result<usize> {
        let doc = parse_json(text)?;
        match doc.get("version").and_then(Json::as_usize) {
            Some(1) => {}
            v => {
                return Err(Error::Config(format!(
                    "unsupported calibration version {v:?}"
                )))
            }
        }
        let entries = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Config("missing `entries` array".into()))?;
        let mut cells = HashMap::new();
        for e in entries {
            let kid = e
                .get("kernel")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Config("entry missing `kernel`".into()))?;
            let kind = KernelKind::parse(kid)
                .ok_or_else(|| Error::Config(format!("unknown kernel `{kid}`")))?;
            let size_class = e
                .get("size_class")
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Config("entry missing `size_class`".into()))?
                as u32;
            let ratio = e
                .get("ratio")
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Config("entry missing `ratio`".into()))?;
            if !ratio.is_finite() || ratio <= 0.0 {
                return Err(Error::Config(format!("degenerate ratio {ratio}")));
            }
            let samples = e
                .get("samples")
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Config("entry missing `samples`".into()))?
                as u64;
            if samples == 0 {
                // A zero-sample cell is degenerate: blend() would divide
                // 0/0 under min_samples = 0, and record() would treat the
                // cell as unseeded and discard its first measurement.
                return Err(Error::Config("entry with samples = 0".into()));
            }
            cells.insert(
                BucketKey { kind, size_class },
                CalibrationEntry {
                    ratio: ratio.clamp(RATIO_MIN, RATIO_MAX),
                    samples,
                },
            );
        }
        let n = cells.len();
        *self.cells.lock().unwrap() = cells;
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> CalibrationTable {
        CalibrationTable::new(0.5, 4)
    }

    #[test]
    fn first_sample_seeds_the_ewma() {
        let t = table();
        t.record(KernelKind::DenseF32, 256, 256, 256, 1.0, 3.0);
        let (_, e) = t.snapshot()[0];
        assert_eq!(e.ratio, 3.0, "first sample must set the EWMA directly");
        assert_eq!(e.samples, 1);
    }

    #[test]
    fn ewma_update_math() {
        let t = table();
        t.record(KernelKind::DenseF32, 256, 256, 256, 1.0, 2.0);
        t.record(KernelKind::DenseF32, 256, 256, 256, 1.0, 4.0);
        let (_, e) = t.snapshot()[0];
        // alpha=0.5: 0.5·4 + 0.5·2 = 3.
        assert!((e.ratio - 3.0).abs() < 1e-12, "ratio {}", e.ratio);
        assert_eq!(e.samples, 2);
    }

    #[test]
    fn confidence_blend_walks_prior_to_posterior() {
        let t = table();
        // Unsampled: pure analytic prior.
        assert_eq!(t.correction(KernelKind::DenseF16, 512, 512, 512), 1.0);
        // One sample of ratio 9, prior strength 4: (4 + 1·9)/5 = 2.6.
        t.record(KernelKind::DenseF16, 512, 512, 512, 1.0, 9.0);
        let c1 = t.correction(KernelKind::DenseF16, 512, 512, 512);
        assert!((c1 - 2.6).abs() < 1e-12, "c1 {c1}");
        // More consistent samples → closer to the measured ratio.
        for _ in 0..40 {
            t.record(KernelKind::DenseF16, 512, 512, 512, 1.0, 9.0);
        }
        let c2 = t.correction(KernelKind::DenseF16, 512, 512, 512);
        assert!(c2 > 8.0 && c2 < 9.0, "c2 {c2}");
    }

    #[test]
    fn cells_keyed_like_the_batcher() {
        let t = table();
        t.record(KernelKind::DenseF32, 1024, 1024, 1024, 1.0, 5.0);
        // Same size class (within 2x) shares the cell...
        assert!(t.correction(KernelKind::DenseF32, 1500, 1500, 1500) > 1.0);
        // ...a different class or kernel does not.
        assert_eq!(t.correction(KernelKind::DenseF32, 2048, 2048, 2048), 1.0);
        assert_eq!(t.correction(KernelKind::DenseF16, 1024, 1024, 1024), 1.0);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn degenerate_samples_rejected_and_clamped() {
        let t = table();
        assert!(t.record(KernelKind::DenseF32, 64, 64, 64, 0.0, 1.0).is_none());
        assert!(t.record(KernelKind::DenseF32, 64, 64, 64, 1.0, 0.0).is_none());
        assert!(t
            .record(KernelKind::DenseF32, 64, 64, 64, f64::NAN, 1.0)
            .is_none());
        assert!(t
            .record(KernelKind::DenseF32, 64, 64, 64, 1.0, f64::INFINITY)
            .is_none());
        assert!(t.is_empty());
        // An absurd-but-finite ratio lands clamped, not infinite.
        t.record(KernelKind::DenseF32, 64, 64, 64, 1e-30, 1e30);
        let (_, e) = t.snapshot()[0];
        assert_eq!(e.ratio, RATIO_MAX);
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let t = table();
        t.record(KernelKind::DenseF32, 1024, 1024, 1024, 1.0, 3.7);
        t.record(KernelKind::LowRankAuto, 8192, 8192, 8192, 2.0, 1.0);
        t.record(KernelKind::LowRankAuto, 8192, 8192, 8192, 2.0, 1.5);
        let json = t.to_json();

        let fresh = CalibrationTable::new(0.5, 4);
        assert_eq!(fresh.load_json(&json).unwrap(), 2);
        let mut a = t.snapshot();
        let mut b = fresh.snapshot();
        a.sort_by_key(|(k, _)| (k.kind.id(), k.size_class));
        b.sort_by_key(|(k, _)| (k.kind.id(), k.size_class));
        assert_eq!(a, b, "round-trip must be bit-exact");
    }

    #[test]
    fn save_load_file_roundtrip() {
        let path = std::env::temp_dir().join(format!(
            "lrg-calibration-{}-{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = path.to_str().unwrap().to_string();
        let t = table();
        t.record(KernelKind::DenseFp8, 4096, 4096, 4096, 0.5, 4.0);
        t.save(&path).unwrap();
        let fresh = CalibrationTable::new(0.2, 8);
        assert_eq!(fresh.load(&path).unwrap(), 1);
        assert_eq!(fresh.snapshot(), t.snapshot());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn autosave_flushes_every_nth_record_without_drop() {
        let path = std::env::temp_dir().join(format!(
            "lrg-autosave-{}-{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);

        let mut t = CalibrationTable::new(0.5, 4);
        t.set_autosave(&path, 3);
        t.record(KernelKind::DenseF32, 256, 256, 256, 1.0, 2.0);
        t.record(KernelKind::DenseF32, 256, 256, 256, 1.0, 2.0);
        assert!(
            !std::path::Path::new(&path).exists(),
            "no flush before the cadence"
        );
        t.record(KernelKind::DenseF32, 256, 256, 256, 1.0, 2.0);
        assert!(
            std::path::Path::new(&path).exists(),
            "3rd record must flush (abrupt-kill durability)"
        );

        // The flushed file is a valid warm-start image of the table.
        let fresh = CalibrationTable::new(0.5, 4);
        assert_eq!(fresh.load(&path).unwrap(), 1);
        assert_eq!(fresh.snapshot(), t.snapshot());

        // Rejected (degenerate) samples do not advance the cadence.
        let _ = std::fs::remove_file(&path);
        for _ in 0..5 {
            assert!(t.record(KernelKind::DenseF32, 64, 64, 64, 0.0, 1.0).is_none());
        }
        assert!(!std::path::Path::new(&path).exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn autosave_cadence_clamped_to_one() {
        let path = std::env::temp_dir().join(format!(
            "lrg-autosave-min-{}-{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let mut t = CalibrationTable::new(0.5, 0);
        t.set_autosave(&path, 0); // min_samples = 0 must still flush
        t.record(KernelKind::DenseF16, 128, 128, 128, 1.0, 3.0);
        assert!(std::path::Path::new(&path).exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_malformed_documents() {
        let t = table();
        assert!(t.load_json("{}").is_err());
        assert!(t.load_json("{\"version\":2,\"entries\":[]}").is_err());
        assert!(t
            .load_json("{\"version\":1,\"entries\":[{\"kernel\":\"nope\",\"size_class\":3,\"ratio\":1.0,\"samples\":1}]}")
            .is_err());
        assert!(t
            .load_json("{\"version\":1,\"entries\":[{\"kernel\":\"dense_f32\",\"size_class\":3,\"ratio\":-1.0,\"samples\":1}]}")
            .is_err());
        assert!(t
            .load_json("{\"version\":1,\"entries\":[{\"kernel\":\"dense_f32\",\"size_class\":3,\"ratio\":1.0,\"samples\":0}]}")
            .is_err());
        // A valid empty document clears the table.
        t.record(KernelKind::DenseF32, 64, 64, 64, 1.0, 2.0);
        assert_eq!(t.load_json("{\"version\":1,\"entries\":[]}").unwrap(), 0);
        assert!(t.is_empty());
    }
}
