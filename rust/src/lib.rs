//! # Low-Rank GEMM
//!
//! A reproduction of *"Low-Rank GEMM: Efficient Matrix Multiplication via
//! Low-Rank Approximation with FP8 Acceleration"* (Metere, 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the serving coordinator: request router,
//!   size-bucketed dynamic batcher, factor cache, auto kernel selector,
//!   worker pool, tile-execution plane, metrics and CLI.
//! - **Layer 2 (`python/compile/model.py`)** — JAX compute graphs (dense
//!   GEMM, FP8 GEMM, randomized-SVD factorization, low-rank factor-chain
//!   application) lowered once, AOT, to HLO text under `artifacts/`.
//! - **Layer 1 (`python/compile/kernels/`)** — Pallas kernels implementing
//!   the tiled matmul, FP8 quantized matmul and factor-chain hot paths.
//!
//! The crate is fully self-contained at runtime: Python never runs on the
//! request path. Compiled artifacts are loaded through the PJRT CPU client
//! (`runtime`), and every substrate the paper depends on — dense linear
//! algebra ("cuBLAS"), software FP8, a roofline GPU model for the paper's
//! RTX 4090/H200/B200 numbers — is implemented here from scratch.
//!
//! ## Layer-3 module map
//!
//! ```text
//!                       ┌─────────────────────────────────────────────┐
//!   GemmRequest ──────▶ │ coordinator: service → router → batcher     │
//!                       │      │ (AutoKernelSelector + kernels::cost: │
//!                       │      │  roofline × parallel-speedup term    │
//!                       │      │  × autotune calibration; ε-greedy    │
//!                       │      │  exploration feeds fresh samples)    │
//!                       │      ▼                                      │
//!                       │   backend ──▶ runtime (XLA artifacts)       │
//!                       │      │                                      │
//!                       │      ▼                                      │
//!                       │   shard: tile-execution plane               │
//!                       │   ┌─ ShardPlan {grid, workers,              │
//!                       │   │             min_parallel_n}             │
//!                       │   │  tile grid → atomic work-claiming over  │
//!                       │   │  exec::ThreadPool (or the unified       │
//!                       │   │  sched::StealPool) → per-shard metrics  │
//!                       │   └─▶ linalg::gemm_panel / fp8 codecs /     │
//!                       │       shard::rsvd (panel-parallel rSVD) /   │
//!                       │       lowrank factor chain                  │
//!                       └─────────────────────────────────────────────┘
//! ```
//!
//! Large requests (`max(m, n) ≥ [shard].min_parallel_n`) are partitioned
//! into an output tile grid and executed across the shard pool; each tile
//! has a fixed summation order, so results are bitwise-identical at every
//! worker count (and, on the default MC/NC-aligned grid, identical to the
//! single-threaded kernels). Small requests never pay the tiling overhead.
//!
//! When `[autotune]` is enabled, the coordinator additionally closes the
//! prediction loop: every completed request's measured execution time is
//! folded into a per-(kernel, size-class) [`autotune::CalibrationTable`],
//! and the selector blends those measured corrections into its analytic
//! cost model (see the [`autotune`] module docs). Disabled (the default),
//! selection is bit-identical to the static roofline model.
//!
//! When `[cache]` is enabled, the coordinator additionally reuses
//! decompositions across requests *without* caller-supplied ids: operands
//! are content-addressed by a [`cache::Fingerprint`] (shape + digest of
//! the exact bit patterns), the [`cache::ContentCache`] holds their
//! `(U, Σ, Vᵀ)` factors behind a byte-budgeted LRU, and the cost model
//! amortizes the decomposition charge over the expected reuse count so
//! low-rank kernels win well below the paper's cold crossover. Disabled
//! (the default), routing and results are bit-identical to a build
//! without the plane.
//!
//! When `[trace]` is enabled, every completed request additionally yields
//! a span tree (route → decompose/cache → pack → per-worker tiles →
//! assemble) retained in the [`trace_plane::FlightRecorder`] and
//! exportable as `chrome://tracing` JSON; counters and histograms are
//! always on (they're lock-free interned handles — see [`metrics`]), and
//! with tracing disabled requests carry no span state at all.
//!
//! When `[accuracy]` is enabled, one in `sample_every` completed requests
//! is additionally *probed*: random matvec probes estimate the relative
//! error actually served (no O(n³) exact product), feeding per-kernel
//! error histograms, a rolling tolerance-SLO budget, and a calibrated
//! [`accuracy::ErrorModel`] the selector folds into its tolerance gate
//! (see the [`accuracy`] module docs). Disabled (the default), no probe
//! work is scheduled and results are bit-identical.
//!
//! When `[scheduler]` is enabled, the request pool and the shard plane's
//! tile pool collapse onto one work-stealing [`sched::StealPool`] —
//! request jobs and their shard tiles become peers on per-worker deques,
//! so a lone huge GEMM fans out across every core while floods of small
//! requests run one-per-worker — and `submit` gains admission control:
//! per-priority depth watermarks (shed lowest-priority-first),
//! deadline-aware load shedding priced by the autotune-calibrated cost
//! model, per-tenant fair dequeue and in-flight quotas, all rejecting
//! with a typed [`error::RejectReason`]. Disabled (the default), the
//! two-pool layout, FIFO dequeue and depth-only backpressure are
//! preserved bit-identically.
//!
//! When `[fault]` is enabled, the service additionally contains failures
//! instead of propagating them: every job boundary (worker loops, shard
//! tiles, background probes) runs under `catch_unwind` with
//! poison-tolerant locks, so a panicking kernel job costs one request a
//! typed [`error::Error::KernelPanicked`] instead of the whole process; a
//! per-kernel [`fault::CircuitBreaker`] routes failing kernel families
//! down a degradation ladder (lowrank → dense f32, with one retry on the
//! fallback, surfaced as `GemmResponse::degraded`); corrupt persistence
//! tables are quarantined at boot instead of failing start; and a seeded
//! [`fault::FaultInjector`] (`[fault.inject]` / `--fault-inject`)
//! deterministically exercises every one of those paths. Disabled (the
//! default), routing, results and metric names are bit-identical to a
//! build without the plane.
//!
//! When `[cluster]` is enabled, the service scales out: a router tier
//! ([`cluster::RouterTier`]) tracks node membership through heartbeats
//! (Alive → Suspect → Dead), routes fingerprinted operands to the node
//! most likely to hold their factors (residency digests + load-weighted
//! rendezvous hashing, cold-fill storms bounded per node), and drives a
//! robustness spine — typed [`error::Error::NodeUnavailable`] /
//! [`error::Error::RpcTimeout`], per-attempt deadlines,
//! decorrelated-jitter retry/failover, per-node circuit breakers, and
//! graceful node drain — over a dependency-free length-prefixed binary
//! protocol on `std::net::TcpStream`. Each node ([`cluster::NodeAgent`])
//! wraps an unmodified single-process service. Disabled (the default),
//! nothing listens and behavior is bit-identical to single-process.
//!
//! ## Quickstart
//!
//! ```no_run
//! use lowrank_gemm::prelude::*;
//!
//! let mut rng = Pcg64::seeded(7);
//! let a = Matrix::low_rank_noisy(512, 512, 24, 1e-4, &mut rng);
//! let b = Matrix::low_rank_noisy(512, 512, 24, 1e-4, &mut rng);
//!
//! let cfg = LowRankConfig { rank: RankStrategy::EnergyFraction(0.99), ..Default::default() };
//! let fa = factorize(&a, &cfg).unwrap();
//! let fb = factorize(&b, &cfg).unwrap();
//! let c = lowrank_matmul(&fa, &fb);
//! let exact = a.matmul(&b);
//! println!("rel err = {:.3e}", c.rel_frobenius_distance(&exact));
//! ```

pub mod accuracy;
pub mod autotune;
pub mod bench_harness;
pub mod cache;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod exec;
pub mod fault;
pub mod fp8;
pub mod gpu_sim;
pub mod kernels;
pub mod linalg;
pub mod lowrank;
pub mod metrics;
pub mod runtime;
pub mod sched;
pub mod shard;
pub mod trace;
pub mod trace_plane;

/// Convenience re-exports covering the common public API surface.
pub mod prelude {
    pub use crate::accuracy::{AccuracyPlane, ErrorModel, SloTracker};
    pub use crate::autotune::{CalibrationTable, ExplorePolicy};
    pub use crate::cache::{ContentCache, Fingerprint};
    pub use crate::cluster::{NodeAgent, RouterTier};
    pub use crate::coordinator::{
        GemmRequest, GemmResponse, GemmService, Priority, ServiceConfig, TenantId,
    };
    pub use crate::error::{Error, RejectReason, Result};
    pub use crate::fault::{CircuitBreaker, DegradeReason, FaultPlane};
    pub use crate::fp8::{Fp8Format, QuantizedTensor};
    pub use crate::gpu_sim::{DeviceProfile, Roofline};
    pub use crate::kernels::{AutoKernelSelector, KernelChoice, KernelKind};
    pub use crate::linalg::{Matrix, Pcg64};
    pub use crate::lowrank::{
        factorize, lowrank_matmul, DecompMethod, FactorCache, LowRankConfig, LowRankFactor,
        RankStrategy,
    };
    pub use crate::metrics::{MetricsRegistry, MetricsSnapshot};
    pub use crate::shard::{ShardExecutor, ShardPlan, TileGrid};
    pub use crate::trace_plane::{FlightRecorder, Tracer};
}
