//! Execution substrate: a small thread pool.
//!
//! The offline vendor set has no tokio, so the coordinator's worker pool is
//! built on `std::thread` + `std::sync::mpsc`. The pool is deliberately
//! simple — FIFO queue, fixed worker count, graceful shutdown — because on
//! the 1-core evaluation host concurrency buys overlap of queueing and
//! compute, not parallel speedup.

pub mod threadpool;

pub use threadpool::ThreadPool;
