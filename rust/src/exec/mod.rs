//! Execution substrate: a small thread pool.
//!
//! The offline vendor set has no tokio, so the worker pools are built on
//! `std::thread` + `std::sync::mpsc` — FIFO queue, fixed worker count,
//! graceful shutdown, and queue-depth accounting (`pending()`). In the
//! default configuration two pools run in the serving stack: the
//! coordinator's request-level pool (overlap of queueing and compute) and
//! the shard plane's tile pool ([`crate::shard`]), which turns multi-core
//! hosts into intra-GEMM parallel speedup via atomic work-claiming over
//! block-partitioned tasks. With `[scheduler]` enabled both roles move to
//! the unified work-stealing [`crate::sched::StealPool`] and this FIFO
//! pool is not constructed.

pub mod threadpool;

pub use threadpool::ThreadPool;
