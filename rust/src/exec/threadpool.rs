//! Fixed-size FIFO thread pool with graceful shutdown.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::fault::flock;
use crate::metrics::Counter;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming a shared FIFO queue.
pub struct ThreadPool {
    sender: mpsc::Sender<Message>,
    workers: Vec<JoinHandle<()>>,
    /// Monotonic count of jobs ever submitted.
    submitted: Arc<AtomicU64>,
    /// Jobs submitted but not yet picked up by a worker: incremented on
    /// submit, decremented when a worker starts the job.
    queued: Arc<AtomicU64>,
    completed: Arc<AtomicU64>,
}

impl ThreadPool {
    /// Spawn `size` workers (clamped to ≥ 1).
    pub fn new(size: usize) -> Self {
        Self::with_panic_hook(size, None)
    }

    /// [`ThreadPool::new`] plus the fault-plane panic hook: with
    /// `panic_counter` set, a panicking job is contained at the worker
    /// loop (the worker survives and counts it — `fault.panic.exec`)
    /// instead of unwinding through and killing the worker thread.
    pub fn with_panic_hook(size: usize, panic_counter: Option<Arc<Counter>>) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let submitted = Arc::new(AtomicU64::new(0));
        let queued = Arc::new(AtomicU64::new(0));
        let completed = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            let queued = Arc::clone(&queued);
            let completed = Arc::clone(&completed);
            let hook = panic_counter.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lrg-worker-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            // Poison-tolerant: a sibling that died unwinding
                            // while holding the receiver lock must not take
                            // the rest of the pool down with it.
                            let guard = flock(&rx);
                            guard.recv()
                        };
                        match msg {
                            Ok(Message::Run(job)) => {
                                queued.fetch_sub(1, Ordering::Relaxed);
                                match &hook {
                                    Some(h) => {
                                        if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                            h.inc();
                                        }
                                    }
                                    None => job(),
                                }
                                // Unconditional even after a contained panic:
                                // `wait_idle` would otherwise spin forever.
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            Ok(Message::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            sender: tx,
            workers,
            submitted,
            queued,
            completed,
        }
    }

    /// Enqueue a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.sender
            .send(Message::Run(Box::new(job)))
            .expect("pool alive");
    }

    /// Jobs submitted so far.
    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Jobs waiting in the queue (submitted, not yet started). The shard
    /// plane polls this to decide whether its claim jobs are still queued
    /// behind other work.
    pub fn pending(&self) -> u64 {
        self.queued.load(Ordering::Relaxed)
    }

    /// Jobs fully executed so far.
    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Block until every submitted job has completed (test/bench helper;
    /// spin+yield is fine at our scale).
    pub fn wait_idle(&self) {
        while self.completed() < self.submitted() {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.sender.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(pool.completed(), 100);
    }

    #[test]
    fn results_via_channel() {
        let pool = ThreadPool::new(2);
        let (tx, rx) = mpsc::channel();
        for i in 0..10u64 {
            let tx = tx.clone();
            pool.execute(move || {
                tx.send(i * i).unwrap();
            });
        }
        drop(tx);
        let mut got: Vec<u64> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn pending_decrements_when_job_starts() {
        let pool = ThreadPool::new(1);
        let (started_tx, started_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        // First job signals that it has started, then blocks on release.
        pool.execute(move || {
            started_tx.send(()).unwrap();
            release_rx.recv().unwrap();
        });
        started_rx.recv().unwrap();
        // Three more jobs queue behind the blocked one.
        for _ in 0..3 {
            pool.execute(|| {});
        }
        assert_eq!(pool.submitted(), 4);
        assert_eq!(pool.pending(), 3, "started job must leave the queue");
        release_tx.send(()).unwrap();
        pool.wait_idle();
        assert_eq!(pool.pending(), 0);
        assert_eq!(pool.completed(), 4);
        assert_eq!(pool.submitted(), 4, "submitted stays monotonic");
    }

    #[test]
    fn panic_hook_contains_job_panics_and_pool_survives() {
        let panics = Arc::new(Counter::default());
        let pool = ThreadPool::with_panic_hook(2, Some(panics.clone()));
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..20 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                if i % 4 == 0 {
                    panic!("boom {i}");
                }
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle(); // must not hang: contained panics still complete
        assert_eq!(counter.load(Ordering::Relaxed), 15);
        assert_eq!(panics.get(), 5);
        assert_eq!(pool.completed(), 20);
        let (tx, rx) = mpsc::channel();
        pool.execute(move || tx.send(7).unwrap());
        assert_eq!(rx.recv().unwrap(), 7, "workers alive after panics");
    }

    #[test]
    fn zero_size_clamped() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let (tx, rx) = mpsc::channel();
        pool.execute(move || tx.send(42).unwrap());
        assert_eq!(rx.recv().unwrap(), 42);
    }
}
