//! The accuracy plane: sampling policy, metric handles, and the glue
//! between probe results and the error model / SLO tracker.
//!
//! One [`AccuracyPlane`] lives on the service (behind an `Arc`). The
//! dispatch loop asks [`AccuracyPlane::sample`] whether a completed
//! request should be probed — a single relaxed atomic increment on the
//! serving path — and, when it should, clones the operands and hands a
//! probe job to the shard pool. The probe job calls
//! [`AccuracyPlane::observe`] with the measured error, which fans the
//! observation out to the error model (EWMA calibration), the SLO
//! tracker (violation budget), and the metrics registry
//! (`accuracy.*` counters and per-kernel error histograms).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::accuracy::model::ErrorModel;
use crate::accuracy::slo::{SloSnapshot, SloTracker};
use crate::config::AccuracySettings;
use crate::kernels::KernelKind;
use crate::metrics::{Counter, HistogramHandle, MetricsRegistry};

/// What one probe observation amounted to (returned to the probe job so
/// it can attach trace attributes without re-deriving anything).
#[derive(Clone, Copy, Debug)]
pub struct ProbeOutcome {
    /// Measured relative error from the probe estimator.
    pub measured: f64,
    /// The analytic prediction the request was routed on.
    pub predicted: f64,
    /// Did the measured error exceed the request's tolerance?
    pub violation: bool,
    /// The model cell's correction factor after folding this probe in
    /// (1.0 if the observation was degenerate and rejected).
    pub correction: f64,
}

/// Point-in-time accuracy statistics, surfaced through `ServiceStats`
/// and the `accuracy` CLI subcommand.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AccuracyStats {
    /// Requests probed since start.
    pub probed: u64,
    /// Lifetime tolerance violations among probed requests.
    pub violations: u64,
    /// Probes currently in the rolling SLO window.
    pub window: u64,
    /// Violations among those.
    pub window_violations: u64,
    /// The rolling error budget: violations per 10k probed requests.
    pub violations_per_10k: f64,
    /// Populated cells in the calibrated error model.
    pub model_cells: usize,
}

/// The accuracy observability plane (see the module docs).
#[derive(Debug)]
pub struct AccuracyPlane {
    settings: AccuracySettings,
    model: Arc<ErrorModel>,
    slo: SloTracker,
    /// Completed requests seen by [`sample`](AccuracyPlane::sample) —
    /// drives the deterministic every-Nth cadence.
    seen: AtomicU64,
    probed: Arc<Counter>,
    violations: Arc<Counter>,
    probe_failures: Arc<Counter>,
    probe_us: Arc<HistogramHandle>,
    /// Per-kernel measured-error histograms, indexed parallel to
    /// [`KernelKind::ALL`].
    errors: Vec<Arc<HistogramHandle>>,
}

impl AccuracyPlane {
    /// Build the plane: interns its metric handles up front so probe
    /// jobs never take the registry's interning lock.
    pub fn new(
        settings: AccuracySettings,
        model: Arc<ErrorModel>,
        registry: &MetricsRegistry,
    ) -> Self {
        AccuracyPlane {
            settings,
            model,
            slo: SloTracker::new(),
            seen: AtomicU64::new(0),
            probed: registry.counter("accuracy.probed"),
            violations: registry.counter("accuracy.violation"),
            probe_failures: registry.counter("accuracy.probe_failed"),
            probe_us: registry.histogram("accuracy.probe_us"),
            errors: KernelKind::ALL
                .iter()
                .map(|k| registry.histogram(&format!("accuracy.error.{}", k.id())))
                .collect(),
        }
    }

    /// The plane's configuration.
    pub fn settings(&self) -> &AccuracySettings {
        &self.settings
    }

    /// The calibrated error model (shared with the router's selector).
    pub fn model(&self) -> &Arc<ErrorModel> {
        &self.model
    }

    /// Should this completed request be probed? Deterministic every-Nth
    /// sampling: exactly one in `sample_every` calls returns true,
    /// starting with the first — a single relaxed `fetch_add` on the
    /// serving path, no RNG, no allocation.
    pub fn sample(&self) -> bool {
        self.seen.fetch_add(1, Ordering::Relaxed) % self.settings.sample_every == 0
    }

    /// Probe-vector seed for one request: the configured base seed mixed
    /// with the request id (splitmix-style), so probes are deterministic
    /// per request yet decorrelated across requests.
    pub fn probe_seed(&self, request_id: u64) -> u64 {
        self.settings
            .seed
            .wrapping_add(request_id.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Fold one probe measurement into the plane. `measured` is the probe
    /// estimator's relative error, `predicted` the analytic prediction
    /// the request was routed on, `tolerance` the request's bound, and
    /// `elapsed_us` what the probe itself cost (observability of the
    /// observer).
    #[allow(clippy::too_many_arguments)]
    pub fn observe(
        &self,
        kernel: KernelKind,
        m: usize,
        k: usize,
        n: usize,
        rank: usize,
        predicted: f64,
        measured: f64,
        tolerance: f64,
        elapsed_us: f64,
    ) -> ProbeOutcome {
        let violation = measured > tolerance;
        self.probed.inc();
        if violation {
            self.violations.inc();
        }
        self.slo.record(violation);
        self.probe_us.observe(elapsed_us);
        if let Some(h) = KernelKind::ALL
            .iter()
            .position(|kk| *kk == kernel)
            .and_then(|i| self.errors.get(i))
        {
            h.observe(measured);
        }
        let correction = self
            .model
            .record(kernel, m, k, n, rank, predicted, measured)
            .unwrap_or(1.0);
        ProbeOutcome {
            measured,
            predicted,
            violation,
            correction,
        }
    }

    /// A probe job could not produce an estimate (shape mismatch after a
    /// factored-output response, degenerate probes). Counted, never
    /// fatal.
    pub fn probe_failed(&self) {
        self.probe_failures.inc();
    }

    /// SLO snapshot (see [`SloTracker::snapshot`]).
    pub fn slo(&self) -> SloSnapshot {
        self.slo.snapshot()
    }

    /// Point-in-time statistics for `ServiceStats` and the CLI.
    pub fn stats(&self) -> AccuracyStats {
        let slo = self.slo.snapshot();
        AccuracyStats {
            probed: slo.probed,
            violations: slo.violations,
            window: slo.window,
            window_violations: slo.window_violations,
            violations_per_10k: slo.violations_per_10k(),
            model_cells: self.model.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plane(sample_every: u64) -> AccuracyPlane {
        let settings = AccuracySettings {
            enabled: true,
            sample_every,
            ..Default::default()
        };
        AccuracyPlane::new(
            settings,
            Arc::new(ErrorModel::new(0.2, 5)),
            &MetricsRegistry::new(),
        )
    }

    #[test]
    fn deterministic_every_nth_sampling() {
        let p = plane(4);
        let picks: Vec<bool> = (0..12).map(|_| p.sample()).collect();
        assert_eq!(
            picks,
            [true, false, false, false, true, false, false, false, true, false, false, false],
            "exactly one in sample_every, starting with the first"
        );
        let p1 = plane(1);
        assert!((0..5).all(|_| p1.sample()), "sample_every = 1 probes all");
    }

    #[test]
    fn probe_seeds_are_decorrelated_but_replayable() {
        let p = plane(1);
        assert_eq!(p.probe_seed(7), p.probe_seed(7));
        assert_ne!(p.probe_seed(7), p.probe_seed(8));
    }

    #[test]
    fn observe_fans_out_to_model_slo_and_metrics() {
        let reg = MetricsRegistry::new();
        let model = Arc::new(ErrorModel::new(0.5, 4));
        let p = AccuracyPlane::new(AccuracySettings::default(), model, &reg);

        // In-tolerance probe.
        let ok = p.observe(KernelKind::LowRankFp8, 512, 512, 512, 64, 0.01, 0.012, 0.05, 3.0);
        assert!(!ok.violation);
        assert!((ok.measured - 0.012).abs() < 1e-12);
        // Out-of-tolerance probe.
        let bad = p.observe(KernelKind::LowRankFp8, 512, 512, 512, 64, 0.01, 0.09, 0.05, 3.0);
        assert!(bad.violation);
        assert!(bad.correction > 1.0, "model must have absorbed the probes");

        let s = p.stats();
        assert_eq!(s.probed, 2);
        assert_eq!(s.violations, 1);
        assert_eq!(s.window, 2);
        assert_eq!(s.window_violations, 1);
        assert!((s.violations_per_10k - 5000.0).abs() < 1e-9);
        assert_eq!(s.model_cells, 1);

        let counters = reg.counters();
        assert_eq!(counters["accuracy.probed"], 2);
        assert_eq!(counters["accuracy.violation"], 1);
        let hists = reg.histogram_summaries();
        assert_eq!(hists["accuracy.error.lowrank_fp8"].count, 2);
        assert_eq!(hists["accuracy.probe_us"].count, 2);
    }

    #[test]
    fn degenerate_probe_keeps_prior_correction() {
        let p = plane(1);
        let o = p.observe(KernelKind::DenseF32, 64, 64, 64, 0, 0.0, 0.01, 0.05, 1.0);
        assert_eq!(o.correction, 1.0, "rejected observation leaves the prior");
        assert_eq!(p.stats().model_cells, 0);
        // It still counts as a probe for SLO purposes: the request WAS
        // measured, only the model update was impossible.
        assert_eq!(p.stats().probed, 1);
    }

    #[test]
    fn probe_failures_counted() {
        let reg = MetricsRegistry::new();
        let p = AccuracyPlane::new(
            AccuracySettings::default(),
            Arc::new(ErrorModel::new(0.2, 5)),
            &reg,
        );
        p.probe_failed();
        assert_eq!(reg.counters()["accuracy.probe_failed"], 1);
        assert_eq!(p.stats().probed, 0);
    }
}
