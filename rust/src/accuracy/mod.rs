//! The accuracy observability plane: online error probes, tolerance-SLO
//! tracking, and a calibrated error model.
//!
//! The paper's value proposition is an accuracy claim (~1–2% relative
//! error at N = 20480, r = 512, §5.4), yet the serving stack otherwise
//! only ever *predicts* error — Eckart–Young bounds at decomposition time
//! and the §5.4.4 heuristic in [`crate::lowrank::errors`] — so a
//! request's `error_tolerance` is enforced on faith. Mixed-precision GEMM
//! error depends strongly on operand distribution (LRAMM, SGEMM-cube in
//! PAPERS.md), i.e. static models drift. This plane measures what was
//! actually served, cheaply, and closes the loop the same way the
//! autotune plane closes the latency loop:
//!
//! ```text
//!             ┌────────────────────────────────────────────────┐
//!             │  AutoKernelSelector::predicted_error           │
//!   request ─▶│   analytic model (§5.4.4 + quantization)       │
//!             │   × ErrorModel::correction  ◀───────────────┐  │
//!             └───────────────┬─────────────────────────────┼──┘
//!                             ▼                             │
//!               Backend::execute ──▶ response C ≈ A·B       │
//!                             │ (one in sample_every,       │
//!                             ▼  off the serving path)      │
//!               probe_rel_error(A, B, C)  ── measured ────▶ │
//!                       │                 ErrorModel::record ┘
//!                       ▼              (EWMA of probed/predicted,
//!               SloTracker + metrics    per (kernel, size-class,
//!               (violations per 10k)        rank-class))
//! ```
//!
//! - [`probe_rel_error`] estimates the served relative error with `s`
//!   random matvec probes — O((m·n + m·k + k·n)·s), quadratic where the
//!   exact check is cubic — scheduled as background work on the shard
//!   pool so probes never block serving.
//! - [`ErrorModel`] holds one EWMA ratio of probed/predicted error per
//!   [`ErrorKey`] (kernel kind × log2 size-class × log2 rank-class),
//!   feeding the selector's tolerance gate the same confidence-blended
//!   way [`crate::autotune::CalibrationTable`] feeds its time estimates.
//! - [`SloTracker`] turns probe outcomes into an SRE-style rolling error
//!   budget (violations per 10k probed requests), surfaced through
//!   `ServiceStats`, the exporters (`lrg_accuracy_*`), trace span
//!   attributes, and the `accuracy` CLI subcommand.
//!
//! Everything is default-off: with `[accuracy]` disabled no probe work is
//! scheduled and results are bit-identical to a build without the plane.
//! This is the observability prerequisite for ROADMAP item 3
//! (precision-recovery kernels priced by *measured* accuracy gain): a
//! selector cannot price accuracy it never observes.

pub mod model;
pub mod plane;
pub mod probe;
pub mod slo;

pub use model::{ErrorEntry, ErrorKey, ErrorModel};
pub use plane::{AccuracyPlane, AccuracyStats, ProbeOutcome};
pub use probe::probe_rel_error;
pub use slo::{SloSnapshot, SloTracker, SLO_WINDOW};
