//! Stochastic error probes: estimate the relative error of a served
//! product without ever forming the exact O(m·k·n) reference.
//!
//! For a served result `C ≈ A·B`, push `s` random probe vectors `x`
//! through both sides and compare the images:
//!
//! ```text
//!   est² = Σ_x ‖C·x − A·(B·x)‖²  /  Σ_x ‖A·(B·x)‖²
//! ```
//!
//! Each probe costs one matvec per operand — O((m·n + m·k + k·n)·s) total,
//! quadratic where the exact check is cubic. For Gaussian probes this is
//! the classic Hutchinson-style stochastic norm estimate: `E‖M·x‖² =
//! ‖M‖_F²`, so the estimator converges on the relative **Frobenius**
//! error, the same quantity [`measured_rel_error`] reports — a handful of
//! probes lands within a small factor of it with high probability.
//!
//! Probe vectors come from a seeded [`Pcg64`], so a probe for a given
//! request id is deterministic and replayable.
//!
//! [`measured_rel_error`]: crate::lowrank::errors::measured_rel_error

use crate::linalg::matrix::Matrix;
use crate::linalg::rng::Pcg64;

/// Estimate the relative Frobenius error of `c` as an approximation of
/// `a·b`, using `probes` random probe vectors drawn from a generator
/// seeded with `seed`.
///
/// Returns `None` when the shapes are inconsistent or `probes == 0`;
/// returns `Some(0.0)` for the degenerate all-zero exact product only
/// when the served product is also (numerically) zero.
pub fn probe_rel_error(a: &Matrix, b: &Matrix, c: &Matrix, probes: usize, seed: u64) -> Option<f64> {
    if probes == 0
        || a.cols() != b.rows()
        || c.rows() != a.rows()
        || c.cols() != b.cols()
    {
        return None;
    }
    let mut rng = Pcg64::seeded(seed);
    let mut x = vec![0.0f32; b.cols()];
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for _ in 0..probes {
        rng.fill_gaussian(&mut x);
        let bx = b.matvec(&x);
        let exact = a.matvec(&bx);
        let served = c.matvec(&x);
        for (s, e) in served.iter().zip(&exact) {
            let d = (*s as f64) - (*e as f64);
            num += d * d;
            den += (*e as f64) * (*e as f64);
        }
    }
    if den <= 0.0 {
        // The exact product annihilated every probe: either A·B = 0 (any
        // nonzero C is infinitely wrong — report 1.0, the zero-matrix
        // baseline) or the probes were degenerate.
        return Some(if num <= 0.0 { 0.0 } else { 1.0 });
    }
    Some((num / den).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::errors::eckart_young_rel_error;
    use crate::linalg::svd::truncated_svd;

    #[test]
    fn exact_product_probes_to_zero() {
        let mut rng = Pcg64::seeded(7);
        let a = Matrix::gaussian(24, 16, &mut rng);
        let b = Matrix::gaussian(16, 20, &mut rng);
        let c = a.matmul(&b);
        let e = probe_rel_error(&a, &b, &c, 4, 99).unwrap();
        // Only f32 matvec-vs-matmul rounding noise remains.
        assert!(e < 1e-5, "e = {e}");
    }

    #[test]
    fn shape_mismatch_and_zero_probes_rejected() {
        let mut rng = Pcg64::seeded(8);
        let a = Matrix::gaussian(8, 6, &mut rng);
        let b = Matrix::gaussian(6, 10, &mut rng);
        let c = a.matmul(&b);
        assert!(probe_rel_error(&a, &b, &c, 0, 1).is_none());
        let wrong = Matrix::zeros(8, 9);
        assert!(probe_rel_error(&a, &b, &wrong, 4, 1).is_none());
        let wrong_b = Matrix::zeros(5, 10);
        assert!(probe_rel_error(&a, &wrong_b, &c, 4, 1).is_none());
    }

    #[test]
    fn deterministic_per_seed() {
        let mut rng = Pcg64::seeded(9);
        let a = Matrix::gaussian(16, 12, &mut rng);
        let b = Matrix::gaussian(12, 16, &mut rng);
        let c = Matrix::zeros(16, 16);
        let e1 = probe_rel_error(&a, &b, &c, 6, 42).unwrap();
        let e2 = probe_rel_error(&a, &b, &c, 6, 42).unwrap();
        let e3 = probe_rel_error(&a, &b, &c, 6, 43).unwrap();
        assert_eq!(e1, e2, "same seed must replay bit-identically");
        assert_ne!(e1, e3, "different seed must draw different probes");
    }

    #[test]
    fn zero_approximation_of_nonzero_product_is_total_error() {
        let mut rng = Pcg64::seeded(10);
        let a = Matrix::gaussian(12, 8, &mut rng);
        let b = Matrix::gaussian(8, 12, &mut rng);
        let c = Matrix::zeros(12, 12);
        let e = probe_rel_error(&a, &b, &c, 8, 5).unwrap();
        // ‖0 − AB‖/‖AB‖ = 1 exactly; the stochastic estimate of a ratio
        // with identical numerator and denominator is exact.
        assert!((e - 1.0).abs() < 1e-6, "e = {e}");
    }

    #[test]
    fn zero_exact_product_edge_case() {
        let a = Matrix::zeros(6, 4);
        let b = Matrix::zeros(4, 6);
        let c = Matrix::zeros(6, 6);
        assert_eq!(probe_rel_error(&a, &b, &c, 4, 1).unwrap(), 0.0);
        let mut rng = Pcg64::seeded(11);
        let wrong = Matrix::gaussian(6, 6, &mut rng);
        assert_eq!(probe_rel_error(&a, &b, &wrong, 4, 1).unwrap(), 1.0);
    }

    #[test]
    fn agrees_with_truncation_error_on_known_spectrum() {
        // B = I so A·B = A, and C = rank-r truncation of A: the true
        // relative error is the Eckart–Young tail, known in closed form.
        let mut rng = Pcg64::seeded(12);
        let sv = [8.0, 5.0, 3.0, 1.5, 0.8, 0.4, 0.2, 0.1];
        let a = Matrix::with_spectrum(32, 28, &sv, &mut rng);
        let mut b = Matrix::zeros(28, 28);
        for i in 0..28 {
            b.data_mut()[i * 28 + i] = 1.0;
        }
        for r in [2usize, 4, 6] {
            let c = truncated_svd(&a, r).unwrap().reconstruct();
            let truth = eckart_young_rel_error(&sv, r) as f64;
            let est = probe_rel_error(&a, &b, &c, 8, 77).unwrap();
            assert!(
                est > truth / 2.0 && est < truth * 2.0,
                "r={r}: est {est} vs truth {truth}"
            );
        }
    }
}
