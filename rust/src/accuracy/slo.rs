//! Tolerance-SLO tracking: a rolling error budget over probed requests.
//!
//! Every probe outcome lands here as a pass/violation bit. Alongside the
//! lifetime counters, a bounded window of the most recent outcomes yields
//! the *current* violation rate, expressed in SRE error-budget units —
//! **violations per 10k probed requests** — so a drifting workload shows
//! up in the budget long before the lifetime ratio moves.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Outcomes retained in the rolling window (the budget's denominator is
/// capped at this, matching the "per 10k probed" unit).
pub const SLO_WINDOW: usize = 10_000;

/// Point-in-time view of the tracker (see [`SloTracker::snapshot`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SloSnapshot {
    /// Probes recorded since start.
    pub probed: u64,
    /// Lifetime tolerance violations.
    pub violations: u64,
    /// Outcomes currently in the rolling window (≤ [`SLO_WINDOW`]).
    pub window: u64,
    /// Violations among those.
    pub window_violations: u64,
}

impl SloSnapshot {
    /// The rolling error budget: violations per 10k probed requests,
    /// scaled up from the window when it holds fewer than 10k outcomes.
    /// 0.0 when nothing has been probed yet.
    pub fn violations_per_10k(&self) -> f64 {
        if self.window == 0 {
            0.0
        } else {
            self.window_violations as f64 * 10_000.0 / self.window as f64
        }
    }
}

/// Rolling tolerance-SLO tracker. Lifetime counters are lock-free; the
/// window sits behind a mutex touched only by probe jobs (one in
/// `sample_every` requests) and stat readers — never the serving path.
#[derive(Debug, Default)]
pub struct SloTracker {
    probed: AtomicU64,
    violations: AtomicU64,
    window: Mutex<VecDeque<bool>>,
}

impl SloTracker {
    /// New empty tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one probe outcome.
    pub fn record(&self, violation: bool) {
        self.probed.fetch_add(1, Ordering::Relaxed);
        if violation {
            self.violations.fetch_add(1, Ordering::Relaxed);
        }
        let mut w = self.window.lock().unwrap();
        if w.len() == SLO_WINDOW {
            w.pop_front();
        }
        w.push_back(violation);
    }

    /// Point-in-time view.
    pub fn snapshot(&self) -> SloSnapshot {
        let w = self.window.lock().unwrap();
        SloSnapshot {
            probed: self.probed.load(Ordering::Relaxed),
            violations: self.violations.load(Ordering::Relaxed),
            window: w.len() as u64,
            window_violations: w.iter().filter(|&&v| v).count() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_tracker_has_zero_budget() {
        let t = SloTracker::new();
        let s = t.snapshot();
        assert_eq!(s, SloSnapshot::default());
        assert_eq!(s.violations_per_10k(), 0.0);
    }

    #[test]
    fn budget_math() {
        let t = SloTracker::new();
        for i in 0..200 {
            t.record(i % 50 == 0); // 4 violations in 200
        }
        let s = t.snapshot();
        assert_eq!(s.probed, 200);
        assert_eq!(s.violations, 4);
        assert_eq!(s.window, 200);
        assert_eq!(s.window_violations, 4);
        // 4/200 → 200 per 10k.
        assert!((s.violations_per_10k() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn window_evicts_oldest_outcomes() {
        let t = SloTracker::new();
        // Fill the window entirely with violations...
        for _ in 0..SLO_WINDOW {
            t.record(true);
        }
        // ...then push a full window of passes: the budget must recover
        // to zero even though the lifetime counter remembers everything.
        for _ in 0..SLO_WINDOW {
            t.record(false);
        }
        let s = t.snapshot();
        assert_eq!(s.probed, 2 * SLO_WINDOW as u64);
        assert_eq!(s.violations, SLO_WINDOW as u64);
        assert_eq!(s.window, SLO_WINDOW as u64);
        assert_eq!(s.window_violations, 0);
        assert_eq!(s.violations_per_10k(), 0.0);
    }
}
