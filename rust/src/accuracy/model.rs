//! The calibrated error model: per-(kernel, size-class, rank-class) EWMA
//! ratios of probed over predicted relative error.
//!
//! Structurally a sibling of [`autotune::CalibrationTable`] — same EWMA +
//! confidence-blend math, same atomic tmp+rename persistence — but keyed
//! one dimension finer: low-rank error depends on the served rank as
//! strongly as on the shape (§5.4.4's `ε ≈ c·sqrt(n/r)`), so cells carry a
//! log2 rank-class alongside the batcher's log2 size-class. The selector
//! multiplies its analytic error prediction by
//! [`ErrorModel::correction`], which is exactly 1.0 until a cell has been
//! probed — routing on the assumed model until observation says otherwise.
//!
//! [`autotune::CalibrationTable`]: crate::autotune::CalibrationTable

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::kernels::KernelKind;
use crate::runtime::json::{parse_json, Json};

/// Probed/predicted error ratios outside this band are clamped. The band
/// is deliberately tighter than the autotune table's (1e-6..1e6): the
/// predicted relative error is itself clamped to [0, 1], so a correction
/// beyond 1e3 saturates the product anyway, and a probe measuring *zero*
/// error (exact kernel, rank ≥ true rank) must pull its cell toward the
/// floor rather than poison the EWMA with a literal 0.
pub const ERR_RATIO_MIN: f64 = 1e-3;
/// Upper clamp for probed/predicted error ratios (see [`ERR_RATIO_MIN`]).
pub const ERR_RATIO_MAX: f64 = 1e3;

/// Cell key: kernel kind × log2 size-class × log2 rank-class.
///
/// The size-class matches [`BucketKey::of`] (shapes within 2x share a
/// cell); the rank-class puts rank 0 (dense kernels, no factorization) in
/// its own class 0 and buckets positive ranks within 2x, so `r = 16` and
/// `r = 31` calibrate together but `r = 16` and `r = 512` do not.
///
/// [`BucketKey::of`]: crate::coordinator::batcher::BucketKey::of
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ErrorKey {
    /// Kernel this cell calibrates.
    pub kernel: KernelKind,
    /// floor(log2(max dim)) — shapes within 2x share the cell.
    pub size_class: u32,
    /// 0 for dense (rank 0); `floor(log2(r)) + 1` otherwise.
    pub rank_class: u32,
}

impl ErrorKey {
    /// Classify a probed request.
    pub fn of(kernel: KernelKind, m: usize, k: usize, n: usize, rank: usize) -> Self {
        let dim = m.max(k).max(n).max(1);
        ErrorKey {
            kernel,
            size_class: usize::BITS - 1 - dim.leading_zeros(),
            rank_class: if rank == 0 {
                0
            } else {
                usize::BITS - rank.leading_zeros()
            },
        }
    }
}

/// One cell of the model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ErrorEntry {
    /// EWMA of probed/predicted relative-error ratios.
    pub ratio: f64,
    /// How many probes have been folded into `ratio`.
    pub samples: u64,
}

/// Concurrent table of measured corrections to the analytic error model.
///
/// Shared between the router's selector (reads on every routing decision)
/// and the accuracy plane's probe jobs (one write per probed request), so
/// all state sits behind a single mutex — probe completions are rare by
/// construction (one in `sample_every` requests), far off the hot path.
#[derive(Debug)]
pub struct ErrorModel {
    /// EWMA smoothing factor in (0, 1]: weight of the newest probe.
    ewma_alpha: f64,
    /// Prior strength of the analytic model, in probes: a cell with this
    /// many observations sits halfway between the analytic prediction and
    /// its probed EWMA (`min_samples` in the `[accuracy]` config).
    prior_samples: f64,
    cells: Mutex<HashMap<ErrorKey, ErrorEntry>>,
    /// Periodic persistence: `(path, every)` flushes after each `every`-th
    /// recorded probe (see the autotune table for the rationale — an
    /// abrupt kill loses at most `every - 1` probes).
    autosave: Option<(String, u64)>,
    /// Probes recorded since construction (drives the autosave cadence).
    recorded: AtomicU64,
    /// Serializes concurrent save calls (tmp+rename writers must not
    /// interleave on the same tmp file).
    io_lock: Mutex<()>,
}

impl ErrorModel {
    /// New empty model. `ewma_alpha` is clamped into (0, 1];
    /// `min_samples` is the analytic prior's strength in probes.
    pub fn new(ewma_alpha: f64, min_samples: u64) -> Self {
        ErrorModel {
            ewma_alpha: ewma_alpha.clamp(f64::MIN_POSITIVE, 1.0),
            prior_samples: min_samples as f64,
            cells: Mutex::new(HashMap::new()),
            autosave: None,
            recorded: AtomicU64::new(0),
            io_lock: Mutex::new(()),
        }
    }

    /// Enable periodic persistence: flush to `path` after every
    /// `every`-th recorded probe (clamped to ≥ 1). Flush failures are
    /// swallowed — losing a checkpoint must never fail a probe job.
    pub fn set_autosave(&mut self, path: &str, every: u64) {
        self.autosave = Some((path.to_string(), every.max(1)));
    }

    /// Fold one probed request into the model and return the cell's
    /// updated correction factor. The predicted error must be finite and
    /// positive; the probed error must be finite and **non-negative** —
    /// a probe measuring exactly zero error is a real observation (the
    /// whole point of admitting 0.0 into the error histograms) and lands
    /// as a ratio clamped to [`ERR_RATIO_MIN`].
    pub fn record(
        &self,
        kernel: KernelKind,
        m: usize,
        k: usize,
        n: usize,
        rank: usize,
        predicted: f64,
        probed: f64,
    ) -> Option<f64> {
        if !predicted.is_finite() || !probed.is_finite() || predicted <= 0.0 || probed < 0.0 {
            return None;
        }
        let ratio = (probed / predicted).clamp(ERR_RATIO_MIN, ERR_RATIO_MAX);
        let key = ErrorKey::of(kernel, m, k, n, rank);
        let blended = {
            let mut cells = self.cells.lock().unwrap();
            let e = cells.entry(key).or_insert(ErrorEntry { ratio, samples: 0 });
            if e.samples > 0 {
                e.ratio = self.ewma_alpha * ratio + (1.0 - self.ewma_alpha) * e.ratio;
            }
            e.samples += 1;
            self.blend(e)
        };
        if let Some((path, every)) = &self.autosave {
            // Cells lock released above; try_lock keeps the cadence
            // best-effort so a probe job never stalls behind another
            // flusher (matches the autotune table).
            if (self.recorded.fetch_add(1, Ordering::Relaxed) + 1) % every == 0 {
                if let Ok(_io) = self.io_lock.try_lock() {
                    let _ = self.write_to(path);
                }
            }
        }
        Some(blended)
    }

    /// Correction factor for one routing decision: the confidence-weighted
    /// blend of the analytic prior (1.0) and the cell's probed EWMA.
    /// Exactly 1.0 when the cell has never been probed, so an empty model
    /// leaves the selector's arithmetic bit-identical.
    pub fn correction(&self, kernel: KernelKind, m: usize, k: usize, n: usize, rank: usize) -> f64 {
        let key = ErrorKey::of(kernel, m, k, n, rank);
        self.cells
            .lock()
            .unwrap()
            .get(&key)
            .map(|e| self.blend(e))
            .unwrap_or(1.0)
    }

    /// `prior·1.0 + samples·ratio` over `prior + samples`: with
    /// `samples == prior_samples` the cell trusts probes exactly as much
    /// as the analytic model.
    fn blend(&self, e: &ErrorEntry) -> f64 {
        let n = e.samples as f64;
        (self.prior_samples + n * e.ratio) / (self.prior_samples + n)
    }

    /// Number of populated cells.
    pub fn len(&self) -> usize {
        self.cells.lock().unwrap().len()
    }

    /// Has any cell been populated?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Point-in-time copy of every cell.
    pub fn snapshot(&self) -> Vec<(ErrorKey, ErrorEntry)> {
        self.cells
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (*k, *v))
            .collect()
    }

    /// Serialize to the persistence format (deterministic cell order,
    /// round-trip `Display` for `f64` so save → load is bit-exact).
    pub fn to_json(&self) -> String {
        let mut entries = self.snapshot();
        entries.sort_by_key(|(k, _)| (k.kernel.id(), k.size_class, k.rank_class));
        let rows: Vec<String> = entries
            .iter()
            .map(|(k, e)| {
                format!(
                    "{{\"kernel\":\"{}\",\"size_class\":{},\"rank_class\":{},\"ratio\":{},\"samples\":{}}}",
                    k.kernel.id(),
                    k.size_class,
                    k.rank_class,
                    e.ratio,
                    e.samples
                )
            })
            .collect();
        format!("{{\"version\":1,\"entries\":[{}]}}\n", rows.join(","))
    }

    /// Write the model to `path` atomically (temp file + rename); a crash
    /// mid-save must never leave a truncated file, because a corrupt one
    /// deliberately fails the next service start.
    pub fn save(&self, path: &str) -> Result<()> {
        let _io = self.io_lock.lock().unwrap();
        self.write_to(path)
    }

    /// The tmp+rename write itself; callers hold (or deliberately
    /// skipped) the io_lock. The temp file is fsynced before the rename:
    /// without it a crash can journal the rename ahead of the data and
    /// leave an *atomically installed* empty or truncated model — exactly
    /// the corruption the tmp+rename dance exists to prevent.
    fn write_to(&self, path: &str) -> Result<()> {
        use std::io::Write;
        let tmp = format!("{path}.tmp");
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(self.to_json().as_bytes())?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Replace the model's contents from a file written by
    /// [`save`](ErrorModel::save). Returns the number of cells loaded.
    /// The smoothing/prior knobs stay as configured — only probes persist.
    pub fn load(&self, path: &str) -> Result<usize> {
        let text = std::fs::read_to_string(path)?;
        self.load_json(&text)
            .map_err(|e| Error::Config(format!("error model {path}: {e}")))
    }

    /// [`load`](ErrorModel::load) from already-read JSON text.
    pub fn load_json(&self, text: &str) -> Result<usize> {
        let doc = parse_json(text)?;
        match doc.get("version").and_then(Json::as_usize) {
            Some(1) => {}
            v => {
                return Err(Error::Config(format!(
                    "unsupported error-model version {v:?}"
                )))
            }
        }
        let entries = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Config("missing `entries` array".into()))?;
        let mut cells = HashMap::new();
        for e in entries {
            let kid = e
                .get("kernel")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Config("entry missing `kernel`".into()))?;
            let kernel = KernelKind::parse(kid)
                .ok_or_else(|| Error::Config(format!("unknown kernel `{kid}`")))?;
            let size_class = e
                .get("size_class")
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Config("entry missing `size_class`".into()))?
                as u32;
            let rank_class = e
                .get("rank_class")
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Config("entry missing `rank_class`".into()))?
                as u32;
            let ratio = e
                .get("ratio")
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Config("entry missing `ratio`".into()))?;
            if !ratio.is_finite() || ratio <= 0.0 {
                return Err(Error::Config(format!("degenerate ratio {ratio}")));
            }
            let samples = e
                .get("samples")
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Config("entry missing `samples`".into()))?
                as u64;
            if samples == 0 {
                // A zero-sample cell is degenerate: blend() would divide
                // 0/0 under min_samples = 0, and record() would treat the
                // cell as unseeded and discard its first probe.
                return Err(Error::Config("entry with samples = 0".into()));
            }
            cells.insert(
                ErrorKey {
                    kernel,
                    size_class,
                    rank_class,
                },
                ErrorEntry {
                    ratio: ratio.clamp(ERR_RATIO_MIN, ERR_RATIO_MAX),
                    samples,
                },
            );
        }
        let n = cells.len();
        *self.cells.lock().unwrap() = cells;
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ErrorModel {
        ErrorModel::new(0.5, 4)
    }

    #[test]
    fn rank_classing() {
        let k = |r| ErrorKey::of(KernelKind::LowRankFp8, 1024, 1024, 1024, r).rank_class;
        assert_eq!(k(0), 0, "dense rank 0 owns class 0");
        assert_eq!(k(1), 1);
        assert_eq!(k(16), 5);
        assert_eq!(k(31), 5, "ranks within 2x share a class");
        assert_eq!(k(32), 6);
        // Size-classing matches the batcher's (within-2x shapes batch).
        let a = ErrorKey::of(KernelKind::DenseF32, 1024, 1024, 1024, 0);
        let b = ErrorKey::of(KernelKind::DenseF32, 1500, 1500, 1500, 0);
        assert_eq!(a, b);
    }

    #[test]
    fn first_probe_seeds_the_ewma() {
        let t = model();
        t.record(KernelKind::LowRankFp8, 2048, 2048, 2048, 64, 0.01, 0.03);
        let (_, e) = t.snapshot()[0];
        assert_eq!(e.ratio, 3.0, "first probe must set the EWMA directly");
        assert_eq!(e.samples, 1);
    }

    #[test]
    fn ewma_update_math() {
        let t = model();
        t.record(KernelKind::LowRankFp8, 2048, 2048, 2048, 64, 0.01, 0.02);
        t.record(KernelKind::LowRankFp8, 2048, 2048, 2048, 64, 0.01, 0.04);
        let (_, e) = t.snapshot()[0];
        // alpha=0.5: 0.5·4 + 0.5·2 = 3.
        assert!((e.ratio - 3.0).abs() < 1e-12, "ratio {}", e.ratio);
        assert_eq!(e.samples, 2);
    }

    #[test]
    fn confidence_blend_walks_prior_to_posterior() {
        let t = model();
        // Unprobed: pure analytic prior.
        assert_eq!(t.correction(KernelKind::LowRankAuto, 512, 512, 512, 32), 1.0);
        // One probe of ratio 9, prior strength 4: (4 + 1·9)/5 = 2.6.
        t.record(KernelKind::LowRankAuto, 512, 512, 512, 32, 0.01, 0.09);
        let c1 = t.correction(KernelKind::LowRankAuto, 512, 512, 512, 32);
        assert!((c1 - 2.6).abs() < 1e-12, "c1 {c1}");
        // More consistent probes → closer to the probed ratio.
        for _ in 0..40 {
            t.record(KernelKind::LowRankAuto, 512, 512, 512, 32, 0.01, 0.09);
        }
        let c2 = t.correction(KernelKind::LowRankAuto, 512, 512, 512, 32);
        assert!(c2 > 8.0 && c2 < 9.0, "c2 {c2}");
    }

    #[test]
    fn cells_split_by_rank_class() {
        let t = model();
        t.record(KernelKind::LowRankFp8, 4096, 4096, 4096, 128, 0.01, 0.05);
        // Same rank class (within 2x) shares the cell...
        assert!(t.correction(KernelKind::LowRankFp8, 4096, 4096, 4096, 200) > 1.0);
        // ...a different rank class, size class, or kernel does not.
        assert_eq!(t.correction(KernelKind::LowRankFp8, 4096, 4096, 4096, 512), 1.0);
        assert_eq!(t.correction(KernelKind::LowRankFp8, 8192, 8192, 8192, 128), 1.0);
        assert_eq!(t.correction(KernelKind::LowRankAuto, 4096, 4096, 4096, 128), 1.0);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn zero_probed_error_is_admitted_and_clamped() {
        let t = model();
        // An exact result (probed error 0.0) is a real observation: it
        // must pull the cell toward the floor, not be discarded.
        assert!(t
            .record(KernelKind::DenseF32, 1024, 1024, 1024, 0, 1e-6, 0.0)
            .is_some());
        let (_, e) = t.snapshot()[0];
        assert_eq!(e.ratio, ERR_RATIO_MIN);
    }

    #[test]
    fn degenerate_probes_rejected_and_clamped() {
        let t = model();
        assert!(t.record(KernelKind::DenseF32, 64, 64, 64, 0, 0.0, 0.01).is_none());
        assert!(t.record(KernelKind::DenseF32, 64, 64, 64, 0, 0.01, -0.5).is_none());
        assert!(t
            .record(KernelKind::DenseF32, 64, 64, 64, 0, f64::NAN, 0.01)
            .is_none());
        assert!(t
            .record(KernelKind::DenseF32, 64, 64, 64, 0, 0.01, f64::INFINITY)
            .is_none());
        assert!(t.is_empty());
        // An absurd-but-finite ratio lands clamped, not unbounded.
        t.record(KernelKind::DenseF32, 64, 64, 64, 0, 1e-10, 1e10);
        let (_, e) = t.snapshot()[0];
        assert_eq!(e.ratio, ERR_RATIO_MAX);
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let t = model();
        t.record(KernelKind::LowRankFp8, 8192, 8192, 8192, 512, 0.016, 0.021);
        t.record(KernelKind::LowRankAuto, 2048, 2048, 2048, 64, 0.01, 0.008);
        t.record(KernelKind::LowRankAuto, 2048, 2048, 2048, 64, 0.01, 0.012);
        let json = t.to_json();

        let fresh = ErrorModel::new(0.5, 4);
        assert_eq!(fresh.load_json(&json).unwrap(), 2);
        let mut a = t.snapshot();
        let mut b = fresh.snapshot();
        a.sort_by_key(|(k, _)| (k.kernel.id(), k.size_class, k.rank_class));
        b.sort_by_key(|(k, _)| (k.kernel.id(), k.size_class, k.rank_class));
        assert_eq!(a, b, "round-trip must be bit-exact");
    }

    #[test]
    fn save_load_file_roundtrip() {
        let path = std::env::temp_dir().join(format!(
            "lrg-errmodel-{}-{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = path.to_str().unwrap().to_string();
        let t = model();
        t.record(KernelKind::DenseFp8, 4096, 4096, 4096, 0, 0.02, 0.03);
        t.save(&path).unwrap();
        let fresh = ErrorModel::new(0.2, 8);
        assert_eq!(fresh.load(&path).unwrap(), 1);
        assert_eq!(fresh.snapshot(), t.snapshot());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn autosave_flushes_every_nth_probe() {
        let path = std::env::temp_dir().join(format!(
            "lrg-errmodel-autosave-{}-{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);

        let mut t = ErrorModel::new(0.5, 4);
        t.set_autosave(&path, 3);
        t.record(KernelKind::LowRankFp8, 256, 256, 256, 16, 0.01, 0.02);
        t.record(KernelKind::LowRankFp8, 256, 256, 256, 16, 0.01, 0.02);
        assert!(
            !std::path::Path::new(&path).exists(),
            "no flush before the cadence"
        );
        t.record(KernelKind::LowRankFp8, 256, 256, 256, 16, 0.01, 0.02);
        assert!(
            std::path::Path::new(&path).exists(),
            "3rd probe must flush (abrupt-kill durability)"
        );
        let fresh = ErrorModel::new(0.5, 4);
        assert_eq!(fresh.load(&path).unwrap(), 1);
        assert_eq!(fresh.snapshot(), t.snapshot());

        // Rejected (degenerate) probes do not advance the cadence.
        let _ = std::fs::remove_file(&path);
        for _ in 0..5 {
            assert!(t.record(KernelKind::DenseF32, 64, 64, 64, 0, 0.0, 0.01).is_none());
        }
        assert!(!std::path::Path::new(&path).exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_malformed_documents() {
        let t = model();
        assert!(t.load_json("{}").is_err());
        assert!(t.load_json("{\"version\":2,\"entries\":[]}").is_err());
        assert!(t
            .load_json("{\"version\":1,\"entries\":[{\"kernel\":\"nope\",\"size_class\":3,\"rank_class\":1,\"ratio\":1.0,\"samples\":1}]}")
            .is_err());
        assert!(
            t.load_json("{\"version\":1,\"entries\":[{\"kernel\":\"dense_f32\",\"size_class\":3,\"ratio\":1.0,\"samples\":1}]}")
                .is_err(),
            "entries without a rank_class are rejected"
        );
        assert!(t
            .load_json("{\"version\":1,\"entries\":[{\"kernel\":\"dense_f32\",\"size_class\":3,\"rank_class\":0,\"ratio\":-1.0,\"samples\":1}]}")
            .is_err());
        assert!(t
            .load_json("{\"version\":1,\"entries\":[{\"kernel\":\"dense_f32\",\"size_class\":3,\"rank_class\":0,\"ratio\":1.0,\"samples\":0}]}")
            .is_err());
        // A valid empty document clears the model.
        t.record(KernelKind::DenseF32, 64, 64, 64, 0, 0.01, 0.02);
        assert_eq!(t.load_json("{\"version\":1,\"entries\":[]}").unwrap(), 0);
        assert!(t.is_empty());
    }
}
