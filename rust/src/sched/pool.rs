//! Work-stealing thread pool: per-worker deques + a global injector.
//!
//! Topology and discipline:
//!
//! - **Injector** — a global FIFO. External threads (the dispatcher, test
//!   callers) spawn here; workers drain it when their own deque is empty.
//! - **Per-worker deques** — a worker that spawns from inside a job (the
//!   shard executor's tile helpers) pushes onto its *own* deque. The owner
//!   pops LIFO (hot caches); idle siblings steal FIFO (oldest first, the
//!   classic Chase–Lev discipline, here under plain mutexes — contention
//!   is a handful of lock ops per *tile*, which is microseconds of work).
//! - **Steal accounting** — every cross-worker deque pop counts into
//!   [`StealPool::steals`], the optional `sched.steal` metrics counter,
//!   and the executing task observes [`task_was_stolen`] = true. With
//!   `steal = false`, deque tasks wait for their owner (the bench's
//!   control arm); the injector is always fair game, and shutdown always
//!   drains everything regardless of the flag.
//!
//! Parking: workers block indefinitely on a condvar when the pool is
//! truly empty (`avail == 0` checked under the gate lock, every push
//! notifies under the same lock — no lost wakeups, no idle CPU burn), and
//! back off on a short timed wait when work exists that they cannot take
//! (steal disabled and the only tasks sit in a sibling's deque).

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::fault::flock;
use crate::metrics::Counter;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// (pool token, worker ordinal) for pool worker threads.
    static WORKER: Cell<Option<(usize, usize)>> = const { Cell::new(None) };
    /// Was the currently executing task acquired by stealing?
    static TASK_STOLEN: Cell<bool> = const { Cell::new(false) };
}

/// Was the task the calling thread is currently executing stolen from
/// another worker's deque? `false` on non-pool threads and for tasks
/// acquired from the own deque or the injector.
pub fn task_was_stolen() -> bool {
    TASK_STOLEN.with(|c| c.get())
}

struct Inner {
    injector: Mutex<VecDeque<Job>>,
    deques: Vec<Mutex<VecDeque<Job>>>,
    /// Gate lock for the parking condvar; pushes notify under it.
    gate: Mutex<()>,
    cv: Condvar,
    /// Tasks pushed but not yet acquired, across injector + deques.
    avail: AtomicUsize,
    submitted: AtomicU64,
    completed: AtomicU64,
    steals: AtomicU64,
    steal_enabled: bool,
    shutdown: AtomicBool,
    steal_counter: Option<Arc<Counter>>,
    /// Fault-plane hook: when set, worker loops run every job under
    /// `catch_unwind` and count contained panics here (`fault.panic.sched`).
    /// `None` preserves the historical behavior bit-for-bit: a panicking
    /// job unwinds through the worker and kills it.
    panic_counter: Option<Arc<Counter>>,
}

impl Inner {
    fn token(self: &Arc<Self>) -> usize {
        Arc::as_ptr(self) as usize
    }

    /// Try to acquire one task for worker `ord`: own deque (LIFO) →
    /// injector (FIFO) → steal a sibling's oldest. Returns the task and
    /// whether it was stolen.
    fn acquire(&self, ord: usize) -> Option<(Job, bool)> {
        if let Some(job) = flock(&self.deques[ord]).pop_back() {
            self.avail.fetch_sub(1, Ordering::AcqRel);
            return Some((job, false));
        }
        if let Some(job) = flock(&self.injector).pop_front() {
            self.avail.fetch_sub(1, Ordering::AcqRel);
            return Some((job, false));
        }
        // Stealing is always permitted during shutdown so the pool drains
        // even when the owner of a deque has already exited.
        if self.steal_enabled || self.shutdown.load(Ordering::Acquire) {
            let n = self.deques.len();
            for i in 1..n {
                let victim = (ord + i) % n;
                if let Some(job) = flock(&self.deques[victim]).pop_front() {
                    self.avail.fetch_sub(1, Ordering::AcqRel);
                    self.steals.fetch_add(1, Ordering::Relaxed);
                    if let Some(c) = &self.steal_counter {
                        c.inc();
                    }
                    return Some((job, true));
                }
            }
        }
        None
    }
}

/// The unified work-stealing pool (see the [module docs](self)).
pub struct StealPool {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl StealPool {
    /// Spawn `size` workers (clamped to ≥ 1). `steal_counter`, when
    /// given, receives one increment per cross-worker steal (the
    /// `sched.steal` metric).
    pub fn new(size: usize, steal: bool, steal_counter: Option<Arc<Counter>>) -> Self {
        Self::with_hooks(size, steal, steal_counter, None)
    }

    /// [`StealPool::new`] plus the fault-plane panic hook: with
    /// `panic_counter` set, a panicking job is contained at the worker
    /// loop (the worker survives and counts it) instead of unwinding
    /// through and killing the worker thread.
    pub fn with_hooks(
        size: usize,
        steal: bool,
        steal_counter: Option<Arc<Counter>>,
        panic_counter: Option<Arc<Counter>>,
    ) -> Self {
        let size = size.max(1);
        let inner = Arc::new(Inner {
            injector: Mutex::new(VecDeque::new()),
            deques: (0..size).map(|_| Mutex::new(VecDeque::new())).collect(),
            gate: Mutex::new(()),
            cv: Condvar::new(),
            avail: AtomicUsize::new(0),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            steal_enabled: steal,
            shutdown: AtomicBool::new(false),
            steal_counter,
            panic_counter,
        });
        let workers = (0..size)
            .map(|ord| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("lrg-sched-{ord}"))
                    .spawn(move || worker_loop(inner, ord))
                    .expect("spawn sched worker")
            })
            .collect();
        StealPool { inner, workers }
    }

    /// Spawn a task. Called from a worker of *this* pool, the task lands
    /// on that worker's own deque (LIFO for the owner, stealable FIFO for
    /// siblings); from any other thread it lands on the global injector.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.inner.submitted.fetch_add(1, Ordering::Relaxed);
        let job: Job = Box::new(job);
        match self.current_ordinal() {
            Some(ord) => flock(&self.inner.deques[ord]).push_back(job),
            None => flock(&self.inner.injector).push_back(job),
        }
        self.inner.avail.fetch_add(1, Ordering::AcqRel);
        let _g = flock(&self.inner.gate);
        self.inner.cv.notify_one();
    }

    /// The calling thread's worker ordinal in this pool, if it is one of
    /// this pool's workers.
    pub fn current_ordinal(&self) -> Option<usize> {
        let token = self.inner.token();
        WORKER
            .with(|w| w.get())
            .and_then(|(t, ord)| (t == token).then_some(ord))
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Tasks spawned so far.
    pub fn submitted(&self) -> u64 {
        self.inner.submitted.load(Ordering::Relaxed)
    }

    /// Tasks fully executed so far.
    pub fn completed(&self) -> u64 {
        self.inner.completed.load(Ordering::Relaxed)
    }

    /// Tasks pushed but not yet picked up by any worker (the analogue of
    /// [`crate::exec::ThreadPool::pending`]).
    pub fn pending(&self) -> u64 {
        self.inner.avail.load(Ordering::Acquire) as u64
    }

    /// Cross-worker steals so far.
    pub fn steals(&self) -> u64 {
        self.inner.steals.load(Ordering::Relaxed)
    }

    /// Block until every spawned task has completed (shutdown/test
    /// helper; spin + yield is fine at our scale).
    pub fn wait_idle(&self) {
        while self.completed() < self.submitted() {
            std::thread::yield_now();
        }
    }
}

impl Drop for StealPool {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        {
            // Poison-tolerant: the shutdown drain must complete even when
            // a worker died unwinding while holding the gate (no panic
            // hook installed), otherwise Drop itself panics and the
            // remaining workers leak instead of being joined.
            let _g = flock(&self.inner.gate);
            self.inner.cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(inner: Arc<Inner>, ord: usize) {
    WORKER.with(|w| w.set(Some((inner.token(), ord))));
    loop {
        if let Some((job, stolen)) = inner.acquire(ord) {
            TASK_STOLEN.with(|c| c.set(stolen));
            match &inner.panic_counter {
                Some(hook) => {
                    if catch_unwind(AssertUnwindSafe(job)).is_err() {
                        hook.inc();
                    }
                }
                None => job(),
            }
            TASK_STOLEN.with(|c| c.set(false));
            // Unconditional even after a contained panic: `wait_idle`
            // compares completed against submitted and would spin forever
            // on a job that unwound before being counted.
            inner.completed.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        let guard = flock(&inner.gate);
        if inner.avail.load(Ordering::Acquire) == 0 {
            if inner.shutdown.load(Ordering::Acquire) {
                return;
            }
            // Truly empty: block until a push notifies. Every push
            // increments `avail` before taking the gate to notify, and we
            // re-check `avail` under the gate, so the wakeup cannot be
            // lost.
            let _unused = inner.cv.wait(guard).unwrap_or_else(PoisonError::into_inner);
        } else {
            // Work exists but none of it is acquirable by this worker
            // right now (steal disabled, tasks in a sibling's deque, or
            // we lost the race). Bounded backoff instead of a spin.
            let _unused = inner
                .cv
                .wait_timeout(guard, Duration::from_millis(1))
                .unwrap_or_else(PoisonError::into_inner);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::mpsc;

    #[test]
    fn runs_all_jobs() {
        let pool = StealPool::new(3, true, None);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..200 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 200);
        assert_eq!(pool.completed(), 200);
    }

    #[test]
    fn worker_spawn_lands_on_own_deque_and_gets_stolen() {
        // One worker spawns local tasks then blocks until every one of
        // them has completed — it cannot run them itself, so the other
        // workers *must* steal them. Deterministic steal coverage.
        let pool = Arc::new(StealPool::new(3, true, None));
        let (done_tx, done_rx) = mpsc::channel::<bool>();
        let p = Arc::clone(&pool);
        pool.spawn(move || {
            assert!(p.current_ordinal().is_some(), "job runs on a pool worker");
            let (tx, rx) = mpsc::channel::<bool>();
            for _ in 0..4 {
                let tx = tx.clone();
                p.spawn(move || {
                    tx.send(task_was_stolen()).unwrap();
                });
            }
            drop(tx);
            // Block the owner: all 4 local tasks must arrive via steals.
            let stolen: Vec<bool> = rx.iter().collect();
            done_tx.send(stolen.iter().all(|&s| s)).unwrap();
        });
        assert!(
            done_rx.recv().unwrap(),
            "all owner-blocked local tasks must be stolen"
        );
        assert!(pool.steals() >= 4);
    }

    #[test]
    fn steal_disabled_still_drains_via_owner() {
        let pool = Arc::new(StealPool::new(2, false, None));
        let counter = Arc::new(AtomicUsize::new(0));
        let p = Arc::clone(&pool);
        let c = Arc::clone(&counter);
        pool.spawn(move || {
            for _ in 0..8 {
                let c = Arc::clone(&c);
                p.spawn(move || {
                    assert!(!task_was_stolen());
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 8);
        assert_eq!(pool.steals(), 0, "steal disabled must never steal");
    }

    #[test]
    fn steal_counter_handle_receives_steals() {
        let c = Arc::new(Counter::default());
        let pool = Arc::new(StealPool::new(2, true, Some(c.clone())));
        let (tx, rx) = mpsc::channel::<()>();
        let p = Arc::clone(&pool);
        pool.spawn(move || {
            let (htx, hrx) = mpsc::channel::<()>();
            for _ in 0..2 {
                let htx = htx.clone();
                p.spawn(move || htx.send(()).unwrap());
            }
            drop(htx);
            for _ in hrx {}
            tx.send(()).unwrap();
        });
        rx.recv().unwrap();
        pool.wait_idle();
        assert_eq!(c.get(), pool.steals());
        assert!(c.get() >= 2);
    }

    #[test]
    fn injector_pickup_is_not_a_steal() {
        let pool = StealPool::new(2, true, None);
        let (tx, rx) = mpsc::channel::<bool>();
        for _ in 0..8 {
            let tx = tx.clone();
            pool.spawn(move || tx.send(task_was_stolen()).unwrap());
        }
        drop(tx);
        assert!(rx.iter().all(|s| !s), "injector tasks are dispatched, not stolen");
        assert_eq!(pool.steals(), 0);
    }

    #[test]
    fn current_ordinal_is_pool_scoped() {
        let a = StealPool::new(1, true, None);
        let b = StealPool::new(1, true, None);
        assert!(a.current_ordinal().is_none());
        let (tx, rx) = mpsc::channel::<(Option<usize>, Option<usize>)>();
        // A job running on pool `a` is a worker of `a`, not of `b`.
        let b = Arc::new(b);
        let b2 = Arc::clone(&b);
        a.spawn(move || {
            tx.send((Some(0), b2.current_ordinal())).unwrap();
        });
        let (_own, other) = rx.recv().unwrap();
        assert_eq!(other, None);
    }

    #[test]
    fn shutdown_drains_pending_work() {
        let pool = StealPool::new(2, false, None);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool); // must complete everything, then join cleanly
        assert_eq!(counter.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn panic_hook_contains_job_panics_and_pool_survives() {
        let panics = Arc::new(Counter::default());
        let pool = StealPool::with_hooks(2, true, None, Some(panics.clone()));
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..20 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                if i % 5 == 0 {
                    panic!("boom {i}");
                }
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        // wait_idle must not hang: contained panics still count as
        // completed. The workers must all survive to run later jobs.
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 16);
        assert_eq!(panics.get(), 4);
        assert_eq!(pool.completed(), 20);
        let (tx, rx) = mpsc::channel();
        pool.spawn(move || tx.send(7).unwrap());
        assert_eq!(rx.recv().unwrap(), 7, "workers alive after panics");
    }

    #[test]
    fn shutdown_drains_after_uncontained_worker_death() {
        // No panic hook: a panicking job unwinds through and kills its
        // worker (historical behavior). The pool must still drain the
        // remaining queue via the survivors and Drop must join cleanly
        // even though locks may have been poisoned by the dying worker.
        let pool = StealPool::new(2, false, None);
        let (tx, rx) = mpsc::channel::<()>();
        pool.spawn(move || {
            let _tx = tx; // dropped on unwind → rx unblocks
            panic!("worker death");
        });
        let _ = rx.recv(); // the panic has started unwinding
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..30 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool); // the survivor drains the injector, then Drop joins
        assert_eq!(counter.load(Ordering::Relaxed), 30);
    }

    #[test]
    fn zero_size_clamped() {
        let pool = StealPool::new(0, true, None);
        assert_eq!(pool.size(), 1);
        let (tx, rx) = mpsc::channel();
        pool.spawn(move || tx.send(42).unwrap());
        assert_eq!(rx.recv().unwrap(), 42);
    }
}
