//! Condvar-signalled submit queue with optional priority + tenant-fair
//! dequeue.
//!
//! This is the dispatcher's inbox in **both** scheduler modes, replacing
//! the historical `mpsc::Receiver::recv_timeout` loop and its fixed 50 ms
//! poll tick: [`SubmitQueue::pop_deadline`] blocks indefinitely when the
//! batcher has no pending deadline (an idle service burns no CPU) and
//! wakes exactly when `submit` pushes or the deadline arrives (batch-flush
//! latency is no longer quantized to a tick).
//!
//! - [`QueueMode::Fifo`] — the legacy discipline: strict arrival order,
//!   priorities and tenants ignored. Byte-identical dequeue order to the
//!   old channel.
//! - [`QueueMode::Fair`] — the `[scheduler]` discipline: strict priority
//!   (Interactive before Batch before Background), and within a priority
//!   a per-tenant round-robin so a tenant flooding the queue cannot
//!   starve the others — under a 10:1 skewed flood the minority tenant
//!   still dequeues every other slot.
//!
//! Closing ([`SubmitQueue::close`]) mirrors `mpsc` disconnect semantics:
//! pops keep draining queued items and only report [`Pop::Closed`] once
//! the queue is closed *and* empty; pushes after close hand the item back
//! to the caller.

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Number of priority classes (Interactive / Batch / Background).
pub const PRIORITIES: usize = 3;

/// Dequeue discipline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueMode {
    /// Strict arrival order (the legacy two-pool service).
    Fifo,
    /// Strict priority, tenant round-robin within a priority.
    Fair,
}

/// Outcome of [`SubmitQueue::pop_deadline`].
#[derive(Debug, PartialEq, Eq)]
pub enum Pop<T> {
    /// An item was dequeued.
    Item(T),
    /// The deadline passed with nothing to dequeue.
    Timeout,
    /// The queue is closed and fully drained.
    Closed,
}

/// One priority lane: tenants with queued items, round-robin order.
struct Lane<T> {
    /// Tenants with a non-empty queue, each exactly once, in dequeue
    /// order. `None` is the anonymous tenant.
    order: VecDeque<Option<u64>>,
    queues: HashMap<Option<u64>, VecDeque<T>>,
}

impl<T> Default for Lane<T> {
    fn default() -> Self {
        Lane {
            order: VecDeque::new(),
            queues: HashMap::new(),
        }
    }
}

impl<T> Lane<T> {
    fn push(&mut self, tenant: Option<u64>, item: T) {
        let q = self.queues.entry(tenant).or_default();
        if q.is_empty() {
            self.order.push_back(tenant);
        }
        q.push_back(item);
    }

    fn pop(&mut self) -> Option<T> {
        let tenant = self.order.pop_front()?;
        let q = self.queues.get_mut(&tenant).expect("ordered tenant has a queue");
        let item = q.pop_front().expect("ordered tenant queue non-empty");
        if q.is_empty() {
            self.queues.remove(&tenant);
        } else {
            self.order.push_back(tenant);
        }
        Some(item)
    }
}

struct State<T> {
    lanes: [Lane<T>; PRIORITIES],
    len: usize,
    closed: bool,
}

/// The dispatcher inbox (see the [module docs](self)).
pub struct SubmitQueue<T> {
    mode: QueueMode,
    state: Mutex<State<T>>,
    cv: Condvar,
}

impl<T> SubmitQueue<T> {
    /// New empty queue with the given dequeue discipline.
    pub fn new(mode: QueueMode) -> Self {
        SubmitQueue {
            mode,
            state: Mutex::new(State {
                lanes: [Lane::default(), Lane::default(), Lane::default()],
                len: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue an item and wake the dispatcher. `prio` indexes the
    /// priority lane (0 = most urgent, clamped to the lane count);
    /// `tenant` selects the fair-dequeue ring. Both are ignored in
    /// [`QueueMode::Fifo`]. Returns the item back if the queue is closed.
    pub fn push(&self, item: T, prio: usize, tenant: Option<u64>) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(item);
        }
        match self.mode {
            QueueMode::Fifo => st.lanes[0].push(None, item),
            QueueMode::Fair => st.lanes[prio.min(PRIORITIES - 1)].push(tenant, item),
        }
        st.len += 1;
        self.cv.notify_one();
        Ok(())
    }

    /// Dequeue the next item, blocking until one arrives, `deadline`
    /// passes (`None` = wait indefinitely), or the queue closes and
    /// drains.
    pub fn pop_deadline(&self, deadline: Option<Instant>) -> Pop<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = Self::take(&mut st) {
                return Pop::Item(item);
            }
            if st.closed {
                return Pop::Closed;
            }
            match deadline {
                None => st = self.cv.wait(st).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Pop::Timeout;
                    }
                    let (guard, _) = self.cv.wait_timeout(st, d - now).unwrap();
                    st = guard;
                }
            }
        }
    }

    fn take(st: &mut State<T>) -> Option<T> {
        for lane in st.lanes.iter_mut() {
            if let Some(item) = lane.pop() {
                st.len -= 1;
                return Some(item);
            }
        }
        None
    }

    /// Close the queue: queued items keep draining, further pushes are
    /// refused, and pops report [`Pop::Closed`] once empty.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().len
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    fn drain(q: &SubmitQueue<u32>) -> Vec<u32> {
        let mut out = Vec::new();
        let deadline = Instant::now() + Duration::from_millis(10);
        loop {
            match q.pop_deadline(Some(deadline)) {
                Pop::Item(v) => out.push(v),
                Pop::Timeout | Pop::Closed => return out,
            }
        }
    }

    #[test]
    fn fifo_mode_ignores_priority_and_tenant() {
        let q = SubmitQueue::new(QueueMode::Fifo);
        q.push(1, 2, Some(7)).unwrap();
        q.push(2, 0, None).unwrap();
        q.push(3, 1, Some(9)).unwrap();
        assert_eq!(drain(&q), vec![1, 2, 3], "legacy mode is strict FIFO");
    }

    #[test]
    fn fair_mode_pops_priority_order() {
        let q = SubmitQueue::new(QueueMode::Fair);
        q.push(30, 2, None).unwrap();
        q.push(10, 0, None).unwrap();
        q.push(20, 1, None).unwrap();
        q.push(11, 0, None).unwrap();
        assert_eq!(drain(&q), vec![10, 11, 20, 30]);
    }

    #[test]
    fn fair_mode_round_robins_tenants_under_skew() {
        let q = SubmitQueue::new(QueueMode::Fair);
        // Tenant 1 floods 10 items, tenant 2 submits one afterwards: the
        // minority tenant dequeues second, not eleventh.
        for i in 0..10 {
            q.push(100 + i, 1, Some(1)).unwrap();
        }
        q.push(200, 1, Some(2)).unwrap();
        let order = drain(&q);
        assert_eq!(order[0], 100);
        assert_eq!(order[1], 200, "minority tenant must not wait out the flood");
        assert_eq!(order.len(), 11);
    }

    #[test]
    fn pop_blocks_until_push() {
        let q = Arc::new(SubmitQueue::new(QueueMode::Fifo));
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.pop_deadline(None));
        std::thread::sleep(Duration::from_millis(5));
        q.push(42, 0, None).unwrap();
        assert_eq!(t.join().unwrap(), Pop::Item(42));
    }

    #[test]
    fn deadline_pop_times_out() {
        let q: SubmitQueue<u32> = SubmitQueue::new(QueueMode::Fifo);
        let t0 = Instant::now();
        let got = q.pop_deadline(Some(t0 + Duration::from_millis(5)));
        assert_eq!(got, Pop::Timeout);
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q = SubmitQueue::new(QueueMode::Fair);
        q.push(1, 0, None).unwrap();
        q.push(2, 1, None).unwrap();
        q.close();
        assert_eq!(q.push(3, 0, None), Err(3), "push after close returns the item");
        assert_eq!(q.pop_deadline(None), Pop::Item(1));
        assert_eq!(q.pop_deadline(None), Pop::Item(2));
        assert_eq!(q.pop_deadline(None), Pop::Closed);
        assert!(q.is_empty());
    }

    #[test]
    fn close_wakes_blocked_pop() {
        let q = Arc::new(SubmitQueue::<u32>::new(QueueMode::Fifo));
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.pop_deadline(None));
        std::thread::sleep(Duration::from_millis(5));
        q.close();
        assert_eq!(t.join().unwrap(), Pop::Closed);
    }
}
