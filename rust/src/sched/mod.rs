//! Unified work-stealing scheduler (the `[scheduler]` plane).
//!
//! Historically the coordinator ran **two** fixed pools: request-level
//! workers (`[service].workers` on an [`crate::exec::ThreadPool`]) and the
//! shard plane's tile pool (`[shard].workers`, owned by
//! [`crate::shard::ShardExecutor`]). Depending on traffic mix the pair
//! either oversubscribes the host (both pools busy) or starves it (a lone
//! huge GEMM keeps one request worker busy while the other request workers
//! idle and cannot help with its tiles).
//!
//! With `[scheduler].enabled = true` both roles collapse onto one
//! [`StealPool`]: every admitted request becomes a task spawned onto the
//! pool, and the request's shard tiles become *stealable leaves* — helper
//! claim-jobs pushed onto the executing worker's local deque, where any
//! idle sibling can steal them. A lone huge GEMM therefore fans out across
//! every core, while a flood of small requests runs one-per-worker without
//! ever paying tile-claim overhead (small requests never shard, exactly as
//! before). Results are bitwise identical at any worker/steal
//! configuration because tile outputs are still written to disjoint
//! MC/NC-aligned regions in a fixed per-tile summation order — *who*
//! computes a tile cannot change its bits.
//!
//! The module also provides [`SubmitQueue`], the condvar-signalled
//! admission queue used by the dispatcher in **both** modes (it replaces
//! the historical 50 ms `recv_timeout` poll tick), and [`TileStats`], the
//! per-request tile/steal accounting surfaced as
//! [`crate::coordinator::GemmResponse::stolen_tiles`].
//!
//! Deadlock freedom on the shared pool: the historical shard executor
//! *owned* its pool precisely because a request worker blocking on its
//! tiles inside a shared FIFO pool can deadlock (all workers blocked
//! waiting on tile jobs that sit queued behind them). The unified design
//! removes that hazard structurally — the requesting job **participates**
//! in its own tile-claim loop instead of only waiting: it spawns helper
//! claim-jobs, then claims tiles itself off the same atomic cursor, so it
//! only ever blocks on tiles a *running* helper has already claimed.
//! Progress is guaranteed at any pool size, including 1.

pub mod pool;
pub mod queue;

pub use pool::{task_was_stolen, StealPool};
pub use queue::{Pop, QueueMode, SubmitQueue};

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Per-request tile accounting: how many tiles ran, and how many of them
/// ran inside a *stolen* helper job. Installed around a request's
/// execution via [`request_scope`]; the shard executor's shared-pool path
/// records into it from every participating worker.
#[derive(Debug, Default)]
pub struct TileStats {
    tiles: AtomicU64,
    stolen: AtomicU64,
}

impl TileStats {
    /// Record one completed tile.
    pub fn record(&self, stolen: bool) {
        self.tiles.fetch_add(1, Ordering::Relaxed);
        if stolen {
            self.stolen.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Tiles recorded so far.
    pub fn tiles(&self) -> u64 {
        self.tiles.load(Ordering::Relaxed)
    }

    /// Tiles that ran inside a stolen helper job.
    pub fn stolen(&self) -> u64 {
        self.stolen.load(Ordering::Relaxed)
    }
}

thread_local! {
    static REQUEST: RefCell<Option<Arc<TileStats>>> = const { RefCell::new(None) };
}

/// Guard restoring the previous request scope on drop.
pub struct RequestScope {
    prev: Option<Arc<TileStats>>,
}

/// Pin `stats` to the executing thread for the duration of the returned
/// guard. The shard executor captures [`current_request`] before fanning
/// tile helpers out, so steal accounting follows the request across
/// worker threads (mirroring how the trace plane threads its `ActiveCtx`).
pub fn request_scope(stats: Arc<TileStats>) -> RequestScope {
    let prev = REQUEST.with(|r| r.replace(Some(stats)));
    RequestScope { prev }
}

impl Drop for RequestScope {
    fn drop(&mut self) {
        let prev = self.prev.take();
        REQUEST.with(|r| *r.borrow_mut() = prev);
    }
}

/// The tile accounting pinned to this thread, if any.
pub fn current_request() -> Option<Arc<TileStats>> {
    REQUEST.with(|r| r.borrow().clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_stats_counts_stolen_separately() {
        let s = TileStats::default();
        s.record(false);
        s.record(true);
        s.record(false);
        assert_eq!(s.tiles(), 3);
        assert_eq!(s.stolen(), 1);
    }

    #[test]
    fn request_scope_nests_and_restores() {
        assert!(current_request().is_none());
        let outer = Arc::new(TileStats::default());
        let g1 = request_scope(outer.clone());
        assert!(Arc::ptr_eq(&current_request().unwrap(), &outer));
        {
            let inner = Arc::new(TileStats::default());
            let _g2 = request_scope(inner.clone());
            assert!(Arc::ptr_eq(&current_request().unwrap(), &inner));
        }
        assert!(Arc::ptr_eq(&current_request().unwrap(), &outer));
        drop(g1);
        assert!(current_request().is_none());
    }
}
