//! Crate-wide error type.
//!
//! Kept dependency-free (no `thiserror` on the offline vendor set beyond the
//! xla closure) and deliberately small: most numerical routines are
//! infallible by construction; errors come from shape mismatches, artifact
//! loading, configuration parsing and service lifecycle.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All the ways the system can fail.
#[derive(Debug)]
pub enum Error {
    /// Incompatible matrix shapes for an operation.
    ShapeMismatch {
        op: &'static str,
        lhs: (usize, usize),
        rhs: (usize, usize),
    },
    /// A rank request that cannot be satisfied (zero, or above min(m, n)).
    InvalidRank { requested: usize, max: usize },
    /// Numerical routine failed to converge.
    NoConvergence { what: &'static str, iters: usize },
    /// Artifact (HLO) loading / manifest problems.
    Artifact(String),
    /// XLA / PJRT runtime failure.
    Xla(String),
    /// Configuration file / CLI parse errors.
    Config(String),
    /// Service lifecycle errors (shutdown, queue overflow, …).
    Service(String),
    /// Anything I/O.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::ShapeMismatch { op, lhs, rhs } => write!(
                f,
                "shape mismatch in {op}: lhs {}x{} vs rhs {}x{}",
                lhs.0, lhs.1, rhs.0, rhs.1
            ),
            Error::InvalidRank { requested, max } => {
                write!(f, "invalid rank {requested} (valid: 1..={max})")
            }
            Error::NoConvergence { what, iters } => {
                write!(f, "{what} failed to converge after {iters} iterations")
            }
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Service(m) => write!(f, "service error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Self {
        // The `xla` crate surfaces errors through anyhow-compatible types.
        Error::Xla(format!("{e:#}"))
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_shape_mismatch() {
        let e = Error::ShapeMismatch {
            op: "matmul",
            lhs: (2, 3),
            rhs: (4, 5),
        };
        let s = e.to_string();
        assert!(s.contains("matmul"));
        assert!(s.contains("2x3"));
        assert!(s.contains("4x5"));
    }

    #[test]
    fn display_invalid_rank() {
        let e = Error::InvalidRank { requested: 99, max: 8 };
        assert!(e.to_string().contains("99"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
