//! Crate-wide error type.
//!
//! Kept dependency-free (no `thiserror` on the offline vendor set beyond the
//! xla closure) and deliberately small: most numerical routines are
//! infallible by construction; errors come from shape mismatches, artifact
//! loading, configuration parsing and service lifecycle.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All the ways the system can fail.
#[derive(Debug)]
pub enum Error {
    /// Incompatible matrix shapes for an operation.
    ShapeMismatch {
        op: &'static str,
        lhs: (usize, usize),
        rhs: (usize, usize),
    },
    /// A rank request that cannot be satisfied (zero, or above min(m, n)).
    InvalidRank { requested: usize, max: usize },
    /// Numerical routine failed to converge.
    NoConvergence { what: &'static str, iters: usize },
    /// Artifact (HLO) loading / manifest problems.
    Artifact(String),
    /// XLA / PJRT runtime failure.
    Xla(String),
    /// Configuration file / CLI parse errors.
    Config(String),
    /// Service lifecycle errors (shutdown, internal invariants, …).
    Service(String),
    /// A request refused at `submit` time by admission control. Carries
    /// the structured reason so callers can branch on backpressure
    /// instead of parsing strings.
    Rejected(RejectReason),
    /// A kernel job panicked and was contained at the job boundary (the
    /// fault plane's panic isolation): the worker survived, the owning
    /// request resolves with this instead of hanging its waiter.
    KernelPanicked(String),
    /// A cluster node could not be reached or refused the connection —
    /// after the router exhausted its retry budget across candidates.
    NodeUnavailable(String),
    /// A cluster RPC timed out (connect or read deadline exceeded) after
    /// the router exhausted its retry budget.
    RpcTimeout(String),
    /// Anything I/O.
    Io(std::io::Error),
}

/// Why admission control refused a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The in-flight backlog reached the (priority-adjusted) queue depth.
    QueueFull {
        /// Requests in flight at rejection time.
        inflight: usize,
        /// The depth watermark the request was admitted against.
        depth: usize,
    },
    /// The deadline is provably unmeetable under the current backlog
    /// estimate from the calibrated cost model.
    DeadlineUnmeetable {
        /// Estimated completion time (backlog + this request), µs.
        estimated_us: u64,
        /// The request's deadline, µs.
        deadline_us: u64,
    },
    /// The tenant already has its full quota of requests in flight.
    TenantQuotaExceeded {
        /// The tenant.
        tenant: u64,
        /// The tenant's requests in flight at rejection time.
        inflight: usize,
        /// The per-tenant in-flight quota.
        quota: usize,
    },
    /// The service is draining toward shutdown.
    Draining,
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            // Must match the historical `Error::Service` string so
            // callers matching on Display keep working.
            RejectReason::QueueFull { inflight, depth } => {
                write!(f, "queue full ({inflight} in flight ≥ depth {depth})")
            }
            RejectReason::DeadlineUnmeetable {
                estimated_us,
                deadline_us,
            } => write!(
                f,
                "deadline unmeetable (estimated {estimated_us} µs ≥ deadline \
                 {deadline_us} µs under current backlog)"
            ),
            RejectReason::TenantQuotaExceeded {
                tenant,
                inflight,
                quota,
            } => write!(
                f,
                "tenant {tenant} quota exceeded ({inflight} in flight ≥ quota {quota})"
            ),
            RejectReason::Draining => write!(f, "service is draining"),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::ShapeMismatch { op, lhs, rhs } => write!(
                f,
                "shape mismatch in {op}: lhs {}x{} vs rhs {}x{}",
                lhs.0, lhs.1, rhs.0, rhs.1
            ),
            Error::InvalidRank { requested, max } => {
                write!(f, "invalid rank {requested} (valid: 1..={max})")
            }
            Error::NoConvergence { what, iters } => {
                write!(f, "{what} failed to converge after {iters} iterations")
            }
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Service(m) => write!(f, "service error: {m}"),
            // Same prefix as `Service`, and `RejectReason`'s Display
            // matches the historical strings — rejections render exactly
            // as they did when they were stringly typed.
            Error::Rejected(r) => write!(f, "service error: {r}"),
            Error::KernelPanicked(m) => write!(f, "kernel panicked (contained): {m}"),
            Error::NodeUnavailable(m) => write!(f, "node unavailable: {m}"),
            Error::RpcTimeout(m) => write!(f, "rpc timeout: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Self {
        // The `xla` crate surfaces errors through anyhow-compatible types.
        Error::Xla(format!("{e:#}"))
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_shape_mismatch() {
        let e = Error::ShapeMismatch {
            op: "matmul",
            lhs: (2, 3),
            rhs: (4, 5),
        };
        let s = e.to_string();
        assert!(s.contains("matmul"));
        assert!(s.contains("2x3"));
        assert!(s.contains("4x5"));
    }

    #[test]
    fn display_invalid_rank() {
        let e = Error::InvalidRank { requested: 99, max: 8 };
        assert!(e.to_string().contains("99"));
    }

    #[test]
    fn rejected_display_matches_legacy_queue_full_string() {
        let e = Error::Rejected(RejectReason::QueueFull {
            inflight: 2,
            depth: 2,
        });
        assert_eq!(e.to_string(), "service error: queue full (2 in flight ≥ depth 2)");
    }

    #[test]
    fn reject_reasons_are_branchable_and_display() {
        let r = RejectReason::DeadlineUnmeetable {
            estimated_us: 1500,
            deadline_us: 100,
        };
        assert!(r.to_string().contains("deadline unmeetable"));
        assert!(r.to_string().contains("1500"));
        let q = RejectReason::TenantQuotaExceeded {
            tenant: 7,
            inflight: 4,
            quota: 4,
        };
        assert!(q.to_string().contains("tenant 7"));
        assert_eq!(RejectReason::Draining.to_string(), "service is draining");
        // Callers can branch on the reason without string matching.
        let e = Error::Rejected(RejectReason::Draining);
        assert!(matches!(e, Error::Rejected(RejectReason::Draining)));
    }

    #[test]
    fn cluster_errors_display_with_distinct_prefixes() {
        let e = Error::NodeUnavailable("node 3 at 127.0.0.1:7071 (connection refused)".into());
        assert!(e.to_string().starts_with("node unavailable: "));
        assert!(e.to_string().contains("7071"));
        let t = Error::RpcTimeout("read from node 1 exceeded 2000 ms".into());
        assert!(t.to_string().starts_with("rpc timeout: "));
        assert!(matches!(t, Error::RpcTimeout(_)));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
