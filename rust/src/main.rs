//! `lowrank-gemm` — leader binary for the Low-Rank GEMM serving system.
//!
//! Subcommands:
//!
//! - `serve`     start the GemmService and replay a synthetic request load
//! - `gemm`      one GEMM through the full router (handy smoke test)
//! - `factorize` offline decomposition of a synthetic matrix; prints
//!               rank/error/memory accounting
//! - `route`     show the AutoKernelSelector's decision table for a size
//! - `trace`     run a few traced requests and dump span trees / exports
//! - `accuracy`  run a probed workload and print the accuracy report
//!               (per-kernel error histograms, SLO budget, error model)
//! - `cluster-router`  run the multi-node routing tier (membership,
//!               health, failover-aware request proxy); with
//!               `--requests` it drives the CI chaos-drill workload
//! - `cluster-node`    run a node agent: local GemmService + register/
//!               heartbeat against the router, serving routed requests
//! - `info`      device profiles, artifact manifest, build info
//!
//! Run `lowrank-gemm help` for flags.

use std::process::ExitCode;
use std::time::{Duration, Instant};

use lowrank_gemm::cli::{parse_args, CliArgs};
use lowrank_gemm::cluster::{NodeAgent, RouterTier};
use lowrank_gemm::config::AppConfig;
use lowrank_gemm::coordinator::{GemmRequest, GemmService, ServiceConfig};
use lowrank_gemm::error::Result;
use lowrank_gemm::gpu_sim::DeviceProfile;
use lowrank_gemm::kernels::{KernelKind, SelectorInputs};
use lowrank_gemm::linalg::{Matrix, Pcg64};
use lowrank_gemm::lowrank::{factorize, LowRankConfig, RankStrategy};
use lowrank_gemm::trace;

fn main() -> ExitCode {
    let args = match parse_args(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::from(2);
        }
    };

    let cmd = args.command.clone().unwrap_or_else(|| "help".into());
    let result = match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "gemm" => cmd_gemm(&args),
        "factorize" => cmd_factorize(&args),
        "route" => cmd_route(&args),
        "trace" => cmd_trace(&args),
        "accuracy" => cmd_accuracy(&args),
        "cluster-router" => cmd_cluster_router(&args),
        "cluster-node" => cmd_cluster_node(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand `{other}`; try `lowrank-gemm help`");
            return ExitCode::from(2);
        }
    };

    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "lowrank-gemm — Low-Rank GEMM serving system (paper reproduction)

USAGE: lowrank-gemm <command> [options]

COMMANDS:
  serve      --requests N --size N [--config F] [--workers W] [--no-xla]
             [--shard-workers W] [--tile-m M] [--tile-n N] [--min-parallel-n N]
             [--kernel-mc M] [--kernel-kc K] [--kernel-nc N] [--naive-cutover F]
             [--autotune] [--autotune-alpha A] [--autotune-epsilon E]
             [--autotune-min-samples K] [--autotune-table F]
             [--cache] [--cache-budget-mb M] [--cache-min-dim D]
             [--cache-fp8] [--cache-prepack] [--cache-amortize R]
             start the service and replay a synthetic transformer trace;
             --kernel-* tune the blocked GEMM's packing geometry
             (MC/KC/NC cache blocks + naive cutover) per host;
             --autotune turns on measured-latency calibration of the
             kernel selector (--autotune-table persists it across runs);
             --cache turns on content-addressed factor caching (anonymous
             repeated operands decompose once, LRU within --cache-budget-mb;
             --cache-prepack also stores Vᵀ pre-packed in panel layout);
             --trace turns on request-scoped span capture ([trace] in TOML:
             --trace-ring N --trace-slowest K --trace-max-spans N
             --trace-export FILE write the retained traces at exit);
             --accuracy turns on online error probing ([accuracy] in TOML:
             --accuracy-sample N probe one in N requests, --accuracy-probes S
             probe vectors, --accuracy-alpha A --accuracy-min-samples K
             EWMA knobs, --accuracy-table F persist the error model,
             --accuracy-seed S);
             --sched turns on the unified work-stealing scheduler +
             admission control ([scheduler] in TOML: --sched-workers W
             pool threads (0 = all cores), --sched-no-steal disables
             cross-worker stealing, --sched-queue-depth D admission depth,
             --sched-tenant-quota Q per-tenant in-flight cap);
             --fault turns on the fault-containment plane ([fault] in
             TOML: panic isolation + per-kernel circuit breakers over the
             degradation ladder; --fault-breaker-window N
             --fault-breaker-threshold K --fault-breaker-cooldown C
             breaker knobs, --fault-no-retry disables the one-retry
             fallback, --fault-strict-boot keeps corrupt tables fatal);
             --fault-inject SPEC arms deterministic fault injection and
             implies --fault (SPEC e.g.
             seed=42,panic_tile=0.08,error_request=0.1,error_kernel=lowrank_fp8);
             --json-out FILE writes the final metrics snapshot + request
             accounting as JSON (chaos-drill report);
             SIGINT/SIGTERM drains gracefully: submission stops,
             in-flight requests finish, autotune/accuracy tables and
             the flight recorder flush, and the process exits 0
  gemm       --n N [--kernel K] [--rank R] [--tolerance T] [--no-xla]
             run one GEMM end-to-end and report error/latency
  factorize  --n N --rank R [--method svd|rsvd|lanczos] [--storage fp8_e4m3|f16|f32]
             offline decomposition; prints error + memory accounting
  route      --n N [--rank R] [--tolerance T] [--device D] [--cached]
             [--autotune-table F] [--amortize R] [--accuracy-table F]
             [--fp8-reencode]
             print the selector's ranked decision table; with a saved
             calibration table, predictions include learned corrections;
             --amortize R prices cold decompositions amortized over R
             expected reuses (the factor-cache plane's routing view);
             --accuracy-table F adds a calibrated-error column from a
             saved error model; --fp8-reencode charges the factor-cache
             FP8 re-encode error to the low-rank candidates
  trace      [--requests N] [--size N] [--kernel K] [--last N] [--slowest]
             [--no-xla] [--chrome-out FILE] [--prom-out FILE] [--json-out FILE]
             run a short traced workload and print span trees (route →
             decompose/cache → pack → per-worker tiles → assemble);
             --chrome-out writes chrome://tracing JSON, --prom-out the
             Prometheus text exposition, --json-out the metrics snapshot
  accuracy   [--requests N] [--size N] [--kernel K] [--tolerance T]
             [--accuracy-sample N] [--accuracy-probes S] [--no-xla]
             [--accuracy-table F] [--json-out FILE]
             run a probed workload and print the accuracy report:
             per-kernel measured-error histograms, tolerance-SLO budget
             (violations per 10k probed) and the calibrated error model;
             --json-out writes the report as JSON
  cluster-router
             [--router HOST:PORT] [--requests N --size N --seed S]
             [--run-ms MS] [--json-out FILE] [--config F]
             run the multi-node routing tier: accepts node Register/
             Heartbeat/Deregister control frames, routes ExecRequest
             data frames by factor-cache affinity (weighted rendezvous
             hashing) with circuit breakers, retry/backoff and failover;
             with --requests it waits for nodes, drives a synthetic
             workload through the routing path and exits (the CI chaos
             drill; --json-out writes the report and the exit code is
             non-zero if any request was lost); without --requests it
             serves until SIGINT/SIGTERM or --run-ms;
             routing knobs: --cluster-heartbeat-ms
             --cluster-heartbeat-timeout-ms --cluster-dead-after-ms
             --cluster-connect-timeout-ms --cluster-read-timeout-ms
             --cluster-max-attempts --cluster-backoff-base-ms
             --cluster-backoff-cap-ms --cluster-fill-cap
             --cluster-affinity-min-dim --cluster-seed
  cluster-node
             [--listen HOST:PORT] [--router HOST:PORT] [--run-ms MS]
             [--config F] [service/cache/… flags as for serve]
             run a node agent: starts the local GemmService, registers
             with the router, heartbeats load + factor-cache occupancy
             digests, serves routed ExecRequests; on SIGINT/SIGTERM or
             after --run-ms it deregisters, finishes in-flight work and
             exits 0
  info       [--artifacts DIR]
             device profiles and the artifact manifest

Config file (TOML subset) via --config; flags override."
    );
}

fn load_config(args: &CliArgs) -> Result<AppConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => AppConfig::from_file(path)?,
        None => AppConfig::default(),
    };
    if let Some(d) = args.get("device") {
        cfg.device = d.to_string();
    }
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    if args.has_flag("no-xla") {
        cfg.use_xla = false;
    }
    cfg.service.workers = args.get_parse("workers", cfg.service.workers)?;
    // `[kernel]` overrides: blocked-GEMM geometry + naive cutover (the
    // knobs the autotune plane calibrates per host).
    cfg.kernel.mc = args.get_parse("kernel-mc", cfg.kernel.mc)?;
    cfg.kernel.kc = args.get_parse("kernel-kc", cfg.kernel.kc)?;
    cfg.kernel.nc = args.get_parse("kernel-nc", cfg.kernel.nc)?;
    cfg.kernel.naive_cutover = args.get_parse("naive-cutover", cfg.kernel.naive_cutover)?;
    // `[shard]` overrides: the tile-execution plane's knobs.
    cfg.shard.workers = args.get_parse("shard-workers", cfg.shard.workers)?;
    cfg.shard.tile_m = args.get_parse("tile-m", cfg.shard.tile_m)?;
    cfg.shard.tile_n = args.get_parse("tile-n", cfg.shard.tile_n)?;
    cfg.shard.min_parallel_n = args.get_parse("min-parallel-n", cfg.shard.min_parallel_n)?;
    // `[autotune]` overrides: the online calibration plane's knobs.
    if args.has_flag("autotune") {
        cfg.autotune.enabled = true;
    }
    cfg.autotune.ewma_alpha = args.get_parse("autotune-alpha", cfg.autotune.ewma_alpha)?;
    cfg.autotune.epsilon = args.get_parse("autotune-epsilon", cfg.autotune.epsilon)?;
    cfg.autotune.min_samples =
        args.get_parse("autotune-min-samples", cfg.autotune.min_samples)?;
    if let Some(p) = args.get("autotune-table") {
        cfg.autotune.table_path = Some(p.to_string());
    }
    // `[cache]` overrides: the factor-cache plane's knobs.
    if args.has_flag("cache") {
        cfg.cache.enabled = true;
    }
    if args.has_flag("cache-fp8") {
        cfg.cache.fp8 = true;
    }
    cfg.cache.budget_mb = args.get_parse("cache-budget-mb", cfg.cache.budget_mb)?;
    cfg.cache.min_dim = args.get_parse("cache-min-dim", cfg.cache.min_dim)?;
    cfg.cache.amortize_over = args.get_parse("cache-amortize", cfg.cache.amortize_over)?;
    if args.has_flag("cache-prepack") {
        cfg.cache.prepack = true;
    }
    // `[trace]` overrides: the tracing plane's knobs.
    if args.has_flag("trace") {
        cfg.trace.enabled = true;
    }
    cfg.trace.ring_capacity = args.get_parse("trace-ring", cfg.trace.ring_capacity)?;
    cfg.trace.slowest_k = args.get_parse("trace-slowest", cfg.trace.slowest_k)?;
    cfg.trace.max_spans = args.get_parse("trace-max-spans", cfg.trace.max_spans)?;
    if let Some(p) = args.get("trace-export") {
        cfg.trace.export_path = Some(p.to_string());
    }
    // `[accuracy]` overrides: the accuracy observability plane's knobs.
    if args.has_flag("accuracy") {
        cfg.accuracy.enabled = true;
    }
    cfg.accuracy.sample_every = args.get_parse("accuracy-sample", cfg.accuracy.sample_every)?;
    cfg.accuracy.probes = args.get_parse("accuracy-probes", cfg.accuracy.probes)?;
    cfg.accuracy.ewma_alpha = args.get_parse("accuracy-alpha", cfg.accuracy.ewma_alpha)?;
    cfg.accuracy.min_samples =
        args.get_parse("accuracy-min-samples", cfg.accuracy.min_samples)?;
    if let Some(p) = args.get("accuracy-table") {
        cfg.accuracy.table_path = Some(p.to_string());
    }
    cfg.accuracy.seed = args.get_parse("accuracy-seed", cfg.accuracy.seed)?;
    // `[scheduler]` overrides: the unified steal-pool / admission plane.
    if args.has_flag("sched") {
        cfg.scheduler.enabled = true;
    }
    if args.has_flag("sched-no-steal") {
        cfg.scheduler.steal = false;
    }
    cfg.scheduler.workers = args.get_parse("sched-workers", cfg.scheduler.workers)?;
    cfg.scheduler.queue_depth =
        args.get_parse("sched-queue-depth", cfg.scheduler.queue_depth)?;
    cfg.scheduler.tenant_quota =
        args.get_parse("sched-tenant-quota", cfg.scheduler.tenant_quota)?;
    // `[fault]` overrides: the fault-containment plane's knobs.
    if args.has_flag("fault") {
        cfg.fault.enabled = true;
    }
    if args.has_flag("fault-strict-boot") {
        cfg.fault.strict_boot = true;
    }
    if args.has_flag("fault-no-retry") {
        cfg.fault.retry = false;
    }
    cfg.fault.breaker_window =
        args.get_parse("fault-breaker-window", cfg.fault.breaker_window)?;
    cfg.fault.breaker_threshold =
        args.get_parse("fault-breaker-threshold", cfg.fault.breaker_threshold)?;
    cfg.fault.breaker_cooldown =
        args.get_parse("fault-breaker-cooldown", cfg.fault.breaker_cooldown)?;
    if let Some(spec) = args.get("fault-inject") {
        // An injection plan implies the plane: the guards it exercises
        // only exist when the plane is up.
        cfg.fault.enabled = true;
        cfg.fault.inject.apply_spec(spec)?;
    }
    // `[cluster]` overrides: the multi-node serving tier's knobs (the
    // cluster-router / cluster-node subcommands flip `enabled` on
    // themselves; everything else stays single-process).
    if let Some(a) = args.get("listen") {
        cfg.cluster.node_addr = a.to_string();
    }
    if let Some(a) = args.get("router") {
        cfg.cluster.router_addr = a.to_string();
    }
    cfg.cluster.heartbeat_ms =
        args.get_parse("cluster-heartbeat-ms", cfg.cluster.heartbeat_ms)?;
    cfg.cluster.heartbeat_timeout_ms =
        args.get_parse("cluster-heartbeat-timeout-ms", cfg.cluster.heartbeat_timeout_ms)?;
    cfg.cluster.dead_after_ms =
        args.get_parse("cluster-dead-after-ms", cfg.cluster.dead_after_ms)?;
    cfg.cluster.connect_timeout_ms =
        args.get_parse("cluster-connect-timeout-ms", cfg.cluster.connect_timeout_ms)?;
    cfg.cluster.read_timeout_ms =
        args.get_parse("cluster-read-timeout-ms", cfg.cluster.read_timeout_ms)?;
    cfg.cluster.max_attempts =
        args.get_parse("cluster-max-attempts", cfg.cluster.max_attempts)?;
    cfg.cluster.backoff_base_ms =
        args.get_parse("cluster-backoff-base-ms", cfg.cluster.backoff_base_ms)?;
    cfg.cluster.backoff_cap_ms =
        args.get_parse("cluster-backoff-cap-ms", cfg.cluster.backoff_cap_ms)?;
    cfg.cluster.fill_cap = args.get_parse("cluster-fill-cap", cfg.cluster.fill_cap)?;
    cfg.cluster.affinity_min_dim =
        args.get_parse("cluster-affinity-min-dim", cfg.cluster.affinity_min_dim)?;
    cfg.cluster.seed = args.get_parse("cluster-seed", cfg.cluster.seed)?;
    // Same validators the TOML path runs — an out-of-range flag must
    // fail loudly, not be silently clamped downstream.
    cfg.kernel.validate()?;
    cfg.autotune.validate()?;
    cfg.cache.validate()?;
    cfg.trace.validate()?;
    cfg.accuracy.validate()?;
    cfg.scheduler.validate()?;
    cfg.fault.validate()?;
    cfg.cluster.validate()?;
    Ok(cfg)
}

/// Dependency-free SIGINT/SIGTERM latch for graceful drains.
///
/// `signal(2)` lives in the libc every Rust binary on unix already links,
/// so no crate is needed; the handler only flips an atomic, which is
/// async-signal-safe. Long-running subcommands poll [`sig::triggered`]
/// and drain instead of dying mid-request.
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static SHUTDOWN: AtomicBool = AtomicBool::new(false);

    #[cfg(unix)]
    mod imp {
        use std::sync::atomic::Ordering;

        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }

        extern "C" fn on_signal(_signum: i32) {
            super::SHUTDOWN.store(true, Ordering::Release);
        }

        pub fn install() {
            // SIGINT = 2, SIGTERM = 15 on every unix target we build for.
            unsafe {
                signal(2, on_signal);
                signal(15, on_signal);
            }
        }
    }

    #[cfg(not(unix))]
    mod imp {
        // No signals to latch; `triggered()` simply never fires and the
        // run-to-completion / --run-ms paths still terminate the loops.
        pub fn install() {}
    }

    /// Arm the handlers (idempotent; cheap to call per subcommand).
    pub fn install() {
        imp::install();
    }

    /// Has a shutdown signal arrived since [`install`]?
    pub fn triggered() -> bool {
        SHUTDOWN.load(Ordering::Acquire)
    }
}

fn cmd_serve(args: &CliArgs) -> Result<()> {
    let app = load_config(args)?;
    sig::install();
    let svc = GemmService::start(ServiceConfig::from_app(&app)?)?;
    let requests: usize = args.get_parse("requests", 64)?;
    let size: usize = args.get_parse("size", 128)?;
    let seed: u64 = args.get_parse("seed", 7)?;
    let mut rng = Pcg64::seeded(seed);

    // Offline-decompose the "weights" of a toy transformer layer, then
    // replay activations against them (the paper's intended deployment).
    let shapes = trace::transformer_layer_trace(size, size, size * 4, 1);
    println!("preloading {} weight factors …", shapes.len());
    let mut weights = Vec::new();
    for (i, shape) in shapes.iter().enumerate() {
        let w = Matrix::low_rank_noisy(shape.k, shape.n, (shape.k / 8).max(2), 1e-4, &mut rng);
        svc.preload_factor(i as u64 + 1, &w)?;
        weights.push(w);
    }

    println!("replaying {requests} requests at batch-size-{size} activations …");
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..requests {
        // Graceful shutdown: a SIGINT/SIGTERM stops *submission*; the
        // requests already accepted finish and are collected below.
        if sig::triggered() {
            println!("shutdown signal: stopping submission after {i} requests, draining …");
            break;
        }
        let wi = i % weights.len();
        let x = Matrix::gaussian(size, weights[wi].rows(), &mut rng);
        let req = GemmRequest::new(x, weights[wi].clone()).with_ids(None, Some(wi as u64 + 1));
        rxs.push(svc.submit(req)?);
    }
    let submitted = rxs.len();
    let mut ok = 0usize;
    let mut failed = 0usize;
    for rx in rxs {
        match rx.recv().map_err(|_| {
            lowrank_gemm::error::Error::Service("response channel closed".into())
        })? {
            Ok(_) => ok += 1,
            // A typed error (e.g. a contained kernel panic whose fallback
            // also failed) still *resolves* the request — the chaos drill
            // below asserts resolved == submitted, not ok == submitted.
            Err(_) => failed += 1,
        }
    }
    if sig::triggered() {
        // Every response is already in, but probes and batched stragglers
        // may still be on the pool: drain before flushing tables so the
        // persisted state reflects everything the run learned.
        svc.drain();
    }
    let dt = t0.elapsed();

    let stats = svc.stats();
    println!(
        "done: {ok}/{submitted} ok ({failed} failed) in {:.3}s ({:.1} req/s)",
        dt.as_secs_f64(),
        submitted as f64 / dt.as_secs_f64()
    );
    println!(
        "id cache: {} hits / {} misses / {} entries",
        stats.cache.hits, stats.cache.misses, stats.cache.entries
    );
    if svc.content_cache().is_some() {
        let cs = stats.content_cache;
        println!(
            "content cache: {} hits / {} misses / {} evictions / {} entries / {} KiB resident",
            cs.hits,
            cs.misses,
            cs.evictions,
            cs.entries,
            cs.resident_bytes / 1024
        );
    }
    println!("{}", svc.metrics().render());
    if let Some(path) = args.get("json-out") {
        let json = format!(
            "{{\"requests\":{submitted},\"ok\":{ok},\"failed\":{failed},\"resolved\":{},\"metrics\":{}}}",
            ok + failed,
            stats.metrics.to_json().trim_end()
        );
        std::fs::write(path, json)
            .map_err(|e| lowrank_gemm::error::Error::Config(format!("{path}: {e}")))?;
        println!("wrote serve report to {path}");
    }
    if svc.tracer().enabled() {
        let recorder = svc.tracer().recorder();
        println!(
            "flight recorder: {} traces recorded, {} retained",
            recorder.total_recorded(),
            recorder.recent().len()
        );
        if let Some(slowest) = recorder.slowest().first() {
            println!("slowest request:");
            print!("{}", lowrank_gemm::trace_plane::export::text_tree(slowest));
        }
        if let Some(path) = &app.trace.export_path {
            let json = lowrank_gemm::trace_plane::export::chrome_trace_json(&recorder.recent());
            std::fs::write(path, json)
                .map_err(|e| lowrank_gemm::error::Error::Config(format!("{path}: {e}")))?;
            println!("wrote chrome trace to {path}");
        }
    }
    // Flush learned state explicitly. Drop also saves best-effort, but a
    // graceful drain (signal or normal completion) should persist and
    // *report* before exiting 0, not rely on destructor ordering.
    match svc.save_calibration() {
        Ok(true) => println!("saved autotune calibration table"),
        Ok(false) => {}
        Err(e) => eprintln!("warning: autotune table not saved: {e}"),
    }
    match svc.save_error_model() {
        Ok(true) => println!("saved accuracy error model"),
        Ok(false) => {}
        Err(e) => eprintln!("warning: accuracy error model not saved: {e}"),
    }
    Ok(())
}

fn cmd_trace(args: &CliArgs) -> Result<()> {
    let mut app = load_config(args)?;
    app.trace.enabled = true;
    let requests: usize = args.get_parse("requests", 3)?;
    let size: usize = args.get_parse("size", 512)?;
    let seed: u64 = args.get_parse("seed", 7)?;
    let last: usize = args.get_parse("last", requests.max(1))?;

    let kernel = match args.get("kernel") {
        Some(k) => Some(KernelKind::parse(k).ok_or_else(|| {
            lowrank_gemm::error::Error::Config(format!("unknown kernel `{k}`"))
        })?),
        None => None,
    };

    let svc = GemmService::start(ServiceConfig::from_app(&app)?)?;
    let mut rng = Pcg64::seeded(seed);
    for _ in 0..requests {
        let a = Matrix::low_rank_noisy(size, size, (size / 16).max(2), 1e-4, &mut rng);
        let b = Matrix::low_rank_noisy(size, size, (size / 16).max(2), 1e-4, &mut rng);
        let mut req = GemmRequest::new(a, b);
        if let Some(k) = kernel {
            req = req.with_kernel(k);
        }
        svc.gemm_blocking(req)?;
    }

    let recorder = svc.tracer().recorder();
    let traces = if args.has_flag("slowest") {
        recorder.slowest()
    } else {
        recorder.recent()
    };
    let skip = traces.len().saturating_sub(last);
    for t in traces.iter().skip(if args.has_flag("slowest") { 0 } else { skip }).take(last) {
        print!("{}", lowrank_gemm::trace_plane::export::text_tree(t));
    }

    let write = |path: &str, payload: String| -> Result<()> {
        std::fs::write(path, payload)
            .map_err(|e| lowrank_gemm::error::Error::Config(format!("{path}: {e}")))
    };
    let chrome_out = args
        .get("chrome-out")
        .map(str::to_string)
        .or_else(|| app.trace.export_path.clone());
    if let Some(path) = chrome_out {
        write(
            &path,
            lowrank_gemm::trace_plane::export::chrome_trace_json(&recorder.recent()),
        )?;
        println!("wrote chrome trace to {path}");
    }
    let stats = svc.stats();
    if let Some(path) = args.get("prom-out") {
        write(path, stats.metrics.to_prometheus())?;
        println!("wrote prometheus exposition to {path}");
    }
    if let Some(path) = args.get("json-out") {
        write(path, stats.metrics.to_json())?;
        println!("wrote metrics snapshot to {path}");
    }
    Ok(())
}

fn cmd_gemm(args: &CliArgs) -> Result<()> {
    let app = load_config(args)?;
    let n: usize = args.get_parse("n", 256)?;
    let seed: u64 = args.get_parse("seed", 1)?;
    let mut cfg = ServiceConfig::from_app(&app)?;
    if let Some(r) = args.get("rank") {
        cfg.router.rank_strategy = RankStrategy::Fixed(r.parse().map_err(|_| {
            lowrank_gemm::error::Error::Config(format!("--rank: bad value `{r}`"))
        })?);
    }
    let svc = GemmService::start(cfg)?;

    let mut rng = Pcg64::seeded(seed);
    let a = Matrix::low_rank_noisy(n, n, (n / 16).max(2), 1e-4, &mut rng);
    let b = Matrix::low_rank_noisy(n, n, (n / 16).max(2), 1e-4, &mut rng);
    let mut req = GemmRequest::new(a.clone(), b.clone());
    if let Some(k) = args.get("kernel") {
        req = req.with_kernel(KernelKind::parse(k).ok_or_else(|| {
            lowrank_gemm::error::Error::Config(format!("unknown kernel `{k}`"))
        })?);
    }
    if let Some(t) = args.get("tolerance") {
        req = req.with_tolerance(t.parse().map_err(|_| {
            lowrank_gemm::error::Error::Config(format!("--tolerance: bad value `{t}`"))
        })?);
    }

    let resp = svc.gemm_blocking(req)?;
    let exact = a.matmul(&b);
    println!(
        "kernel={} backend={} rank={} exec={}us queue={}us",
        resp.kernel.paper_name(),
        resp.backend.name(),
        resp.rank,
        resp.exec_us,
        resp.queue_us
    );
    println!(
        "predicted rel err = {:.3e}, measured = {:.3e}",
        resp.predicted_rel_error,
        resp.c.rel_frobenius_distance(&exact)
    );
    Ok(())
}

fn cmd_factorize(args: &CliArgs) -> Result<()> {
    let n: usize = args.get_parse("n", 512)?;
    let rank: usize = args.get_parse("rank", n / 16)?;
    let seed: u64 = args.get_parse("seed", 1)?;
    let mut cfg = LowRankConfig {
        rank: RankStrategy::Fixed(rank),
        ..Default::default()
    };
    if let Some(m) = args.get("method") {
        cfg.method = lowrank_gemm::lowrank::DecompMethod::parse(m).ok_or_else(|| {
            lowrank_gemm::error::Error::Config(format!("unknown method `{m}`"))
        })?;
    }
    if let Some(s) = args.get("storage") {
        cfg.storage = lowrank_gemm::fp8::StorageFormat::parse(s).ok_or_else(|| {
            lowrank_gemm::error::Error::Config(format!("unknown storage `{s}`"))
        })?;
    }

    let mut rng = Pcg64::seeded(seed);
    let a = Matrix::low_rank_noisy(n, n, rank, 1e-3, &mut rng);
    let t0 = std::time::Instant::now();
    let f = factorize(&a, &cfg)?;
    let dt = t0.elapsed();
    println!(
        "factorized {n}x{n} with {} → rank {} in {:.1} ms",
        cfg.method.name(),
        f.rank(),
        dt.as_secs_f64() * 1e3
    );
    println!(
        "storage: {} KiB factored vs {} KiB dense ({:.1}% saving)",
        f.storage_bytes() / 1024,
        f.dense_bytes() / 1024,
        100.0 * f.memory_saving()
    );
    println!("measured rel error = {:.3e}", f.measured_error(&a));
    Ok(())
}

fn cmd_route(args: &CliArgs) -> Result<()> {
    let n: usize = args.get_parse("n", 4096)?;
    let rank: usize = args.get_parse("rank", (n / 16).max(1))?;
    let tolerance: f32 = args.get_parse("tolerance", 0.05)?;
    let device = args.get("device").unwrap_or("rtx4090");
    let profile = DeviceProfile::by_name(device).ok_or_else(|| {
        lowrank_gemm::error::Error::Config(format!("unknown device `{device}`"))
    })?;
    let mut selector = lowrank_gemm::kernels::AutoKernelSelector::new(profile.clone());
    if let Some(path) = args.get("autotune-table") {
        // A calibration table holds observed/(shard-adjusted analytic)
        // ratios, so reproduce the serving selector exactly: same shard
        // plan and same blend knobs, all sourced from the config/flag
        // pipeline the service uses.
        let app = load_config(args)?;
        let at = &app.autotune;
        let table = lowrank_gemm::autotune::CalibrationTable::new(at.ewma_alpha, at.min_samples);
        let loaded = table.load(path)?;
        println!("(applying {loaded} calibration cells from {path})");
        selector = lowrank_gemm::kernels::AutoKernelSelector::with_shard(
            profile,
            lowrank_gemm::shard::ShardPlan::from(&app.shard),
        )
        .with_calibration(std::sync::Arc::new(table));
    }

    // Calibrated-error view: a saved error model adds a column of
    // probe-corrected predictions next to the analytic ones, so the
    // table shows exactly what the tolerance gate will route on.
    let err_model = match args.get("accuracy-table") {
        Some(path) => {
            let app = load_config(args)?;
            let ac = &app.accuracy;
            let model = lowrank_gemm::accuracy::ErrorModel::new(ac.ewma_alpha, ac.min_samples);
            let loaded = model.load(path)?;
            println!("(applying {loaded} error-model cells from {path})");
            let model = std::sync::Arc::new(model);
            selector = selector.with_error_model(model.clone());
            Some(model)
        }
        None => None,
    };

    let inp = SelectorInputs {
        m: n,
        k: n,
        n,
        error_tolerance: tolerance,
        rank,
        factors_cached: args.has_flag("cached"),
        factored_output_ok: args.has_flag("factored-ok"),
        decomp_amortization: args.get_parse("amortize", 1.0)?,
        fp8_reencode: args.has_flag("fp8-reencode"),
    };
    println!(
        "decision table for N={n}, r={rank}, tol={tolerance}, cached={}, amortize={}:",
        inp.factors_cached, inp.decomp_amortization
    );
    if err_model.is_some() {
        println!(
            "{:<22} {:>12} {:>14} {:>12} {:>12}",
            "kernel", "pred time", "pred TFLOPS", "pred err", "cal err"
        );
    } else {
        println!(
            "{:<22} {:>12} {:>14} {:>12}",
            "kernel", "pred time", "pred TFLOPS", "pred err"
        );
    }
    for c in selector.ranked(&inp) {
        if err_model.is_some() {
            // The choice carries the calibrated prediction; dividing the
            // correction back out recovers the analytic value so both
            // columns are visible side by side.
            let raw = c.predicted_error as f64 / c.error_correction;
            println!(
                "{:<22} {:>10.3} ms {:>14.1} {:>12.2e} {:>12.2e}",
                c.kind.paper_name(),
                c.cost.time_s * 1e3,
                c.cost.flops / c.cost.time_s / 1e12,
                raw,
                c.predicted_error
            );
        } else {
            println!(
                "{:<22} {:>10.3} ms {:>14.1} {:>12.2e}",
                c.kind.paper_name(),
                c.cost.time_s * 1e3,
                c.cost.flops / c.cost.time_s / 1e12,
                c.predicted_error
            );
        }
    }
    let best = selector.select(&inp);
    println!("selected: {}", best.kind.paper_name());
    Ok(())
}

fn cmd_accuracy(args: &CliArgs) -> Result<()> {
    let mut app = load_config(args)?;
    app.accuracy.enabled = true;
    // Probe every request unless the caller asked for a sparser sample —
    // a short demo workload should produce a populated report.
    if args.get("accuracy-sample").is_none() {
        app.accuracy.sample_every = 1;
    }
    let requests: usize = args.get_parse("requests", 24)?;
    let size: usize = args.get_parse("size", 256)?;
    let seed: u64 = args.get_parse("seed", 7)?;
    let kernel = match args.get("kernel") {
        Some(k) => Some(KernelKind::parse(k).ok_or_else(|| {
            lowrank_gemm::error::Error::Config(format!("unknown kernel `{k}`"))
        })?),
        None => None,
    };
    let tolerance: Option<f32> = match args.get("tolerance") {
        Some(t) => Some(t.parse().map_err(|_| {
            lowrank_gemm::error::Error::Config(format!("--tolerance: bad value `{t}`"))
        })?),
        None => None,
    };

    let svc = GemmService::start(ServiceConfig::from_app(&app)?)?;
    let mut rng = Pcg64::seeded(seed);
    for _ in 0..requests {
        let a = Matrix::low_rank_noisy(size, size, (size / 16).max(2), 1e-4, &mut rng);
        let b = Matrix::low_rank_noisy(size, size, (size / 16).max(2), 1e-4, &mut rng);
        let mut req = GemmRequest::new(a, b);
        if let Some(k) = kernel {
            req = req.with_kernel(k);
        }
        if let Some(t) = tolerance {
            req = req.with_tolerance(t);
        }
        svc.gemm_blocking(req)?;
    }

    // Probes ride the shard pool behind serving work: wait for the
    // sampled jobs to drain (probed + failed = sampled) before reporting.
    let plane = svc.accuracy().expect("plane enabled above");
    let want = (requests as u64).div_ceil(app.accuracy.sample_every);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let settled = plane.stats().probed
            + svc
                .metrics()
                .counters()
                .get("accuracy.probe_failed")
                .copied()
                .unwrap_or(0);
        if settled >= want || std::time::Instant::now() >= deadline {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    let stats = svc.stats();
    let acc = stats.accuracy.expect("plane enabled above");
    let failures = stats
        .metrics
        .counters
        .get("accuracy.probe_failed")
        .copied()
        .unwrap_or(0);
    println!(
        "accuracy report: {requests} requests, {} probed ({} probe vectors each), {failures} probe failures",
        acc.probed, app.accuracy.probes
    );
    println!(
        "SLO: {} violations lifetime; {:.1} per 10k probed over the last {} probes",
        acc.violations, acc.violations_per_10k, acc.window
    );

    println!(
        "\n{:<22} {:>8} {:>12} {:>12} {:>12}",
        "kernel", "probed", "mean err", "p99 err", "max err"
    );
    for kind in KernelKind::ALL {
        let key = format!("accuracy.error.{}", kind.id());
        if let Some(h) = stats.metrics.histograms.get(&key) {
            if h.count > 0 {
                println!(
                    "{:<22} {:>8} {:>12.2e} {:>12.2e} {:>12.2e}",
                    kind.paper_name(),
                    h.count,
                    h.mean,
                    h.p99,
                    h.max
                );
            }
        }
    }

    let cells = plane.model().snapshot();
    println!("\nerror model: {} calibrated cells (probed/predicted EWMA)", cells.len());
    if !cells.is_empty() {
        println!(
            "{:<22} {:>10} {:>10} {:>10} {:>9}",
            "kernel", "size 2^", "rank cls", "ratio", "samples"
        );
        for (k, e) in &cells {
            println!(
                "{:<22} {:>10} {:>10} {:>10.3} {:>9}",
                k.kernel.paper_name(),
                k.size_class,
                k.rank_class,
                e.ratio,
                e.samples
            );
        }
    }

    if let Some(path) = args.get("json-out") {
        let mut kernels = String::new();
        for kind in KernelKind::ALL {
            let key = format!("accuracy.error.{}", kind.id());
            if let Some(h) = stats.metrics.histograms.get(&key) {
                if h.count > 0 {
                    if !kernels.is_empty() {
                        kernels.push(',');
                    }
                    kernels.push_str(&format!(
                        "{{\"kernel\":\"{}\",\"probed\":{},\"mean_err\":{:e},\"p99_err\":{:e},\"max_err\":{:e}}}",
                        kind.id(),
                        h.count,
                        h.mean,
                        h.p99,
                        h.max
                    ));
                }
            }
        }
        let json = format!(
            "{{\"requests\":{requests},\"probed\":{},\"violations\":{},\"violations_per_10k\":{:e},\"window\":{},\"probe_failures\":{failures},\"model_cells\":{},\"kernels\":[{kernels}]}}\n",
            acc.probed, acc.violations, acc.violations_per_10k, acc.window, acc.model_cells
        );
        std::fs::write(path, json)
            .map_err(|e| lowrank_gemm::error::Error::Config(format!("{path}: {e}")))?;
        println!("wrote accuracy report to {path}");
    }
    if let Some(path) = &app.accuracy.table_path {
        svc.save_error_model()?;
        println!("saved error model to {path}");
    }
    Ok(())
}

fn cmd_cluster_router(args: &CliArgs) -> Result<()> {
    let mut app = load_config(args)?;
    app.cluster.enabled = true;
    app.cluster.validate()?;
    sig::install();
    let mut router = RouterTier::start(&app)?;
    println!("cluster-router listening on {}", router.addr());

    let requests: usize = args.get_parse("requests", 0)?;
    let size: usize = args.get_parse("size", 128)?;
    let seed: u64 = args.get_parse("seed", 7)?;
    let run_ms: u64 = args.get_parse("run-ms", 0)?;

    if requests == 0 {
        // Pure serving mode: route until a signal (or --run-ms elapses,
        // which CI uses to bound the job).
        let deadline = (run_ms > 0).then(|| Instant::now() + Duration::from_millis(run_ms));
        while !sig::triggered() && !deadline.is_some_and(|d| Instant::now() >= d) {
            std::thread::sleep(Duration::from_millis(50));
        }
        println!("cluster-router shutting down …");
        router.shutdown();
        return Ok(());
    }

    // Chaos-drill mode: wait for membership (router and nodes launch
    // concurrently in CI), replay the workload, report, and fail loudly
    // if anything was lost.
    let deadline = Instant::now() + Duration::from_secs(30);
    while router.registry().is_empty() {
        if sig::triggered() || Instant::now() >= deadline {
            router.shutdown();
            return Err(lowrank_gemm::error::Error::Service(
                "no nodes registered before workload start".into(),
            ));
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    println!(
        "{} node(s) registered; replaying {requests} requests at size {size} …",
        router.registry().len()
    );
    let t0 = Instant::now();
    let report = router.run_workload(requests, size, seed);
    let dt = t0.elapsed();
    println!(
        "done: {} ok / {} rejected / {} failed of {} submitted ({} resolved) in {:.3}s",
        report.ok,
        report.rejected,
        report.failed,
        report.requests,
        report.resolved(),
        dt.as_secs_f64()
    );
    println!("{}", router.metrics().render());
    if let Some(path) = args.get("json-out") {
        let json = format!(
            "{{\"requests\":{},\"ok\":{},\"rejected\":{},\"failed\":{},\"resolved\":{},\"metrics\":{}}}",
            report.requests,
            report.ok,
            report.rejected,
            report.failed,
            report.resolved(),
            router.metrics().snapshot().to_json().trim_end()
        );
        std::fs::write(path, json)
            .map_err(|e| lowrank_gemm::error::Error::Config(format!("{path}: {e}")))?;
        println!("wrote cluster report to {path}");
    }
    router.shutdown();
    if report.resolved() != report.requests {
        return Err(lowrank_gemm::error::Error::Service(format!(
            "lost requests: {} submitted but only {} resolved",
            report.requests,
            report.resolved()
        )));
    }
    Ok(())
}

fn cmd_cluster_node(args: &CliArgs) -> Result<()> {
    let mut app = load_config(args)?;
    app.cluster.enabled = true;
    app.cluster.validate()?;
    sig::install();
    let mut node = NodeAgent::start(&app)?;
    println!(
        "cluster-node {} serving on {} (router {})",
        node.node_id(),
        node.addr(),
        app.cluster.router_addr
    );

    let run_ms: u64 = args.get_parse("run-ms", 0)?;
    let deadline = (run_ms > 0).then(|| Instant::now() + Duration::from_millis(run_ms));
    while !sig::triggered() && !deadline.is_some_and(|d| Instant::now() >= d) {
        std::thread::sleep(Duration::from_millis(50));
    }
    // Graceful exit: deregister from the router, finish in-flight RPCs,
    // drain the local service, then stop the accept loop.
    println!("cluster-node draining …");
    node.shutdown();
    Ok(())
}

fn cmd_info(args: &CliArgs) -> Result<()> {
    println!("device profiles:");
    for name in ["rtx4090", "h200", "b200", "cpu"] {
        let p = DeviceProfile::by_name(name).expect("built-in profile");
        println!(
            "  {:<8} {:>7.1} GB  {:>6.2} TB/s  fp8 {:>8.1} TFLOPS  f32 {:>7.1} TFLOPS",
            p.name,
            p.memory_bytes as f64 / 1e9,
            p.bandwidth_bps / 1e12,
            p.peak_flops(lowrank_gemm::gpu_sim::Precision::Fp8) / 1e12,
            p.peak_flops(lowrank_gemm::gpu_sim::Precision::F32) / 1e12,
        );
    }
    let dir = args.get("artifacts").unwrap_or("artifacts");
    match lowrank_gemm::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!("\nartifacts in {dir} (oversample {}):", m.oversample);
            for e in m.entries() {
                println!(
                    "  {:<30} op={:<18} n={:<5} r={:<3} {} in / {} out",
                    e.name,
                    e.op,
                    e.n,
                    e.rank,
                    e.inputs.len(),
                    e.outputs.len()
                );
            }
        }
        Err(e) => println!("\nno artifact manifest: {e}"),
    }
    Ok(())
}
