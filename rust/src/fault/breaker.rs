//! Per-kernel circuit breaker: rolling failure window, trip / half-open /
//! probe states.
//!
//! One cell per [`KernelKind`]. A cell starts **Closed** (traffic flows;
//! outcomes fill a rolling window). When the window holds `threshold`
//! failures the cell **trips to Open**: requests are denied (the router
//! walks the degradation ladder instead) until `cooldown` denials have
//! accumulated, at which point the cell moves to **HalfOpen** and admits
//! exactly one probe request. The probe's outcome decides: success closes
//! the cell (recovered), failure re-opens it for another cooldown.
//!
//! Denial-counted cooldown (rather than wall-clock) keeps the state
//! machine deterministic for tests and seeded chaos runs: the Nth denied
//! request is the probe trigger at any request rate.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::fault::flock;
use crate::kernels::KernelKind;

/// Breaker cell state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Traffic flows; outcomes fill the rolling window.
    Closed,
    /// Tripped: deny until `cooldown` denials, then probe.
    Open,
    /// One probe request is in flight; its outcome decides.
    HalfOpen,
}

/// A state transition worth counting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerTransition {
    /// Closed/HalfOpen → Open.
    Tripped,
    /// HalfOpen → Closed.
    Recovered,
}

struct Cell {
    state: BreakerState,
    /// Rolling outcome window, `true` = failure. Bounded at `window`.
    recent: VecDeque<bool>,
    /// Denials since the cell opened (cooldown progress).
    denied: usize,
}

impl Cell {
    fn new() -> Self {
        Cell {
            state: BreakerState::Closed,
            recent: VecDeque::new(),
            denied: 0,
        }
    }
}

/// One standalone breaker cell: the Closed/Open/HalfOpen state machine
/// over a rolling outcome window, keyed by nothing. [`CircuitBreaker`]
/// arrays these per [`KernelKind`]; the cluster router holds one per
/// serving node.
pub struct BreakerCell {
    window: usize,
    threshold: usize,
    cooldown: usize,
    cell: Mutex<Cell>,
}

impl BreakerCell {
    /// New closed cell: `threshold` failures within the last `window`
    /// outcomes trip it; `cooldown` denials later it admits one probe.
    pub fn new(window: usize, threshold: usize, cooldown: usize) -> Self {
        BreakerCell {
            window: window.max(1),
            threshold: threshold.max(1),
            cooldown: cooldown.max(1),
            cell: Mutex::new(Cell::new()),
        }
    }

    /// May a request be served right now? Open cells count the denial
    /// toward their cooldown; the call that completes the cooldown moves
    /// the cell to HalfOpen and is admitted as the probe.
    pub fn allows(&self) -> bool {
        let mut cell = flock(&self.cell);
        match cell.state {
            BreakerState::Closed => true,
            BreakerState::HalfOpen => false, // a probe is already out
            BreakerState::Open => {
                cell.denied += 1;
                if cell.denied >= self.cooldown {
                    cell.state = BreakerState::HalfOpen;
                    cell.denied = 0;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a served request's outcome. Returns the transition it
    /// caused, if any (callers count trips and recoveries).
    pub fn observe(&self, ok: bool) -> Option<BreakerTransition> {
        let mut cell = flock(&self.cell);
        match cell.state {
            BreakerState::Closed => {
                if cell.recent.len() == self.window {
                    cell.recent.pop_front();
                }
                cell.recent.push_back(!ok);
                if cell.recent.iter().filter(|f| **f).count() >= self.threshold {
                    cell.state = BreakerState::Open;
                    cell.recent.clear();
                    cell.denied = 0;
                    Some(BreakerTransition::Tripped)
                } else {
                    None
                }
            }
            BreakerState::HalfOpen => {
                if ok {
                    cell.state = BreakerState::Closed;
                    cell.recent.clear();
                    Some(BreakerTransition::Recovered)
                } else {
                    cell.state = BreakerState::Open;
                    cell.denied = 0;
                    Some(BreakerTransition::Tripped)
                }
            }
            // A straggler finishing after the trip; its outcome is stale.
            BreakerState::Open => None,
        }
    }

    /// Current state (observability / tests).
    pub fn state(&self) -> BreakerState {
        flock(&self.cell).state
    }
}

/// Per-[`KernelKind`] circuit breaker: one [`BreakerCell`] per kernel.
pub struct CircuitBreaker {
    cells: [BreakerCell; KernelKind::ALL.len()],
}

fn idx(kind: KernelKind) -> usize {
    KernelKind::ALL
        .iter()
        .position(|k| *k == kind)
        .expect("every kernel kind is in ALL")
}

impl CircuitBreaker {
    /// New breaker with all cells closed. `threshold` failures within the
    /// last `window` outcomes trip a cell; `cooldown` denials later it
    /// admits one probe.
    pub fn new(window: usize, threshold: usize, cooldown: usize) -> Self {
        CircuitBreaker {
            cells: std::array::from_fn(|_| BreakerCell::new(window, threshold, cooldown)),
        }
    }

    /// May a request be served on this kernel right now? See
    /// [`BreakerCell::allows`].
    pub fn allows(&self, kind: KernelKind) -> bool {
        self.cells[idx(kind)].allows()
    }

    /// Record a served request's outcome. See [`BreakerCell::observe`].
    pub fn observe(&self, kind: KernelKind, ok: bool) -> Option<BreakerTransition> {
        self.cells[idx(kind)].observe(ok)
    }

    /// Current state of a cell (observability / tests).
    pub fn state(&self, kind: KernelKind) -> BreakerState {
        self.cells[idx(kind)].state()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const K: KernelKind = KernelKind::LowRankFp8;

    #[test]
    fn trips_at_threshold_within_window() {
        let b = CircuitBreaker::new(4, 2, 3);
        assert!(b.allows(K));
        assert_eq!(b.observe(K, false), None);
        assert_eq!(b.observe(K, true), None);
        assert_eq!(b.observe(K, false), Some(BreakerTransition::Tripped));
        assert_eq!(b.state(K), BreakerState::Open);
    }

    #[test]
    fn window_forgets_old_failures() {
        let b = CircuitBreaker::new(2, 2, 1);
        b.observe(K, false);
        b.observe(K, true); // pushes the failure toward the window edge
        // One old failure + one new failure would trip on window 2 only
        // if both were retained; the success in between evicted the first.
        assert_eq!(b.observe(K, false), None);
        assert_eq!(b.state(K), BreakerState::Closed);
    }

    #[test]
    fn cooldown_denials_admit_one_probe() {
        let b = CircuitBreaker::new(2, 1, 3);
        b.observe(K, false); // trips at threshold 1
        assert_eq!(b.state(K), BreakerState::Open);
        assert!(!b.allows(K));
        assert!(!b.allows(K));
        assert!(b.allows(K), "third denial completes the cooldown");
        assert_eq!(b.state(K), BreakerState::HalfOpen);
        assert!(!b.allows(K), "only one probe at a time");
    }

    #[test]
    fn probe_success_recovers_probe_failure_reopens() {
        let b = CircuitBreaker::new(2, 1, 1);
        b.observe(K, false);
        assert!(b.allows(K)); // cooldown 1: first denial is the probe
        assert_eq!(b.observe(K, false), Some(BreakerTransition::Tripped));
        assert_eq!(b.state(K), BreakerState::Open);
        assert!(b.allows(K));
        assert_eq!(b.observe(K, true), Some(BreakerTransition::Recovered));
        assert_eq!(b.state(K), BreakerState::Closed);
        assert!(b.allows(K));
    }

    #[test]
    fn stale_outcomes_ignored_while_open() {
        let b = CircuitBreaker::new(2, 1, 10);
        b.observe(K, false);
        assert_eq!(b.observe(K, true), None, "straggler while open is stale");
        assert_eq!(b.state(K), BreakerState::Open);
    }

    #[test]
    fn standalone_cell_runs_the_same_state_machine() {
        // The cluster router keys these per node rather than per kernel;
        // the lifecycle must match the kernel breaker exactly.
        let c = BreakerCell::new(4, 2, 2);
        assert!(c.allows());
        assert_eq!(c.observe(false), None);
        assert_eq!(c.observe(false), Some(BreakerTransition::Tripped));
        assert_eq!(c.state(), BreakerState::Open);
        assert!(!c.allows());
        assert!(c.allows(), "second denial completes the cooldown");
        assert_eq!(c.state(), BreakerState::HalfOpen);
        assert_eq!(c.observe(true), Some(BreakerTransition::Recovered));
        assert_eq!(c.state(), BreakerState::Closed);
    }

    #[test]
    fn cells_are_independent() {
        let b = CircuitBreaker::new(2, 1, 1);
        b.observe(KernelKind::DenseFp8, false);
        assert_eq!(b.state(KernelKind::DenseFp8), BreakerState::Open);
        for k in KernelKind::ALL {
            if k != KernelKind::DenseFp8 {
                assert_eq!(b.state(k), BreakerState::Closed);
                assert!(b.allows(k));
            }
        }
    }
}
