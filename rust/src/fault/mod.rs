//! Fault-containment & graceful-degradation plane.
//!
//! The serving planes before this one all assume the happy path: a
//! panicking tile job poisons a pool mutex and takes every later request
//! down with it, a kernel family that starts failing keeps receiving
//! traffic, and a corrupt persistence table fails the whole boot. This
//! plane closes those gaps with four pillars, default-off and
//! bitwise-identical when disabled like every prior plane:
//!
//! 1. **Panic isolation** — every job boundary (the [`crate::sched`]
//!    steal-pool worker loop, [`crate::exec::ThreadPool`] jobs, shard
//!    tile claim loops, background accuracy probes) runs under
//!    `catch_unwind`, locks are acquired poison-tolerantly through
//!    [`flock`], each contained panic increments a `fault.panic.<site>`
//!    counter, the worker thread survives, and the owning request
//!    completes as a typed [`crate::error::Error::KernelPanicked`]
//!    instead of hanging its waiter.
//! 2. **Degradation ladder + circuit breaker** — a per-`KernelKind`
//!    [`CircuitBreaker`] (rolling failure window, trip / half-open /
//!    probe states) consulted by the router, so a failing kernel family
//!    routes down the ladder (lowrank → dense f32) and a failed request
//!    gets one retry on its fallback kernel. Degraded responses carry
//!    [`DegradeReason`] and a `degrade` trace span.
//! 3. **Degraded boot** — corrupt autotune/accuracy persistence files
//!    are quarantined to `<path>.corrupt-<n>` ([`quarantine`]) with a
//!    warning and a `fault.quarantined_table` counter instead of failing
//!    start; `[fault] strict_boot = true` keeps the old behavior.
//! 4. **Deterministic fault injection** — a seeded [`FaultInjector`]
//!    (`[fault.inject]` TOML / `--fault-inject` CLI) fires panics,
//!    kernel errors, decode corruption and slow-tile stalls at exactly
//!    the sites the containment code guards, so every recovery path is
//!    exercised by tests and the CI chaos job rather than trusted on
//!    faith. Draws are pure hashes of (seed, site, ids): the same seed
//!    replays the same faults at any worker count.
//!
//! Metric inventory (interned only when the plane is enabled):
//! `fault.panic.{sched,exec,tile,request,probe}`, `fault.degraded`,
//! `fault.breaker.trip`, `fault.breaker.recover`,
//! `fault.quarantined_table`, `fault.injected`.

pub mod breaker;
pub mod inject;
pub mod plane;

pub use breaker::{BreakerCell, BreakerState, BreakerTransition, CircuitBreaker};
pub use inject::{FaultInjector, TileFault};
pub use plane::{quarantine, DegradeReason, FaultPlane};

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Poison-tolerant lock acquisition: a mutex poisoned by a panicking
/// holder is still structurally sound (the panic unwound out of the
/// critical section; our guarded data is counters, deques and condvar
/// gates whose invariants hold between operations), so serving threads
/// take the data as-is instead of propagating the poison and cascading
/// one worker's death into every later lock site.
pub fn flock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn flock_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(41));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        *flock(&m) += 1;
        assert_eq!(*flock(&m), 42);
    }
}
