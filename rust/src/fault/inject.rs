//! Deterministic fault injection: seeded, site-keyed probability draws.
//!
//! Every draw is a pure hash of `(seed, site, a, b)` — no RNG state, no
//! wall clock — so a given seed fires the same faults at the same sites
//! on every run, at any worker count, and a test can enumerate exactly
//! which requests/tiles will fault before submitting them. The injector
//! only *decides*; the sites that act on the decision (panic, typed
//! error, decode corruption, stall) are the same job boundaries the
//! containment code guards, so every injected fault exercises a real
//! recovery path.

use crate::config::FaultInjectSettings;
use crate::kernels::KernelKind;

/// Site constants folded into the draw hash so the same (a, b) pair
/// draws independently per site.
const SITE_TILE_PANIC: u64 = 0x7111;
const SITE_TILE_STALL: u64 = 0x57a1;
const SITE_REQ_PANIC: u64 = 0x9a_1c;
const SITE_REQ_ERROR: u64 = 0xe770;
const SITE_DECODE: u64 = 0xdec0;
const SITE_NET_REFUSE: u64 = 0x4e3f;
const SITE_NET_STALL: u64 = 0x4e57;
const SITE_NET_TRUNC: u64 = 0x4e74;
const SITE_NET_HB_DROP: u64 = 0x4eb8;

/// What an injected tile fault does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileFault {
    /// Panic inside the tile job (exercises `catch_unwind` containment).
    Panic,
    /// Sleep this many milliseconds before computing (slow-tile stall).
    Stall(u64),
}

/// Seeded, stateless fault decisions (see module docs).
#[derive(Clone, Debug)]
pub struct FaultInjector {
    plan: FaultInjectSettings,
    /// `error_kernel` pre-parsed; `None` = any kernel.
    error_kernel: Option<KernelKind>,
}

/// splitmix64 finalizer: a full-avalanche 64-bit mix.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl FaultInjector {
    /// Build from a validated `[fault.inject]` plan.
    pub fn new(plan: &FaultInjectSettings) -> Self {
        FaultInjector {
            error_kernel: KernelKind::parse(&plan.error_kernel),
            plan: plan.clone(),
        }
    }

    /// Uniform draw in [0, 1) keyed by (seed, site, a, b).
    fn draw(&self, site: u64, a: u64, b: u64) -> f64 {
        let h = mix(self.plan.seed ^ mix(site ^ mix(a ^ mix(b))));
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    fn fires(&self, p: f64, site: u64, a: u64, b: u64) -> bool {
        p > 0.0 && self.draw(site, a, b) < p
    }

    /// Fault (if any) for tile `tile` of the GEMM with plane-assigned
    /// sequence number `seq`. Panic wins over stall when both fire.
    pub fn tile_fault(&self, seq: u64, tile: usize) -> Option<TileFault> {
        if self.fires(self.plan.panic_tile, SITE_TILE_PANIC, seq, tile as u64) {
            return Some(TileFault::Panic);
        }
        if self.fires(self.plan.stall_tile, SITE_TILE_STALL, seq, tile as u64) {
            return Some(TileFault::Stall(self.plan.stall_ms));
        }
        None
    }

    /// Should request `id`'s kernel execution panic at the request
    /// boundary (exercises dispatch-level containment + retry)?
    pub fn request_panic(&self, id: u64) -> bool {
        self.fires(self.plan.panic_request, SITE_REQ_PANIC, id, 0)
    }

    /// Should request `id`, served on `kind`, fail with a typed kernel
    /// error? `error_requests_under` is the deterministic test knob: ids
    /// below it always fail (on the matching kernel); the probability
    /// draw covers the rest.
    pub fn request_error(&self, id: u64, kind: KernelKind) -> bool {
        if let Some(k) = self.error_kernel {
            if k != kind {
                return false;
            }
        }
        if self.plan.error_requests_under > 0 && id < self.plan.error_requests_under {
            return true;
        }
        self.fires(self.plan.error_request, SITE_REQ_ERROR, id, 0)
    }

    /// Should the FP8 decode of GEMM `seq` be corrupted (bit-flip in the
    /// decoded output, exercising the accuracy/breaker response to a
    /// silently-wrong kernel)?
    pub fn corrupt_decode(&self, seq: u64) -> bool {
        self.fires(self.plan.corrupt_decode, SITE_DECODE, seq, 0)
    }

    /// Should attempt `attempt` against cluster node `node` be refused
    /// at connect time (synthesized ConnectionRefused, exercising the
    /// retry/backoff/failover path)?
    pub fn net_refuse(&self, node: u64, attempt: u64) -> bool {
        self.fires(self.plan.net_refuse, SITE_NET_REFUSE, node, attempt)
    }

    /// Stall (ms) to inject before node `node` replies to request `id`,
    /// if any — long enough relative to the client read deadline this
    /// becomes an [`crate::error::Error::RpcTimeout`].
    pub fn net_stall(&self, node: u64, id: u64) -> Option<u64> {
        if self.fires(self.plan.net_stall, SITE_NET_STALL, node, id) {
            Some(self.plan.net_stall_ms)
        } else {
            None
        }
    }

    /// Should node `node`'s reply to request `id` be truncated mid-frame
    /// (the connection drops after a partial header, exercising the
    /// client's short-read handling)?
    pub fn net_truncate(&self, node: u64, id: u64) -> bool {
        self.fires(self.plan.net_truncate, SITE_NET_TRUNC, node, id)
    }

    /// Should node `node` skip sending heartbeat `seq` (exercising the
    /// Alive → Suspect → Dead health transitions without killing the
    /// node)?
    pub fn drop_heartbeat(&self, node: u64, seq: u64) -> bool {
        self.fires(self.plan.net_heartbeat_drop, SITE_NET_HB_DROP, node, seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(seed: u64) -> FaultInjectSettings {
        FaultInjectSettings {
            seed,
            panic_tile: 0.25,
            stall_tile: 0.25,
            stall_ms: 2,
            panic_request: 0.25,
            error_request: 0.25,
            ..Default::default()
        }
    }

    #[test]
    fn draws_are_deterministic_and_site_independent() {
        let a = FaultInjector::new(&plan(42));
        let b = FaultInjector::new(&plan(42));
        let mut fired = 0usize;
        for seq in 0..64u64 {
            for tile in 0..16usize {
                assert_eq!(a.tile_fault(seq, tile), b.tile_fault(seq, tile));
                fired += a.tile_fault(seq, tile).is_some() as usize;
            }
            assert_eq!(a.request_panic(seq), b.request_panic(seq));
        }
        // ~44% of 1024 tiles should fault (panic ∪ stall at 0.25 each);
        // accept a wide band — this guards "all" / "none" hash bugs.
        assert!((200..=700).contains(&fired), "fired {fired}");
    }

    #[test]
    fn different_seeds_differ() {
        let a = FaultInjector::new(&plan(1));
        let b = FaultInjector::new(&plan(2));
        let same = (0..256u64)
            .filter(|&s| a.tile_fault(s, 0) == b.tile_fault(s, 0))
            .count();
        assert!(same < 256, "seeds 1 and 2 produced identical fault plans");
    }

    #[test]
    fn zero_probabilities_never_fire() {
        let inj = FaultInjector::new(&FaultInjectSettings::default());
        for s in 0..512u64 {
            assert_eq!(inj.tile_fault(s, s as usize), None);
            assert!(!inj.request_panic(s));
            assert!(!inj.request_error(s, KernelKind::DenseF32));
            assert!(!inj.corrupt_decode(s));
            assert!(!inj.net_refuse(s, 0));
            assert_eq!(inj.net_stall(s, 0), None);
            assert!(!inj.net_truncate(s, 0));
            assert!(!inj.drop_heartbeat(s, 0));
        }
    }

    #[test]
    fn network_faults_are_deterministic_and_per_site() {
        let p = FaultInjectSettings {
            seed: 7,
            net_refuse: 0.5,
            net_stall: 0.5,
            net_stall_ms: 9,
            net_truncate: 0.5,
            net_heartbeat_drop: 0.5,
            ..Default::default()
        };
        let a = FaultInjector::new(&p);
        let b = FaultInjector::new(&p);
        let mut per_site = [0usize; 4];
        for node in 0..8u64 {
            for x in 0..64u64 {
                assert_eq!(a.net_refuse(node, x), b.net_refuse(node, x));
                assert_eq!(a.net_stall(node, x), b.net_stall(node, x));
                assert_eq!(a.net_truncate(node, x), b.net_truncate(node, x));
                assert_eq!(a.drop_heartbeat(node, x), b.drop_heartbeat(node, x));
                per_site[0] += a.net_refuse(node, x) as usize;
                per_site[1] += a.net_stall(node, x).is_some() as usize;
                per_site[2] += a.net_truncate(node, x) as usize;
                per_site[3] += a.drop_heartbeat(node, x) as usize;
            }
        }
        // Distinct site constants: each fires near half of 512 draws, and
        // an injected stall carries the configured duration.
        for n in per_site {
            assert!((150..=360).contains(&n), "site fired {n}/512");
        }
        let stalled = (0..64u64).find_map(|x| a.net_stall(0, x));
        assert_eq!(stalled, Some(9));
    }

    #[test]
    fn error_requests_under_is_exact_and_kernel_filtered() {
        let p = FaultInjectSettings {
            error_kernel: "lowrank_fp8".into(),
            error_requests_under: 3,
            ..Default::default()
        };
        let inj = FaultInjector::new(&p);
        for id in 0..3 {
            assert!(inj.request_error(id, KernelKind::LowRankFp8));
            assert!(!inj.request_error(id, KernelKind::DenseF32), "filtered");
        }
        assert!(!inj.request_error(3, KernelKind::LowRankFp8));
    }

    #[test]
    fn stall_carries_configured_ms() {
        let p = FaultInjectSettings {
            stall_tile: 1.0,
            stall_ms: 7,
            ..Default::default()
        };
        let inj = FaultInjector::new(&p);
        assert_eq!(inj.tile_fault(0, 0), Some(TileFault::Stall(7)));
    }
}
