//! The fault plane proper: breaker + injector + degradation ladder +
//! interned `fault.*` counters, plus the degraded-boot quarantine helper.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::config::FaultSettings;
use crate::fault::breaker::{BreakerTransition, CircuitBreaker};
use crate::fault::inject::{FaultInjector, TileFault};
use crate::kernels::KernelKind;
use crate::metrics::{Counter, MetricsRegistry};

/// Why a response was served on a kernel other than the routed one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradeReason {
    /// The routed kernel's breaker was open at route time.
    BreakerOpen {
        /// The kernel the request would have been served on.
        from: KernelKind,
    },
    /// The routed kernel returned an error; this is the fallback retry.
    RetryAfterError {
        /// The kernel that failed.
        from: KernelKind,
    },
    /// The routed kernel panicked (contained); this is the fallback retry.
    RetryAfterPanic {
        /// The kernel that panicked.
        from: KernelKind,
    },
}

impl DegradeReason {
    /// The kernel the request degraded away from.
    pub fn from_kind(self) -> KernelKind {
        match self {
            DegradeReason::BreakerOpen { from }
            | DegradeReason::RetryAfterError { from }
            | DegradeReason::RetryAfterPanic { from } => from,
        }
    }

    /// Stable label for trace spans and logs.
    pub fn reason_str(self) -> &'static str {
        match self {
            DegradeReason::BreakerOpen { .. } => "breaker_open",
            DegradeReason::RetryAfterError { .. } => "retry_after_error",
            DegradeReason::RetryAfterPanic { .. } => "retry_after_panic",
        }
    }
}

/// The fault-containment & graceful-degradation plane (see the
/// [module docs](crate::fault)). Constructed only when `[fault]` is
/// enabled — the `fault.*` counters below are interned here, so a
/// disabled plane leaves the metric namespace byte-identical.
pub struct FaultPlane {
    settings: FaultSettings,
    breaker: CircuitBreaker,
    injector: FaultInjector,
    /// Per-GEMM sequence number keying tile-site injection draws.
    gemm_seq: AtomicU64,
    /// In-flight background probe jobs (satellite: probe-backlog cap).
    bg_pending: AtomicUsize,
    panic_sched: Arc<Counter>,
    panic_exec: Arc<Counter>,
    panic_tile: Arc<Counter>,
    panic_request: Arc<Counter>,
    panic_probe: Arc<Counter>,
    degraded: Arc<Counter>,
    breaker_trip: Arc<Counter>,
    breaker_recover: Arc<Counter>,
    quarantined: Arc<Counter>,
    injected: Arc<Counter>,
    /// Interned here (not in the accuracy plane) because probes can only
    /// be shed when the fault plane's backlog cap is active — keeping it
    /// here preserves the accuracy plane's metric namespace when `[fault]`
    /// is off.
    probe_shed: Arc<Counter>,
}

impl FaultPlane {
    /// Build from validated settings, interning the plane's counters.
    pub fn new(settings: &FaultSettings, metrics: &MetricsRegistry) -> Arc<Self> {
        Arc::new(FaultPlane {
            breaker: CircuitBreaker::new(
                settings.breaker_window,
                settings.breaker_threshold,
                settings.breaker_cooldown,
            ),
            injector: FaultInjector::new(&settings.inject),
            gemm_seq: AtomicU64::new(0),
            bg_pending: AtomicUsize::new(0),
            panic_sched: metrics.counter("fault.panic.sched"),
            panic_exec: metrics.counter("fault.panic.exec"),
            panic_tile: metrics.counter("fault.panic.tile"),
            panic_request: metrics.counter("fault.panic.request"),
            panic_probe: metrics.counter("fault.panic.probe"),
            degraded: metrics.counter("fault.degraded"),
            breaker_trip: metrics.counter("fault.breaker.trip"),
            breaker_recover: metrics.counter("fault.breaker.recover"),
            quarantined: metrics.counter("fault.quarantined_table"),
            injected: metrics.counter("fault.injected"),
            probe_shed: metrics.counter("accuracy.probe_shed"),
            settings: settings.clone(),
        })
    }

    /// The validated settings the plane was built from.
    pub fn settings(&self) -> &FaultSettings {
        &self.settings
    }

    /// Is the one-retry-on-fallback policy enabled?
    pub fn retry(&self) -> bool {
        self.settings.retry
    }

    /// Next step down the degradation ladder. The ladder walks toward
    /// the most accurate, least exotic kernel: factor-chain kernels fall
    /// back to dense f32, reduced-precision dense kernels likewise.
    /// Dense f32 is the floor — it has no fallback and serves even with
    /// its breaker open (refusing every kernel would just convert
    /// degradation into an outage).
    pub fn fallback_for(kind: KernelKind) -> Option<KernelKind> {
        match kind {
            KernelKind::LowRankAuto => Some(KernelKind::LowRankFp8),
            KernelKind::LowRankFp8 => Some(KernelKind::DenseF32),
            KernelKind::DenseFp8 => Some(KernelKind::DenseF32),
            KernelKind::DenseF16 => Some(KernelKind::DenseF32),
            KernelKind::DenseF32 => None,
        }
    }

    /// Route-time breaker consult: if `kind`'s breaker denies, walk the
    /// ladder to the first admitted kernel and report the degrade.
    /// `None` = serve as routed (including the admitted half-open probe).
    pub fn reroute(&self, kind: KernelKind) -> Option<(KernelKind, DegradeReason)> {
        let mut cur = kind;
        let mut moved = false;
        while !self.breaker.allows(cur) {
            match Self::fallback_for(cur) {
                Some(next) => {
                    cur = next;
                    moved = true;
                }
                None => break, // the floor serves regardless
            }
        }
        moved.then(|| (cur, DegradeReason::BreakerOpen { from: kind }))
    }

    /// Feed a served request's outcome to the breaker; counts trips and
    /// recoveries.
    pub fn observe(&self, kind: KernelKind, ok: bool) {
        match self.breaker.observe(kind, ok) {
            Some(BreakerTransition::Tripped) => self.breaker_trip.inc(),
            Some(BreakerTransition::Recovered) => self.breaker_recover.inc(),
            None => {}
        }
    }

    /// Breaker state of one kernel (observability / tests).
    pub fn breaker_state(&self, kind: KernelKind) -> crate::fault::BreakerState {
        self.breaker.state(kind)
    }

    /// Sequence number for the next GEMM's tile-injection draws.
    pub fn next_gemm_seq(&self) -> u64 {
        self.gemm_seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Injected fault (if any) for one tile; counts it.
    pub fn tile_fault(&self, seq: u64, tile: usize) -> Option<TileFault> {
        let f = self.injector.tile_fault(seq, tile);
        if f.is_some() {
            self.injected.inc();
        }
        f
    }

    /// Should this request's kernel execution panic (injected)? Counts it.
    pub fn inject_request_panic(&self, id: u64) -> bool {
        let fire = self.injector.request_panic(id);
        if fire {
            self.injected.inc();
        }
        fire
    }

    /// Should this request fail with a typed kernel error (injected)?
    /// Counts it.
    pub fn inject_request_error(&self, id: u64, kind: KernelKind) -> bool {
        let fire = self.injector.request_error(id, kind);
        if fire {
            self.injected.inc();
        }
        fire
    }

    /// Should this GEMM's FP8 decode be corrupted (injected)? Counts it.
    pub fn inject_corrupt_decode(&self, seq: u64) -> bool {
        let fire = self.injector.corrupt_decode(seq);
        if fire {
            self.injected.inc();
        }
        fire
    }

    /// Panic-counter handles for the pools (cloned into worker loops).
    pub fn panic_sched_counter(&self) -> Arc<Counter> {
        self.panic_sched.clone()
    }

    /// See [`FaultPlane::panic_sched_counter`].
    pub fn panic_exec_counter(&self) -> Arc<Counter> {
        self.panic_exec.clone()
    }

    /// A tile job panicked and was contained.
    pub fn note_panic_tile(&self) {
        self.panic_tile.inc();
    }

    /// A request-boundary kernel execution panicked and was contained.
    pub fn note_panic_request(&self) {
        self.panic_request.inc();
    }

    /// A background accuracy probe panicked and was contained.
    pub fn note_panic_probe(&self) {
        self.panic_probe.inc();
    }

    /// A response was served degraded.
    pub fn note_degraded(&self) {
        self.degraded.inc();
    }

    /// A corrupt persistence table was quarantined at boot.
    pub fn note_quarantined(&self) {
        self.quarantined.inc();
    }

    /// An accuracy probe was shed because the backlog cap was reached.
    pub fn note_probe_shed(&self) {
        self.probe_shed.inc();
    }

    /// Try to reserve a background-probe slot; `false` = backlog full
    /// (caller sheds the probe). Pair with [`FaultPlane::release_probe`].
    pub fn try_reserve_probe(&self, cap: usize) -> bool {
        let mut cur = self.bg_pending.load(Ordering::Relaxed);
        loop {
            if cur >= cap {
                return false;
            }
            match self.bg_pending.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// Release a reserved probe slot (runs even when the probe panics —
    /// call from a drop guard).
    pub fn release_probe(&self) {
        self.bg_pending.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Quarantine a corrupt persistence file: rename it to the first free
/// `<path>.corrupt-<n>` so the bytes stay inspectable but the next boot
/// starts clean. Returns the quarantine path.
pub fn quarantine(path: &str) -> std::io::Result<String> {
    for n in 1u32.. {
        let dst = format!("{path}.corrupt-{n}");
        if !std::path::Path::new(&dst).exists() {
            std::fs::rename(path, &dst)?;
            return Ok(dst);
        }
    }
    unreachable!("u32 quarantine slots exhausted")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FaultSettings;

    fn plane() -> Arc<FaultPlane> {
        let s = FaultSettings {
            enabled: true,
            breaker_window: 2,
            breaker_threshold: 2,
            breaker_cooldown: 2,
            ..Default::default()
        };
        FaultPlane::new(&s, &MetricsRegistry::new())
    }

    #[test]
    fn ladder_terminates_at_dense_f32() {
        for mut k in KernelKind::ALL {
            let mut steps = 0;
            while let Some(next) = FaultPlane::fallback_for(k) {
                k = next;
                steps += 1;
                assert!(steps <= KernelKind::ALL.len(), "ladder must not cycle");
            }
            assert_eq!(k, KernelKind::DenseF32, "every ladder ends at the floor");
        }
    }

    #[test]
    fn reroute_walks_ladder_when_tripped() {
        let p = plane();
        assert_eq!(p.reroute(KernelKind::LowRankFp8), None);
        p.observe(KernelKind::LowRankFp8, false);
        p.observe(KernelKind::LowRankFp8, false); // trips (window 2 / threshold 2)
        let (to, why) = p.reroute(KernelKind::LowRankFp8).expect("must degrade");
        assert_eq!(to, KernelKind::DenseF32);
        assert_eq!(why.from_kind(), KernelKind::LowRankFp8);
        assert_eq!(why.reason_str(), "breaker_open");
    }

    #[test]
    fn floor_serves_even_with_open_breaker() {
        let p = plane();
        p.observe(KernelKind::DenseF32, false);
        p.observe(KernelKind::DenseF32, false);
        assert_eq!(
            p.breaker_state(KernelKind::DenseF32),
            crate::fault::BreakerState::Open
        );
        assert_eq!(p.reroute(KernelKind::DenseF32), None, "floor never refuses");
    }

    #[test]
    fn probe_slots_are_bounded_and_released() {
        let p = plane();
        assert!(p.try_reserve_probe(2));
        assert!(p.try_reserve_probe(2));
        assert!(!p.try_reserve_probe(2), "cap reached");
        p.release_probe();
        assert!(p.try_reserve_probe(2));
    }

    #[test]
    fn quarantine_renames_to_first_free_slot() {
        let dir = std::env::temp_dir().join(format!("lrg_quarantine_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("table.json");
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, b"corrupt one").unwrap();
        let q1 = quarantine(&path).unwrap();
        assert_eq!(q1, format!("{path}.corrupt-1"));
        std::fs::write(&path, b"corrupt two").unwrap();
        let q2 = quarantine(&path).unwrap();
        assert_eq!(q2, format!("{path}.corrupt-2"));
        assert!(!std::path::Path::new(&path).exists());
        assert_eq!(std::fs::read(&q1).unwrap(), b"corrupt one");
        assert_eq!(std::fs::read(&q2).unwrap(), b"corrupt two");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
