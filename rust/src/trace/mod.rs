//! Workload generation for benchmarks and the serving examples.
//!
//! - the paper's §4.3 sweep: N from 1024 to 20480 in √2-geometric steps;
//! - transformer inference GEMM traces (the workload the paper's intro
//!   motivates: attention and MLP matmuls at LLM-ish shapes);
//! - structured matrix generators with controlled spectra for the error
//!   analysis.

use crate::linalg::matrix::Matrix;
use crate::linalg::rng::Pcg64;

/// The paper's benchmark sweep: geometric progression by √2 from `lo` up
/// to (and including, when it lands exactly) `hi`, rounded to multiples
/// of 64 for tile friendliness.
pub fn sqrt2_sweep(lo: usize, hi: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut x = lo as f64;
    while (x as usize) <= hi {
        let n = ((x / 64.0).round() as usize * 64).max(64);
        if out.last() != Some(&n) {
            out.push(n);
        }
        x *= std::f64::consts::SQRT_2;
    }
    // The paper's sweep is inclusive of its maximum (N = 20480 appears in
    // every table); append the endpoint when √2 stepping skips past it.
    let hi_tile = (hi / 64).max(1) * 64;
    if out.last().is_none_or(|&last| last < hi_tile) {
        out.push(hi_tile);
    }
    out
}

/// One GEMM in a workload trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmShape {
    /// Output rows.
    pub m: usize,
    /// Inner dimension.
    pub k: usize,
    /// Output cols.
    pub n: usize,
    /// Stable identity of the weight operand (None = both operands dynamic).
    pub weight_id: Option<u64>,
}

impl GemmShape {
    /// Square helper.
    pub fn square(n: usize) -> Self {
        GemmShape {
            m: n,
            k: n,
            n,
            weight_id: None,
        }
    }
}

/// Transformer decoder-layer GEMM trace for a given model size, mirroring
/// the shapes a serving stack issues per layer per step:
/// QKV projection, attention output, MLP up, MLP down.
pub fn transformer_layer_trace(
    batch_tokens: usize,
    d_model: usize,
    d_ff: usize,
    layer: u64,
) -> Vec<GemmShape> {
    let wid = |slot: u64| Some(layer * 8 + slot);
    vec![
        // x · W_qkv : (T, d) × (d, 3d)
        GemmShape { m: batch_tokens, k: d_model, n: 3 * d_model, weight_id: wid(0) },
        // attn_out : (T, d) × (d, d)
        GemmShape { m: batch_tokens, k: d_model, n: d_model, weight_id: wid(1) },
        // mlp up : (T, d) × (d, d_ff)
        GemmShape { m: batch_tokens, k: d_model, n: d_ff, weight_id: wid(2) },
        // mlp down : (T, d_ff) × (d_ff, d)
        GemmShape { m: batch_tokens, k: d_ff, n: d_model, weight_id: wid(3) },
    ]
}

/// Full-model trace: `layers` decoder layers at the given shapes.
pub fn transformer_model_trace(
    batch_tokens: usize,
    d_model: usize,
    d_ff: usize,
    layers: usize,
) -> Vec<GemmShape> {
    (0..layers)
        .flat_map(|l| transformer_layer_trace(batch_tokens, d_model, d_ff, l as u64))
        .collect()
}

/// Spectrum families for the §5.4 error study.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpectrumKind {
    /// σ_j = ρ^j (exponential decay — the paper's favorable case).
    ExponentialDecay,
    /// σ_j = 1/(1+j) (heavy-tail power law).
    PowerLaw,
    /// σ_j = 1 (flat — the adversarial case where low-rank must fail).
    Flat,
}

impl SpectrumKind {
    /// Generate `k` singular values of the family.
    pub fn values(self, k: usize) -> Vec<f32> {
        match self {
            SpectrumKind::ExponentialDecay => {
                (0..k).map(|j| (0.85f32).powi(j as i32)).collect()
            }
            SpectrumKind::PowerLaw => (0..k).map(|j| 1.0 / (1.0 + j as f32)).collect(),
            SpectrumKind::Flat => vec![1.0; k],
        }
    }

    /// Human name.
    pub fn name(self) -> &'static str {
        match self {
            SpectrumKind::ExponentialDecay => "exp_decay",
            SpectrumKind::PowerLaw => "power_law",
            SpectrumKind::Flat => "flat",
        }
    }
}

/// Build a test matrix with the requested spectrum family.
pub fn matrix_with_spectrum(n: usize, kind: SpectrumKind, rng: &mut Pcg64) -> Matrix {
    let k = n.min(96); // enough spectral content; keeps generation cheap
    let sv = kind.values(k);
    Matrix::with_spectrum(n, n, &sv, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_matches_paper_progression() {
        let s = sqrt2_sweep(1024, 20480);
        assert_eq!(s.first(), Some(&1024));
        assert!(s.contains(&4096));
        assert_eq!(s.last(), Some(&20480), "paper's sweep includes its max");
        // Each step ≈ √2× the previous (the final step to the appended
        // endpoint may be shorter).
        for w in s.windows(2) {
            let ratio = w[1] as f64 / w[0] as f64;
            assert!((1.20..1.55).contains(&ratio), "ratio {ratio}");
        }
    }

    #[test]
    fn sweep_is_tile_aligned() {
        for n in sqrt2_sweep(1024, 20480) {
            assert_eq!(n % 64, 0);
        }
    }

    #[test]
    fn transformer_trace_shapes() {
        let t = transformer_layer_trace(128, 512, 2048, 0);
        assert_eq!(t.len(), 4);
        assert_eq!(t[0].n, 3 * 512);
        assert_eq!(t[3].k, 2048);
        // weight ids stable and distinct
        let ids: Vec<_> = t.iter().map(|g| g.weight_id.unwrap()).collect();
        let mut dedup = ids.clone();
        dedup.dedup();
        assert_eq!(ids, dedup);
    }

    #[test]
    fn model_trace_scales_with_layers() {
        let t = transformer_model_trace(64, 256, 1024, 3);
        assert_eq!(t.len(), 12);
        // Layer 2's ids don't collide with layer 0's.
        assert_ne!(t[0].weight_id, t[8].weight_id);
    }

    #[test]
    fn spectra_families() {
        let e = SpectrumKind::ExponentialDecay.values(10);
        assert!(e[9] < e[0] * 0.3);
        let f = SpectrumKind::Flat.values(5);
        assert!(f.iter().all(|&v| v == 1.0));
        let p = SpectrumKind::PowerLaw.values(4);
        assert!((p[3] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn spectrum_matrix_is_finite() {
        let mut rng = Pcg64::seeded(91);
        let m = matrix_with_spectrum(48, SpectrumKind::PowerLaw, &mut rng);
        assert!(m.all_finite());
        assert_eq!(m.shape(), (48, 48));
    }
}
