//! Router-side node registry: membership, health, load, and the
//! distributed factor-cache affinity map.
//!
//! Health is heartbeat-driven: a node is **Alive** while heartbeats land
//! within `heartbeat_timeout_ms`, **Suspect** once they stop (still
//! routable, but only after every Alive candidate), and **Dead** after
//! `dead_after_ms` of silence — at which point the registry drops the
//! node and every affinity entry it held, so its fingerprints re-home to
//! the surviving nodes on their next request.
//!
//! Routing preference for a fingerprinted operand is **weighted
//! rendezvous hashing**: each node scores `w / -ln(u)` where `u` is a
//! uniform draw keyed by `(fingerprint, node_id)` and the weight `w` is
//! the node's worker count discounted by its reported load. The same
//! fingerprint therefore lands on the same node run after run (cache
//! affinity), a loaded node sheds new fingerprints smoothly rather than
//! at a cliff, and when a node dies only its own fingerprints move
//! (minimal-disruption property of rendezvous hashing). Nodes that
//! already hold the factors (per heartbeat digest) outrank score order —
//! observed residency beats predicted placement.
//!
//! Cold-fill storms are bounded: routing a fingerprint to a node that
//! does not hold its factors counts against the node's concurrent-fill
//! cap (`fill_cap`); capped nodes drop to the back of the candidate list
//! so a mass re-home after a node death trickles rather than floods.

use std::collections::{BTreeMap, HashSet};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::cache::Fingerprint;
use crate::config::ClusterSettings;
use crate::fault::{flock, BreakerCell, BreakerTransition};

/// Per-node breaker shape: `BREAKER_THRESHOLD` failures in the last
/// `BREAKER_WINDOW` RPCs trip the node; `BREAKER_COOLDOWN` denials later
/// one probe RPC is admitted.
const BREAKER_WINDOW: usize = 8;
const BREAKER_THRESHOLD: usize = 3;
const BREAKER_COOLDOWN: usize = 4;

/// Node health as seen by the router.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum NodeHealth {
    /// Heartbeats landing on time.
    Alive,
    /// Heartbeats missing past `heartbeat_timeout_ms`; routable last.
    Suspect,
    /// Silent past `dead_after_ms`; removed from the registry.
    Dead,
}

/// A health transition produced by [`NodeRegistry::tick`], for metrics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HealthTransition {
    /// Node went Alive → Suspect.
    Suspect(u64),
    /// Node went Suspect → Dead and was dropped (affinity evicted).
    Dead(u64),
}

struct Node {
    addr: String,
    workers: u32,
    health: NodeHealth,
    last_heartbeat: Instant,
    queue_depth: u32,
    inflight: u32,
    /// Fingerprints the node reported resident in its last heartbeat.
    resident: HashSet<Fingerprint>,
    /// Cold fills currently routed at this node (re-fill storm bound).
    filling: usize,
    breaker: BreakerCell,
}

/// A routing candidate, in preference order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Candidate {
    pub id: u64,
    pub addr: String,
    /// Did the node's last heartbeat report the fingerprint resident?
    pub resident: bool,
}

/// Observability snapshot of one registered node.
#[derive(Clone, Debug)]
pub struct NodeView {
    pub id: u64,
    pub addr: String,
    pub health: NodeHealth,
    pub queue_depth: u32,
    pub inflight: u32,
    pub resident_fingerprints: usize,
}

struct Inner {
    nodes: BTreeMap<u64, Node>,
    next_id: u64,
}

/// Thread-safe node registry + affinity map (see module docs).
pub struct NodeRegistry {
    cfg: ClusterSettings,
    inner: Mutex<Inner>,
}

/// splitmix64 finalizer (same mix as the fault injector's draws).
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Fold a fingerprint's stable wire bytes into one u64 hash key.
fn fp_key(fp: Fingerprint) -> u64 {
    let w = fp.to_wire_bytes();
    let mut k = 0u64;
    for c in w.chunks(8) {
        let mut b = [0u8; 8];
        b[..c.len()].copy_from_slice(c);
        k = mix(k ^ u64::from_le_bytes(b));
    }
    k
}

impl NodeRegistry {
    /// Empty registry governed by the given cluster settings.
    pub fn new(cfg: ClusterSettings) -> Self {
        NodeRegistry {
            cfg,
            inner: Mutex::new(Inner {
                nodes: BTreeMap::new(),
                next_id: 1,
            }),
        }
    }

    /// Admit a node; returns its registry id. A node re-registering the
    /// same serving address replaces its previous entry (restart case) —
    /// the stale entry's affinity dies with it.
    pub fn register(&self, addr: &str, workers: u32, now: Instant) -> u64 {
        let mut g = flock(&self.inner);
        let stale: Vec<u64> = g
            .nodes
            .iter()
            .filter(|(_, n)| n.addr == addr)
            .map(|(&id, _)| id)
            .collect();
        for id in stale {
            g.nodes.remove(&id);
        }
        let id = g.next_id;
        g.next_id += 1;
        g.nodes.insert(
            id,
            Node {
                addr: addr.to_string(),
                workers: workers.max(1),
                health: NodeHealth::Alive,
                last_heartbeat: now,
                queue_depth: 0,
                inflight: 0,
                resident: HashSet::new(),
                filling: 0,
                breaker: BreakerCell::new(BREAKER_WINDOW, BREAKER_THRESHOLD, BREAKER_COOLDOWN),
            },
        );
        id
    }

    /// Apply a heartbeat. Returns `false` for unknown ids (the node was
    /// declared Dead or never registered — it must re-register).
    pub fn heartbeat(
        &self,
        node_id: u64,
        queue_depth: u32,
        inflight: u32,
        resident: Vec<Fingerprint>,
        now: Instant,
    ) -> bool {
        let mut g = flock(&self.inner);
        match g.nodes.get_mut(&node_id) {
            Some(n) => {
                n.last_heartbeat = now;
                n.health = NodeHealth::Alive;
                n.queue_depth = queue_depth;
                n.inflight = inflight;
                n.resident = resident.into_iter().collect();
                true
            }
            None => false,
        }
    }

    /// Graceful drain: drop the node from routing immediately. In-flight
    /// work on connections the node already holds finishes server-side.
    pub fn deregister(&self, node_id: u64) -> bool {
        flock(&self.inner).nodes.remove(&node_id).is_some()
    }

    /// Advance health from heartbeat age: Alive → Suspect past
    /// `heartbeat_timeout_ms`, Suspect → Dead (dropped, affinity evicted)
    /// past `dead_after_ms`. Returns the transitions for metrics.
    pub fn tick(&self, now: Instant) -> Vec<HealthTransition> {
        let suspect_after = Duration::from_millis(self.cfg.heartbeat_timeout_ms);
        let dead_after = Duration::from_millis(self.cfg.dead_after_ms);
        let mut out = Vec::new();
        let mut g = flock(&self.inner);
        let mut dead = Vec::new();
        for (&id, n) in g.nodes.iter_mut() {
            let age = now.saturating_duration_since(n.last_heartbeat);
            if age >= dead_after {
                dead.push(id);
            } else if age >= suspect_after && n.health == NodeHealth::Alive {
                n.health = NodeHealth::Suspect;
                out.push(HealthTransition::Suspect(id));
            }
        }
        for id in dead {
            g.nodes.remove(&id);
            out.push(HealthTransition::Dead(id));
        }
        out
    }

    /// Weight for rendezvous scoring: worker capacity discounted by the
    /// load the node itself reported.
    fn weight(n: &Node) -> f64 {
        n.workers as f64 / (1.0 + n.queue_depth as f64 + n.inflight as f64)
    }

    /// Candidate nodes in routing-preference order.
    ///
    /// With a fingerprint: health rank, then observed residency, then
    /// weighted rendezvous score; non-resident nodes at their fill cap
    /// drop to the back (bounded re-fill storm). Without one (anonymous
    /// operands): health rank, then least load per worker.
    pub fn candidates(&self, fp: Option<Fingerprint>) -> Vec<Candidate> {
        let g = flock(&self.inner);
        struct Scored {
            id: u64,
            resident: bool,
            capped: bool,
            suspect: bool,
            score: f64,
        }
        let mut scored: Vec<Scored> = g
            .nodes
            .iter()
            .map(|(&id, n)| {
                let resident = fp.map(|f| n.resident.contains(&f)).unwrap_or(false);
                let score = match fp {
                    Some(f) => {
                        let u = ((mix(fp_key(f) ^ mix(id ^ self.cfg.seed)) >> 11) + 1) as f64
                            / ((1u64 << 53) + 2) as f64;
                        Self::weight(n) / -u.ln()
                    }
                    None => Self::weight(n),
                };
                Scored {
                    id,
                    resident,
                    capped: !resident && fp.is_some() && n.filling >= self.cfg.fill_cap,
                    suspect: n.health != NodeHealth::Alive,
                    score,
                }
            })
            .collect();
        // Preference: healthy before suspect, uncapped before capped,
        // resident before cold, then score descending, id as tie-break.
        scored.sort_by(|a, b| {
            (a.suspect, a.capped, !a.resident)
                .cmp(&(b.suspect, b.capped, !b.resident))
                .then_with(|| {
                    b.score
                        .partial_cmp(&a.score)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .then_with(|| a.id.cmp(&b.id))
        });
        scored
            .into_iter()
            .map(|s| Candidate {
                id: s.id,
                addr: g.nodes[&s.id].addr.clone(),
                resident: s.resident,
            })
            .collect()
    }

    /// Reserve a cold-fill slot on a node (router is about to route a
    /// non-resident fingerprint there). Pair with [`end_fill`].
    ///
    /// [`end_fill`]: NodeRegistry::end_fill
    pub fn begin_fill(&self, node_id: u64) {
        if let Some(n) = flock(&self.inner).nodes.get_mut(&node_id) {
            n.filling += 1;
        }
    }

    /// Release a cold-fill slot.
    pub fn end_fill(&self, node_id: u64) {
        if let Some(n) = flock(&self.inner).nodes.get_mut(&node_id) {
            n.filling = n.filling.saturating_sub(1);
        }
    }

    /// Consult the node's circuit breaker before dialing it.
    pub fn breaker_allows(&self, node_id: u64) -> bool {
        flock(&self.inner)
            .nodes
            .get(&node_id)
            .map(|n| n.breaker.allows())
            .unwrap_or(false)
    }

    /// Record an RPC outcome against the node's breaker.
    pub fn breaker_observe(&self, node_id: u64, ok: bool) -> Option<BreakerTransition> {
        flock(&self.inner)
            .nodes
            .get(&node_id)
            .and_then(|n| n.breaker.observe(ok))
    }

    /// Number of registered (non-Dead) nodes.
    pub fn len(&self) -> usize {
        flock(&self.inner).nodes.len()
    }

    /// True when no nodes are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Observability snapshot, id order.
    pub fn views(&self) -> Vec<NodeView> {
        flock(&self.inner)
            .nodes
            .iter()
            .map(|(&id, n)| NodeView {
                id,
                addr: n.addr.clone(),
                health: n.health,
                queue_depth: n.queue_depth,
                inflight: n.inflight,
                resident_fingerprints: n.resident.len(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::linalg::rng::Pcg64;

    fn cfg() -> ClusterSettings {
        ClusterSettings {
            heartbeat_timeout_ms: 100,
            dead_after_ms: 300,
            fill_cap: 2,
            ..Default::default()
        }
    }

    fn fp(seed: u64) -> Fingerprint {
        let mut rng = Pcg64::seeded(seed);
        Fingerprint::of(&Matrix::gaussian(8, 8, &mut rng))
    }

    #[test]
    fn register_heartbeat_and_health_transitions() {
        let r = NodeRegistry::new(cfg());
        let t0 = Instant::now();
        let a = r.register("n1:1", 4, t0);
        let b = r.register("n2:1", 4, t0);
        assert_eq!(r.len(), 2);
        // b heartbeats; a goes silent.
        let t1 = t0 + Duration::from_millis(150);
        assert!(r.heartbeat(b, 0, 0, vec![], t1));
        let tr = r.tick(t1);
        assert_eq!(tr, vec![HealthTransition::Suspect(a)]);
        // Past dead_after, a is dropped.
        let t2 = t0 + Duration::from_millis(350);
        assert!(r.heartbeat(b, 0, 0, vec![], t2));
        let tr = r.tick(t2);
        assert_eq!(tr, vec![HealthTransition::Dead(a)]);
        assert_eq!(r.len(), 1);
        // Dead node's heartbeat is refused: it must re-register.
        assert!(!r.heartbeat(a, 0, 0, vec![], t2));
    }

    #[test]
    fn re_register_same_addr_replaces_stale_entry() {
        let r = NodeRegistry::new(cfg());
        let t0 = Instant::now();
        let a = r.register("n1:1", 4, t0);
        let a2 = r.register("n1:1", 4, t0);
        assert_ne!(a, a2);
        assert_eq!(r.len(), 1);
        assert!(!r.heartbeat(a, 0, 0, vec![], t0));
        assert!(r.heartbeat(a2, 0, 0, vec![], t0));
    }

    #[test]
    fn rendezvous_is_stable_and_rehomes_minimally() {
        let r = NodeRegistry::new(cfg());
        let t0 = Instant::now();
        let ids: Vec<u64> = (0..3).map(|i| r.register(&format!("n{i}:1"), 4, t0)).collect();
        let fps: Vec<Fingerprint> = (0..32).map(fp).collect();
        let owner: Vec<u64> = fps.iter().map(|&f| r.candidates(Some(f))[0].id).collect();
        // Stable: same fingerprint, same first choice.
        for (i, &f) in fps.iter().enumerate() {
            assert_eq!(r.candidates(Some(f))[0].id, owner[i]);
        }
        // All three nodes own some share (hash spreads).
        for id in &ids {
            assert!(owner.contains(id), "node {id} owns nothing");
        }
        // Kill the busiest owner: only its fingerprints move.
        let dead = owner[0];
        r.deregister(dead);
        for (i, &f) in fps.iter().enumerate() {
            let now = r.candidates(Some(f))[0].id;
            if owner[i] == dead {
                assert_ne!(now, dead);
            } else {
                assert_eq!(now, owner[i], "fingerprint moved needlessly");
            }
        }
    }

    #[test]
    fn residency_outranks_score_and_suspects_go_last() {
        let r = NodeRegistry::new(cfg());
        let t0 = Instant::now();
        let a = r.register("n1:1", 4, t0);
        let b = r.register("n2:1", 4, t0);
        let f = fp(9);
        // b reports the fingerprint resident: it must come first.
        r.heartbeat(b, 0, 0, vec![f], t0);
        let c = r.candidates(Some(f));
        assert_eq!((c[0].id, c[0].resident), (b, true));
        // b goes Suspect: healthy a now leads even without residency.
        let t1 = t0 + Duration::from_millis(150);
        r.heartbeat(a, 0, 0, vec![], t1);
        r.tick(t1);
        let c = r.candidates(Some(f));
        assert_eq!(c[0].id, a);
        assert_eq!(c[1].id, b);
    }

    #[test]
    fn anonymous_routing_prefers_least_loaded() {
        let r = NodeRegistry::new(cfg());
        let t0 = Instant::now();
        let a = r.register("n1:1", 4, t0);
        let b = r.register("n2:1", 4, t0);
        r.heartbeat(a, 10, 4, vec![], t0);
        r.heartbeat(b, 0, 1, vec![], t0);
        assert_eq!(r.candidates(None)[0].id, b);
    }

    #[test]
    fn fill_cap_pushes_capped_nodes_to_the_back() {
        let r = NodeRegistry::new(cfg()); // fill_cap = 2
        let t0 = Instant::now();
        let ids: Vec<u64> = (0..2).map(|i| r.register(&format!("n{i}:1"), 4, t0)).collect();
        let f = fp(21);
        let first = r.candidates(Some(f))[0].id;
        let other = ids.iter().copied().find(|&i| i != first).unwrap();
        r.begin_fill(first);
        r.begin_fill(first);
        // first is at its fill cap and f is not resident there: the
        // other node now leads, bounding the re-fill storm.
        assert_eq!(r.candidates(Some(f))[0].id, other);
        r.end_fill(first);
        assert_eq!(r.candidates(Some(f))[0].id, first);
        // Residency exempts a node from the cap ordering.
        r.begin_fill(first);
        r.heartbeat(first, 0, 0, vec![f], t0);
        assert_eq!(r.candidates(Some(f))[0].id, first);
    }

    #[test]
    fn per_node_breaker_trips_and_recovers() {
        let r = NodeRegistry::new(cfg());
        let a = r.register("n1:1", 4, Instant::now());
        assert!(r.breaker_allows(a));
        for _ in 0..BREAKER_THRESHOLD - 1 {
            assert_eq!(r.breaker_observe(a, false), None);
        }
        assert_eq!(
            r.breaker_observe(a, false),
            Some(BreakerTransition::Tripped)
        );
        assert!(!r.breaker_allows(a));
        // Unknown nodes are never dialable.
        assert!(!r.breaker_allows(999));
    }
}
