//! The router tier: cluster membership endpoint + failover-aware
//! request proxy.
//!
//! One TCP listener serves both planes of traffic. **Control** frames
//! (`Register`/`Heartbeat`/`Deregister`) maintain the [`NodeRegistry`];
//! a background sweeper advances heartbeat-age health (Alive → Suspect
//! → Dead) on `heartbeat_timeout_ms` / `dead_after_ms`. **Data** frames
//! (`ExecRequest`) are routed: the B operand is fingerprinted (when
//! large enough to be cache-worthy, `affinity_min_dim`), the registry
//! yields candidates in affinity/health/load preference order, and the
//! robustness spine drives the attempt loop — per-node circuit breaker
//! consult, per-attempt connect/read deadlines, decorrelated-jitter
//! backoff, failover to the next-best node, at most `max_attempts`
//! transport-level retries. Typed node replies (rejections, panics) are
//! **not** retried: the node made a decision; re-sending would mask it
//! or double-execute.
//!
//! Everything is observable: `cluster.*` counters and histograms in the
//! shared [`MetricsRegistry`], and `rpc` / `failover` / `refill` spans
//! in the trace plane when `[trace]` is enabled.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::cache::Fingerprint;
use crate::cluster::client::{self, ExecReply};
use crate::cluster::proto::{self, Msg};
use crate::cluster::registry::{Candidate, HealthTransition, NodeRegistry};
use crate::config::{AppConfig, ClusterSettings};
use crate::error::{Error, Result};
use crate::fault::FaultInjector;
use crate::linalg::matrix::Matrix;
use crate::linalg::rng::Pcg64;
use crate::metrics::MetricsRegistry;
use crate::trace_plane::{self, Attr, Tracer, ROOT_SPAN};

struct RouterShared {
    cfg: ClusterSettings,
    registry: NodeRegistry,
    metrics: Arc<MetricsRegistry>,
    tracer: Arc<Tracer>,
    inject: FaultInjector,
    stop: AtomicBool,
    next_id: AtomicU64,
}

/// A running router tier. See module docs.
pub struct RouterTier {
    shared: Arc<RouterShared>,
    addr: String,
    accept: Option<JoinHandle<()>>,
    sweeper: Option<JoinHandle<()>>,
}

/// Outcome tally of [`RouterTier::run_workload`] (the CI chaos drill):
/// every submitted request must land in exactly one bucket.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkloadReport {
    /// Requests submitted.
    pub requests: u64,
    /// Resolved successfully (including degraded responses).
    pub ok: u64,
    /// Resolved as a typed rejection (admission/drain backpressure).
    pub rejected: u64,
    /// Resolved as any other typed error.
    pub failed: u64,
}

impl WorkloadReport {
    /// Requests that resolved one way or another. The chaos drill
    /// asserts `resolved == requests`: nothing may be lost.
    pub fn resolved(&self) -> u64 {
        self.ok + self.rejected + self.failed
    }
}

impl RouterTier {
    /// Bind the router socket and spawn the accept + health-sweeper
    /// threads.
    pub fn start(app: &AppConfig) -> Result<RouterTier> {
        app.cluster.validate()?;
        let cfg = app.cluster.clone();
        let listener = TcpListener::bind(&cfg.router_addr)?;
        let addr = listener.local_addr()?.to_string();
        let metrics = Arc::new(MetricsRegistry::new());
        let tracer = Arc::new(Tracer::new(&app.trace));
        let shared = Arc::new(RouterShared {
            registry: NodeRegistry::new(cfg.clone()),
            metrics,
            tracer,
            inject: FaultInjector::new(&app.fault.inject),
            cfg,
            stop: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
        });
        let accept = {
            let shared = shared.clone();
            thread::Builder::new()
                .name("cluster-router-accept".into())
                .spawn(move || accept_loop(listener, shared))
                .map_err(|e| Error::Service(format!("spawn router accept: {e}")))?
        };
        let sweeper = {
            let shared = shared.clone();
            thread::Builder::new()
                .name("cluster-router-sweeper".into())
                .spawn(move || sweeper_loop(shared))
                .map_err(|e| Error::Service(format!("spawn router sweeper: {e}")))?
        };
        Ok(RouterTier {
            shared,
            addr,
            accept: Some(accept),
            sweeper: Some(sweeper),
        })
    }

    /// The resolved listen address (useful when bound to port 0).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The registry (tests inspect membership and health).
    pub fn registry(&self) -> &NodeRegistry {
        &self.shared.registry
    }

    /// The router's metrics registry (`cluster.*` inventory).
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.shared.metrics
    }

    /// Route one GEMM through the cluster (the in-process entry point —
    /// the TCP data plane and the CI drill both funnel here).
    pub fn exec(&self, a: &Matrix, b: &Matrix, tolerance: Option<f32>) -> Result<ExecReply> {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        exec_routed(&self.shared, id, a, b, tolerance)
    }

    /// Drive a synthetic workload through the routing path: `requests`
    /// square GEMMs of side `size`, the B operand drawn from a pool of
    /// reused weight matrices so fingerprint affinity engages. The pool
    /// grows with the request count: rendezvous placement is a hash
    /// coin-flip per fingerprint, and the CI chaos drill asserts the
    /// killed node actually owned traffic — with only a handful of
    /// fingerprints there is a real chance one node owns none of them.
    /// Used by the `cluster-router --requests N` CI chaos drill.
    pub fn run_workload(&self, requests: usize, size: usize, seed: u64) -> WorkloadReport {
        let mut rng = Pcg64::seeded(seed);
        let distinct = (requests / 12).clamp(4, 32);
        let pool: Vec<Matrix> =
            (0..distinct).map(|_| Matrix::gaussian(size, size, &mut rng)).collect();
        let mut report = WorkloadReport::default();
        for i in 0..requests {
            let a = Matrix::gaussian(size, size, &mut rng);
            let b = &pool[i % pool.len()];
            report.requests += 1;
            match self.exec(&a, b, None) {
                Ok(_) => report.ok += 1,
                Err(Error::Rejected(_)) => report.rejected += 1,
                Err(_) => report.failed += 1,
            }
        }
        report
    }

    /// Stop the sweeper and accept threads. Registered nodes are left
    /// running — they notice on their next heartbeat.
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        let _ = TcpStream::connect(&self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sweeper.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RouterTier {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown();
        }
    }
}

fn sweeper_loop(shared: Arc<RouterShared>) {
    let tick = Duration::from_millis((shared.cfg.heartbeat_ms / 2).max(10));
    while !shared.stop.load(Ordering::Acquire) {
        thread::sleep(tick);
        for t in shared.registry.tick(Instant::now()) {
            match t {
                HealthTransition::Suspect(_) => {
                    shared.metrics.count("cluster.node.suspect", 1);
                }
                HealthTransition::Dead(_) => shared.metrics.count("cluster.node.dead", 1),
            }
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<RouterShared>) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::Acquire) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let shared = shared.clone();
        let _ = thread::Builder::new()
            .name("cluster-router-conn".into())
            .spawn(move || handle_conn(stream, shared));
    }
}

/// Serve one connection: control frames from nodes, data frames from
/// clients — a connection may carry any mix.
fn handle_conn(mut stream: TcpStream, shared: Arc<RouterShared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(shared.cfg.read_timeout_ms)));
    loop {
        let mut probe = [0u8; 1];
        match stream.peek(&mut probe) {
            Ok(0) => return,
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                ) =>
            {
                if shared.stop.load(Ordering::Acquire) {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        let msg = match proto::read_msg(&mut stream) {
            Ok(m) => m,
            Err(_) => return,
        };
        let reply = match msg {
            Msg::Register {
                addr, workers, ..
            } => {
                let node_id = shared.registry.register(&addr, workers, Instant::now());
                shared.metrics.count("cluster.node.register", 1);
                Msg::RegisterAck { node_id }
            }
            Msg::Heartbeat {
                node_id,
                queue_depth,
                inflight,
                fingerprints,
                ..
            } => {
                let known = shared.registry.heartbeat(
                    node_id,
                    queue_depth,
                    inflight,
                    fingerprints,
                    Instant::now(),
                );
                shared.metrics.count("cluster.heartbeat.recv", 1);
                shared
                    .metrics
                    .observe("cluster.queue_depth", queue_depth as f64);
                Msg::HeartbeatAck { known }
            }
            Msg::Deregister { node_id } => {
                if shared.registry.deregister(node_id) {
                    shared.metrics.count("cluster.node.deregister", 1);
                }
                Msg::DeregisterAck
            }
            Msg::ExecRequest { id, tolerance, a, b } => {
                match exec_routed(&shared, id, &a, &b, tolerance) {
                    Ok(r) => Msg::ExecOk {
                        id,
                        kernel: r.kernel,
                        degraded: r.degraded,
                        c: r.c,
                    },
                    Err(e) => Msg::ExecErr {
                        id,
                        code: client::encode_exec_err(&e),
                        message: e.to_string(),
                    },
                }
            }
            _ => return, // replies are never requests; drop the conn
        };
        if proto::write_msg(&mut stream, &reply).is_err() {
            return;
        }
    }
}

/// The routing + robustness spine (see module docs).
fn exec_routed(
    shared: &RouterShared,
    id: u64,
    a: &Matrix,
    b: &Matrix,
    tolerance: Option<f32>,
) -> Result<ExecReply> {
    let cfg = &shared.cfg;
    let fp = (b.rows().min(b.cols()) >= cfg.affinity_min_dim).then(|| Fingerprint::of(b));
    let trace = shared.tracer.begin();
    let _scope = trace
        .as_ref()
        .map(|t| trace_plane::scope(t.clone(), ROOT_SPAN));

    let mut rng = Pcg64::seeded(cfg.seed ^ id);
    let mut sleep_ms = cfg.backoff_base_ms;
    let mut last_node: Option<u64> = None;
    let mut last_err = Error::NodeUnavailable("no nodes registered".into());
    let mut attempts = 0u64;

    let outcome = loop {
        if attempts >= cfg.max_attempts as u64 {
            break Err(last_err);
        }
        // Fresh candidate list each attempt: health and residency may
        // have changed while we backed off.
        let cands = shared.registry.candidates(fp);
        if cands.is_empty() {
            break Err(Error::NodeUnavailable("no nodes registered".into()));
        }
        let Some(cand) = pick(shared, &cands, last_node, attempts) else {
            break Err(Error::NodeUnavailable(
                "all nodes circuit-open or exhausted".into(),
            ));
        };
        if attempts > 0 {
            shared.metrics.count("cluster.rpc.retry", 1);
            if last_node != Some(cand.id) {
                shared.metrics.count("cluster.failover", 1);
                let mut s = trace_plane::span("failover");
                s.attr_u64("from", last_node.unwrap_or(0));
                s.attr_u64("to", cand.id);
            }
            thread::sleep(Duration::from_millis(sleep_ms));
            sleep_ms = client::backoff_ms(sleep_ms, cfg, &mut rng);
        }
        attempts += 1;
        last_node = Some(cand.id);
        let cold_fill = fp.is_some() && !cand.resident;
        if cold_fill {
            shared.registry.begin_fill(cand.id);
            shared.metrics.count("cluster.refill.start", 1);
            trace_plane::span("refill").attr_u64("node", cand.id);
        }
        shared.metrics.count(
            if fp.is_some() {
                "cluster.route.affinity"
            } else {
                "cluster.route.least_loaded"
            },
            1,
        );
        shared.metrics.count("cluster.rpc.attempt", 1);
        let t0 = Instant::now();
        let result = {
            let mut s = trace_plane::span("rpc");
            s.attr_u64("node", cand.id);
            s.attr_u64("attempt", attempts);
            if shared.inject.net_refuse(cand.id, attempts - 1) {
                Err(Error::NodeUnavailable(
                    "injected connection refused".into(),
                ))
            } else {
                client::exec_once(&cand.addr, cfg, id, a, b, tolerance)
            }
        };
        shared
            .metrics
            .observe("cluster.rpc_us", t0.elapsed().as_micros() as f64);
        if cold_fill {
            shared.registry.end_fill(cand.id);
        }
        match result {
            Ok(r) => {
                shared.registry.breaker_observe(cand.id, true);
                shared.metrics.count("cluster.rpc.ok", 1);
                break Ok(r);
            }
            Err(e) if client::retryable(&e) => {
                shared.registry.breaker_observe(cand.id, false);
                shared.metrics.count(
                    match e {
                        Error::RpcTimeout(_) => "cluster.rpc.timeout",
                        _ => "cluster.rpc.error",
                    },
                    1,
                );
                last_err = e;
            }
            Err(e) => {
                // The node answered with a decision (rejection, panic):
                // transport is healthy, the outcome is final.
                shared.registry.breaker_observe(cand.id, true);
                shared.metrics.count("cluster.rpc.error", 1);
                break Err(e);
            }
        }
    };
    if let Some(t) = &trace {
        shared.tracer.finish(
            t,
            &[
                Attr::u64("attempts", attempts),
                Attr::str("plane", "cluster"),
            ],
        );
    }
    outcome
}

/// First candidate whose breaker admits traffic, preferring a different
/// node than the one that just failed when any other is willing.
fn pick<'c>(
    shared: &RouterShared,
    cands: &'c [Candidate],
    last: Option<u64>,
    attempt: u64,
) -> Option<&'c Candidate> {
    let admitted = |c: &&Candidate| shared.registry.breaker_allows(c.id);
    if attempt > 0 {
        if let Some(c) = cands
            .iter()
            .filter(|c| Some(c.id) != last)
            .find(admitted)
        {
            return Some(c);
        }
    }
    cands.iter().find(admitted)
}
