//! Multi-node serving tier: node registry, heartbeats, failover, and
//! distributed factor-cache affinity.
//!
//! The single-process [`crate::coordinator::GemmService`] scales out by
//! composition, not modification: a **router** ([`RouterTier`]) tracks
//! membership and routes requests, and each **node** ([`NodeAgent`])
//! wraps an unmodified `GemmService` behind a dependency-free,
//! length-prefixed binary protocol ([`proto`]) on `std::net::TcpStream`.
//! Like every plane before it, the tier is default-off: with no
//! `[cluster]` section, nothing here runs and single-process results
//! and metric names stay bit-identical.
//!
//! The moving parts:
//!
//! - **Registry + health** ([`registry`]) — nodes register with their
//!   capacity, heartbeat load (`queue_depth`, in-flight) and a
//!   factor-cache occupancy digest; the router walks heartbeat age
//!   through Alive → Suspect (`heartbeat_timeout_ms`) → Dead
//!   (`dead_after_ms`), evicting Dead nodes and their affinity entries.
//! - **Affinity routing** — fingerprinted operands go to the node most
//!   likely to already hold their factors: observed residency first,
//!   then load-weighted rendezvous hashing (stable placement, minimal
//!   re-homing on membership change); anonymous operands go least-loaded.
//!   When a node dies its fingerprints re-home and the new owners
//!   cold-fill through the normal rSVD path, bounded per node by
//!   `fill_cap` concurrent fills.
//! - **Robustness spine** ([`client`], [`router_tier`]) — typed errors
//!   ([`crate::error::Error::NodeUnavailable`],
//!   [`crate::error::Error::RpcTimeout`]), per-attempt connect/read
//!   deadlines, decorrelated-jitter backoff with failover to the
//!   next-best node (at most `max_attempts`, transport failures only —
//!   a node's typed decision is never retried), a per-node circuit
//!   breaker reusing [`crate::fault::BreakerCell`], and graceful drain:
//!   a deregistering node finishes its in-flight work while the router
//!   stops routing to it.
//! - **Deterministic chaos** — the `[fault.inject]` plan gained seeded
//!   network faults (connection refused, read stall, truncated frame,
//!   heartbeat drop), so the whole tier is testable in-process: router
//!   plus N node agents as threads in one test binary, replaying the
//!   same faults every run.
//!
//! Metric inventory (interned only when the tier runs):
//! `cluster.node.{register,suspect,dead,deregister}`,
//! `cluster.heartbeat.recv`, `cluster.route.{affinity,least_loaded}`,
//! `cluster.rpc.{attempt,ok,error,timeout,retry}`, `cluster.failover`,
//! `cluster.refill.start`, histograms `cluster.rpc_us`,
//! `cluster.queue_depth`. Trace spans: `rpc`, `failover`, `refill`.

pub mod client;
pub mod node;
pub mod proto;
pub mod registry;
pub mod router_tier;

pub use client::{backoff_ms, exec_once, ExecReply};
pub use node::NodeAgent;
pub use proto::Msg;
pub use registry::{Candidate, HealthTransition, NodeHealth, NodeRegistry, NodeView};
pub use router_tier::{RouterTier, WorkloadReport};
