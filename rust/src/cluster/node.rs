//! Node agent: one serving process in the cluster.
//!
//! Wraps a full single-process [`GemmService`] with the cluster's wire
//! surface: a TCP accept loop executing [`Msg::ExecRequest`]s, a
//! heartbeat thread reporting load and factor-cache occupancy to the
//! router, and a graceful [`shutdown`](NodeAgent::shutdown) that
//! deregisters first (router stops routing here), finishes every
//! in-flight RPC, drains the service, and only then exits — the drain
//! contract the failover tests pin.
//!
//! Server-side fault injection hooks (`[fault.inject]` net knobs) fire
//! here: a reply can be stalled (`net_stall`, long enough to trip the
//! client's read deadline) or truncated mid-frame (`net_truncate`, the
//! connection drops after a partial length header), and heartbeats can
//! be skipped (`net_heartbeat_drop`, driving the router's Alive →
//! Suspect → Dead ladder without killing the process). All draws are
//! seeded and keyed by `(node_id, request id | seq)`, so a chaos run
//! replays exactly.

use std::io::{ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::cluster::client;
use crate::cluster::proto::{self, err_code, Msg, MAX_HEARTBEAT_FPS};
use crate::config::{AppConfig, ClusterSettings};
use crate::coordinator::{GemmRequest, GemmService, ServiceConfig};
use crate::error::{Error, RejectReason, Result};
use crate::fault::FaultInjector;
use crate::linalg::matrix::Matrix;

struct Shared {
    svc: GemmService,
    cfg: ClusterSettings,
    inject: FaultInjector,
    node_id: AtomicU64,
    stop: AtomicBool,
    /// RPCs currently being executed by connection handlers; the
    /// graceful shutdown waits for this to reach zero.
    active_rpcs: AtomicUsize,
}

/// A running node agent. Dropping it without calling
/// [`shutdown`](NodeAgent::shutdown) shuts down non-gracefully.
pub struct NodeAgent {
    shared: Arc<Shared>,
    /// The address peers dial (listener-resolved, so `:0` works).
    addr: String,
    accept: Option<JoinHandle<()>>,
    heartbeat: Option<JoinHandle<()>>,
}

impl NodeAgent {
    /// Bind the serving socket, register with the router, and spawn the
    /// accept + heartbeat threads. The embedded [`GemmService`] is built
    /// from the same `AppConfig` a single-process `serve` would use.
    pub fn start(app: &AppConfig) -> Result<NodeAgent> {
        app.cluster.validate()?;
        let cfg = app.cluster.clone();
        let svc = GemmService::start(ServiceConfig::from_app(app)?)?;
        let listener = TcpListener::bind(&cfg.node_addr)?;
        let addr = listener.local_addr()?.to_string();

        // Register, retrying with backoff — the router may still be
        // binding its socket when a fleet starts in parallel.
        let workers = app.service.workers as u32;
        let budget = (app.cache.budget_mb as u64) << 20;
        let node_id = register_with_retry(&cfg, &addr, workers, budget)?;

        let shared = Arc::new(Shared {
            svc,
            inject: FaultInjector::new(&app.fault.inject),
            cfg,
            node_id: AtomicU64::new(node_id),
            stop: AtomicBool::new(false),
            active_rpcs: AtomicUsize::new(0),
        });

        let accept = {
            let shared = shared.clone();
            thread::Builder::new()
                .name("cluster-node-accept".into())
                .spawn(move || accept_loop(listener, shared))
                .map_err(|e| Error::Service(format!("spawn accept loop: {e}")))?
        };
        let heartbeat = {
            let shared = shared.clone();
            let addr = addr.clone();
            let w = workers;
            thread::Builder::new()
                .name("cluster-node-heartbeat".into())
                .spawn(move || heartbeat_loop(shared, addr, w, budget))
                .map_err(|e| Error::Service(format!("spawn heartbeat loop: {e}")))?
        };

        Ok(NodeAgent {
            shared,
            addr,
            accept: Some(accept),
            heartbeat: Some(heartbeat),
        })
    }

    /// The resolved serving address (useful when bound to port 0).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The router-assigned node id.
    pub fn node_id(&self) -> u64 {
        self.shared.node_id.load(Ordering::Relaxed)
    }

    /// The embedded service (tests inspect its stats and caches).
    pub fn service(&self) -> &GemmService {
        &self.shared.svc
    }

    /// Graceful drain: deregister (router stops routing here), finish
    /// every in-flight RPC, drain the embedded service, then stop the
    /// accept and heartbeat threads.
    pub fn shutdown(&mut self) {
        let id = self.shared.node_id.load(Ordering::Relaxed);
        let _ = client::call(
            &self.shared.cfg.router_addr,
            &self.shared.cfg,
            &Msg::Deregister { node_id: id },
        );
        // In-flight RPCs keep executing: the router stopped handing out
        // this address, but work already here must complete.
        while self.shared.active_rpcs.load(Ordering::Acquire) > 0 {
            thread::sleep(Duration::from_micros(200));
        }
        self.shared.svc.drain();
        self.shared.stop.store(true, Ordering::Release);
        // Nudge the accept loop out of its blocking accept.
        let _ = TcpStream::connect(&self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.heartbeat.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NodeAgent {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown();
        }
    }
}

fn register_with_retry(
    cfg: &ClusterSettings,
    addr: &str,
    workers: u32,
    cache_budget: u64,
) -> Result<u64> {
    let mut rng = crate::linalg::rng::Pcg64::seeded(cfg.seed ^ 0x9e67);
    let mut sleep_ms = cfg.backoff_base_ms;
    let mut last = None;
    for attempt in 0..cfg.max_attempts.max(1) {
        if attempt > 0 {
            thread::sleep(Duration::from_millis(sleep_ms));
            sleep_ms = client::backoff_ms(sleep_ms, cfg, &mut rng);
        }
        match client::call(
            &cfg.router_addr,
            cfg,
            &Msg::Register {
                addr: addr.to_string(),
                workers,
                cache_budget,
            },
        ) {
            Ok(Msg::RegisterAck { node_id }) => return Ok(node_id),
            Ok(other) => {
                return Err(Error::Service(format!(
                    "cluster proto: unexpected register reply {other:?}"
                )))
            }
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| Error::NodeUnavailable("register: no attempts".into())))
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::Acquire) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let shared = shared.clone();
        let _ = thread::Builder::new()
            .name("cluster-node-conn".into())
            .spawn(move || handle_conn(stream, shared));
    }
}

/// Serve one client connection: a loop of ExecRequest frames. The read
/// deadline doubles as the idle/shutdown poll tick.
fn handle_conn(mut stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(shared.cfg.read_timeout_ms)));
    loop {
        // Wait for the next frame without consuming bytes, so an idle
        // timeout can never desync mid-frame.
        let mut probe = [0u8; 1];
        match stream.peek(&mut probe) {
            Ok(0) => return, // peer closed
            Ok(_) => {}
            Err(e) if matches!(e.kind(), ErrorKind::TimedOut | ErrorKind::WouldBlock) => {
                if shared.stop.load(Ordering::Acquire) {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        let msg = match proto::read_msg(&mut stream) {
            Ok(m) => m,
            Err(_) => return, // deadline mid-frame or malformed: drop conn
        };
        match msg {
            Msg::ExecRequest { id, tolerance, a, b } => {
                shared.active_rpcs.fetch_add(1, Ordering::AcqRel);
                let reply = execute(&shared, id, tolerance, a, b);
                let done = (|| -> std::io::Result<()> {
                    let node = shared.node_id.load(Ordering::Relaxed);
                    if let Some(ms) = shared.inject.net_stall(node, id) {
                        thread::sleep(Duration::from_millis(ms));
                    }
                    if shared.inject.net_truncate(node, id) {
                        // Injected mid-frame connection drop: a partial
                        // length header, then hang up.
                        stream.write_all(&[7u8, 0u8])?;
                        let _ = stream.shutdown(std::net::Shutdown::Both);
                        return Err(std::io::Error::other("injected truncation"));
                    }
                    proto::write_msg(&mut stream, &reply)
                        .map_err(|e| std::io::Error::other(e.to_string()))
                })();
                shared.active_rpcs.fetch_sub(1, Ordering::AcqRel);
                if done.is_err() {
                    return;
                }
            }
            // Control traffic belongs to the router; drop the conn.
            _ => return,
        }
    }
}

fn execute(shared: &Shared, id: u64, tolerance: Option<f32>, a: Matrix, b: Matrix) -> Msg {
    let mut req = GemmRequest::new(a, b);
    if let Some(t) = tolerance {
        req = req.with_tolerance(t);
    }
    match shared.svc.gemm_blocking(req) {
        Ok(resp) => Msg::ExecOk {
            id,
            kernel: resp.kernel.id().to_string(),
            degraded: resp.degraded.is_some(),
            c: resp.c,
        },
        Err(e) => {
            let (code, message) = match &e {
                Error::Rejected(RejectReason::Draining) => {
                    (err_code::DRAINING, e.to_string())
                }
                Error::Rejected(_) => (err_code::REJECTED, e.to_string()),
                Error::KernelPanicked(m) => (err_code::PANICKED, m.clone()),
                other => (err_code::OTHER, other.to_string()),
            };
            Msg::ExecErr { id, code, message }
        }
    }
}

fn heartbeat_loop(shared: Arc<Shared>, addr: String, workers: u32, cache_budget: u64) {
    let mut seq = 0u64;
    while !shared.stop.load(Ordering::Acquire) {
        thread::sleep(Duration::from_millis(shared.cfg.heartbeat_ms));
        if shared.stop.load(Ordering::Acquire) {
            break;
        }
        seq += 1;
        let node = shared.node_id.load(Ordering::Relaxed);
        if shared.inject.drop_heartbeat(node, seq) {
            continue;
        }
        let backlog = shared.svc.inflight() as u32;
        let queued = backlog.saturating_sub(workers.max(1));
        let (resident_bytes, fingerprints) = match shared.svc.content_cache() {
            Some(c) => (
                c.stats().resident_bytes,
                c.resident_fingerprints(MAX_HEARTBEAT_FPS),
            ),
            None => (0, Vec::new()),
        };
        let hb = Msg::Heartbeat {
            node_id: node,
            seq,
            queue_depth: queued,
            inflight: backlog,
            cache_resident_bytes: resident_bytes,
            fingerprints,
        };
        if let Ok(Msg::HeartbeatAck { known: false }) =
            client::call(&shared.cfg.router_addr, &shared.cfg, &hb)
        {
            // The router declared us Dead (e.g. after a long stall);
            // rejoin so traffic can come back.
            if let Ok(id) = register_with_retry(&shared.cfg, &addr, workers, cache_budget) {
                shared.node_id.store(id, Ordering::Relaxed);
            }
        }
    }
}
