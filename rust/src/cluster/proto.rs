//! Length-prefixed binary wire protocol for the cluster tier.
//!
//! Dependency-free by construction: every message is a hand-rolled
//! little-endian encoding over `std::net::TcpStream`, framed as a 4-byte
//! LE payload length followed by the payload (1 tag byte + body). All
//! integers are fixed-width LE; floats are IEEE-754 bit patterns via
//! `to_le_bytes`/`from_le_bytes`, so an f32 matrix crosses the wire
//! bit-exactly — the cluster ≡ single-process equivalence test depends
//! on that. Strings are u32-length-prefixed UTF-8. [`Fingerprint`]s use
//! the stable 24-byte [`Fingerprint::to_wire_bytes`] layout.
//!
//! The decoder is strict: unknown tags, short bodies, and trailing bytes
//! are all `Error::Service("cluster proto: ...")`, and the frame reader
//! rejects lengths above [`MAX_FRAME`] before allocating, so a corrupt
//! or truncated peer cannot make a node allocate gigabytes or misparse
//! silently.

use std::io::{Read, Write};

use crate::cache::Fingerprint;
use crate::error::{Error, Result};
use crate::linalg::matrix::Matrix;

/// Hard ceiling on a frame payload (tag + body). Two 8k×8k f32 operands
/// fit with headroom; anything larger is a protocol error, not a malloc.
pub const MAX_FRAME: usize = 1 << 30;

/// Heartbeats cap the fingerprint digest they carry: enough for the
/// router's affinity map, bounded so a huge cache cannot bloat the
/// heartbeat path.
pub const MAX_HEARTBEAT_FPS: usize = 256;

/// One protocol message. Tags are stable wire constants — append-only.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Node → router: join the registry. `addr` is the node's serving
    /// address as clients should dial it.
    Register {
        addr: String,
        workers: u32,
        cache_budget: u64,
    },
    /// Router → node: registration accepted; `node_id` keys heartbeats.
    RegisterAck { node_id: u64 },
    /// Node → router: periodic liveness + load + cache-occupancy digest.
    Heartbeat {
        node_id: u64,
        seq: u64,
        queue_depth: u32,
        inflight: u32,
        cache_resident_bytes: u64,
        fingerprints: Vec<Fingerprint>,
    },
    /// Router → node: heartbeat applied (`known = false` means the
    /// router no longer has this node — it should re-register).
    HeartbeatAck { known: bool },
    /// Node → router: graceful drain — stop routing to me; my in-flight
    /// work finishes on the connections that already carry it.
    Deregister { node_id: u64 },
    /// Router → node: deregistration applied.
    DeregisterAck,
    /// Client/router → node: execute one GEMM.
    ExecRequest {
        id: u64,
        tolerance: Option<f32>,
        a: Matrix,
        b: Matrix,
    },
    /// Node → client: result. `kernel` is the [`crate::kernels::KernelKind`]
    /// id string; `degraded` marks a fallback-served response.
    ExecOk {
        id: u64,
        kernel: String,
        degraded: bool,
        c: Matrix,
    },
    /// Node → client: typed failure. See [`ErrCode`].
    ExecErr { id: u64, code: u8, message: String },
}

/// `ExecErr` code space: the client reconstructs a typed
/// [`crate::error::Error`] from these.
pub mod err_code {
    /// Node is draining — `Error::Rejected(RejectReason::Draining)`.
    pub const DRAINING: u8 = 1;
    /// Admission rejection (queue full, deadline, quota) — `Error::Service`.
    pub const REJECTED: u8 = 2;
    /// Kernel panicked — `Error::KernelPanicked`.
    pub const PANICKED: u8 = 3;
    /// Anything else — `Error::Service`.
    pub const OTHER: u8 = 4;
    /// Router exhausted its retry budget — `Error::NodeUnavailable`.
    pub const UNAVAILABLE: u8 = 5;
    /// Router attempts all timed out — `Error::RpcTimeout`.
    pub const TIMEOUT: u8 = 6;
}

const TAG_REGISTER: u8 = 1;
const TAG_REGISTER_ACK: u8 = 2;
const TAG_HEARTBEAT: u8 = 3;
const TAG_HEARTBEAT_ACK: u8 = 4;
const TAG_DEREGISTER: u8 = 5;
const TAG_DEREGISTER_ACK: u8 = 6;
const TAG_EXEC_REQUEST: u8 = 7;
const TAG_EXEC_OK: u8 = 8;
const TAG_EXEC_ERR: u8 = 9;

fn perr(what: &str) -> Error {
    Error::Service(format!("cluster proto: {what}"))
}

// ---- encode ------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_matrix(buf: &mut Vec<u8>, m: &Matrix) {
    put_u32(buf, m.rows() as u32);
    put_u32(buf, m.cols() as u32);
    buf.reserve(m.data().len() * 4);
    for v in m.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

// ---- decode ------------------------------------------------------------

/// Strict forward-only cursor over a frame body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(perr("short body"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| perr("invalid utf-8"))
    }

    fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let n = rows
            .checked_mul(cols)
            .filter(|n| n * 4 <= MAX_FRAME)
            .ok_or_else(|| perr("matrix too large"))?;
        let raw = self.take(n * 4)?;
        let mut data = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Matrix::from_vec(rows, cols, data)
    }

    fn done(&self) -> Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(perr("trailing bytes"))
        }
    }
}

impl Msg {
    /// Encode to a frame payload (tag + body), without the length prefix.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        match self {
            Msg::Register {
                addr,
                workers,
                cache_budget,
            } => {
                buf.push(TAG_REGISTER);
                put_str(&mut buf, addr);
                put_u32(&mut buf, *workers);
                put_u64(&mut buf, *cache_budget);
            }
            Msg::RegisterAck { node_id } => {
                buf.push(TAG_REGISTER_ACK);
                put_u64(&mut buf, *node_id);
            }
            Msg::Heartbeat {
                node_id,
                seq,
                queue_depth,
                inflight,
                cache_resident_bytes,
                fingerprints,
            } => {
                buf.push(TAG_HEARTBEAT);
                put_u64(&mut buf, *node_id);
                put_u64(&mut buf, *seq);
                put_u32(&mut buf, *queue_depth);
                put_u32(&mut buf, *inflight);
                put_u64(&mut buf, *cache_resident_bytes);
                let fps = &fingerprints[..fingerprints.len().min(MAX_HEARTBEAT_FPS)];
                put_u32(&mut buf, fps.len() as u32);
                for fp in fps {
                    buf.extend_from_slice(&fp.to_wire_bytes());
                }
            }
            Msg::HeartbeatAck { known } => {
                buf.push(TAG_HEARTBEAT_ACK);
                buf.push(*known as u8);
            }
            Msg::Deregister { node_id } => {
                buf.push(TAG_DEREGISTER);
                put_u64(&mut buf, *node_id);
            }
            Msg::DeregisterAck => buf.push(TAG_DEREGISTER_ACK),
            Msg::ExecRequest { id, tolerance, a, b } => {
                buf.push(TAG_EXEC_REQUEST);
                put_u64(&mut buf, *id);
                buf.push(tolerance.is_some() as u8);
                buf.extend_from_slice(&tolerance.unwrap_or(0.0).to_le_bytes());
                put_matrix(&mut buf, a);
                put_matrix(&mut buf, b);
            }
            Msg::ExecOk {
                id,
                kernel,
                degraded,
                c,
            } => {
                buf.push(TAG_EXEC_OK);
                put_u64(&mut buf, *id);
                put_str(&mut buf, kernel);
                buf.push(*degraded as u8);
                put_matrix(&mut buf, c);
            }
            Msg::ExecErr { id, code, message } => {
                buf.push(TAG_EXEC_ERR);
                put_u64(&mut buf, *id);
                buf.push(*code);
                put_str(&mut buf, message);
            }
        }
        buf
    }

    /// Decode a frame payload. Strict: unknown tag, short body, and
    /// trailing bytes are all errors.
    pub fn decode(payload: &[u8]) -> Result<Msg> {
        let mut c = Cursor {
            buf: payload,
            pos: 0,
        };
        let msg = match c.u8()? {
            TAG_REGISTER => Msg::Register {
                addr: c.str()?,
                workers: c.u32()?,
                cache_budget: c.u64()?,
            },
            TAG_REGISTER_ACK => Msg::RegisterAck { node_id: c.u64()? },
            TAG_HEARTBEAT => {
                let node_id = c.u64()?;
                let seq = c.u64()?;
                let queue_depth = c.u32()?;
                let inflight = c.u32()?;
                let cache_resident_bytes = c.u64()?;
                let n = c.u32()? as usize;
                if n > MAX_HEARTBEAT_FPS {
                    return Err(perr("heartbeat digest too large"));
                }
                let mut fingerprints = Vec::with_capacity(n);
                for _ in 0..n {
                    let raw: [u8; Fingerprint::WIRE_LEN] =
                        c.take(Fingerprint::WIRE_LEN)?.try_into().unwrap();
                    fingerprints.push(Fingerprint::from_wire_bytes(&raw));
                }
                Msg::Heartbeat {
                    node_id,
                    seq,
                    queue_depth,
                    inflight,
                    cache_resident_bytes,
                    fingerprints,
                }
            }
            TAG_HEARTBEAT_ACK => Msg::HeartbeatAck {
                known: c.u8()? != 0,
            },
            TAG_DEREGISTER => Msg::Deregister { node_id: c.u64()? },
            TAG_DEREGISTER_ACK => Msg::DeregisterAck,
            TAG_EXEC_REQUEST => {
                let id = c.u64()?;
                let has_tol = c.u8()? != 0;
                let tol = c.f32()?;
                Msg::ExecRequest {
                    id,
                    tolerance: has_tol.then_some(tol),
                    a: c.matrix()?,
                    b: c.matrix()?,
                }
            }
            TAG_EXEC_OK => Msg::ExecOk {
                id: c.u64()?,
                kernel: c.str()?,
                degraded: c.u8()? != 0,
                c: c.matrix()?,
            },
            TAG_EXEC_ERR => Msg::ExecErr {
                id: c.u64()?,
                code: c.u8()?,
                message: c.str()?,
            },
            t => return Err(perr(&format!("unknown tag {t}"))),
        };
        c.done()?;
        Ok(msg)
    }
}

/// Write one frame: 4-byte LE payload length, then the payload.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<()> {
    let payload = msg.encode();
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. Length-guarded before allocation; a cleanly closed
/// peer surfaces as `Error::Io(UnexpectedEof)`.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(perr(&format!("bad frame length {len}")));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Msg::decode(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    fn round_trip(msg: Msg) {
        let payload = msg.encode();
        assert_eq!(Msg::decode(&payload).unwrap(), msg);
    }

    #[test]
    fn all_messages_round_trip() {
        let mut rng = Pcg64::seeded(3);
        let a = Matrix::gaussian(5, 7, &mut rng);
        let b = Matrix::gaussian(7, 3, &mut rng);
        let fp = Fingerprint::of(&b);
        round_trip(Msg::Register {
            addr: "127.0.0.1:7071".into(),
            workers: 4,
            cache_budget: 1 << 26,
        });
        round_trip(Msg::RegisterAck { node_id: 9 });
        round_trip(Msg::Heartbeat {
            node_id: 9,
            seq: 17,
            queue_depth: 3,
            inflight: 2,
            cache_resident_bytes: 4096,
            fingerprints: vec![fp, fp],
        });
        round_trip(Msg::HeartbeatAck { known: true });
        round_trip(Msg::HeartbeatAck { known: false });
        round_trip(Msg::Deregister { node_id: 9 });
        round_trip(Msg::DeregisterAck);
        round_trip(Msg::ExecRequest {
            id: 42,
            tolerance: Some(1e-3),
            a: a.clone(),
            b: b.clone(),
        });
        round_trip(Msg::ExecRequest {
            id: 43,
            tolerance: None,
            a: a.clone(),
            b: b.clone(),
        });
        round_trip(Msg::ExecOk {
            id: 42,
            kernel: "lowrank_fp8".into(),
            degraded: false,
            c: a.matmul(&b),
        });
        round_trip(Msg::ExecErr {
            id: 42,
            code: err_code::DRAINING,
            message: "service is draining".into(),
        });
    }

    #[test]
    fn matrices_cross_the_wire_bit_exactly() {
        let mut rng = Pcg64::seeded(5);
        let a = Matrix::gaussian(16, 16, &mut rng);
        let msg = Msg::ExecRequest {
            id: 1,
            tolerance: None,
            a: a.clone(),
            b: a.clone(),
        };
        match Msg::decode(&msg.encode()).unwrap() {
            Msg::ExecRequest { a: da, b: db, .. } => {
                for (x, y) in a.data().iter().zip(da.data()) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
                for (x, y) in a.data().iter().zip(db.data()) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn decoder_rejects_malformed_frames() {
        // Unknown tag.
        assert!(Msg::decode(&[0xfe]).is_err());
        // Empty payload.
        assert!(Msg::decode(&[]).is_err());
        // Short body: RegisterAck wants 8 bytes of node id.
        assert!(Msg::decode(&[TAG_REGISTER_ACK, 1, 2]).is_err());
        // Trailing bytes after a valid message.
        let mut payload = Msg::DeregisterAck.encode();
        payload.push(0);
        assert!(Msg::decode(&payload).is_err());
        // String length overrunning the body.
        let mut bad = vec![TAG_EXEC_ERR];
        bad.extend_from_slice(&7u64.to_le_bytes());
        bad.push(err_code::OTHER);
        bad.extend_from_slice(&100u32.to_le_bytes()); // claims 100 bytes
        bad.extend_from_slice(b"short");
        assert!(Msg::decode(&bad).is_err());
    }

    #[test]
    fn framed_stream_round_trips_and_guards_length() {
        let msg = Msg::RegisterAck { node_id: 3 };
        let mut wire = Vec::new();
        write_msg(&mut wire, &msg).unwrap();
        let mut r = &wire[..];
        assert_eq!(read_msg(&mut r).unwrap(), msg);
        // Oversized frame length is rejected before allocation.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_msg(&mut &huge[..]).is_err());
        // Truncated frame surfaces as an io error.
        let mut cut = wire.clone();
        cut.truncate(wire.len() - 2);
        assert!(matches!(
            read_msg(&mut &cut[..]),
            Err(crate::error::Error::Io(_))
        ));
    }

    #[test]
    fn heartbeat_digest_is_capped() {
        let mut rng = Pcg64::seeded(6);
        let fp = Fingerprint::of(&Matrix::gaussian(4, 4, &mut rng));
        let msg = Msg::Heartbeat {
            node_id: 1,
            seq: 1,
            queue_depth: 0,
            inflight: 0,
            cache_resident_bytes: 0,
            fingerprints: vec![fp; MAX_HEARTBEAT_FPS + 50],
        };
        match Msg::decode(&msg.encode()).unwrap() {
            Msg::Heartbeat { fingerprints, .. } => {
                assert_eq!(fingerprints.len(), MAX_HEARTBEAT_FPS);
            }
            other => panic!("wrong message: {other:?}"),
        }
    }
}
