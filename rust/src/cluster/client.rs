//! Client-side RPC plumbing: per-attempt deadlines, typed error
//! mapping, and decorrelated-jitter backoff.
//!
//! Every attempt is one short-lived connection: connect under
//! `connect_timeout_ms`, write the request, read the reply under
//! `read_timeout_ms`. Connection-level failures map to
//! [`Error::NodeUnavailable`], deadline expiries to [`Error::RpcTimeout`]
//! — the retry loop in the router treats both as "try the next-best
//! node", while application-level `ExecErr` replies are **not** retried
//! (the node executed or definitively rejected; re-sending would
//! double-execute).
//!
//! Backoff between attempts is decorrelated jitter
//! (`sleep = min(cap, base + rand_below(3·prev − base))`): successive
//! sleeps random-walk upward from `base` toward `cap`, decorrelating
//! competing clients after a shared failure instead of marching them in
//! lockstep.

use std::io::ErrorKind;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::cluster::proto::{self, err_code, Msg};
use crate::config::ClusterSettings;
use crate::error::{Error, RejectReason, Result};
use crate::linalg::matrix::Matrix;
use crate::linalg::rng::Pcg64;

/// Map an io error from the dial/read path to the typed cluster error.
fn net_err(addr: &str, stage: &str, e: std::io::Error) -> Error {
    match e.kind() {
        ErrorKind::TimedOut | ErrorKind::WouldBlock => {
            Error::RpcTimeout(format!("{stage} {addr}: {e}"))
        }
        _ => Error::NodeUnavailable(format!("{stage} {addr}: {e}")),
    }
}

/// Dial `addr` under the configured timeouts. A node at its listen
/// backlog or gone entirely both surface as [`Error::NodeUnavailable`].
pub fn connect(addr: &str, cfg: &ClusterSettings) -> Result<TcpStream> {
    let sa = addr
        .to_socket_addrs()
        .map_err(|e| Error::NodeUnavailable(format!("resolve {addr}: {e}")))?
        .next()
        .ok_or_else(|| Error::NodeUnavailable(format!("resolve {addr}: no address")))?;
    let s = TcpStream::connect_timeout(&sa, Duration::from_millis(cfg.connect_timeout_ms))
        .map_err(|e| net_err(addr, "connect", e))?;
    s.set_read_timeout(Some(Duration::from_millis(cfg.read_timeout_ms)))?;
    s.set_write_timeout(Some(Duration::from_millis(cfg.read_timeout_ms)))?;
    s.set_nodelay(true).ok();
    Ok(s)
}

/// One request/reply exchange on a fresh connection.
pub fn call(addr: &str, cfg: &ClusterSettings, msg: &Msg) -> Result<Msg> {
    let mut s = connect(addr, cfg)?;
    write_checked(addr, &mut s, msg)?;
    read_checked(addr, &mut s)
}

fn write_checked(addr: &str, s: &mut TcpStream, msg: &Msg) -> Result<()> {
    proto::write_msg(s, msg).map_err(|e| match e {
        Error::Io(e) => net_err(addr, "write", e),
        other => other,
    })
}

fn read_checked(addr: &str, s: &mut TcpStream) -> Result<Msg> {
    proto::read_msg(s).map_err(|e| match e {
        // A peer that closed mid-frame (crash, injected truncation) is
        // an unavailable node, not a protocol bug.
        Error::Io(e) => net_err(addr, "read", e),
        other => other,
    })
}

/// Reconstruct the typed error an `ExecErr` reply carries.
pub fn decode_exec_err(code: u8, message: String) -> Error {
    match code {
        err_code::DRAINING => Error::Rejected(RejectReason::Draining),
        err_code::PANICKED => Error::KernelPanicked(message),
        err_code::UNAVAILABLE => Error::NodeUnavailable(message),
        err_code::TIMEOUT => Error::RpcTimeout(message),
        _ => Error::Service(message),
    }
}

/// The wire code for an error crossing back through the router to its
/// client (inverse of [`decode_exec_err`], modulo message formatting).
pub fn encode_exec_err(e: &Error) -> u8 {
    match e {
        Error::Rejected(RejectReason::Draining) => err_code::DRAINING,
        Error::Rejected(_) => err_code::REJECTED,
        Error::KernelPanicked(_) => err_code::PANICKED,
        Error::NodeUnavailable(_) => err_code::UNAVAILABLE,
        Error::RpcTimeout(_) => err_code::TIMEOUT,
        _ => err_code::OTHER,
    }
}

/// Next decorrelated-jitter sleep given the previous one (see module
/// docs). Deterministic per `rng` stream.
pub fn backoff_ms(prev_ms: u64, cfg: &ClusterSettings, rng: &mut Pcg64) -> u64 {
    let base = cfg.backoff_base_ms;
    let span = (prev_ms.max(base).saturating_mul(3)).saturating_sub(base);
    let next = base + if span == 0 { 0 } else { rng.below(span) };
    next.min(cfg.backoff_cap_ms)
}

/// The result of one executed GEMM RPC.
pub struct ExecReply {
    pub kernel: String,
    pub degraded: bool,
    pub c: Matrix,
}

/// Execute one GEMM against a node (single attempt, no retry — the
/// router owns the retry/failover loop).
pub fn exec_once(
    addr: &str,
    cfg: &ClusterSettings,
    id: u64,
    a: &Matrix,
    b: &Matrix,
    tolerance: Option<f32>,
) -> Result<ExecReply> {
    let reply = call(
        addr,
        cfg,
        &Msg::ExecRequest {
            id,
            tolerance,
            a: a.clone(),
            b: b.clone(),
        },
    )?;
    match reply {
        Msg::ExecOk {
            id: rid,
            kernel,
            degraded,
            c,
        } => {
            if rid != id {
                return Err(Error::Service(format!(
                    "cluster proto: reply id {rid} for request {id}"
                )));
            }
            Ok(ExecReply {
                kernel,
                degraded,
                c,
            })
        }
        Msg::ExecErr { code, message, .. } => Err(decode_exec_err(code, message)),
        other => Err(Error::Service(format!(
            "cluster proto: unexpected reply {other:?}"
        ))),
    }
}

/// May this failure be retried on another node? Only transport-level
/// failures qualify: the request provably never executed. Typed replies
/// (`ExecErr`) mean a node made a decision; re-sending risks
/// double-execution and masks real rejections.
pub fn retryable(e: &Error) -> bool {
    matches!(e, Error::NodeUnavailable(_) | Error::RpcTimeout(_))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_walks_within_base_and_cap() {
        let cfg = ClusterSettings {
            backoff_base_ms: 10,
            backoff_cap_ms: 500,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(1);
        let mut prev = cfg.backoff_base_ms;
        for _ in 0..64 {
            let next = backoff_ms(prev, &cfg, &mut rng);
            assert!(
                (cfg.backoff_base_ms..=cfg.backoff_cap_ms).contains(&next),
                "sleep {next} outside [{}, {}]",
                cfg.backoff_base_ms,
                cfg.backoff_cap_ms
            );
            prev = next;
        }
        // Deterministic per seed.
        let mut r1 = Pcg64::seeded(2);
        let mut r2 = Pcg64::seeded(2);
        assert_eq!(backoff_ms(10, &cfg, &mut r1), backoff_ms(10, &cfg, &mut r2));
    }

    #[test]
    fn exec_err_codes_map_to_typed_errors() {
        assert!(matches!(
            decode_exec_err(err_code::DRAINING, String::new()),
            Error::Rejected(RejectReason::Draining)
        ));
        assert!(matches!(
            decode_exec_err(err_code::PANICKED, "boom".into()),
            Error::KernelPanicked(_)
        ));
        assert!(matches!(
            decode_exec_err(err_code::REJECTED, "queue full".into()),
            Error::Service(_)
        ));
        assert!(matches!(
            decode_exec_err(err_code::OTHER, "x".into()),
            Error::Service(_)
        ));
        // encode ∘ decode is the identity where the decoded error is
        // distinct (REJECTED decodes to the generic Service error).
        for code in [
            err_code::DRAINING,
            err_code::PANICKED,
            err_code::UNAVAILABLE,
            err_code::TIMEOUT,
        ] {
            assert_eq!(encode_exec_err(&decode_exec_err(code, "m".into())), code);
        }
    }

    #[test]
    fn only_transport_failures_are_retryable() {
        assert!(retryable(&Error::NodeUnavailable("x".into())));
        assert!(retryable(&Error::RpcTimeout("x".into())));
        assert!(!retryable(&Error::Rejected(RejectReason::Draining)));
        assert!(!retryable(&Error::KernelPanicked("x".into())));
        assert!(!retryable(&Error::Service("x".into())));
    }

    #[test]
    fn refused_connection_is_node_unavailable() {
        // Port 1 on localhost: nothing listens there in CI or dev.
        let cfg = ClusterSettings {
            connect_timeout_ms: 200,
            ..Default::default()
        };
        match connect("127.0.0.1:1", &cfg) {
            Err(Error::NodeUnavailable(_)) | Err(Error::RpcTimeout(_)) => {}
            other => panic!("expected transport error, got {other:?}"),
        }
    }
}
