//! AOT artifact runtime: PJRT CPU client + manifest + executor thread.
//!
//! The bridge between the Python compile path and the Rust serving path:
//!
//! - [`json`]: dependency-free JSON parser for the manifest,
//! - [`manifest`]: typed artifact index ((op, n, rank) -> HLO file),
//! - [`client`]: [`XlaRuntime`] — loads HLO text, compiles once per
//!   artifact, executes with validated shapes (single-threaded: the
//!   `xla` crate's client is `Rc`-backed),
//! - [`executor`]: [`XlaExecutor`] — confines the runtime to a dedicated
//!   thread and exposes a `Send + Clone` handle to the coordinator.
//!
//! Python runs only at `make artifacts` time; everything here consumes the
//! frozen `artifacts/` directory.

pub mod client;
pub mod executor;
pub mod json;
pub mod manifest;

pub use client::XlaRuntime;
pub use executor::{XlaExecutor, XlaHandle};
pub use manifest::{ArtifactEntry, Manifest};
