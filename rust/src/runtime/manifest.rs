//! Typed view of `artifacts/manifest.json`.
//!
//! The manifest is the contract between `compile/aot.py` (which writes it)
//! and the serving runtime (which routes requests onto artifacts by op kind
//! and shape). Shapes are static in HLO, so lookup is exact-match; anything
//! off-lattice takes the CPU `linalg` fallback path in the executor.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::runtime::json::{parse_json, Json};

/// One AOT-compiled computation.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    /// Unique artifact name, e.g. `lowrank_apply_fp8_n256_r16`.
    pub name: String,
    /// Op kind: `dense_f32`, `dense_f16`, `dense_fp8`, `lowrank_apply`,
    /// `lowrank_apply_fp8`, `rsvd`, `lowrank_gemm[_fp8]`, `lowrank_e2e`.
    pub op: String,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Square problem edge this entry was lowered for.
    pub n: usize,
    /// Rank (0 for dense ops).
    pub rank: usize,
    /// Input shapes, in call order (all f32).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes, in tuple order (all f32).
    pub outputs: Vec<Vec<usize>>,
}

impl ArtifactEntry {
    /// Total f32 elements expected for input `i`.
    pub fn input_len(&self, i: usize) -> usize {
        self.inputs[i].iter().product()
    }
}

/// The parsed manifest with an op/shape index.
#[derive(Debug)]
pub struct Manifest {
    /// Artifact directory (files in entries are relative to this).
    pub dir: PathBuf,
    /// rSVD oversampling used at lowering time (sketch width = r + this).
    pub oversample: usize,
    entries: Vec<ArtifactEntry>,
    by_name: HashMap<String, usize>,
    /// (op, n, rank) -> entry index.
    by_key: HashMap<(String, usize, usize), usize>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = parse_json(text)?;
        let version = root
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Artifact("manifest missing integer 'version'".into()))?;
        if version != 1 {
            return Err(Error::Artifact(format!(
                "unsupported manifest version {version} (expected 1)"
            )));
        }
        let oversample = root
            .get("oversample")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Artifact("manifest missing 'oversample'".into()))?;

        let raw_entries = root
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Artifact("manifest missing 'entries' array".into()))?;

        let mut entries = Vec::with_capacity(raw_entries.len());
        for (i, e) in raw_entries.iter().enumerate() {
            entries.push(Self::parse_entry(e).map_err(|err| {
                Error::Artifact(format!("manifest entry {i}: {err}"))
            })?);
        }

        let mut by_name = HashMap::new();
        let mut by_key = HashMap::new();
        for (i, e) in entries.iter().enumerate() {
            if by_name.insert(e.name.clone(), i).is_some() {
                return Err(Error::Artifact(format!("duplicate artifact name {}", e.name)));
            }
            by_key.insert((e.op.clone(), e.n, e.rank), i);
        }

        Ok(Manifest {
            dir,
            oversample,
            entries,
            by_name,
            by_key,
        })
    }

    fn parse_entry(e: &Json) -> Result<ArtifactEntry> {
        let get_str = |k: &str| -> Result<String> {
            e.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| Error::Artifact(format!("missing string field '{k}'")))
        };
        let get_usize = |k: &str| -> Result<usize> {
            e.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Artifact(format!("missing integer field '{k}'")))
        };
        let get_shapes = |k: &str| -> Result<Vec<Vec<usize>>> {
            let arr = e
                .get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Artifact(format!("missing array field '{k}'")))?;
            arr.iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .ok_or_else(|| Error::Artifact(format!("'{k}' element not an array")))?
                        .iter()
                        .map(|d| {
                            d.as_usize()
                                .ok_or_else(|| Error::Artifact(format!("bad dim in '{k}'")))
                        })
                        .collect()
                })
                .collect()
        };

        Ok(ArtifactEntry {
            name: get_str("name")?,
            op: get_str("op")?,
            file: get_str("file")?,
            n: get_usize("n")?,
            rank: get_usize("rank")?,
            inputs: get_shapes("inputs")?,
            outputs: get_shapes("outputs")?,
        })
    }

    /// All entries.
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Lookup by unique name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.by_name.get(name).map(|&i| &self.entries[i])
    }

    /// Exact lookup by (op, n, rank); dense ops use rank 0.
    pub fn lookup(&self, op: &str, n: usize, rank: usize) -> Option<&ArtifactEntry> {
        self.by_key
            .get(&(op.to_string(), n, rank))
            .map(|&i| &self.entries[i])
    }

    /// Largest lattice edge available for `op` that is >= `n` (used to
    /// decide whether a request can be padded onto an artifact or must
    /// fall back to the CPU substrate).
    pub fn best_cover(&self, op: &str, n: usize, rank: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.op == op && e.rank == rank && e.n >= n)
            .min_by_key(|e| e.n)
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "oversample": 8,
      "entries": [
        {"name": "dense_f32_n128", "op": "dense_f32", "file": "dense_f32_n128.hlo.txt",
         "n": 128, "rank": 0, "inputs": [[128,128],[128,128]], "outputs": [[128,128]]},
        {"name": "rsvd_n128_r16", "op": "rsvd", "file": "rsvd_n128_r16.hlo.txt",
         "n": 128, "rank": 16, "inputs": [[128,128],[128,24]],
         "outputs": [[128,16],[16],[16,128]]}
      ]
    }"#;

    fn sample() -> Manifest {
        Manifest::parse(SAMPLE, PathBuf::from("/tmp/artifacts")).unwrap()
    }

    #[test]
    fn parses_entries() {
        let m = sample();
        assert_eq!(m.entries().len(), 2);
        assert_eq!(m.oversample, 8);
        let e = m.by_name("rsvd_n128_r16").unwrap();
        assert_eq!(e.inputs[1], vec![128, 24]);
        assert_eq!(e.outputs.len(), 3);
    }

    #[test]
    fn lookup_by_key() {
        let m = sample();
        assert!(m.lookup("dense_f32", 128, 0).is_some());
        assert!(m.lookup("dense_f32", 256, 0).is_none());
        assert!(m.lookup("rsvd", 128, 16).is_some());
    }

    #[test]
    fn best_cover_picks_smallest_geq() {
        let m = sample();
        assert_eq!(m.best_cover("dense_f32", 100, 0).unwrap().n, 128);
        assert!(m.best_cover("dense_f32", 129, 0).is_none());
    }

    #[test]
    fn input_len() {
        let m = sample();
        let e = m.by_name("dense_f32_n128").unwrap();
        assert_eq!(e.input_len(0), 128 * 128);
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 2");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let dup = SAMPLE.replace("rsvd_n128_r16\", \"op\": \"rsvd", "dense_f32_n128\", \"op\": \"rsvd");
        assert!(Manifest::parse(&dup, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn missing_field_is_error() {
        let bad = r#"{"version": 1, "oversample": 8, "entries": [{"name": "x"}]}"#;
        assert!(Manifest::parse(bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn hlo_path_joins_dir() {
        let m = sample();
        let e = m.by_name("dense_f32_n128").unwrap();
        assert_eq!(
            m.hlo_path(e),
            PathBuf::from("/tmp/artifacts/dense_f32_n128.hlo.txt")
        );
    }
}
