//! Minimal JSON parser for `artifacts/manifest.json`.
//!
//! The offline vendor set has no serde, and the manifest is machine-written
//! by `compile/aot.py` with a known shape (objects, arrays, strings,
//! integers, floats, booleans, null — no exponents in practice but parsed
//! anyway). This is a strict recursive-descent parser: trailing garbage,
//! unterminated strings and malformed escapes are errors, not silently
//! accepted.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64; the manifest's ints are < 2^53).
    Num(f64),
    /// String (escapes decoded).
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (order-insensitive; BTreeMap for deterministic iteration).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Member lookup on an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integer-valued number).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }
}

/// Parse a complete JSON document (rejects trailing non-whitespace).
pub fn parse_json(text: &str) -> Result<Json> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters after JSON document"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Artifact(format!("manifest JSON parse error at byte {}: {msg}", self.i))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        if self.i + 4 > self.b.len() {
                            return Err(self.err("truncated \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                            .map_err(|_| self.err("non-utf8 \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.i += 4;
                        // Surrogates in the manifest would be a bug; map to
                        // the replacement char rather than failing hard.
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        if start + width > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + width])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number slice");
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("malformed number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse_json("null").unwrap(), Json::Null);
        assert_eq!(parse_json("true").unwrap(), Json::Bool(true));
        assert_eq!(parse_json("false").unwrap(), Json::Bool(false));
        assert_eq!(parse_json("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse_json("-3.5").unwrap(), Json::Num(-3.5));
        assert_eq!(parse_json("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse_json("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = parse_json(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn decodes_escapes() {
        let j = parse_json(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn handles_utf8() {
        let j = parse_json("\"héllo — ≥\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo — ≥"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse_json("{} x").is_err());
        assert!(parse_json("1 2").is_err());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"unterminated", "{\"a\" 1}", "tru", "01x", "--1"] {
            assert!(parse_json(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(parse_json("7").unwrap().as_usize(), Some(7));
        assert_eq!(parse_json("-7").unwrap().as_usize(), None);
        assert_eq!(parse_json("7.5").unwrap().as_usize(), None);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse_json("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse_json("{}").unwrap(), Json::Obj(Default::default()));
    }
}
