//! The PJRT CPU runtime: load HLO-text artifacts, compile once, execute.
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6 / xla_extension 0.5.1) exactly
//! the way /opt/xla-example/load_hlo does: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`, with a
//! per-artifact executable cache so each HLO is compiled at most once per
//! process.
//!
//! `PjRtClient` is `Rc`-backed — **not Send** — so [`XlaRuntime`] is a
//! single-thread object; cross-thread access goes through
//! [`crate::runtime::executor::XlaExecutor`], which confines the client to
//! one dedicated thread and speaks over channels.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::runtime::manifest::Manifest;

/// Single-threaded PJRT runtime: manifest + client + executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Number of artifact compilations performed (for tests/metrics).
    compiles: u64,
}

impl XlaRuntime {
    /// Create a CPU PJRT client and load the manifest from `artifact_dir`.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(Error::from)?;
        Ok(XlaRuntime {
            client,
            manifest,
            cache: HashMap::new(),
            compiles: 0,
        })
    }

    /// The manifest this runtime serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// How many artifacts have been compiled so far.
    pub fn compiles(&self) -> u64 {
        self.compiles
    }

    /// Compile (or fetch cached) executable for a manifest entry name.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .by_name(name)
                .ok_or_else(|| Error::Artifact(format!("unknown artifact '{name}'")))?
                .clone();
            let path = self.manifest.hlo_path(&entry);
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
                Error::Artifact(format!("loading {}: {e:#}", path.display()))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(Error::from)?;
            self.cache.insert(name.to_string(), exe);
            self.compiles += 1;
        }
        Ok(&self.cache[name])
    }

    /// Pre-compile an artifact (warmup path; avoids first-request latency).
    pub fn warm(&mut self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Execute an artifact by name with raw f32 inputs.
    ///
    /// `inputs[i]` must have exactly the element count of the entry's
    /// i-th input shape (validated here — shape bugs fail fast with a
    /// useful message instead of an opaque PJRT buffer error).
    pub fn run_raw(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let entry = self
            .manifest
            .by_name(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact '{name}'")))?
            .clone();

        if inputs.len() != entry.inputs.len() {
            return Err(Error::Artifact(format!(
                "artifact '{name}' expects {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().zip(&entry.inputs).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                return Err(Error::Artifact(format!(
                    "artifact '{name}' input {i}: expected {want} elements \
                     for shape {shape:?}, got {}",
                    data.len()
                )));
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims).map_err(Error::from)?;
            literals.push(lit);
        }

        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&literals).map_err(Error::from)?;
        let tuple = result[0][0].to_literal_sync().map_err(Error::from)?;
        // aot.py lowers with return_tuple=True: always a tuple, any arity.
        let parts = tuple.to_tuple().map_err(Error::from)?;

        if parts.len() != entry.outputs.len() {
            return Err(Error::Artifact(format!(
                "artifact '{name}': manifest says {} outputs, program returned {}",
                entry.outputs.len(),
                parts.len()
            )));
        }
        parts
            .iter()
            .map(|p| p.to_vec::<f32>().map_err(Error::from))
            .collect()
    }

    /// Execute and reshape outputs to matrices per the manifest.
    /// 1-D outputs (singular values) become 1xK row matrices.
    pub fn run(&mut self, name: &str, inputs: &[&Matrix]) -> Result<Vec<Matrix>> {
        let raws: Vec<&[f32]> = inputs.iter().map(|m| m.data()).collect();
        let outs = self.run_raw(name, &raws)?;
        let entry = self.manifest.by_name(name).expect("validated in run_raw");
        outs.into_iter()
            .zip(entry.outputs.clone())
            .map(|(data, shape)| {
                let (r, c) = match shape.len() {
                    1 => (1, shape[0]),
                    2 => (shape[0], shape[1]),
                    _ => {
                        return Err(Error::Artifact(format!(
                            "artifact '{name}': unsupported output rank {shape:?}"
                        )))
                    }
                };
                Matrix::from_vec(r, c, data)
            })
            .collect()
    }

    /// Convenience: dense GEMM through an artifact (`op` is one of the
    /// dense op kinds), exact-shape lattice hit required.
    pub fn dense_gemm(&mut self, op: &str, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let n = a.rows();
        let entry = self
            .manifest
            .lookup(op, n, 0)
            .ok_or_else(|| Error::Artifact(format!("no {op} artifact for n={n}")))?;
        let name = entry.name.clone();
        Ok(self.run(&name, &[a, b])?.remove(0))
    }
}

#[cfg(test)]
mod tests {
    //! Integration-grade tests live in `rust/tests/runtime_roundtrip.rs`
    //! (they need built artifacts); here we only check input validation
    //! logic that does not require a PJRT client.
}
