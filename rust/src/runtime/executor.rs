//! Thread-confined XLA execution service.
//!
//! `PjRtClient` is not `Send`, so the runtime lives on one dedicated
//! thread; [`XlaExecutor`] is the cloneable, `Send` handle the coordinator
//! workers use. Jobs are (artifact name, input tensors); responses come
//! back over a per-job oneshot channel. On the single-core evaluation host
//! this serialization costs nothing — PJRT execution is CPU-bound anyway.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::runtime::client::XlaRuntime;
use crate::runtime::manifest::Manifest;

enum Job {
    Run {
        name: String,
        inputs: Vec<Matrix>,
        respond: Sender<Result<Vec<Matrix>>>,
    },
    Warm {
        name: String,
        respond: Sender<Result<()>>,
    },
    Stats {
        respond: Sender<u64>,
    },
    Shutdown,
}

/// Cloneable handle to the XLA executor thread.
pub struct XlaExecutor {
    tx: Sender<Job>,
    /// Join handle, present only on the original (for clean shutdown).
    join: Option<JoinHandle<()>>,
    /// Manifest snapshot (parsed a second time on the caller side so the
    /// router can consult shapes without a channel round-trip).
    manifest: Manifest,
}

impl XlaExecutor {
    /// Spawn the executor thread and load artifacts from `dir`.
    ///
    /// Fails fast (before returning) if the manifest is unreadable or the
    /// PJRT client cannot start.
    pub fn start(dir: impl AsRef<std::path::Path>) -> Result<XlaExecutor> {
        let dir = dir.as_ref().to_path_buf();
        // Parse the manifest on the caller side first: cheap, and gives
        // the router its own copy.
        let manifest = Manifest::load(&dir)?;

        let (tx, rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("xla-executor".into())
            .spawn(move || {
                let mut rt = match XlaRuntime::new(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                Self::serve(&mut rt, rx);
            })
            .map_err(|e| Error::Service(format!("spawning xla-executor: {e}")))?;

        ready_rx
            .recv()
            .map_err(|_| Error::Service("xla-executor died during startup".into()))??;

        Ok(XlaExecutor {
            tx,
            join: Some(join),
            manifest,
        })
    }

    fn serve(rt: &mut XlaRuntime, rx: Receiver<Job>) {
        while let Ok(job) = rx.recv() {
            match job {
                Job::Run {
                    name,
                    inputs,
                    respond,
                } => {
                    let refs: Vec<&Matrix> = inputs.iter().collect();
                    let _ = respond.send(rt.run(&name, &refs));
                }
                Job::Warm { name, respond } => {
                    let _ = respond.send(rt.warm(&name));
                }
                Job::Stats { respond } => {
                    let _ = respond.send(rt.compiles());
                }
                Job::Shutdown => break,
            }
        }
    }

    /// The artifact manifest (caller-side copy).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute artifact `name`; blocks until the result is back.
    pub fn run(&self, name: &str, inputs: Vec<Matrix>) -> Result<Vec<Matrix>> {
        let (respond, rx) = channel();
        self.tx
            .send(Job::Run {
                name: name.to_string(),
                inputs,
                respond,
            })
            .map_err(|_| Error::Service("xla-executor is gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Service("xla-executor dropped the response".into()))?
    }

    /// Pre-compile an artifact.
    pub fn warm(&self, name: &str) -> Result<()> {
        let (respond, rx) = channel();
        self.tx
            .send(Job::Warm {
                name: name.to_string(),
                respond,
            })
            .map_err(|_| Error::Service("xla-executor is gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Service("xla-executor dropped the response".into()))?
    }

    /// Number of artifact compilations performed so far.
    pub fn compile_count(&self) -> Result<u64> {
        let (respond, rx) = channel();
        self.tx
            .send(Job::Stats { respond })
            .map_err(|_| Error::Service("xla-executor is gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Service("xla-executor dropped the response".into()))
    }

    /// Cloneable sender-only handle for worker threads.
    pub fn handle(&self) -> XlaHandle {
        XlaHandle {
            tx: self.tx.clone(),
        }
    }
}

impl Drop for XlaExecutor {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Lightweight `Send + Clone` handle used inside worker threads.
#[derive(Clone)]
pub struct XlaHandle {
    tx: Sender<Job>,
}

impl XlaHandle {
    /// Execute artifact `name`; blocks until the result is back.
    pub fn run(&self, name: &str, inputs: Vec<Matrix>) -> Result<Vec<Matrix>> {
        let (respond, rx) = channel();
        self.tx
            .send(Job::Run {
                name: name.to_string(),
                inputs,
                respond,
            })
            .map_err(|_| Error::Service("xla-executor is gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Service("xla-executor dropped the response".into()))?
    }
}
