//! Log-bucketed histogram with percentile estimation.
//!
//! Buckets are powers of `2^(1/8)` spanning ~1 ns … ~10⁶ s when samples are
//! seconds, giving ≤ 9% relative quantile error — plenty for latency
//! reporting. Exact min/max/sum are tracked alongside.

/// Growth factor per bucket: 2^(1/8).
const BUCKET_FACTOR: f64 = 1.0905077326652577;
/// Smallest representable sample.
const MIN_SAMPLE: f64 = 1e-9;
/// Number of buckets (covers up to ~3.5e6 × MIN_SAMPLE^-1).
pub(crate) const NBUCKETS: usize = 512;

/// A fixed-size log-bucketed histogram.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    dropped: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; NBUCKETS],
            count: 0,
            dropped: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub(crate) fn bucket_of(v: f64) -> usize {
        let v = v.max(MIN_SAMPLE);
        let idx = (v / MIN_SAMPLE).ln() / BUCKET_FACTOR.ln();
        (idx as usize).min(NBUCKETS - 1)
    }

    fn bucket_value(idx: usize) -> f64 {
        MIN_SAMPLE * BUCKET_FACTOR.powi(idx as i32)
    }

    /// Record one sample. Non-finite **and negative** samples are
    /// dropped (and counted in [`Histogram::dropped`]): the log buckets
    /// only represent non-negative magnitudes, and admitting `v < 0` used
    /// to skew `sum`/`mean`/`min` while the bucket index silently clamped
    /// to 0. Exactly 0.0 is admitted — a probed relative error of zero
    /// (dense/exact kernel, rank ≥ true rank) is a real observation; it
    /// lands in the smallest bucket and contributes to count/sum/min.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            self.dropped += 1;
            return;
        }
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold a raw bucket shard (e.g. one thread-striped atomic shard) into
    /// this histogram. `buckets` shorter than [`NBUCKETS`] is allowed; the
    /// tail is treated as zero.
    pub(crate) fn absorb_raw(
        &mut self,
        buckets: &[u64],
        count: u64,
        dropped: u64,
        sum: f64,
        min: f64,
        max: f64,
    ) {
        for (dst, &src) in self.buckets.iter_mut().zip(buckets) {
            *dst += src;
        }
        self.count += count;
        self.dropped += dropped;
        self.sum += sum;
        if count > 0 {
            self.min = self.min.min(min);
            self.max = self.max.max(max);
        }
    }

    /// Sample count.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Samples rejected by [`Histogram::record`] (non-finite or < 0).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Mean of recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Quantile estimate (`q` in [0, 1]).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                // Clamp the bucket midpoint into the true observed range.
                return Self::bucket_value(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Summary tuple used by the registry.
    pub fn summary(&self) -> crate::metrics::HistogramSummary {
        crate::metrics::HistogramSummary {
            count: self.count,
            dropped: self.dropped,
            mean: self.mean(),
            p10: self.quantile(0.10),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            max: if self.count == 0 { 0.0 } else { self.max },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        for v in [1.0, 2.0, 3.0] {
            h.record(v);
        }
        assert!((h.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0); // uniform on (0, 1]
        }
        let p50 = h.quantile(0.5);
        assert!((p50 - 0.5).abs() < 0.05, "p50 {p50}");
        let p99 = h.quantile(0.99);
        assert!((p99 - 0.99).abs() / 0.99 < 0.10, "p99 {p99}");
    }

    #[test]
    fn min_max_clamping() {
        let mut h = Histogram::new();
        h.record(5.0);
        assert_eq!(h.quantile(0.0), 5.0);
        assert_eq!(h.quantile(1.0), 5.0);
    }

    #[test]
    fn ignores_non_finite() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 0);
        assert_eq!(h.dropped(), 2);
    }

    #[test]
    fn drops_negatives_but_admits_zero() {
        let mut h = Histogram::new();
        h.record(-1.0);
        h.record(0.0);
        h.record(2.0);
        // Zero is a valid observation (a probed relative error of exactly
        // 0.0); only the negative sample is rejected.
        assert_eq!(h.count(), 2);
        assert_eq!(h.dropped(), 1);
        assert!((h.mean() - 1.0).abs() < 1e-12);
        assert_eq!(h.quantile(0.0), 0.0, "zero must become the observed min");
        assert_eq!(h.quantile(1.0), 2.0);
        let s = h.summary();
        assert_eq!(s.dropped, 1);
        assert_eq!(s.count, 2);
    }

    #[test]
    fn p10_tracks_distribution_tail() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0); // uniform on (0, 1]
        }
        let s = h.summary();
        assert!((s.p10 - 0.10).abs() < 0.02, "p10 {}", s.p10);
        assert!(s.p10 < s.p50 && s.p50 < s.p99);
    }

    #[test]
    fn absorb_raw_merges_shards() {
        let mut a = Histogram::new();
        a.record(1.0);
        let mut shard = vec![0u64; NBUCKETS];
        shard[Histogram::bucket_of(4.0)] = 2;
        a.absorb_raw(&shard, 2, 1, 8.0, 4.0, 4.0);
        assert_eq!(a.count(), 3);
        assert_eq!(a.dropped(), 1);
        assert!((a.mean() - 3.0).abs() < 1e-12);
        assert_eq!(a.summary().max, 4.0);
        // Empty shard merge is a no-op on min/max.
        let before = a.summary();
        a.absorb_raw(&[0u64; NBUCKETS], 0, 0, 0.0, f64::INFINITY, f64::NEG_INFINITY);
        let after = a.summary();
        assert_eq!(before.count, after.count);
        assert_eq!(before.max, after.max);
    }

    #[test]
    fn wide_dynamic_range() {
        let mut h = Histogram::new();
        h.record(1e-8);
        h.record(1e3);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.1) < 1e-6);
        assert!(h.quantile(0.99) > 100.0);
    }
}
