//! Metrics substrate: counters, latency histograms, timers.
//!
//! No external metrics crate offline, so this is a minimal but real
//! implementation: lock-free counters, a log-bucketed histogram with
//! p50/p90/p99 estimation, and a scoped timer. The coordinator exposes a
//! [`MetricsRegistry`] snapshot through the CLI `stats` output and the
//! serving example's final report.

pub mod histogram;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

pub use histogram::Histogram;

/// A named, thread-safe monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by 1.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Registry of counters and histograms, keyed by name.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, u64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl MetricsRegistry {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `v` to the named counter (creating it at 0).
    pub fn count(&self, name: &str, v: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += v;
    }

    /// Record a sample (e.g. seconds) into the named histogram.
    pub fn observe(&self, name: &str, v: f64) {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(Histogram::new)
            .record(v);
    }

    /// Time a closure into the named histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Snapshot counter values.
    pub fn counters(&self) -> BTreeMap<String, u64> {
        self.counters.lock().unwrap().clone()
    }

    /// Snapshot histogram summaries as `(count, mean, p50, p90, p99, max)`.
    pub fn histogram_summaries(&self) -> BTreeMap<String, HistogramSummary> {
        self.histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), h.summary()))
            .collect()
    }

    /// Render a human-readable report block.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters() {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, s) in self.histogram_summaries() {
            out.push_str(&format!(
                "hist {k}: n={} mean={:.3e} p50={:.3e} p90={:.3e} p99={:.3e} max={:.3e}\n",
                s.count, s.mean, s.p50, s.p90, s.p99, s.max
            ));
        }
        out
    }
}

/// Point-in-time histogram summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct HistogramSummary {
    /// Sample count.
    pub count: u64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median estimate.
    pub p50: f64,
    /// 90th percentile estimate.
    pub p90: f64,
    /// 99th percentile estimate.
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_ops() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn registry_counts_and_observes() {
        let r = MetricsRegistry::new();
        r.count("req", 1);
        r.count("req", 2);
        r.observe("lat", 0.5);
        r.observe("lat", 1.5);
        assert_eq!(r.counters()["req"], 3);
        let s = r.histogram_summaries()["lat"];
        assert_eq!(s.count, 2);
        assert!((s.mean - 1.0).abs() < 1e-9);
    }

    #[test]
    fn time_records() {
        let r = MetricsRegistry::new();
        let out = r.time("t", || 7);
        assert_eq!(out, 7);
        assert_eq!(r.histogram_summaries()["t"].count, 1);
    }

    #[test]
    fn render_contains_names() {
        let r = MetricsRegistry::new();
        r.count("a", 1);
        r.observe("b", 2.0);
        let s = r.render();
        assert!(s.contains("counter a = 1"));
        assert!(s.contains("hist b"));
    }
}
