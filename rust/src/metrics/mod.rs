//! Metrics substrate: lock-free counters, striped atomic histograms,
//! interned handles, and snapshot exporters.
//!
//! No external metrics crate offline, so this is a minimal but real
//! implementation. Two layers:
//!
//! - **Handles** ([`Counter`], [`HistogramHandle`]): pre-registered via
//!   [`MetricsRegistry::counter`] / [`MetricsRegistry::histogram`], then
//!   recorded into with plain atomic ops — no lock, no allocation, no
//!   string hashing on the hot path. Histograms stripe their buckets
//!   across [`HIST_SHARDS`] shards selected by a per-thread ordinal, so
//!   concurrent `observe` calls from the shard pool don't contend on one
//!   cache line; shards are merged at snapshot time.
//! - **String API** ([`MetricsRegistry::count`] / `observe` / `time`):
//!   kept for cold paths and tests. After first registration it is a
//!   read-lock + hash lookup — still allocation-free at steady state —
//!   but hot paths should hold a handle instead.
//!
//! [`MetricsRegistry::snapshot`] clones and merges everything **once**
//! into a [`MetricsSnapshot`], which renders as a human report block,
//! Prometheus text exposition, or a JSON document. The coordinator
//! exposes it through the CLI `stats`/`trace` output and the serving
//! example's final report.

pub mod histogram;

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

pub use histogram::Histogram;

/// A named, thread-safe monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by 1.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Histogram stripe count. 8 shards is enough to decorrelate the default
/// 4-worker shard pool plus the dispatcher without bloating snapshots.
const HIST_SHARDS: usize = 8;

/// Stable small ordinal for the calling thread, assigned on first use from
/// a global counter. Used to pick a histogram stripe (and by the trace
/// plane to label spans) without allocating thread-local state.
pub(crate) fn thread_ordinal() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    std::thread_local! {
        static ORDINAL: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
    }
    ORDINAL.with(|c| {
        let v = c.get();
        if v != usize::MAX {
            return v;
        }
        let v = NEXT.fetch_add(1, Ordering::Relaxed);
        c.set(v);
        v
    })
}

/// CAS-add `delta` into an f64 stored as bits in an `AtomicU64`.
fn atomic_f64_add(cell: &AtomicU64, delta: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + delta).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// CAS-min/max an f64 stored as bits in an `AtomicU64`.
fn atomic_f64_extreme(cell: &AtomicU64, v: f64, want_min: bool) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let seen = f64::from_bits(cur);
        let better = if want_min { v < seen } else { v > seen };
        if !better {
            return;
        }
        match cell.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// One histogram stripe: atomic log buckets plus exact moments.
struct HistShard {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    dropped: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl HistShard {
    fn new() -> Self {
        HistShard {
            buckets: (0..histogram::NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }
}

/// A pre-registered histogram handle: thread-striped atomic buckets,
/// merged into a plain [`Histogram`] at snapshot time. `observe` is
/// lock-free and allocation-free.
pub struct HistogramHandle {
    shards: Vec<HistShard>,
}

impl Default for HistogramHandle {
    fn default() -> Self {
        Self::new()
    }
}

impl HistogramHandle {
    /// New empty handle.
    pub fn new() -> Self {
        HistogramHandle {
            shards: (0..HIST_SHARDS).map(|_| HistShard::new()).collect(),
        }
    }

    /// Record one sample. Same admission rule as [`Histogram::record`]:
    /// non-finite and negative samples are dropped and counted; exactly
    /// 0.0 is a valid observation (e.g. a probed relative error of zero).
    pub fn observe(&self, v: f64) {
        let shard = &self.shards[thread_ordinal() % HIST_SHARDS];
        if !v.is_finite() || v < 0.0 {
            shard.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        shard.buckets[Histogram::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        shard.count.fetch_add(1, Ordering::Relaxed);
        atomic_f64_add(&shard.sum_bits, v);
        atomic_f64_extreme(&shard.min_bits, v, true);
        atomic_f64_extreme(&shard.max_bits, v, false);
    }

    /// Merge all stripes into a plain histogram (snapshot path only).
    pub fn merged(&self) -> Histogram {
        let mut out = Histogram::new();
        let mut scratch = vec![0u64; histogram::NBUCKETS];
        for shard in &self.shards {
            for (dst, src) in scratch.iter_mut().zip(shard.buckets.iter()) {
                *dst = src.load(Ordering::Relaxed);
            }
            out.absorb_raw(
                &scratch,
                shard.count.load(Ordering::Relaxed),
                shard.dropped.load(Ordering::Relaxed),
                f64::from_bits(shard.sum_bits.load(Ordering::Relaxed)),
                f64::from_bits(shard.min_bits.load(Ordering::Relaxed)),
                f64::from_bits(shard.max_bits.load(Ordering::Relaxed)),
            );
        }
        out
    }

    /// Summary of the merged stripes.
    pub fn summary(&self) -> HistogramSummary {
        self.merged().summary()
    }
}

/// Registry of counters and histograms, keyed by name. Names are interned
/// once on registration; handles record through atomics afterwards.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: RwLock<HashMap<String, Arc<Counter>>>,
    histograms: RwLock<HashMap<String, Arc<HistogramHandle>>>,
}

impl MetricsRegistry {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern (or fetch) the named counter handle. Hot paths should call
    /// this once at setup and keep the `Arc`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        if let Some(c) = self.counters.read().unwrap().get(name) {
            return c.clone();
        }
        self.counters
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Intern (or fetch) the named histogram handle.
    pub fn histogram(&self, name: &str) -> Arc<HistogramHandle> {
        if let Some(h) = self.histograms.read().unwrap().get(name) {
            return h.clone();
        }
        self.histograms
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Add `v` to the named counter (creating it at 0). Steady state is a
    /// read-lock + hash lookup — no allocation after first registration.
    pub fn count(&self, name: &str, v: u64) {
        if let Some(c) = self.counters.read().unwrap().get(name) {
            c.add(v);
            return;
        }
        self.counter(name).add(v);
    }

    /// Record a sample (e.g. seconds) into the named histogram.
    pub fn observe(&self, name: &str, v: f64) {
        if let Some(h) = self.histograms.read().unwrap().get(name) {
            h.observe(v);
            return;
        }
        self.histogram(name).observe(v);
    }

    /// Time a closure into the named histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        out
    }

    /// One-pass snapshot: clone the handle tables under their read locks,
    /// then merge stripes handle by handle. This replaces the old
    /// lock-per-metric summaries path.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self
            .counters
            .read()
            .unwrap()
            .iter()
            .map(|(k, c)| (k.clone(), c.get()))
            .collect();
        let histograms = self
            .histograms
            .read()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), h.summary()))
            .collect();
        MetricsSnapshot {
            counters,
            histograms,
        }
    }

    /// Snapshot counter values.
    pub fn counters(&self) -> BTreeMap<String, u64> {
        self.snapshot().counters
    }

    /// Snapshot histogram summaries.
    pub fn histogram_summaries(&self) -> BTreeMap<String, HistogramSummary> {
        self.snapshot().histograms
    }

    /// Render a human-readable report block.
    pub fn render(&self) -> String {
        self.snapshot().render()
    }
}

/// Point-in-time histogram summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct HistogramSummary {
    /// Sample count.
    pub count: u64,
    /// Samples rejected at record time (non-finite or < 0).
    pub dropped: u64,
    /// Arithmetic mean.
    pub mean: f64,
    /// 10th percentile estimate (queueing-analysis floor).
    pub p10: f64,
    /// Median estimate.
    pub p50: f64,
    /// 90th percentile estimate.
    pub p90: f64,
    /// 99th percentile estimate.
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
}

/// Immutable point-in-time view of a registry, with exporters.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Counter values by name.
    pub counters: BTreeMap<String, u64>,
    /// Histogram summaries by name.
    pub histograms: BTreeMap<String, HistogramSummary>,
}

/// Metric names use dots; Prometheus wants `[a-zA-Z_:][a-zA-Z0-9_:]*`.
fn prometheus_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 4);
    out.push_str("lrg_");
    for ch in name.chars() {
        if ch.is_ascii_alphanumeric() {
            out.push(ch);
        } else {
            out.push('_');
        }
    }
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl MetricsSnapshot {
    /// Render a human-readable report block (same shape as the historical
    /// `MetricsRegistry::render`, plus p10).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, s) in &self.histograms {
            out.push_str(&format!(
                "hist {k}: n={} mean={:.3e} p50={:.3e} p90={:.3e} p99={:.3e} max={:.3e}\n",
                s.count, s.mean, s.p50, s.p90, s.p99, s.max
            ));
        }
        out
    }

    /// Prometheus text exposition (format 0.0.4): counters as `counter`,
    /// histograms as `summary` with quantile labels plus `_sum`/`_count`.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            let name = prometheus_name(k);
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        for (k, s) in &self.histograms {
            let name = prometheus_name(k);
            out.push_str(&format!("# TYPE {name} summary\n"));
            for (q, v) in [
                ("0.1", s.p10),
                ("0.5", s.p50),
                ("0.9", s.p90),
                ("0.99", s.p99),
                ("1", s.max),
            ] {
                out.push_str(&format!("{name}{{quantile=\"{q}\"}} {v:e}\n"));
            }
            out.push_str(&format!("{name}_sum {:e}\n", s.mean * s.count as f64));
            out.push_str(&format!("{name}_count {}\n", s.count));
        }
        out
    }

    /// JSON document: `{"counters": {...}, "histograms": {name: {...}}}`.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"counters\":{");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{v}", json_escape(k)));
        }
        out.push_str("},\"histograms\":{");
        for (i, (k, s)) in self.histograms.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\"{}\":{{\"count\":{},\"dropped\":{},\"mean\":{:e},\"p10\":{:e},\
                 \"p50\":{:e},\"p90\":{:e},\"p99\":{:e},\"max\":{:e}}}",
                json_escape(k),
                s.count,
                s.dropped,
                s.mean,
                s.p10,
                s.p50,
                s.p90,
                s.p99,
                s.max
            ));
        }
        out.push_str("}}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_ops() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn registry_counts_and_observes() {
        let r = MetricsRegistry::new();
        r.count("req", 1);
        r.count("req", 2);
        r.observe("lat", 0.5);
        r.observe("lat", 1.5);
        assert_eq!(r.counters()["req"], 3);
        let s = r.histogram_summaries()["lat"];
        assert_eq!(s.count, 2);
        assert!((s.mean - 1.0).abs() < 1e-9);
    }

    #[test]
    fn time_records() {
        let r = MetricsRegistry::new();
        let out = r.time("t", || 7);
        assert_eq!(out, 7);
        assert_eq!(r.histogram_summaries()["t"].count, 1);
    }

    #[test]
    fn render_contains_names() {
        let r = MetricsRegistry::new();
        r.count("a", 1);
        r.observe("b", 2.0);
        let s = r.render();
        assert!(s.contains("counter a = 1"));
        assert!(s.contains("hist b"));
    }

    #[test]
    fn handles_alias_string_api() {
        let r = MetricsRegistry::new();
        let c = r.counter("x");
        c.add(2);
        r.count("x", 3);
        assert_eq!(r.counters()["x"], 5);
        let h = r.histogram("lat");
        h.observe(1.0);
        r.observe("lat", 3.0);
        let s = r.histogram_summaries()["lat"];
        assert_eq!(s.count, 2);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_handle_drops_negatives_admits_zero() {
        let h = HistogramHandle::new();
        h.observe(-3.0);
        h.observe(f64::NAN);
        h.observe(0.0);
        h.observe(2.0);
        let s = h.summary();
        assert_eq!(s.count, 2, "zero is a valid observation");
        assert_eq!(s.dropped, 2);
        assert!((s.mean - 1.0).abs() < 1e-12);
    }

    #[test]
    fn striped_histogram_merges_across_threads() {
        let h = Arc::new(HistogramHandle::new());
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        h.observe((t * 1000 + i + 1) as f64);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let s = h.summary();
        assert_eq!(s.count, 8000);
        // Sum of 1..=8000 — CAS adds are exact per stripe; merging eight
        // partial sums of like-magnitude positives is accurate to ulps.
        let expect = 8000.0 * 8001.0 / 2.0 / 8000.0;
        assert!((s.mean - expect).abs() / expect < 1e-12, "mean {}", s.mean);
        assert_eq!(s.max, 8000.0);
    }

    #[test]
    fn snapshot_is_one_consistent_pass() {
        let r = MetricsRegistry::new();
        r.count("a", 1);
        r.observe("b", 2.0);
        let snap = r.snapshot();
        assert_eq!(snap.counters["a"], 1);
        assert_eq!(snap.histograms["b"].count, 1);
        assert!(snap.histograms["b"].p10 <= snap.histograms["b"].p50);
    }

    #[test]
    fn prometheus_exposition_well_formed() {
        let r = MetricsRegistry::new();
        r.count("gemm.submitted", 4);
        r.observe("gemm.exec_us", 120.0);
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("# TYPE lrg_gemm_submitted counter"));
        assert!(text.contains("lrg_gemm_submitted 4"));
        assert!(text.contains("# TYPE lrg_gemm_exec_us summary"));
        assert!(text.contains("quantile=\"0.1\""));
        assert!(text.contains("lrg_gemm_exec_us_count 1"));
    }

    #[test]
    fn json_snapshot_parses_by_eye() {
        let r = MetricsRegistry::new();
        r.count("a.b", 2);
        r.observe("c", 1.0);
        let j = r.snapshot().to_json();
        assert!(j.starts_with("{\"counters\":{"));
        assert!(j.contains("\"a.b\":2"));
        assert!(j.contains("\"count\":1"));
        assert!(j.ends_with("}}"));
    }

    #[test]
    fn thread_ordinals_are_stable_and_distinct() {
        let a = thread_ordinal();
        assert_eq!(a, thread_ordinal());
        let b = std::thread::spawn(thread_ordinal).join().unwrap();
        assert_ne!(a, b);
    }
}
