//! Minimal TOML-subset parser.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed TOML scalar.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
}

impl TomlValue {
    /// As string, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As integer (accepts exact floats too).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            TomlValue::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    /// As float (accepts ints).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `section.key → value`; top-level keys use section "".
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-subset document.
pub fn parse_toml(input: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| {
                Error::Config(format!("line {}: unterminated section header", lineno + 1))
            })?;
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| {
            Error::Config(format!("line {}: expected `key = value`", lineno + 1))
        })?;
        let key = line[..eq].trim().to_string();
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| Error::Config(format!("line {}: {}", lineno + 1, e)))?;
        if key.is_empty() {
            return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
        }
        doc.get_mut(&section).unwrap().insert(key, val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(q) = s.strip_prefix('"') {
        let inner = q
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(unescape(inner)));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse_toml(
            r#"
# top comment
name = "lowrank"   # trailing comment
threads = 4

[service]
queue_depth = 1_024
tolerance = 0.05
enabled = true
label = "a # not comment"
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["name"], TomlValue::Str("lowrank".into()));
        assert_eq!(doc[""]["threads"], TomlValue::Int(4));
        assert_eq!(doc["service"]["queue_depth"], TomlValue::Int(1024));
        assert_eq!(doc["service"]["tolerance"], TomlValue::Float(0.05));
        assert_eq!(doc["service"]["enabled"], TomlValue::Bool(true));
        assert_eq!(
            doc["service"]["label"],
            TomlValue::Str("a # not comment".into())
        );
    }

    #[test]
    fn value_coercions() {
        assert_eq!(TomlValue::Int(3).as_float(), Some(3.0));
        assert_eq!(TomlValue::Float(3.0).as_int(), Some(3));
        assert_eq!(TomlValue::Float(3.5).as_int(), None);
        assert_eq!(TomlValue::Bool(true).as_bool(), Some(true));
        assert_eq!(TomlValue::Str("x".into()).as_str(), Some("x"));
    }

    #[test]
    fn error_on_bad_lines() {
        assert!(parse_toml("[unterminated").is_err());
        assert!(parse_toml("novalue").is_err());
        assert!(parse_toml("= 3").is_err());
        assert!(parse_toml("x = ").is_err());
        assert!(parse_toml("x = \"open").is_err());
    }

    #[test]
    fn escapes() {
        let doc = parse_toml(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(doc[""]["s"], TomlValue::Str("a\nb\t\"c\"".into()));
    }

    #[test]
    fn negative_and_float_forms() {
        let doc = parse_toml("a = -5\nb = 1e-3\nc = -2.5").unwrap();
        assert_eq!(doc[""]["a"], TomlValue::Int(-5));
        assert_eq!(doc[""]["b"], TomlValue::Float(1e-3));
        assert_eq!(doc[""]["c"], TomlValue::Float(-2.5));
    }
}
