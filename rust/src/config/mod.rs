//! Configuration system.
//!
//! A TOML-subset parser (no serde offline) plus the typed service
//! configuration. Supported TOML features: `[section]` headers, `key =
//! value` with string/int/float/bool values, comments, and blank lines —
//! exactly what the shipped `lowrank-gemm.toml` files need.

pub mod schema;
pub mod toml;

pub use schema::{
    AccuracySettings, AppConfig, AutotuneSettings, CacheSettings, ClusterSettings,
    FaultInjectSettings, FaultSettings, KernelSettings, SchedulerSettings, ServiceSettings,
    ShardSettings, TraceSettings,
};
pub use toml::{parse_toml, TomlValue};
