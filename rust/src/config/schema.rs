//! Typed application configuration backed by the TOML-subset parser.

use crate::config::toml::{parse_toml, TomlDoc};
use crate::error::{Error, Result};
use crate::fp8::StorageFormat;
use crate::lowrank::factor::DecompMethod;
use crate::lowrank::rank::RankStrategy;

/// `[service]` section: the coordinator's knobs.
#[derive(Clone, Debug)]
pub struct ServiceSettings {
    /// Worker threads executing GEMMs.
    pub workers: usize,
    /// Max queued requests before backpressure rejects (paper-free knob;
    /// any serving system needs it).
    pub queue_depth: usize,
    /// Max requests fused into one batch by the dynamic batcher.
    pub max_batch: usize,
    /// Batching window in microseconds.
    pub batch_window_us: u64,
    /// Default relative-error tolerance when a request doesn't set one.
    pub default_tolerance: f32,
    /// Factor-cache budget in bytes.
    pub factor_cache_bytes: usize,
}

impl Default for ServiceSettings {
    fn default() -> Self {
        ServiceSettings {
            workers: 2,
            queue_depth: 1024,
            max_batch: 8,
            batch_window_us: 200,
            default_tolerance: 0.05,
            factor_cache_bytes: 256 << 20,
        }
    }
}

/// Whole-app configuration.
#[derive(Clone, Debug)]
pub struct AppConfig {
    /// Device profile name for the cost model ("rtx4090", "h200", …).
    pub device: String,
    /// Directory containing AOT artifacts + manifest.
    pub artifacts_dir: String,
    /// Prefer XLA-compiled artifacts over the native CPU substrate when a
    /// matching artifact exists.
    pub use_xla: bool,
    /// Low-rank defaults.
    pub rank_strategy: RankStrategy,
    /// Decomposition method.
    pub decomp: DecompMethod,
    /// Factor storage precision.
    pub storage: StorageFormat,
    /// `[service]` knobs.
    pub service: ServiceSettings,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            device: "rtx4090".into(),
            artifacts_dir: "artifacts".into(),
            use_xla: true,
            rank_strategy: RankStrategy::EnergyFraction(0.99),
            decomp: DecompMethod::RandomizedSvd,
            storage: StorageFormat::Fp8(crate::fp8::Fp8Format::E4M3),
            service: ServiceSettings::default(),
        }
    }
}

impl AppConfig {
    /// Parse from TOML text; unset keys keep defaults.
    pub fn from_toml(text: &str) -> Result<AppConfig> {
        let doc = parse_toml(text)?;
        Self::from_doc(&doc)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<AppConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    fn from_doc(doc: &TomlDoc) -> Result<AppConfig> {
        let mut cfg = AppConfig::default();
        if let Some(top) = doc.get("") {
            if let Some(v) = top.get("device") {
                cfg.device = req_str(v, "device")?;
            }
            if let Some(v) = top.get("artifacts_dir") {
                cfg.artifacts_dir = req_str(v, "artifacts_dir")?;
            }
            if let Some(v) = top.get("use_xla") {
                cfg.use_xla = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("use_xla must be bool".into()))?;
            }
        }
        if let Some(lr) = doc.get("lowrank") {
            if let Some(v) = lr.get("decomp") {
                let s = req_str(v, "lowrank.decomp")?;
                cfg.decomp = DecompMethod::parse(&s)
                    .ok_or_else(|| Error::Config(format!("unknown decomp `{s}`")))?;
            }
            if let Some(v) = lr.get("storage") {
                let s = req_str(v, "lowrank.storage")?;
                cfg.storage = StorageFormat::parse(&s)
                    .ok_or_else(|| Error::Config(format!("unknown storage `{s}`")))?;
            }
            cfg.rank_strategy = parse_rank_strategy(lr)?;
        }
        if let Some(svc) = doc.get("service") {
            let s = &mut cfg.service;
            if let Some(v) = svc.get("workers") {
                s.workers = req_usize(v, "service.workers")?;
            }
            if let Some(v) = svc.get("queue_depth") {
                s.queue_depth = req_usize(v, "service.queue_depth")?;
            }
            if let Some(v) = svc.get("max_batch") {
                s.max_batch = req_usize(v, "service.max_batch")?;
            }
            if let Some(v) = svc.get("batch_window_us") {
                s.batch_window_us = req_usize(v, "service.batch_window_us")? as u64;
            }
            if let Some(v) = svc.get("default_tolerance") {
                s.default_tolerance = v
                    .as_float()
                    .ok_or_else(|| Error::Config("default_tolerance must be float".into()))?
                    as f32;
            }
            if let Some(v) = svc.get("factor_cache_mb") {
                s.factor_cache_bytes = req_usize(v, "service.factor_cache_mb")? << 20;
            }
        }
        Ok(cfg)
    }
}

fn parse_rank_strategy(
    section: &std::collections::BTreeMap<String, crate::config::toml::TomlValue>,
) -> Result<RankStrategy> {
    let name = match section.get("rank_strategy") {
        Some(v) => req_str(v, "lowrank.rank_strategy")?,
        None => return Ok(AppConfig::default().rank_strategy),
    };
    Ok(match name.as_str() {
        "fixed" => RankStrategy::Fixed(match section.get("rank") {
            Some(v) => req_usize(v, "lowrank.rank")?,
            None => 64,
        }),
        "fixed_fraction" => RankStrategy::FixedFraction(get_f32(section, "alpha", 0.025)?),
        "energy" => RankStrategy::EnergyFraction(get_f32(section, "tau", 0.99)?),
        "error_bound" => RankStrategy::ErrorBound(get_f32(section, "epsilon", 0.02)?),
        "hardware_aware" => RankStrategy::HardwareAware {
            memory_fraction: get_f32(section, "memory_fraction", 0.15)?,
            granule: match section.get("granule") {
                Some(v) => req_usize(v, "lowrank.granule")?,
                None => 16,
            },
        },
        other => return Err(Error::Config(format!("unknown rank_strategy `{other}`"))),
    })
}

fn get_f32(
    section: &std::collections::BTreeMap<String, crate::config::toml::TomlValue>,
    key: &str,
    default: f32,
) -> Result<f32> {
    match section.get(key) {
        Some(v) => Ok(v
            .as_float()
            .ok_or_else(|| Error::Config(format!("{key} must be a number")))?
            as f32),
        None => Ok(default),
    }
}

fn req_str(v: &crate::config::toml::TomlValue, key: &str) -> Result<String> {
    v.as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| Error::Config(format!("{key} must be a string")))
}

fn req_usize(v: &crate::config::toml::TomlValue, key: &str) -> Result<usize> {
    let i = v
        .as_int()
        .ok_or_else(|| Error::Config(format!("{key} must be an integer")))?;
    if i < 0 {
        return Err(Error::Config(format!("{key} must be non-negative")));
    }
    Ok(i as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_empty() {
        let cfg = AppConfig::from_toml("").unwrap();
        assert_eq!(cfg.device, "rtx4090");
        assert_eq!(cfg.service.workers, 2);
    }

    #[test]
    fn full_document() {
        let cfg = AppConfig::from_toml(
            r#"
device = "h200"
artifacts_dir = "art"
use_xla = false

[lowrank]
decomp = "lanczos"
storage = "fp8_e5m2"
rank_strategy = "energy"
tau = 0.999

[service]
workers = 8
queue_depth = 64
max_batch = 4
batch_window_us = 500
default_tolerance = 0.01
factor_cache_mb = 128
"#,
        )
        .unwrap();
        assert_eq!(cfg.device, "h200");
        assert!(!cfg.use_xla);
        assert_eq!(cfg.decomp, DecompMethod::Lanczos);
        assert_eq!(cfg.storage.name(), "fp8_e5m2");
        assert_eq!(cfg.rank_strategy, RankStrategy::EnergyFraction(0.999));
        assert_eq!(cfg.service.workers, 8);
        assert_eq!(cfg.service.factor_cache_bytes, 128 << 20);
    }

    #[test]
    fn rank_strategy_variants() {
        let fixed = AppConfig::from_toml("[lowrank]\nrank_strategy = \"fixed\"\nrank = 32").unwrap();
        assert_eq!(fixed.rank_strategy, RankStrategy::Fixed(32));
        let hw = AppConfig::from_toml(
            "[lowrank]\nrank_strategy = \"hardware_aware\"\nmemory_fraction = 0.2\ngranule = 8",
        )
        .unwrap();
        assert_eq!(
            hw.rank_strategy,
            RankStrategy::HardwareAware {
                memory_fraction: 0.2,
                granule: 8
            }
        );
    }

    #[test]
    fn bad_values_rejected() {
        assert!(AppConfig::from_toml("use_xla = 3").is_err());
        assert!(AppConfig::from_toml("[lowrank]\ndecomp = \"qr\"").is_err());
        assert!(AppConfig::from_toml("[lowrank]\nrank_strategy = \"nope\"").is_err());
        assert!(AppConfig::from_toml("[service]\nworkers = -1").is_err());
    }
}
