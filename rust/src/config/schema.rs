//! Typed application configuration backed by the TOML-subset parser.
//!
//! Shipped example (`lowrank-gemm.toml` in the repo root stays in sync
//! with this schema — asserted by the e2e tests):
//!
//! ```toml
//! device = "rtx4090"
//! artifacts_dir = "artifacts"
//! use_xla = true
//!
//! [lowrank]
//! decomp = "rsvd"                # rsvd | svd | lanczos
//! storage = "fp8_e4m3"           # fp8_e4m3 | fp8_e5m2 | f16 | bf16 | f32
//! rank_strategy = "energy"       # fixed | fixed_fraction | energy | error_bound | hardware_aware
//! tau = 0.99
//!
//! [service]
//! workers = 2                    # request-level dispatcher pool
//! queue_depth = 1024
//! max_batch = 8
//! batch_window_us = 200
//! default_tolerance = 0.05
//! factor_cache_mb = 256
//!
//! [kernel]                       # blocked-GEMM geometry (linalg::gemm)
//! mc = 128                       # packed A block height
//! kc = 256                       # shared inner blocking depth
//! nc = 256                       # packed B panel width
//! naive_cutover = 512000         # m·n·k at/below which the naive loop runs
//!
//! [shard]                        # tile-execution plane (crate::shard)
//! workers = 4                    # intra-GEMM worker threads
//! tile_m = 256                   # output tile height (keep % [kernel].mc == 0)
//! tile_n = 256                   # output tile width  (keep % [kernel].nc == 0)
//! min_parallel_n = 512           # below this, requests stay single-threaded
//!
//! [autotune]                     # online calibration plane (crate::autotune)
//! enabled = false                # default-off: selection stays analytic
//! ewma_alpha = 0.2               # EWMA weight of the newest sample
//! epsilon = 0.05                 # ε-greedy exploration rate
//! min_samples = 5                # analytic prior strength, in samples
//! table_path = ""                # persistence path ("" = in-memory only)
//!
//! [cache]                        # factor-cache plane (crate::cache)
//! enabled = false                # default-off: routing stays bit-identical
//! budget_mb = 256                # content-cache byte budget (MiB, LRU)
//! min_dim = 128                  # admission gate on min(rows, cols)
//! fp8 = false                    # store cached factors FP8-encoded
//! prepack = false                # store Vᵀ pre-packed in kernel panel layout
//! amortize_over = 8              # expected reuses amortizing a cold rSVD
//!
//! [trace]                        # tracing plane (crate::trace_plane)
//! enabled = false                # default-off: requests stay span-free
//! ring_capacity = 64             # flight recorder keeps the last N traces
//! slowest_k = 8                  # ... plus the K slowest ever seen
//! max_spans = 256                # per-request span arena (overflow drops)
//! export_path = ""               # chrome-trace JSON written at shutdown ("" = off)
//!
//! [accuracy]                     # accuracy plane (crate::accuracy)
//! enabled = false                # default-off: no probes, results bit-identical
//! sample_every = 16              # probe one in N completed requests
//! probes = 8                     # random probe vectors per probed request
//! ewma_alpha = 0.2               # EWMA weight of the newest probe
//! min_samples = 5                # analytic error model's prior strength
//! table_path = ""                # error-model persistence ("" = in-memory only)
//! seed = 181165805               # probe-vector RNG seed (deterministic replay)
//!
//! [scheduler]                    # unified scheduler plane (crate::sched)
//! enabled = false                # default-off: the legacy two-pool layout
//! workers = 0                    # steal-pool threads (0 = all cores)
//! steal = true                   # cross-worker stealing (false = bench control)
//! queue_depth = 0                # admission depth (0 = inherit [service].queue_depth)
//! tenant_quota = 0               # per-tenant in-flight cap (0 = unlimited)
//!
//! [fault]                        # fault-containment plane (crate::fault)
//! enabled = false                # default-off: panics propagate as before
//! strict_boot = false            # true = corrupt tables fail start (old behavior)
//! breaker_window = 16            # rolling outcome window per kernel
//! breaker_threshold = 8         # failures in window that trip the breaker
//! breaker_cooldown = 32          # denials before one half-open probe
//! retry = true                   # one retry on the fallback kernel
//!
//! [fault.inject]                 # deterministic fault injection (chaos)
//! seed = 0                       # draw seed; same seed ⇒ same faults
//! panic_tile = 0.0               # P(tile job panics)
//! stall_tile = 0.0               # P(tile stalls stall_ms first)
//! stall_ms = 1                   # stall duration
//! panic_request = 0.0            # P(request-boundary panic)
//! error_request = 0.0            # P(typed kernel error)
//! error_kernel = ""              # limit error injection to one kernel id
//! error_requests_under = 0       # ids below this always error (test knob)
//! corrupt_decode = 0.0           # P(FP8 decode corrupted)
//! net_refuse = 0.0               # P(cluster connect attempt refused)
//! net_stall = 0.0                # P(node stalls net_stall_ms before replying)
//! net_stall_ms = 1               # injected reply stall duration
//! net_truncate = 0.0             # P(node reply truncated mid-frame)
//! net_heartbeat_drop = 0.0       # P(a heartbeat is silently dropped)
//!
//! [cluster]                      # multi-node serving tier (crate::cluster)
//! enabled = false                # default-off: single-process, bit-identical
//! router_addr = "127.0.0.1:7070" # router bind / connect address
//! node_addr = "127.0.0.1:0"      # node agent's serving address (0 = ephemeral)
//! heartbeat_ms = 500             # node heartbeat cadence
//! heartbeat_timeout_ms = 2000    # silence before a node turns Suspect
//! dead_after_ms = 5000           # silence before Suspect turns Dead
//! connect_timeout_ms = 250       # per-attempt connect deadline
//! read_timeout_ms = 2000         # per-attempt read deadline
//! max_attempts = 3               # RPC attempts across candidate nodes
//! backoff_base_ms = 10           # decorrelated-jitter backoff base
//! backoff_cap_ms = 500           # backoff ceiling
//! fill_cap = 2                   # concurrent cold-fills routed per node
//! affinity_min_dim = 128         # fingerprint gate on min(rows, cols)
//! seed = 49413                   # backoff jitter seed
//! ```

use crate::config::toml::{parse_toml, TomlDoc};
use crate::error::{Error, Result};
use crate::fp8::StorageFormat;
use crate::lowrank::factor::DecompMethod;
use crate::lowrank::rank::RankStrategy;

/// `[service]` section: the coordinator's knobs.
#[derive(Clone, Debug)]
pub struct ServiceSettings {
    /// Worker threads executing GEMMs.
    pub workers: usize,
    /// Max queued requests before backpressure rejects (paper-free knob;
    /// any serving system needs it).
    pub queue_depth: usize,
    /// Max requests fused into one batch by the dynamic batcher.
    pub max_batch: usize,
    /// Batching window in microseconds.
    pub batch_window_us: u64,
    /// Default relative-error tolerance when a request doesn't set one.
    pub default_tolerance: f32,
    /// Factor-cache budget in bytes.
    pub factor_cache_bytes: usize,
}

impl Default for ServiceSettings {
    fn default() -> Self {
        ServiceSettings {
            workers: 2,
            queue_depth: 1024,
            max_batch: 8,
            batch_window_us: 200,
            default_tolerance: 0.05,
            factor_cache_bytes: 256 << 20,
        }
    }
}

/// `[kernel]` section: the blocked-GEMM geometry
/// (see [`crate::linalg::gemm::KernelParams`], installed process-wide at
/// service boot so the autotune plane can calibrate the blocking per
/// host). Defaults reproduce the historical constants bit-for-bit.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelSettings {
    /// Packed A block height (rows per block). Keep `[shard].tile_m` a
    /// multiple of this to preserve the shard plane's bitwise equality
    /// with single-threaded execution.
    pub mc: usize,
    /// Shared inner blocking depth of A blocks and B panels. Changes the
    /// summation grouping: different `kc` ⇒ different (equally valid)
    /// result bits.
    pub kc: usize,
    /// Packed B panel width. Keep `[shard].tile_n` a multiple of this.
    pub nc: usize,
    /// `m·n·k` at/below which the naive loop runs (0 = always blocked).
    pub naive_cutover: usize,
}

impl Default for KernelSettings {
    fn default() -> Self {
        let p = crate::linalg::gemm::KernelParams::default();
        KernelSettings {
            mc: p.mc,
            kc: p.kc,
            nc: p.nc,
            naive_cutover: p.naive_cutover,
        }
    }
}

impl KernelSettings {
    /// Range-check the knobs (delegates to the kernel plane's single
    /// validator, [`crate::linalg::gemm::KernelParams::validate`]).
    pub fn validate(&self) -> Result<()> {
        self.params().validate()
    }

    /// The kernel-plane view of these settings.
    pub fn params(&self) -> crate::linalg::gemm::KernelParams {
        crate::linalg::gemm::KernelParams {
            mc: self.mc,
            kc: self.kc,
            nc: self.nc,
            naive_cutover: self.naive_cutover,
        }
    }
}

/// `[shard]` section: the tile-execution plane's knobs
/// (see [`crate::shard::ShardPlan`], built from these settings).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSettings {
    /// Worker threads in the shard pool (intra-GEMM parallelism; the
    /// `[service]` workers handle request-level concurrency).
    pub workers: usize,
    /// Output tile height. Keep a multiple of 128 (the blocked kernel's
    /// MC) to preserve bitwise equality with single-threaded execution.
    pub tile_m: usize,
    /// Output tile width. Keep a multiple of 256 (the blocked kernel's NC).
    pub tile_n: usize,
    /// Requests with `max(m, n)` below this stay single-threaded.
    pub min_parallel_n: usize,
}

impl Default for ShardSettings {
    fn default() -> Self {
        ShardSettings {
            workers: 4,
            tile_m: 256,
            tile_n: 256,
            min_parallel_n: 512,
        }
    }
}

/// `[autotune]` section: the online autotuning plane
/// (see [`crate::autotune`] — measured-latency calibration of the
/// kernel selector). Default-off; when off, kernel selection is
/// bit-identical to the static analytic cost model.
#[derive(Clone, Debug, PartialEq)]
pub struct AutotuneSettings {
    /// Master switch for the calibration loop.
    pub enabled: bool,
    /// EWMA smoothing factor in (0, 1]: weight of the newest
    /// observed/predicted sample.
    pub ewma_alpha: f64,
    /// ε-greedy exploration rate in [0, 1]: fraction of auto-routed
    /// requests served on a non-optimal (but in-tolerance) kernel to
    /// keep its calibration cell fresh.
    pub epsilon: f64,
    /// Prior strength of the analytic model, in samples: a calibration
    /// cell with this many observations is trusted exactly as much as
    /// the analytic prediction.
    pub min_samples: u64,
    /// Calibration persistence path (JSON). Loaded at startup when the
    /// file exists, saved at shutdown; `None` keeps the table in-memory
    /// only.
    pub table_path: Option<String>,
    /// Seed for the exploration RNG (deterministic routing in tests and
    /// replay runs).
    pub explore_seed: u64,
}

impl Default for AutotuneSettings {
    fn default() -> Self {
        AutotuneSettings {
            enabled: false,
            ewma_alpha: 0.2,
            epsilon: 0.05,
            min_samples: 5,
            table_path: None,
            explore_seed: 0x0a70_7e5e,
        }
    }
}

impl AutotuneSettings {
    /// Range-check the knobs. The single validator for every input path
    /// (TOML and CLI flags): out-of-range values must fail loudly, not
    /// be silently clamped downstream.
    pub fn validate(&self) -> Result<()> {
        if !(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            return Err(Error::Config(format!(
                "autotune ewma_alpha must be in (0, 1], got {}",
                self.ewma_alpha
            )));
        }
        if !(0.0..=1.0).contains(&self.epsilon) {
            return Err(Error::Config(format!(
                "autotune epsilon must be in [0, 1], got {}",
                self.epsilon
            )));
        }
        Ok(())
    }
}

/// `[cache]` section: the factor-cache plane
/// (see [`crate::cache`] — content-addressed reuse of SVD/rSVD factors
/// across requests). Default-off; when off, routing and results are
/// bit-identical to a build without the plane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CacheSettings {
    /// Master switch for content-addressed factor caching.
    pub enabled: bool,
    /// Byte budget of the content cache, in MiB.
    pub budget_mb: usize,
    /// Admission gate: operands with `min(rows, cols)` below this are
    /// neither fingerprinted nor cached (their decomposition is cheaper
    /// than the bookkeeping).
    pub min_dim: usize,
    /// Store cached factors FP8-encoded through the existing codecs
    /// (~75% resident-memory saving vs f32 factors). Both the cache fill
    /// and every hit use the same storage, so hit/cold bit-identity is
    /// preserved.
    pub fp8: bool,
    /// Additionally store each factor's `Vᵀ` pre-packed into the kernel
    /// panel layout, so a cache hit's reconstruction product skips the
    /// decode-and-pack entirely (f32 panels: `r·n·4` extra resident
    /// bytes per entry, charged against the budget). Hit ≡ cold stays
    /// bitwise: cold fills use the same panels they just built.
    pub prepack: bool,
    /// Amortized-decomposition term: on a cache miss the cost model
    /// divides the decomposition charge by this expected reuse count
    /// (the decomposition is paid once, the factors serve many
    /// requests). 1 = charge the full cold cost every time.
    pub amortize_over: u64,
}

impl Default for CacheSettings {
    fn default() -> Self {
        CacheSettings {
            enabled: false,
            budget_mb: 256,
            min_dim: 128,
            fp8: false,
            prepack: false,
            amortize_over: 8,
        }
    }
}

impl CacheSettings {
    /// Resolved byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.budget_mb << 20
    }

    /// Range-check the knobs — the single validator for every input path
    /// (TOML, CLI flags, programmatic [`crate::coordinator::ServiceConfig`]).
    pub fn validate(&self) -> Result<()> {
        if self.budget_mb == 0 {
            return Err(Error::Config("cache budget_mb must be positive".into()));
        }
        if self.min_dim == 0 {
            return Err(Error::Config("cache min_dim must be positive".into()));
        }
        if self.amortize_over == 0 {
            return Err(Error::Config(
                "cache amortize_over must be at least 1".into(),
            ));
        }
        Ok(())
    }
}

/// `[trace]` section: the tracing plane
/// (see [`crate::trace_plane`] — request-scoped span trees retained in a
/// flight recorder). Default-off; when off, requests carry no span arena
/// and results are bit-identical to a build without the plane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceSettings {
    /// Master switch for span capture.
    pub enabled: bool,
    /// Flight-recorder ring size: the last N completed request traces.
    pub ring_capacity: usize,
    /// Also retain the K slowest traces ever recorded (they survive ring
    /// eviction, so a latency spike stays inspectable).
    pub slowest_k: usize,
    /// Per-request span arena size; spans past this are dropped and
    /// counted, never blocking the request.
    pub max_spans: usize,
    /// Chrome-trace JSON written at service shutdown (`None` = no export).
    pub export_path: Option<String>,
}

impl Default for TraceSettings {
    fn default() -> Self {
        TraceSettings {
            enabled: false,
            ring_capacity: 64,
            slowest_k: 8,
            max_spans: 256,
            export_path: None,
        }
    }
}

impl TraceSettings {
    /// Range-check the knobs — the single validator for every input path
    /// (TOML, CLI flags, programmatic [`crate::coordinator::ServiceConfig`]).
    pub fn validate(&self) -> Result<()> {
        if self.ring_capacity == 0 {
            return Err(Error::Config("trace ring_capacity must be positive".into()));
        }
        if self.max_spans < 2 {
            return Err(Error::Config(
                "trace max_spans must be at least 2 (root + one stage)".into(),
            ));
        }
        Ok(())
    }
}

/// `[accuracy]` section: the accuracy observability plane
/// (see [`crate::accuracy`] — online error probes, tolerance-SLO
/// tracking, and the calibrated error model). Default-off; when off, no
/// probe work is scheduled and results are bit-identical to a build
/// without the plane.
#[derive(Clone, Debug, PartialEq)]
pub struct AccuracySettings {
    /// Master switch for online error probing.
    pub enabled: bool,
    /// Sampling cadence: probe one in this many completed requests
    /// (deterministic every-Nth, not random — replayable overhead).
    pub sample_every: u64,
    /// Random probe vectors per probed request. The estimator's cost is
    /// O((m·n + m·k + k·n) · probes); its variance shrinks as 1/probes.
    pub probes: usize,
    /// EWMA smoothing factor in (0, 1]: weight of the newest
    /// probed/predicted error ratio.
    pub ewma_alpha: f64,
    /// Prior strength of the analytic error model, in probes: a model
    /// cell with this many observations is trusted exactly as much as the
    /// analytic prediction.
    pub min_samples: u64,
    /// Error-model persistence path (JSON). Loaded at startup when the
    /// file exists, saved at shutdown; `None` keeps the model in-memory
    /// only.
    pub table_path: Option<String>,
    /// Base seed for the probe-vector RNG (combined with the request id,
    /// so every probe is deterministic and replayable).
    pub seed: u64,
}

impl Default for AccuracySettings {
    fn default() -> Self {
        AccuracySettings {
            enabled: false,
            sample_every: 16,
            probes: 8,
            ewma_alpha: 0.2,
            min_samples: 5,
            table_path: None,
            seed: 0x0acc_5eed,
        }
    }
}

impl AccuracySettings {
    /// Range-check the knobs — the single validator for every input path
    /// (TOML, CLI flags, programmatic [`crate::coordinator::ServiceConfig`]).
    pub fn validate(&self) -> Result<()> {
        if self.sample_every == 0 {
            return Err(Error::Config(
                "accuracy sample_every must be at least 1".into(),
            ));
        }
        if self.probes == 0 {
            return Err(Error::Config("accuracy probes must be positive".into()));
        }
        if !(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            return Err(Error::Config(format!(
                "accuracy ewma_alpha must be in (0, 1], got {}",
                self.ewma_alpha
            )));
        }
        Ok(())
    }
}

/// `[scheduler]` section: the unified work-stealing scheduler and
/// admission-control plane (see [`crate::sched`]). Default-off; when off,
/// the service runs the historical two-pool layout (request pool + owned
/// shard pool, FIFO dequeue, depth-only backpressure) bit-identically.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SchedulerSettings {
    /// Master switch for the unified scheduler.
    pub enabled: bool,
    /// Worker threads in the steal pool; 0 = one per available core.
    /// Replaces *both* `[service].workers` and `[shard].workers` when the
    /// plane is enabled.
    pub workers: usize,
    /// Allow idle workers to steal queued tasks from busy siblings.
    /// `false` is the benchmark control arm: same pool, no stealing.
    pub steal: bool,
    /// Admission queue depth (the Interactive watermark; Batch admits to
    /// 3/4 of it, Background to 1/2). 0 = inherit `[service].queue_depth`.
    pub queue_depth: usize,
    /// Per-tenant in-flight request cap; 0 = unlimited. Only identified
    /// tenants ([`crate::coordinator::GemmRequest::with_tenant`]) are
    /// counted.
    pub tenant_quota: usize,
}

impl Default for SchedulerSettings {
    fn default() -> Self {
        SchedulerSettings {
            enabled: false,
            workers: 0,
            steal: true,
            queue_depth: 0,
            tenant_quota: 0,
        }
    }
}

impl SchedulerSettings {
    /// Range-check the knobs — the single validator for every input path
    /// (TOML, CLI flags, programmatic [`crate::coordinator::ServiceConfig`]).
    /// All zero-valued knobs are sentinels (auto / inherit / unlimited),
    /// so there is little to reject; the cap guards against typo'd worker
    /// counts spawning thousands of threads.
    pub fn validate(&self) -> Result<()> {
        if self.workers > 1024 {
            return Err(Error::Config(format!(
                "scheduler workers must be at most 1024 (0 = all cores), got {}",
                self.workers
            )));
        }
        Ok(())
    }
}

/// `[fault.inject]` subsection: the deterministic fault-injection plan
/// (see [`crate::fault::FaultInjector`]). All probabilities default to
/// 0.0, so an enabled fault plane with an empty plan injects nothing —
/// containment without chaos.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultInjectSettings {
    /// Draw seed: every injection decision is a pure hash of
    /// (seed, site, ids), so the same seed replays the same faults.
    pub seed: u64,
    /// Probability a shard tile job panics (contained at the tile
    /// boundary; the request resolves as `Error::KernelPanicked`).
    pub panic_tile: f64,
    /// Probability a shard tile stalls `stall_ms` before computing.
    pub stall_tile: f64,
    /// Stall duration in milliseconds.
    pub stall_ms: u64,
    /// Probability a request's kernel execution panics at the dispatch
    /// boundary (contained; retried on the fallback kernel).
    pub panic_request: f64,
    /// Probability a request fails with a typed kernel error.
    pub error_request: f64,
    /// Restrict error injection to one kernel id ("" = any kernel).
    pub error_kernel: String,
    /// Deterministic test knob: request ids below this always take the
    /// injected error (on the matching kernel). 0 = off.
    pub error_requests_under: u64,
    /// Probability a GEMM's FP8 decode output is corrupted.
    pub corrupt_decode: f64,
    /// Probability a cluster connect attempt is refused (synthesized
    /// ConnectionRefused before dialing — exercises retry/failover).
    pub net_refuse: f64,
    /// Probability a node stalls `net_stall_ms` before replying (long
    /// stalls become client read timeouts).
    pub net_stall: f64,
    /// Injected reply-stall duration in milliseconds.
    pub net_stall_ms: u64,
    /// Probability a node truncates its reply mid-frame and drops the
    /// connection (exercises the client's short-read handling).
    pub net_truncate: f64,
    /// Probability a node silently skips a heartbeat (exercises the
    /// Alive → Suspect → Dead health transitions).
    pub net_heartbeat_drop: f64,
}

impl Default for FaultInjectSettings {
    fn default() -> Self {
        FaultInjectSettings {
            seed: 0,
            panic_tile: 0.0,
            stall_tile: 0.0,
            stall_ms: 1,
            panic_request: 0.0,
            error_request: 0.0,
            error_kernel: String::new(),
            error_requests_under: 0,
            corrupt_decode: 0.0,
            net_refuse: 0.0,
            net_stall: 0.0,
            net_stall_ms: 1,
            net_truncate: 0.0,
            net_heartbeat_drop: 0.0,
        }
    }
}

impl FaultInjectSettings {
    /// Apply a compact `key=value,key=value` spec (the `--fault-inject`
    /// CLI syntax, e.g. `seed=42,panic_tile=0.08,error_request=0.1`)
    /// over the current values.
    pub fn apply_spec(&mut self, spec: &str) -> Result<()> {
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (key, val) = part.split_once('=').ok_or_else(|| {
                Error::Config(format!("--fault-inject: `{part}` is not key=value"))
            })?;
            let (key, val) = (key.trim(), val.trim());
            let bad = |_| Error::Config(format!("--fault-inject: {key}: bad value `{val}`"));
            match key {
                "seed" => self.seed = val.parse().map_err(bad)?,
                "panic_tile" => self.panic_tile = val.parse().map_err(bad)?,
                "stall_tile" => self.stall_tile = val.parse().map_err(bad)?,
                "stall_ms" => self.stall_ms = val.parse().map_err(bad)?,
                "panic_request" => self.panic_request = val.parse().map_err(bad)?,
                "error_request" => self.error_request = val.parse().map_err(bad)?,
                "error_kernel" => self.error_kernel = val.to_string(),
                "error_requests_under" => {
                    self.error_requests_under = val.parse().map_err(bad)?
                }
                "corrupt_decode" => self.corrupt_decode = val.parse().map_err(bad)?,
                "net_refuse" => self.net_refuse = val.parse().map_err(bad)?,
                "net_stall" => self.net_stall = val.parse().map_err(bad)?,
                "net_stall_ms" => self.net_stall_ms = val.parse().map_err(bad)?,
                "net_truncate" => self.net_truncate = val.parse().map_err(bad)?,
                "net_heartbeat_drop" => self.net_heartbeat_drop = val.parse().map_err(bad)?,
                other => {
                    return Err(Error::Config(format!(
                        "--fault-inject: unknown key `{other}`"
                    )))
                }
            }
        }
        Ok(())
    }
}

/// `[fault]` section: the fault-containment & graceful-degradation plane
/// (see [`crate::fault`]). Default-off; when off, no containment wrapping
/// or breaker consults happen and routing, results and metric names are
/// bit-identical to a build without the plane.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSettings {
    /// Master switch for containment, breaker routing and injection.
    pub enabled: bool,
    /// Old strict behavior: a corrupt persistence table fails start
    /// instead of being quarantined to `<path>.corrupt-<n>`.
    pub strict_boot: bool,
    /// Rolling outcome window per kernel breaker cell.
    pub breaker_window: usize,
    /// Failures within the window that trip a cell open.
    pub breaker_threshold: usize,
    /// Denials an open cell accumulates before admitting one half-open
    /// probe (denial-counted, not wall-clock, for deterministic tests).
    pub breaker_cooldown: usize,
    /// Retry a failed/panicked request once on its fallback kernel.
    pub retry: bool,
    /// `[fault.inject]` plan.
    pub inject: FaultInjectSettings,
}

impl Default for FaultSettings {
    fn default() -> Self {
        FaultSettings {
            enabled: false,
            strict_boot: false,
            breaker_window: 16,
            breaker_threshold: 8,
            breaker_cooldown: 32,
            retry: true,
            inject: FaultInjectSettings::default(),
        }
    }
}

impl FaultSettings {
    /// Range-check the knobs — the single validator for every input path
    /// (TOML, CLI flags, programmatic [`crate::coordinator::ServiceConfig`]).
    pub fn validate(&self) -> Result<()> {
        if self.breaker_window == 0 {
            return Err(Error::Config("fault breaker_window must be positive".into()));
        }
        if self.breaker_threshold == 0 || self.breaker_threshold > self.breaker_window {
            return Err(Error::Config(format!(
                "fault breaker_threshold must be in [1, breaker_window={}], got {}",
                self.breaker_window, self.breaker_threshold
            )));
        }
        if self.breaker_cooldown == 0 {
            return Err(Error::Config(
                "fault breaker_cooldown must be positive".into(),
            ));
        }
        let inj = &self.inject;
        for (name, p) in [
            ("panic_tile", inj.panic_tile),
            ("stall_tile", inj.stall_tile),
            ("panic_request", inj.panic_request),
            ("error_request", inj.error_request),
            ("corrupt_decode", inj.corrupt_decode),
            ("net_refuse", inj.net_refuse),
            ("net_stall", inj.net_stall),
            ("net_truncate", inj.net_truncate),
            ("net_heartbeat_drop", inj.net_heartbeat_drop),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::Config(format!(
                    "fault.inject {name} must be in [0, 1], got {p}"
                )));
            }
        }
        if !inj.error_kernel.is_empty()
            && crate::kernels::KernelKind::parse(&inj.error_kernel).is_none()
        {
            return Err(Error::Config(format!(
                "fault.inject error_kernel: unknown kernel `{}`",
                inj.error_kernel
            )));
        }
        Ok(())
    }
}

/// `[cluster]` section: the multi-node serving tier (see
/// [`crate::cluster`] — router, node registry, heartbeats, failover and
/// fingerprint-affinity routing). Default-off; when off, no socket is
/// opened and single-process behavior, results and metric names are
/// bit-identical to a build without the tier.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterSettings {
    /// Master switch for the cluster tier.
    pub enabled: bool,
    /// Router address: where `cluster-router` binds and where nodes and
    /// clients connect.
    pub router_addr: String,
    /// Node agent's serving address (bind + advertise). Port 0 binds an
    /// ephemeral port and advertises the resolved one.
    pub node_addr: String,
    /// Heartbeat cadence, milliseconds.
    pub heartbeat_ms: u64,
    /// Heartbeat silence before a node transitions Alive → Suspect
    /// (Suspect nodes are deprioritized but still routable).
    pub heartbeat_timeout_ms: u64,
    /// Heartbeat silence before Suspect → Dead (Dead nodes are removed
    /// and their affinity entries evicted; fingerprints re-home).
    pub dead_after_ms: u64,
    /// Per-attempt TCP connect deadline, milliseconds.
    pub connect_timeout_ms: u64,
    /// Per-attempt read deadline, milliseconds (covers the node's whole
    /// GEMM execution, not just socket latency).
    pub read_timeout_ms: u64,
    /// Total RPC attempts across candidate nodes before the request
    /// fails with a typed `NodeUnavailable` / `RpcTimeout`.
    pub max_attempts: usize,
    /// Decorrelated-jitter backoff base, milliseconds.
    pub backoff_base_ms: u64,
    /// Backoff ceiling, milliseconds.
    pub backoff_cap_ms: u64,
    /// Per-node concurrent cold-fill cap: at most this many in-flight
    /// requests whose fingerprint the node does not yet hold are routed
    /// to it at once (bounds the re-fill storm after a node loss).
    pub fill_cap: usize,
    /// Fingerprint gate on `min(rows, cols)`: smaller right-hand
    /// operands route least-loaded instead of by affinity.
    pub affinity_min_dim: usize,
    /// Seed for the backoff jitter (deterministic retry schedules in
    /// tests and chaos runs).
    pub seed: u64,
}

impl Default for ClusterSettings {
    fn default() -> Self {
        ClusterSettings {
            enabled: false,
            router_addr: "127.0.0.1:7070".into(),
            node_addr: "127.0.0.1:0".into(),
            heartbeat_ms: 500,
            heartbeat_timeout_ms: 2000,
            dead_after_ms: 5000,
            connect_timeout_ms: 250,
            read_timeout_ms: 2000,
            max_attempts: 3,
            backoff_base_ms: 10,
            backoff_cap_ms: 500,
            fill_cap: 2,
            affinity_min_dim: 128,
            seed: 0xc105,
        }
    }
}

impl ClusterSettings {
    /// Range-check the knobs — the single validator for every input path
    /// (TOML, CLI flags, programmatic construction).
    pub fn validate(&self) -> Result<()> {
        if self.router_addr.is_empty() {
            return Err(Error::Config("cluster router_addr must be set".into()));
        }
        if self.node_addr.is_empty() {
            return Err(Error::Config("cluster node_addr must be set".into()));
        }
        for (name, v) in [
            ("heartbeat_ms", self.heartbeat_ms),
            ("connect_timeout_ms", self.connect_timeout_ms),
            ("read_timeout_ms", self.read_timeout_ms),
            ("backoff_base_ms", self.backoff_base_ms),
        ] {
            if v == 0 {
                return Err(Error::Config(format!("cluster {name} must be positive")));
            }
        }
        if self.heartbeat_timeout_ms < self.heartbeat_ms {
            return Err(Error::Config(format!(
                "cluster heartbeat_timeout_ms must be at least heartbeat_ms={}, got {}",
                self.heartbeat_ms, self.heartbeat_timeout_ms
            )));
        }
        if self.dead_after_ms < self.heartbeat_timeout_ms {
            return Err(Error::Config(format!(
                "cluster dead_after_ms must be at least heartbeat_timeout_ms={}, got {}",
                self.heartbeat_timeout_ms, self.dead_after_ms
            )));
        }
        if self.max_attempts == 0 {
            return Err(Error::Config("cluster max_attempts must be at least 1".into()));
        }
        if self.backoff_cap_ms < self.backoff_base_ms {
            return Err(Error::Config(format!(
                "cluster backoff_cap_ms must be at least backoff_base_ms={}, got {}",
                self.backoff_base_ms, self.backoff_cap_ms
            )));
        }
        if self.fill_cap == 0 {
            return Err(Error::Config("cluster fill_cap must be at least 1".into()));
        }
        if self.affinity_min_dim == 0 {
            return Err(Error::Config(
                "cluster affinity_min_dim must be positive".into(),
            ));
        }
        Ok(())
    }
}

/// Whole-app configuration.
#[derive(Clone, Debug)]
pub struct AppConfig {
    /// Device profile name for the cost model ("rtx4090", "h200", …).
    pub device: String,
    /// Directory containing AOT artifacts + manifest.
    pub artifacts_dir: String,
    /// Prefer XLA-compiled artifacts over the native CPU substrate when a
    /// matching artifact exists.
    pub use_xla: bool,
    /// Low-rank defaults.
    pub rank_strategy: RankStrategy,
    /// Decomposition method.
    pub decomp: DecompMethod,
    /// Factor storage precision.
    pub storage: StorageFormat,
    /// `[service]` knobs.
    pub service: ServiceSettings,
    /// `[kernel]` knobs.
    pub kernel: KernelSettings,
    /// `[shard]` knobs.
    pub shard: ShardSettings,
    /// `[autotune]` knobs.
    pub autotune: AutotuneSettings,
    /// `[cache]` knobs.
    pub cache: CacheSettings,
    /// `[trace]` knobs.
    pub trace: TraceSettings,
    /// `[accuracy]` knobs.
    pub accuracy: AccuracySettings,
    /// `[scheduler]` knobs.
    pub scheduler: SchedulerSettings,
    /// `[fault]` knobs.
    pub fault: FaultSettings,
    /// `[cluster]` knobs.
    pub cluster: ClusterSettings,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            device: "rtx4090".into(),
            artifacts_dir: "artifacts".into(),
            use_xla: true,
            rank_strategy: RankStrategy::EnergyFraction(0.99),
            decomp: DecompMethod::RandomizedSvd,
            storage: StorageFormat::Fp8(crate::fp8::Fp8Format::E4M3),
            service: ServiceSettings::default(),
            kernel: KernelSettings::default(),
            shard: ShardSettings::default(),
            autotune: AutotuneSettings::default(),
            cache: CacheSettings::default(),
            trace: TraceSettings::default(),
            accuracy: AccuracySettings::default(),
            scheduler: SchedulerSettings::default(),
            fault: FaultSettings::default(),
            cluster: ClusterSettings::default(),
        }
    }
}

impl AppConfig {
    /// Parse from TOML text; unset keys keep defaults.
    pub fn from_toml(text: &str) -> Result<AppConfig> {
        let doc = parse_toml(text)?;
        Self::from_doc(&doc)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<AppConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    fn from_doc(doc: &TomlDoc) -> Result<AppConfig> {
        let mut cfg = AppConfig::default();
        if let Some(top) = doc.get("") {
            if let Some(v) = top.get("device") {
                cfg.device = req_str(v, "device")?;
            }
            if let Some(v) = top.get("artifacts_dir") {
                cfg.artifacts_dir = req_str(v, "artifacts_dir")?;
            }
            if let Some(v) = top.get("use_xla") {
                cfg.use_xla = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("use_xla must be bool".into()))?;
            }
        }
        if let Some(lr) = doc.get("lowrank") {
            if let Some(v) = lr.get("decomp") {
                let s = req_str(v, "lowrank.decomp")?;
                cfg.decomp = DecompMethod::parse(&s)
                    .ok_or_else(|| Error::Config(format!("unknown decomp `{s}`")))?;
            }
            if let Some(v) = lr.get("storage") {
                let s = req_str(v, "lowrank.storage")?;
                cfg.storage = StorageFormat::parse(&s)
                    .ok_or_else(|| Error::Config(format!("unknown storage `{s}`")))?;
            }
            cfg.rank_strategy = parse_rank_strategy(lr)?;
        }
        if let Some(svc) = doc.get("service") {
            let s = &mut cfg.service;
            if let Some(v) = svc.get("workers") {
                s.workers = req_usize(v, "service.workers")?;
            }
            if let Some(v) = svc.get("queue_depth") {
                s.queue_depth = req_usize(v, "service.queue_depth")?;
            }
            if let Some(v) = svc.get("max_batch") {
                s.max_batch = req_usize(v, "service.max_batch")?;
            }
            if let Some(v) = svc.get("batch_window_us") {
                s.batch_window_us = req_usize(v, "service.batch_window_us")? as u64;
            }
            if let Some(v) = svc.get("default_tolerance") {
                s.default_tolerance = v
                    .as_float()
                    .ok_or_else(|| Error::Config("default_tolerance must be float".into()))?
                    as f32;
            }
            if let Some(v) = svc.get("factor_cache_mb") {
                s.factor_cache_bytes = req_usize(v, "service.factor_cache_mb")? << 20;
            }
        }
        if let Some(ke) = doc.get("kernel") {
            let s = &mut cfg.kernel;
            if let Some(v) = ke.get("mc") {
                s.mc = req_nonzero(v, "kernel.mc")?;
            }
            if let Some(v) = ke.get("kc") {
                s.kc = req_nonzero(v, "kernel.kc")?;
            }
            if let Some(v) = ke.get("nc") {
                s.nc = req_nonzero(v, "kernel.nc")?;
            }
            if let Some(v) = ke.get("naive_cutover") {
                s.naive_cutover = req_usize(v, "kernel.naive_cutover")?;
            }
            s.validate()?;
        }
        if let Some(sh) = doc.get("shard") {
            let s = &mut cfg.shard;
            if let Some(v) = sh.get("workers") {
                s.workers = req_usize(v, "shard.workers")?;
            }
            if let Some(v) = sh.get("tile_m") {
                s.tile_m = req_nonzero(v, "shard.tile_m")?;
            }
            if let Some(v) = sh.get("tile_n") {
                s.tile_n = req_nonzero(v, "shard.tile_n")?;
            }
            if let Some(v) = sh.get("min_parallel_n") {
                s.min_parallel_n = req_usize(v, "shard.min_parallel_n")?;
            }
        }
        if let Some(at) = doc.get("autotune") {
            let s = &mut cfg.autotune;
            if let Some(v) = at.get("enabled") {
                s.enabled = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("autotune.enabled must be bool".into()))?;
            }
            if let Some(v) = at.get("ewma_alpha") {
                s.ewma_alpha = v.as_float().ok_or_else(|| {
                    Error::Config("autotune.ewma_alpha must be a number".into())
                })?;
            }
            if let Some(v) = at.get("epsilon") {
                s.epsilon = v
                    .as_float()
                    .ok_or_else(|| Error::Config("autotune.epsilon must be a number".into()))?;
            }
            if let Some(v) = at.get("min_samples") {
                s.min_samples = req_usize(v, "autotune.min_samples")? as u64;
            }
            if let Some(v) = at.get("table_path") {
                let p = req_str(v, "autotune.table_path")?;
                s.table_path = if p.is_empty() { None } else { Some(p) };
            }
            if let Some(v) = at.get("explore_seed") {
                s.explore_seed = req_usize(v, "autotune.explore_seed")? as u64;
            }
            s.validate()?;
        }
        if let Some(ca) = doc.get("cache") {
            let s = &mut cfg.cache;
            if let Some(v) = ca.get("enabled") {
                s.enabled = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("cache.enabled must be bool".into()))?;
            }
            if let Some(v) = ca.get("budget_mb") {
                s.budget_mb = req_nonzero(v, "cache.budget_mb")?;
            }
            if let Some(v) = ca.get("min_dim") {
                s.min_dim = req_nonzero(v, "cache.min_dim")?;
            }
            if let Some(v) = ca.get("fp8") {
                s.fp8 = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("cache.fp8 must be bool".into()))?;
            }
            if let Some(v) = ca.get("prepack") {
                s.prepack = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("cache.prepack must be bool".into()))?;
            }
            if let Some(v) = ca.get("amortize_over") {
                s.amortize_over = req_nonzero(v, "cache.amortize_over")? as u64;
            }
            s.validate()?;
        }
        if let Some(tr) = doc.get("trace") {
            let s = &mut cfg.trace;
            if let Some(v) = tr.get("enabled") {
                s.enabled = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("trace.enabled must be bool".into()))?;
            }
            if let Some(v) = tr.get("ring_capacity") {
                s.ring_capacity = req_nonzero(v, "trace.ring_capacity")?;
            }
            if let Some(v) = tr.get("slowest_k") {
                s.slowest_k = req_usize(v, "trace.slowest_k")?;
            }
            if let Some(v) = tr.get("max_spans") {
                s.max_spans = req_nonzero(v, "trace.max_spans")?;
            }
            if let Some(v) = tr.get("export_path") {
                let p = req_str(v, "trace.export_path")?;
                s.export_path = if p.is_empty() { None } else { Some(p) };
            }
            s.validate()?;
        }
        if let Some(ac) = doc.get("accuracy") {
            let s = &mut cfg.accuracy;
            if let Some(v) = ac.get("enabled") {
                s.enabled = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("accuracy.enabled must be bool".into()))?;
            }
            if let Some(v) = ac.get("sample_every") {
                s.sample_every = req_nonzero(v, "accuracy.sample_every")? as u64;
            }
            if let Some(v) = ac.get("probes") {
                s.probes = req_nonzero(v, "accuracy.probes")?;
            }
            if let Some(v) = ac.get("ewma_alpha") {
                s.ewma_alpha = v.as_float().ok_or_else(|| {
                    Error::Config("accuracy.ewma_alpha must be a number".into())
                })?;
            }
            if let Some(v) = ac.get("min_samples") {
                s.min_samples = req_usize(v, "accuracy.min_samples")? as u64;
            }
            if let Some(v) = ac.get("table_path") {
                let p = req_str(v, "accuracy.table_path")?;
                s.table_path = if p.is_empty() { None } else { Some(p) };
            }
            if let Some(v) = ac.get("seed") {
                s.seed = req_usize(v, "accuracy.seed")? as u64;
            }
            s.validate()?;
        }
        if let Some(sc) = doc.get("scheduler") {
            let s = &mut cfg.scheduler;
            if let Some(v) = sc.get("enabled") {
                s.enabled = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("scheduler.enabled must be bool".into()))?;
            }
            if let Some(v) = sc.get("workers") {
                s.workers = req_usize(v, "scheduler.workers")?;
            }
            if let Some(v) = sc.get("steal") {
                s.steal = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("scheduler.steal must be bool".into()))?;
            }
            if let Some(v) = sc.get("queue_depth") {
                s.queue_depth = req_usize(v, "scheduler.queue_depth")?;
            }
            if let Some(v) = sc.get("tenant_quota") {
                s.tenant_quota = req_usize(v, "scheduler.tenant_quota")?;
            }
            s.validate()?;
        }
        if let Some(fa) = doc.get("fault") {
            let s = &mut cfg.fault;
            if let Some(v) = fa.get("enabled") {
                s.enabled = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("fault.enabled must be bool".into()))?;
            }
            if let Some(v) = fa.get("strict_boot") {
                s.strict_boot = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("fault.strict_boot must be bool".into()))?;
            }
            if let Some(v) = fa.get("breaker_window") {
                s.breaker_window = req_nonzero(v, "fault.breaker_window")?;
            }
            if let Some(v) = fa.get("breaker_threshold") {
                s.breaker_threshold = req_nonzero(v, "fault.breaker_threshold")?;
            }
            if let Some(v) = fa.get("breaker_cooldown") {
                s.breaker_cooldown = req_nonzero(v, "fault.breaker_cooldown")?;
            }
            if let Some(v) = fa.get("retry") {
                s.retry = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("fault.retry must be bool".into()))?;
            }
        }
        if let Some(fi) = doc.get("fault.inject") {
            let s = &mut cfg.fault.inject;
            if let Some(v) = fi.get("seed") {
                s.seed = req_usize(v, "fault.inject.seed")? as u64;
            }
            if let Some(v) = fi.get("stall_ms") {
                s.stall_ms = req_usize(v, "fault.inject.stall_ms")? as u64;
            }
            if let Some(v) = fi.get("error_requests_under") {
                s.error_requests_under = req_usize(v, "fault.inject.error_requests_under")? as u64;
            }
            if let Some(v) = fi.get("error_kernel") {
                s.error_kernel = req_str(v, "fault.inject.error_kernel")?;
            }
            if let Some(v) = fi.get("panic_tile") {
                s.panic_tile = req_f64(v, "fault.inject.panic_tile")?;
            }
            if let Some(v) = fi.get("stall_tile") {
                s.stall_tile = req_f64(v, "fault.inject.stall_tile")?;
            }
            if let Some(v) = fi.get("panic_request") {
                s.panic_request = req_f64(v, "fault.inject.panic_request")?;
            }
            if let Some(v) = fi.get("error_request") {
                s.error_request = req_f64(v, "fault.inject.error_request")?;
            }
            if let Some(v) = fi.get("corrupt_decode") {
                s.corrupt_decode = req_f64(v, "fault.inject.corrupt_decode")?;
            }
            if let Some(v) = fi.get("net_refuse") {
                s.net_refuse = req_f64(v, "fault.inject.net_refuse")?;
            }
            if let Some(v) = fi.get("net_stall") {
                s.net_stall = req_f64(v, "fault.inject.net_stall")?;
            }
            if let Some(v) = fi.get("net_stall_ms") {
                s.net_stall_ms = req_usize(v, "fault.inject.net_stall_ms")? as u64;
            }
            if let Some(v) = fi.get("net_truncate") {
                s.net_truncate = req_f64(v, "fault.inject.net_truncate")?;
            }
            if let Some(v) = fi.get("net_heartbeat_drop") {
                s.net_heartbeat_drop = req_f64(v, "fault.inject.net_heartbeat_drop")?;
            }
        }
        if doc.get("fault").is_some() || doc.get("fault.inject").is_some() {
            cfg.fault.validate()?;
        }
        if let Some(cl) = doc.get("cluster") {
            let s = &mut cfg.cluster;
            if let Some(v) = cl.get("enabled") {
                s.enabled = v
                    .as_bool()
                    .ok_or_else(|| Error::Config("cluster.enabled must be bool".into()))?;
            }
            if let Some(v) = cl.get("router_addr") {
                s.router_addr = req_str(v, "cluster.router_addr")?;
            }
            if let Some(v) = cl.get("node_addr") {
                s.node_addr = req_str(v, "cluster.node_addr")?;
            }
            if let Some(v) = cl.get("heartbeat_ms") {
                s.heartbeat_ms = req_nonzero(v, "cluster.heartbeat_ms")? as u64;
            }
            if let Some(v) = cl.get("heartbeat_timeout_ms") {
                s.heartbeat_timeout_ms = req_nonzero(v, "cluster.heartbeat_timeout_ms")? as u64;
            }
            if let Some(v) = cl.get("dead_after_ms") {
                s.dead_after_ms = req_nonzero(v, "cluster.dead_after_ms")? as u64;
            }
            if let Some(v) = cl.get("connect_timeout_ms") {
                s.connect_timeout_ms = req_nonzero(v, "cluster.connect_timeout_ms")? as u64;
            }
            if let Some(v) = cl.get("read_timeout_ms") {
                s.read_timeout_ms = req_nonzero(v, "cluster.read_timeout_ms")? as u64;
            }
            if let Some(v) = cl.get("max_attempts") {
                s.max_attempts = req_nonzero(v, "cluster.max_attempts")?;
            }
            if let Some(v) = cl.get("backoff_base_ms") {
                s.backoff_base_ms = req_nonzero(v, "cluster.backoff_base_ms")? as u64;
            }
            if let Some(v) = cl.get("backoff_cap_ms") {
                s.backoff_cap_ms = req_nonzero(v, "cluster.backoff_cap_ms")? as u64;
            }
            if let Some(v) = cl.get("fill_cap") {
                s.fill_cap = req_nonzero(v, "cluster.fill_cap")?;
            }
            if let Some(v) = cl.get("affinity_min_dim") {
                s.affinity_min_dim = req_nonzero(v, "cluster.affinity_min_dim")?;
            }
            if let Some(v) = cl.get("seed") {
                s.seed = req_usize(v, "cluster.seed")? as u64;
            }
            s.validate()?;
        }
        Ok(cfg)
    }
}

fn parse_rank_strategy(
    section: &std::collections::BTreeMap<String, crate::config::toml::TomlValue>,
) -> Result<RankStrategy> {
    let name = match section.get("rank_strategy") {
        Some(v) => req_str(v, "lowrank.rank_strategy")?,
        None => return Ok(AppConfig::default().rank_strategy),
    };
    Ok(match name.as_str() {
        "fixed" => RankStrategy::Fixed(match section.get("rank") {
            Some(v) => req_usize(v, "lowrank.rank")?,
            None => 64,
        }),
        "fixed_fraction" => RankStrategy::FixedFraction(get_f32(section, "alpha", 0.025)?),
        "energy" => RankStrategy::EnergyFraction(get_f32(section, "tau", 0.99)?),
        "error_bound" => RankStrategy::ErrorBound(get_f32(section, "epsilon", 0.02)?),
        "hardware_aware" => RankStrategy::HardwareAware {
            memory_fraction: get_f32(section, "memory_fraction", 0.15)?,
            granule: match section.get("granule") {
                Some(v) => req_usize(v, "lowrank.granule")?,
                None => 16,
            },
        },
        other => return Err(Error::Config(format!("unknown rank_strategy `{other}`"))),
    })
}

fn get_f32(
    section: &std::collections::BTreeMap<String, crate::config::toml::TomlValue>,
    key: &str,
    default: f32,
) -> Result<f32> {
    match section.get(key) {
        Some(v) => Ok(v
            .as_float()
            .ok_or_else(|| Error::Config(format!("{key} must be a number")))?
            as f32),
        None => Ok(default),
    }
}

fn req_str(v: &crate::config::toml::TomlValue, key: &str) -> Result<String> {
    v.as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| Error::Config(format!("{key} must be a string")))
}

fn req_usize(v: &crate::config::toml::TomlValue, key: &str) -> Result<usize> {
    let i = v
        .as_int()
        .ok_or_else(|| Error::Config(format!("{key} must be an integer")))?;
    if i < 0 {
        return Err(Error::Config(format!("{key} must be non-negative")));
    }
    Ok(i as usize)
}

fn req_f64(v: &crate::config::toml::TomlValue, key: &str) -> Result<f64> {
    v.as_float()
        .ok_or_else(|| Error::Config(format!("{key} must be a number")))
}

fn req_nonzero(v: &crate::config::toml::TomlValue, key: &str) -> Result<usize> {
    let u = req_usize(v, key)?;
    if u == 0 {
        return Err(Error::Config(format!("{key} must be positive")));
    }
    Ok(u)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_empty() {
        let cfg = AppConfig::from_toml("").unwrap();
        assert_eq!(cfg.device, "rtx4090");
        assert_eq!(cfg.service.workers, 2);
    }

    #[test]
    fn full_document() {
        let cfg = AppConfig::from_toml(
            r#"
device = "h200"
artifacts_dir = "art"
use_xla = false

[lowrank]
decomp = "lanczos"
storage = "fp8_e5m2"
rank_strategy = "energy"
tau = 0.999

[service]
workers = 8
queue_depth = 64
max_batch = 4
batch_window_us = 500
default_tolerance = 0.01
factor_cache_mb = 128

[shard]
workers = 6
tile_m = 128
tile_n = 512
min_parallel_n = 1024
"#,
        )
        .unwrap();
        assert_eq!(cfg.device, "h200");
        assert!(!cfg.use_xla);
        assert_eq!(cfg.decomp, DecompMethod::Lanczos);
        assert_eq!(cfg.storage.name(), "fp8_e5m2");
        assert_eq!(cfg.rank_strategy, RankStrategy::EnergyFraction(0.999));
        assert_eq!(cfg.service.workers, 8);
        assert_eq!(cfg.service.factor_cache_bytes, 128 << 20);
        assert_eq!(
            cfg.shard,
            ShardSettings {
                workers: 6,
                tile_m: 128,
                tile_n: 512,
                min_parallel_n: 1024
            }
        );
    }

    #[test]
    fn shard_defaults_and_validation() {
        let cfg = AppConfig::from_toml("").unwrap();
        assert_eq!(cfg.shard, ShardSettings::default());
        let cfg = AppConfig::from_toml("[shard]\nworkers = 1").unwrap();
        assert_eq!(cfg.shard.workers, 1);
        assert_eq!(cfg.shard.tile_m, 256);
        assert!(AppConfig::from_toml("[shard]\ntile_m = 0").is_err());
        assert!(AppConfig::from_toml("[shard]\ntile_n = 0").is_err());
        assert!(AppConfig::from_toml("[shard]\nworkers = -2").is_err());
    }

    #[test]
    fn autotune_defaults_and_full_section() {
        let cfg = AppConfig::from_toml("").unwrap();
        assert_eq!(cfg.autotune, AutotuneSettings::default());
        assert!(!cfg.autotune.enabled, "autotune must default off");

        let cfg = AppConfig::from_toml(
            r#"
[autotune]
enabled = true
ewma_alpha = 0.5
epsilon = 0.1
min_samples = 12
table_path = "cal.json"
explore_seed = 99
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.autotune,
            AutotuneSettings {
                enabled: true,
                ewma_alpha: 0.5,
                epsilon: 0.1,
                min_samples: 12,
                table_path: Some("cal.json".into()),
                explore_seed: 99,
            }
        );
    }

    #[test]
    fn autotune_validation() {
        // Empty path means "no persistence", not a path named "".
        let cfg = AppConfig::from_toml("[autotune]\ntable_path = \"\"").unwrap();
        assert_eq!(cfg.autotune.table_path, None);
        assert!(AppConfig::from_toml("[autotune]\newma_alpha = 0.0").is_err());
        assert!(AppConfig::from_toml("[autotune]\newma_alpha = 1.5").is_err());
        assert!(AppConfig::from_toml("[autotune]\nepsilon = -0.1").is_err());
        assert!(AppConfig::from_toml("[autotune]\nepsilon = 1.1").is_err());
        assert!(AppConfig::from_toml("[autotune]\nenabled = 1").is_err());
        // Integer alpha/epsilon inside range parse via as_float.
        let cfg = AppConfig::from_toml("[autotune]\newma_alpha = 1\nepsilon = 0").unwrap();
        assert_eq!(cfg.autotune.ewma_alpha, 1.0);
        assert_eq!(cfg.autotune.epsilon, 0.0);
    }

    #[test]
    fn cache_defaults_and_full_section() {
        let cfg = AppConfig::from_toml("").unwrap();
        assert_eq!(cfg.cache, CacheSettings::default());
        assert!(!cfg.cache.enabled, "factor cache must default off");
        assert_eq!(cfg.cache.budget_bytes(), 256 << 20);

        let cfg = AppConfig::from_toml(
            r#"
[cache]
enabled = true
budget_mb = 64
min_dim = 256
fp8 = true
prepack = true
amortize_over = 16
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.cache,
            CacheSettings {
                enabled: true,
                budget_mb: 64,
                min_dim: 256,
                fp8: true,
                prepack: true,
                amortize_over: 16,
            }
        );
    }

    #[test]
    fn kernel_defaults_full_section_and_validation() {
        let cfg = AppConfig::from_toml("").unwrap();
        assert_eq!(cfg.kernel, KernelSettings::default());
        assert_eq!(
            cfg.kernel.params(),
            crate::linalg::gemm::KernelParams::default(),
            "defaults must reproduce the built-in kernel geometry"
        );

        let cfg = AppConfig::from_toml(
            r#"
[kernel]
mc = 64
kc = 128
nc = 512
naive_cutover = 0
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.kernel,
            KernelSettings {
                mc: 64,
                kc: 128,
                nc: 512,
                naive_cutover: 0,
            }
        );
        assert!(AppConfig::from_toml("[kernel]\nmc = 0").is_err());
        assert!(AppConfig::from_toml("[kernel]\nkc = 0").is_err());
        assert!(AppConfig::from_toml("[kernel]\nnc = 0").is_err());
        assert!(AppConfig::from_toml("[kernel]\nnaive_cutover = -1").is_err());
    }

    #[test]
    fn cache_validation() {
        assert!(AppConfig::from_toml("[cache]\nbudget_mb = 0").is_err());
        assert!(AppConfig::from_toml("[cache]\nmin_dim = 0").is_err());
        assert!(AppConfig::from_toml("[cache]\namortize_over = 0").is_err());
        assert!(AppConfig::from_toml("[cache]\nenabled = 1").is_err());
        assert!(AppConfig::from_toml("[cache]\nfp8 = \"yes\"").is_err());
        assert!(AppConfig::from_toml("[cache]\nprepack = 1").is_err());
    }

    #[test]
    fn trace_defaults_and_full_section() {
        let cfg = AppConfig::from_toml("").unwrap();
        assert_eq!(cfg.trace, TraceSettings::default());
        assert!(!cfg.trace.enabled, "tracing must default off");

        let cfg = AppConfig::from_toml(
            r#"
[trace]
enabled = true
ring_capacity = 16
slowest_k = 4
max_spans = 64
export_path = "trace.json"
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.trace,
            TraceSettings {
                enabled: true,
                ring_capacity: 16,
                slowest_k: 4,
                max_spans: 64,
                export_path: Some("trace.json".into()),
            }
        );
    }

    #[test]
    fn trace_validation() {
        // Empty path means "no export", not a file named "".
        let cfg = AppConfig::from_toml("[trace]\nexport_path = \"\"").unwrap();
        assert_eq!(cfg.trace.export_path, None);
        assert!(AppConfig::from_toml("[trace]\nring_capacity = 0").is_err());
        assert!(AppConfig::from_toml("[trace]\nmax_spans = 1").is_err());
        assert!(AppConfig::from_toml("[trace]\nmax_spans = 0").is_err());
        assert!(AppConfig::from_toml("[trace]\nenabled = 1").is_err());
        // slowest_k = 0 is legal: ring only, no slow-path retention.
        let cfg = AppConfig::from_toml("[trace]\nslowest_k = 0").unwrap();
        assert_eq!(cfg.trace.slowest_k, 0);
    }

    #[test]
    fn accuracy_defaults_and_full_section() {
        let cfg = AppConfig::from_toml("").unwrap();
        assert_eq!(cfg.accuracy, AccuracySettings::default());
        assert!(!cfg.accuracy.enabled, "accuracy plane must default off");

        let cfg = AppConfig::from_toml(
            r#"
[accuracy]
enabled = true
sample_every = 4
probes = 16
ewma_alpha = 0.5
min_samples = 10
table_path = "errors.json"
seed = 99
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.accuracy,
            AccuracySettings {
                enabled: true,
                sample_every: 4,
                probes: 16,
                ewma_alpha: 0.5,
                min_samples: 10,
                table_path: Some("errors.json".into()),
                seed: 99,
            }
        );
    }

    #[test]
    fn accuracy_validation() {
        // Empty path means "in-memory only", not a file named "".
        let cfg = AppConfig::from_toml("[accuracy]\ntable_path = \"\"").unwrap();
        assert_eq!(cfg.accuracy.table_path, None);
        assert!(AppConfig::from_toml("[accuracy]\nsample_every = 0").is_err());
        assert!(AppConfig::from_toml("[accuracy]\nprobes = 0").is_err());
        assert!(AppConfig::from_toml("[accuracy]\newma_alpha = 0.0").is_err());
        assert!(AppConfig::from_toml("[accuracy]\newma_alpha = 1.5").is_err());
        assert!(AppConfig::from_toml("[accuracy]\nenabled = 1").is_err());
        // min_samples = 0 is legal: trust probes immediately.
        let cfg = AppConfig::from_toml("[accuracy]\nmin_samples = 0").unwrap();
        assert_eq!(cfg.accuracy.min_samples, 0);
    }

    #[test]
    fn scheduler_defaults_and_full_section() {
        let cfg = AppConfig::from_toml("").unwrap();
        assert_eq!(cfg.scheduler, SchedulerSettings::default());
        assert!(!cfg.scheduler.enabled, "scheduler plane must default off");
        assert!(cfg.scheduler.steal, "stealing must default on when enabled");

        let text = r#"
[scheduler]
enabled = true
workers = 8
steal = false
queue_depth = 64
tenant_quota = 4
"#;
        let cfg = AppConfig::from_toml(text).unwrap();
        assert_eq!(
            cfg.scheduler,
            SchedulerSettings {
                enabled: true,
                workers: 8,
                steal: false,
                queue_depth: 64,
                tenant_quota: 4,
            }
        );
    }

    #[test]
    fn scheduler_validation() {
        // Zero-valued knobs are sentinels (auto / inherit / unlimited).
        let cfg = AppConfig::from_toml("[scheduler]\nworkers = 0\nqueue_depth = 0").unwrap();
        assert_eq!(cfg.scheduler.workers, 0);
        assert_eq!(cfg.scheduler.queue_depth, 0);
        assert!(AppConfig::from_toml("[scheduler]\nworkers = 2000").is_err());
        assert!(AppConfig::from_toml("[scheduler]\nenabled = 1").is_err());
        assert!(AppConfig::from_toml("[scheduler]\nsteal = \"yes\"").is_err());
        assert!(AppConfig::from_toml("[scheduler]\nworkers = -1").is_err());
    }

    #[test]
    fn fault_defaults_and_full_section() {
        let cfg = AppConfig::from_toml("").unwrap();
        assert_eq!(cfg.fault, FaultSettings::default());
        assert!(!cfg.fault.enabled, "fault plane must default off");
        assert!(cfg.fault.retry, "fallback retry must default on");

        let cfg = AppConfig::from_toml(
            r#"
[fault]
enabled = true
strict_boot = true
breaker_window = 4
breaker_threshold = 2
breaker_cooldown = 3
retry = false

[fault.inject]
seed = 42
panic_tile = 0.08
stall_tile = 0.5
stall_ms = 2
panic_request = 0.1
error_request = 0.25
error_kernel = "lowrank_fp8"
error_requests_under = 3
corrupt_decode = 0.01
net_refuse = 0.1
net_stall = 0.2
net_stall_ms = 3
net_truncate = 0.3
net_heartbeat_drop = 0.4
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.fault,
            FaultSettings {
                enabled: true,
                strict_boot: true,
                breaker_window: 4,
                breaker_threshold: 2,
                breaker_cooldown: 3,
                retry: false,
                inject: FaultInjectSettings {
                    seed: 42,
                    panic_tile: 0.08,
                    stall_tile: 0.5,
                    stall_ms: 2,
                    panic_request: 0.1,
                    error_request: 0.25,
                    error_kernel: "lowrank_fp8".into(),
                    error_requests_under: 3,
                    corrupt_decode: 0.01,
                    net_refuse: 0.1,
                    net_stall: 0.2,
                    net_stall_ms: 3,
                    net_truncate: 0.3,
                    net_heartbeat_drop: 0.4,
                },
            }
        );
    }

    #[test]
    fn fault_validation() {
        assert!(AppConfig::from_toml("[fault]\nbreaker_window = 0").is_err());
        assert!(AppConfig::from_toml("[fault]\nbreaker_threshold = 0").is_err());
        assert!(AppConfig::from_toml("[fault]\nbreaker_cooldown = 0").is_err());
        assert!(
            AppConfig::from_toml("[fault]\nbreaker_window = 2\nbreaker_threshold = 3").is_err(),
            "threshold above window can never trip"
        );
        assert!(AppConfig::from_toml("[fault]\nenabled = 1").is_err());
        assert!(AppConfig::from_toml("[fault.inject]\npanic_tile = 1.5").is_err());
        assert!(AppConfig::from_toml("[fault.inject]\nerror_request = -0.1").is_err());
        assert!(AppConfig::from_toml("[fault.inject]\nerror_kernel = \"magic\"").is_err());
        // Integer probabilities inside range parse via as_float.
        let cfg = AppConfig::from_toml("[fault.inject]\npanic_tile = 1").unwrap();
        assert_eq!(cfg.fault.inject.panic_tile, 1.0);
    }

    #[test]
    fn fault_inject_spec_parses_and_rejects() {
        let mut s = FaultInjectSettings::default();
        s.apply_spec("seed=42,panic_tile=0.08, error_request=0.1,error_kernel=lowrank_fp8")
            .unwrap();
        assert_eq!(s.seed, 42);
        assert_eq!(s.panic_tile, 0.08);
        assert_eq!(s.error_request, 0.1);
        assert_eq!(s.error_kernel, "lowrank_fp8");
        assert_eq!(s.stall_ms, 1, "untouched keys keep their values");
        assert!(s.apply_spec("nope=1").is_err());
        assert!(s.apply_spec("panic_tile").is_err());
        assert!(s.apply_spec("seed=abc").is_err());
    }

    #[test]
    fn cluster_defaults_and_full_section() {
        let cfg = AppConfig::from_toml("").unwrap();
        assert_eq!(cfg.cluster, ClusterSettings::default());
        assert!(!cfg.cluster.enabled, "cluster tier must default off");

        let cfg = AppConfig::from_toml(
            r#"
[cluster]
enabled = true
router_addr = "10.0.0.1:9000"
node_addr = "10.0.0.2:9001"
heartbeat_ms = 100
heartbeat_timeout_ms = 400
dead_after_ms = 900
connect_timeout_ms = 50
read_timeout_ms = 800
max_attempts = 5
backoff_base_ms = 5
backoff_cap_ms = 100
fill_cap = 4
affinity_min_dim = 64
seed = 7
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.cluster,
            ClusterSettings {
                enabled: true,
                router_addr: "10.0.0.1:9000".into(),
                node_addr: "10.0.0.2:9001".into(),
                heartbeat_ms: 100,
                heartbeat_timeout_ms: 400,
                dead_after_ms: 900,
                connect_timeout_ms: 50,
                read_timeout_ms: 800,
                max_attempts: 5,
                backoff_base_ms: 5,
                backoff_cap_ms: 100,
                fill_cap: 4,
                affinity_min_dim: 64,
                seed: 7,
            }
        );
    }

    #[test]
    fn cluster_validation() {
        assert!(AppConfig::from_toml("[cluster]\nrouter_addr = \"\"").is_err());
        assert!(AppConfig::from_toml("[cluster]\nheartbeat_ms = 0").is_err());
        assert!(AppConfig::from_toml("[cluster]\nmax_attempts = 0").is_err());
        assert!(AppConfig::from_toml("[cluster]\nfill_cap = 0").is_err());
        assert!(AppConfig::from_toml("[cluster]\nenabled = 1").is_err());
        // Health deadlines must be ordered: heartbeat ≤ timeout ≤ dead.
        assert!(
            AppConfig::from_toml("[cluster]\nheartbeat_ms = 500\nheartbeat_timeout_ms = 100")
                .is_err()
        );
        assert!(
            AppConfig::from_toml("[cluster]\nheartbeat_timeout_ms = 2000\ndead_after_ms = 1000")
                .is_err()
        );
        assert!(
            AppConfig::from_toml("[cluster]\nbackoff_base_ms = 100\nbackoff_cap_ms = 10").is_err()
        );
    }

    #[test]
    fn fault_inject_net_spec_keys_parse() {
        let mut s = FaultInjectSettings::default();
        s.apply_spec("net_refuse=0.5,net_stall=0.25,net_stall_ms=7,net_truncate=0.1,net_heartbeat_drop=0.9")
            .unwrap();
        assert_eq!(s.net_refuse, 0.5);
        assert_eq!(s.net_stall, 0.25);
        assert_eq!(s.net_stall_ms, 7);
        assert_eq!(s.net_truncate, 0.1);
        assert_eq!(s.net_heartbeat_drop, 0.9);
        assert!(AppConfig::from_toml("[fault.inject]\nnet_refuse = 1.5").is_err());
    }

    #[test]
    fn rank_strategy_variants() {
        let fixed = AppConfig::from_toml("[lowrank]\nrank_strategy = \"fixed\"\nrank = 32").unwrap();
        assert_eq!(fixed.rank_strategy, RankStrategy::Fixed(32));
        let hw = AppConfig::from_toml(
            "[lowrank]\nrank_strategy = \"hardware_aware\"\nmemory_fraction = 0.2\ngranule = 8",
        )
        .unwrap();
        assert_eq!(
            hw.rank_strategy,
            RankStrategy::HardwareAware {
                memory_fraction: 0.2,
                granule: 8
            }
        );
    }

    #[test]
    fn bad_values_rejected() {
        assert!(AppConfig::from_toml("use_xla = 3").is_err());
        assert!(AppConfig::from_toml("[lowrank]\ndecomp = \"qr\"").is_err());
        assert!(AppConfig::from_toml("[lowrank]\nrank_strategy = \"nope\"").is_err());
        assert!(AppConfig::from_toml("[service]\nworkers = -1").is_err());
    }
}
