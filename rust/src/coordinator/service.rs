//! `GemmService` — the serving loop tying everything together.
//!
//! Architecture (the vLLM-router shape, DESIGN.md §4):
//!
//! ```text
//!   submit() ─ admission ─▶ SubmitQueue ──▶ dispatcher ──▶ size-bucketed
//!      ▲      │ shape/depth   (condvar)       thread          batcher
//!      │      │ deadline/tenant  ▲                              │ full/expired
//!      │      ▼ route()          │ push wakes pop               ▼
//!      │  Router+FactorCache     │                     exec pool ── request jobs
//!      │                         │                   (ThreadPool, or the unified
//!   callers ◀── Error::Rejected(RejectReason)         sched::StealPool when
//!               on backpressure / shed                [scheduler] is enabled —
//!                                                     shard tiles then become
//!                                                     stealable leaves)
//!                                                        │ Backend::execute
//!                                                        ▼
//!                                XLA artifacts (PJRT thread)  /  CPU substrate
//! ```
//!
//! Callers get a `Receiver` per request (async completion without tokio);
//! `gemm_blocking` is the convenience wrapper. Backpressure is a hard
//! bound on in-flight requests: beyond `queue_depth`, `submit` fails fast
//! with [`Error::Rejected`] rather than buffering unboundedly.
//!
//! With `[scheduler]` enabled the service additionally prices admission:
//! per-priority depth watermarks shed lowest-priority traffic first,
//! deadlines that are provably unmeetable under the calibrated backlog
//! estimate reject at `submit` (never after execution), tenants dequeue
//! round-robin within a priority and can carry an in-flight quota, and
//! [`GemmService::drain`] completes in-flight work while refusing new
//! submits with [`RejectReason::Draining`]. The default configuration
//! (`[scheduler]` unset) keeps the historical two-pool behavior — same
//! routing, same result bits, same metric names.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::accuracy::{probe_rel_error, AccuracyPlane, AccuracyStats, ErrorModel};
use crate::autotune::CalibrationTable;
use crate::cache::ContentCache;
use crate::config::schema::{
    AccuracySettings, AppConfig, AutotuneSettings, CacheSettings, FaultSettings, KernelSettings,
    SchedulerSettings, ShardSettings, TraceSettings,
};
use crate::fault::{self, DegradeReason, FaultPlane};
use crate::coordinator::backend::Backend;
use crate::coordinator::batcher::{Batcher, BucketKey};
use crate::coordinator::request::{BackendKind, GemmRequest, GemmResponse, Priority};
use crate::coordinator::router::{Router, RouterConfig, RoutePlan};
use crate::error::{Error, RejectReason, Result};
use crate::exec::ThreadPool;
use crate::kernels::KernelKind;
use crate::linalg::Matrix;
use crate::lowrank::cache::{CacheStats, MatrixId};
use crate::lowrank::FactorCache;
use crate::sched::{self, Pop, QueueMode, StealPool, SubmitQueue, TileStats};
use crate::shard::factorize_sharded;
use crate::metrics::{Counter, HistogramHandle, MetricsRegistry, MetricsSnapshot};
use crate::runtime::{Manifest, XlaExecutor};
use crate::shard::{ShardExecutor, ShardPlan};
use crate::trace_plane::{self, Attr, RequestTrace, Tracer};

/// Max accuracy probes waiting on the shard pool before further samples
/// are shed (`accuracy.probe_shed`). Only enforced when the fault plane
/// is up — without it the backlog is unbounded, as it always was.
const PROBE_BACKLOG_CAP: usize = 32;

/// Service configuration (distilled from [`AppConfig`]).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Routing configuration (device model, rank strategy, ...).
    pub router: RouterConfig,
    /// Worker threads.
    pub workers: usize,
    /// Max in-flight requests before `submit` rejects.
    pub queue_depth: usize,
    /// Dynamic batcher: max requests per batch.
    pub max_batch: usize,
    /// Dynamic batcher: flush window.
    pub batch_window: Duration,
    /// Factor-cache byte budget.
    pub factor_cache_bytes: usize,
    /// AOT artifact directory; `None` runs CPU-substrate-only.
    pub artifacts_dir: Option<String>,
    /// Blocked-kernel geometry (`[kernel]`): installed process-wide at
    /// `start()` when it differs from the built-in defaults, so the
    /// autotune plane can calibrate MC/KC/NC and the naive cutover per
    /// host. Note the kernel params are a process-global — two services
    /// in one process share them.
    pub kernel: KernelSettings,
    /// Tile-execution plane settings (intra-GEMM parallelism; `workers`
    /// above is request-level concurrency). Single source of truth for
    /// the plane: `start()` derives `router.shard` from this, overriding
    /// whatever the `router` field carries.
    pub shard: ShardSettings,
    /// Online autotuning plane (measured-latency calibration of the
    /// kernel selector). Default-off: routing is then bit-identical to
    /// the static analytic cost model.
    pub autotune: AutotuneSettings,
    /// Factor-cache plane (content-addressed reuse of decompositions
    /// across requests). Default-off: routing and results are then
    /// bit-identical to a build without the plane.
    pub cache: CacheSettings,
    /// Tracing plane (request-scoped span trees + flight recorder).
    /// Default-off: requests then carry no span state and results are
    /// bit-identical to a build without the plane.
    pub trace: TraceSettings,
    /// Accuracy observability plane (online error probes, tolerance-SLO
    /// tracking, calibrated error model). Default-off: no probe work is
    /// scheduled and results are bit-identical to a build without it.
    pub accuracy: AccuracySettings,
    /// Unified work-stealing scheduler + admission control (`[scheduler]`).
    /// Default-off: the service then runs the historical two-pool layout
    /// (request `ThreadPool` + owned shard pool, FIFO dequeue, depth-only
    /// backpressure) bit-identically.
    pub scheduler: SchedulerSettings,
    /// Fault-containment & graceful-degradation plane (`[fault]`): panic
    /// isolation at every job boundary, per-kernel circuit breakers over
    /// a degradation ladder, degraded boot for corrupt persistence
    /// tables, deterministic fault injection. Default-off: no guards, no
    /// breaker, no injection — routing, results and metric names are
    /// bit-identical to a build without the plane.
    pub fault: FaultSettings,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            router: RouterConfig::default(),
            workers: 2,
            queue_depth: 1024,
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            factor_cache_bytes: 256 << 20,
            artifacts_dir: None,
            kernel: KernelSettings::default(),
            shard: ShardSettings::default(),
            autotune: AutotuneSettings::default(),
            cache: CacheSettings::default(),
            trace: TraceSettings::default(),
            accuracy: AccuracySettings::default(),
            scheduler: SchedulerSettings::default(),
            fault: FaultSettings::default(),
        }
    }
}

impl ServiceConfig {
    /// Build from a parsed [`AppConfig`] (file/CLI configuration).
    pub fn from_app(app: &AppConfig) -> Result<ServiceConfig> {
        let device = crate::gpu_sim::DeviceProfile::by_name(&app.device)
            .ok_or_else(|| Error::Config(format!("unknown device '{}'", app.device)))?;
        Ok(ServiceConfig {
            router: RouterConfig {
                device,
                rank_strategy: app.rank_strategy,
                decomp: app.decomp,
                storage: app.storage,
                default_tolerance: app.service.default_tolerance,
                shard: ShardPlan::from(&app.shard),
            },
            workers: app.service.workers,
            queue_depth: app.service.queue_depth,
            max_batch: app.service.max_batch,
            batch_window: Duration::from_micros(app.service.batch_window_us),
            factor_cache_bytes: app.service.factor_cache_bytes,
            artifacts_dir: if app.use_xla {
                Some(app.artifacts_dir.clone())
            } else {
                None
            },
            kernel: app.kernel.clone(),
            shard: app.shard.clone(),
            autotune: app.autotune.clone(),
            cache: app.cache.clone(),
            trace: app.trace.clone(),
            accuracy: app.accuracy.clone(),
            scheduler: app.scheduler.clone(),
            fault: app.fault.clone(),
        })
    }
}

struct Pending {
    id: u64,
    req: GemmRequest,
    plan: RoutePlan,
    respond: Sender<Result<GemmResponse>>,
    enqueued: Instant,
    /// Span arena when the tracing plane is on (`None` otherwise).
    trace: Option<Arc<RequestTrace>>,
    /// Time spent in admission + routing at `submit`, microseconds.
    sched_us: u64,
    /// Cost-model execution estimate charged to the admission backlog
    /// (0 when admission control is off); refunded on completion.
    cost_ns: u64,
}

/// The pool dispatch jobs run on: the legacy per-service [`ThreadPool`],
/// or the unified [`StealPool`] shared with the shard executor when
/// `[scheduler]` is enabled.
enum ExecPool {
    Owned(ThreadPool),
    Steal(Arc<StealPool>),
}

impl ExecPool {
    fn execute(&self, job: impl FnOnce() + Send + 'static) {
        match self {
            ExecPool::Owned(p) => p.execute(job),
            ExecPool::Steal(p) => p.spawn(job),
        }
    }

    fn wait_idle(&self) {
        match self {
            ExecPool::Owned(p) => p.wait_idle(),
            ExecPool::Steal(p) => p.wait_idle(),
        }
    }
}

/// Batching window under load: the full window while the in-flight
/// backlog sits at or below half the admission depth, then shrinking
/// linearly to zero at full depth — deep queues flush immediately, so
/// latency degrades gracefully under overload instead of stacking the
/// batching delay on top of the queueing delay. Continuous at the
/// half-depth knee (scale there is 1.0).
fn overload_window(full: Duration, inflight: usize, depth: usize) -> Duration {
    let depth = depth.max(1);
    if inflight * 2 <= depth {
        return full;
    }
    let frac = (inflight as f64 / depth as f64).min(1.0);
    full.mul_f64((1.0 - frac) * 2.0)
}

/// Admission control state (`[scheduler]` only): priority depth
/// watermarks, the deadline-pricing backlog estimate, per-tenant in-flight
/// quotas and the drain flag. All checks run at `submit`, before the
/// request queues — a shed request never consumes dispatcher or pool time.
struct Admission {
    /// Full queue depth (the Interactive watermark).
    depth: usize,
    /// Workers in the unified pool — divides the backlog estimate, since
    /// queued work drains in parallel.
    workers: usize,
    /// Per-tenant in-flight quota; 0 = unlimited.
    tenant_quota: usize,
    /// Sum of cost-model estimates (ns) for admitted, uncompleted
    /// requests. An estimate, not a measurement: charged from the same
    /// autotune-calibrated model the router plans with.
    backlog_ns: AtomicU64,
    /// In-flight count per identified tenant (anonymous requests are not
    /// quota-tracked).
    tenants: Mutex<HashMap<u64, usize>>,
    /// Set by [`GemmService::drain`]; new submits then reject with
    /// [`RejectReason::Draining`] while in-flight work completes.
    draining: AtomicBool,
    /// `sched.shed` — requests rejected by admission control.
    shed: Arc<Counter>,
    /// `sched.queue_depth` — in-flight depth observed at each admit.
    queue_depth: Arc<HistogramHandle>,
}

impl Admission {
    /// Depth watermark for a priority class: Background yields queue room
    /// first (depth/2), then Batch (3·depth/4), Interactive last (full
    /// depth) — under overload the service sheds lowest-priority-first.
    fn watermark(&self, prio: Priority) -> usize {
        let w = match prio {
            Priority::Interactive => self.depth,
            Priority::Batch => self.depth * 3 / 4,
            Priority::Background => self.depth / 2,
        };
        w.max(1)
    }

    /// Checks that need no routing: drain flag, priority watermark,
    /// tenant quota. Run before the router prices the request.
    fn pre_route(
        &self,
        req: &GemmRequest,
        inflight: usize,
    ) -> std::result::Result<(), RejectReason> {
        if self.draining.load(Ordering::Acquire) {
            return Err(RejectReason::Draining);
        }
        let depth = self.watermark(req.priority);
        if inflight >= depth {
            return Err(RejectReason::QueueFull { inflight, depth });
        }
        if self.tenant_quota > 0 {
            if let Some(t) = req.tenant {
                let held = self.tenants.lock().unwrap().get(&t).copied().unwrap_or(0);
                if held >= self.tenant_quota {
                    return Err(RejectReason::TenantQuotaExceeded {
                        tenant: t,
                        inflight: held,
                        quota: self.tenant_quota,
                    });
                }
            }
        }
        Ok(())
    }

    /// Deadline pricing, after routing: the request completes no earlier
    /// than (backlog drained across the pool) + (its own estimated cost).
    /// If that already meets or exceeds the deadline, reject now rather
    /// than executing work the caller will discard.
    fn deadline_check(
        &self,
        cost_ns: u64,
        deadline: Option<Duration>,
    ) -> std::result::Result<(), RejectReason> {
        let Some(deadline) = deadline else {
            return Ok(());
        };
        let backlog = self.backlog_ns.load(Ordering::Relaxed);
        let estimated_ns = backlog / self.workers.max(1) as u64 + cost_ns;
        let deadline_ns = deadline.as_nanos().min(u64::MAX as u128) as u64;
        if estimated_ns >= deadline_ns {
            return Err(RejectReason::DeadlineUnmeetable {
                estimated_us: estimated_ns / 1_000,
                deadline_us: deadline_ns / 1_000,
            });
        }
        Ok(())
    }

    /// Record an admitted request: charge the backlog, count the tenant,
    /// observe the depth.
    fn admitted(&self, req: &GemmRequest, cost_ns: u64, inflight: usize) {
        self.backlog_ns.fetch_add(cost_ns, Ordering::Relaxed);
        if self.tenant_quota > 0 {
            if let Some(t) = req.tenant {
                *self.tenants.lock().unwrap().entry(t).or_insert(0) += 1;
            }
        }
        self.queue_depth.observe((inflight + 1) as f64);
    }

    /// Refund a completed request's backlog charge and tenant slot.
    fn complete(&self, tenant: Option<u64>, cost_ns: u64) {
        // Saturating subtract via CAS: the counter is an estimate and must
        // never wrap past zero.
        let mut cur = self.backlog_ns.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(cost_ns);
            match self.backlog_ns.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        if self.tenant_quota > 0 {
            if let Some(t) = tenant {
                let mut map = self.tenants.lock().unwrap();
                if let Some(n) = map.get_mut(&t) {
                    *n = n.saturating_sub(1);
                    if *n == 0 {
                        map.remove(&t);
                    }
                }
            }
        }
    }
}

/// Pre-registered handles for every dispatch-path metric, interned once
/// at boot — no string formatting, hashing or locking per request.
struct ServiceMetrics {
    exec_us: Arc<HistogramHandle>,
    queue_us: Arc<HistogramHandle>,
    errors: Arc<Counter>,
    explore_total: Arc<Counter>,
    autotune_correction: Arc<HistogramHandle>,
    autotune_table_entries: Arc<HistogramHandle>,
    /// Indexed parallel to [`KernelKind::ALL`].
    kernels: Vec<Arc<Counter>>,
    backend_xla: Arc<Counter>,
    backend_cpu: Arc<Counter>,
}

impl ServiceMetrics {
    fn new(registry: &MetricsRegistry) -> Self {
        ServiceMetrics {
            exec_us: registry.histogram("gemm.exec_us"),
            queue_us: registry.histogram("gemm.queue_us"),
            errors: registry.counter("gemm.errors"),
            explore_total: registry.counter("autotune.explore_total"),
            autotune_correction: registry.histogram("autotune.correction"),
            autotune_table_entries: registry.histogram("autotune.table_entries"),
            kernels: KernelKind::ALL
                .iter()
                .map(|k| registry.counter(&format!("gemm.kernel.{}", k.id())))
                .collect(),
            backend_xla: registry.counter("gemm.backend.xla"),
            backend_cpu: registry.counter("gemm.backend.cpu"),
        }
    }

    fn kernel(&self, kind: KernelKind) -> &Counter {
        let idx = KernelKind::ALL
            .iter()
            .position(|k| *k == kind)
            .expect("every KernelKind is in ALL");
        &self.kernels[idx]
    }

    fn backend(&self, kind: BackendKind) -> &Counter {
        match kind {
            BackendKind::Xla => &self.backend_xla,
            BackendKind::CpuSubstrate => &self.backend_cpu,
        }
    }
}

/// Point-in-time service statistics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    /// Requests accepted.
    pub submitted: u64,
    /// Requests completed (ok or error).
    pub completed: u64,
    /// Requests rejected by backpressure.
    pub rejected: u64,
    /// Id-keyed factor-cache counters (offline decomposition).
    pub cache: CacheStats,
    /// Content-addressed factor-cache counters (the `[cache]` plane);
    /// all-zero when the plane is disabled.
    pub content_cache: CacheStats,
    /// Structured registry snapshot (counters + histogram summaries) —
    /// the same data `metrics().render()` prints, machine-readable.
    pub metrics: MetricsSnapshot,
    /// Accuracy-plane counters (probes, violations, SLO budget, model
    /// size); `None` when the `[accuracy]` plane is disabled.
    pub accuracy: Option<AccuracyStats>,
}

/// The serving coordinator. See module docs for the dataflow.
pub struct GemmService {
    /// Dispatcher inbox — condvar-signalled, so an idle service burns no
    /// CPU and submits wake the dispatcher immediately (no poll tick).
    queue: Arc<SubmitQueue<Pending>>,
    /// Admission control when `[scheduler]` is enabled.
    admission: Option<Arc<Admission>>,
    dispatcher: Option<JoinHandle<()>>,
    router: Arc<Router>,
    cache: Arc<FactorCache>,
    /// Content-addressed factor cache when the `[cache]` plane is on.
    content: Option<Arc<ContentCache>>,
    backend: Arc<Backend>,
    metrics: Arc<MetricsRegistry>,
    inflight: Arc<AtomicUsize>,
    queue_depth: usize,
    next_id: AtomicU64,
    submitted: AtomicU64,
    rejected: AtomicU64,
    completed: Arc<AtomicU64>,
    lr_cfg: crate::lowrank::LowRankConfig,
    /// Online calibration table when `[autotune]` is enabled.
    autotune: Option<Arc<CalibrationTable>>,
    /// Persistence path for the calibration table (saved on shutdown).
    autotune_path: Option<String>,
    /// Tracing plane: span arenas + flight recorder (inert when off).
    tracer: Arc<Tracer>,
    /// Accuracy plane when `[accuracy]` is enabled.
    accuracy: Option<Arc<AccuracyPlane>>,
    /// Persistence path for the error model (saved on shutdown).
    accuracy_path: Option<String>,
    /// Fault plane when `[fault]` is enabled.
    fault: Option<Arc<FaultPlane>>,
    /// Interned submit-path counters.
    submitted_h: Arc<Counter>,
    rejected_h: Arc<Counter>,
    /// Keeps the PJRT thread alive for the service lifetime.
    _xla: Option<XlaExecutor>,
}

impl GemmService {
    /// Start the service: spawns the dispatcher, worker pool and (if
    /// configured) the XLA executor thread, then warms the artifact most
    /// likely to serve first traffic.
    pub fn start(cfg: ServiceConfig) -> Result<GemmService> {
        // Kernel plane: install the `[kernel]` geometry process-wide, but
        // only when it deviates from the defaults — services booted with
        // default settings (the overwhelmingly common case, and every
        // test fixture) must not touch the global and cannot perturb a
        // concurrently-tuned sibling. (set_kernel_params validates.)
        if cfg.kernel != KernelSettings::default() {
            crate::linalg::gemm::set_kernel_params(&cfg.kernel.params())?;
        }
        // A tile grid off the kernel blocking is legal (results stay
        // correct via the per-tile fallback) but silently loses both the
        // shared-packed fast path and the bitwise-equal-to-monolithic
        // guarantee — surface it at boot instead of only as a runtime
        // `pack.unaligned_fallback` counter.
        if cfg.shard.tile_m % cfg.kernel.mc != 0 || cfg.shard.tile_n % cfg.kernel.nc != 0 {
            eprintln!(
                "warning: [shard] tile {}x{} is not a multiple of [kernel] mc/nc {}x{}; \
                 sharded GEMMs will re-pack per tile (pack.unaligned_fallback) and lose \
                 bitwise equality with the monolithic kernel",
                cfg.shard.tile_m, cfg.shard.tile_n, cfg.kernel.mc, cfg.kernel.nc
            );
        }
        let cache = Arc::new(FactorCache::new(cfg.factor_cache_bytes));
        let metrics = Arc::new(MetricsRegistry::new());
        // Tracing plane: programmatic ServiceConfig bypasses the TOML/CLI
        // parsers, so this is the path's validate() call.
        if cfg.trace.enabled {
            cfg.trace.validate()?;
        }
        let tracer = Arc::new(Tracer::new(&cfg.trace));
        let handles = Arc::new(ServiceMetrics::new(&metrics));
        // Fault plane: built before the persistence loads below so the
        // degraded-boot path can quarantine a corrupt table instead of
        // failing start(). Disabled (the default) no `fault.*` metric is
        // interned, no guard wraps any job, and the service is
        // bit-identical to a build without the plane.
        let fault = if cfg.fault.enabled {
            // Programmatic ServiceConfig bypasses the TOML/CLI parsers,
            // so this is the path's validate() call.
            cfg.fault.validate()?;
            Some(FaultPlane::new(&cfg.fault, &metrics))
        } else {
            None
        };
        let mut router_cfg = cfg.router.clone();
        // `cfg.shard` is the single source of truth for the tile plane
        // (see its doc): the router's cost model must describe the plane
        // that will actually execute, so any hand-set `router.shard` is
        // deliberately overridden here.
        router_cfg.shard = ShardPlan::from(&cfg.shard);

        // Autotune plane: build the calibration table (warm-started from
        // the persisted file when one exists) and hand it to the router,
        // whose selector then blends measured corrections into the cost
        // model. A corrupt table file fails start() — silently serving
        // uncalibrated after a restart would defeat the warm start.
        let autotune = if cfg.autotune.enabled {
            // Programmatic ServiceConfig bypasses the TOML/CLI parsers,
            // so this is the path's validate() call — out-of-range knobs
            // must fail start(), not be silently clamped downstream.
            cfg.autotune.validate()?;
            let mut table =
                CalibrationTable::new(cfg.autotune.ewma_alpha, cfg.autotune.min_samples);
            if let Some(path) = &cfg.autotune.table_path {
                // Periodic flush every min_samples-th recorded sample: an
                // abrupt kill then loses at most a flush window of a long
                // calibration run, not all of it (Drop still saves last).
                table.set_autosave(path, cfg.autotune.min_samples.max(1));
            }
            let table = Arc::new(table);
            if let Some(path) = &cfg.autotune.table_path {
                if std::path::Path::new(path).exists() {
                    match table.load(path) {
                        Ok(loaded) => {
                            metrics.count("autotune.warm_start_entries", loaded as u64)
                        }
                        Err(e) => {
                            Self::quarantine_or_fail(&fault, path, "autotune calibration table", e)?
                        }
                    }
                }
            }
            Some(table)
        } else {
            None
        };
        // Factor-cache plane: one content-addressed store shared by the
        // router (plans against it) and the backend (fills and serves
        // from it), metrics-wired so hits/misses/evictions surface as
        // `cache.*`. Disabled (the default) nothing is fingerprinted and
        // routing is bit-identical to the id-only world.
        let content = if cfg.cache.enabled {
            // Programmatic ServiceConfig bypasses the TOML/CLI parsers,
            // so this is the path's validate() call.
            cfg.cache.validate()?;
            Some(Arc::new(
                ContentCache::with_metrics(
                    cfg.cache.budget_bytes(),
                    cfg.cache.min_dim,
                    metrics.clone(),
                )
                .with_prepack(cfg.cache.prepack),
            ))
        } else {
            None
        };

        // Accuracy plane: online error probes close the *accuracy* loop
        // the same way autotune closes the latency loop — a sampled
        // fraction of completed requests is probed in the background, the
        // probed/predicted ratio feeds an EWMA error model, and the
        // selector blends that correction into its tolerance gate.
        // Disabled (the default) nothing is sampled and routing is
        // bit-identical to the analytic error heuristic.
        let accuracy = if cfg.accuracy.enabled {
            // Programmatic ServiceConfig bypasses the TOML/CLI parsers,
            // so this is the path's validate() call.
            cfg.accuracy.validate()?;
            let mut model = ErrorModel::new(cfg.accuracy.ewma_alpha, cfg.accuracy.min_samples);
            if let Some(path) = &cfg.accuracy.table_path {
                // Same flush cadence rationale as the autotune table: an
                // abrupt kill loses at most one window of probes.
                model.set_autosave(path, cfg.accuracy.min_samples.max(1));
            }
            let model = Arc::new(model);
            if let Some(path) = &cfg.accuracy.table_path {
                if std::path::Path::new(path).exists() {
                    match model.load(path) {
                        Ok(loaded) => {
                            metrics.count("accuracy.warm_start_entries", loaded as u64)
                        }
                        Err(e) => {
                            Self::quarantine_or_fail(&fault, path, "accuracy error model", e)?
                        }
                    }
                }
            }
            Some(Arc::new(AccuracyPlane::new(
                cfg.accuracy.clone(),
                model,
                &metrics,
            )))
        } else {
            None
        };

        let mut router = match &autotune {
            Some(table) => {
                Router::with_autotune(router_cfg, cache.clone(), table.clone(), &cfg.autotune)
            }
            None => Router::new(router_cfg, cache.clone()),
        };
        if let Some(cc) = &content {
            router = router.with_content_cache(cc.clone(), cfg.cache.clone());
        }
        if let Some(plane) = &accuracy {
            router = router.with_error_model(plane.model().clone());
        }
        if let Some(plane) = &fault {
            router = router.with_fault(plane.clone());
        }
        let router = Arc::new(router);

        // Scheduler plane: one work-stealing pool replacing both the
        // request ThreadPool and the shard executor's owned pool. Request
        // jobs and their shard tiles become peers on the same deques: a
        // lone huge GEMM fans its tiles across every core, a flood of
        // small requests runs one-per-worker, and anything in between
        // load-balances by stealing. Disabled (the default) the two-pool
        // layout below is preserved bit-for-bit.
        let sched_pool = if cfg.scheduler.enabled {
            // Programmatic ServiceConfig bypasses the TOML/CLI parsers,
            // so this is the path's validate() call.
            cfg.scheduler.validate()?;
            let workers = if cfg.scheduler.workers == 0 {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            } else {
                cfg.scheduler.workers
            };
            Some(Arc::new(StealPool::with_hooks(
                workers,
                cfg.scheduler.steal,
                Some(metrics.counter("sched.steal")),
                fault.as_ref().map(|p| p.panic_sched_counter()),
            )))
        } else {
            None
        };
        let shard = {
            let ex = match &sched_pool {
                Some(pool) => ShardExecutor::with_shared_pool(
                    ShardPlan::from(&cfg.shard),
                    pool.clone(),
                    metrics.clone(),
                ),
                None => {
                    ShardExecutor::with_metrics(ShardPlan::from(&cfg.shard), metrics.clone())
                }
            };
            Arc::new(match &fault {
                Some(plane) => ex.with_fault(plane.clone()),
                None => ex,
            })
        };

        let xla = match &cfg.artifacts_dir {
            Some(dir) => Some(XlaExecutor::start(dir)?),
            None => None,
        };
        let xla_pair = xla.as_ref().map(|x| {
            (
                x.handle(),
                Arc::new(Manifest::load(cfg.artifacts_dir.as_ref().unwrap()).expect(
                    "manifest already parsed once in XlaExecutor::start",
                )),
            )
        });

        let mut backend = Backend::with_shard(xla_pair, cache.clone(), router.lowrank_config(), shard);
        if let Some(cc) = &content {
            backend = backend.with_content_cache(cc.clone(), &cfg.cache);
        }
        let backend = Arc::new(backend);

        let pool = match &sched_pool {
            Some(p) => ExecPool::Steal(p.clone()),
            None => ExecPool::Owned(ThreadPool::with_panic_hook(
                cfg.workers.max(1),
                fault.as_ref().map(|p| p.panic_exec_counter()),
            )),
        };
        let queue = Arc::new(SubmitQueue::new(match &sched_pool {
            Some(_) => QueueMode::Fair,
            None => QueueMode::Fifo,
        }));
        let admission = sched_pool.as_ref().map(|p| {
            Arc::new(Admission {
                depth: if cfg.scheduler.queue_depth > 0 {
                    cfg.scheduler.queue_depth
                } else {
                    cfg.queue_depth
                },
                workers: p.size(),
                tenant_quota: cfg.scheduler.tenant_quota,
                backlog_ns: AtomicU64::new(0),
                tenants: Mutex::new(HashMap::new()),
                draining: AtomicBool::new(false),
                shed: metrics.counter("sched.shed"),
                queue_depth: metrics.histogram("sched.queue_depth"),
            })
        });
        let completed = Arc::new(AtomicU64::new(0));
        let inflight = Arc::new(AtomicUsize::new(0));

        let dispatcher = {
            let backend = backend.clone();
            let handles = handles.clone();
            let tracer = tracer.clone();
            let completed = completed.clone();
            let inflight = inflight.clone();
            let autotune = autotune.clone();
            let accuracy = accuracy.clone();
            let admission = admission.clone();
            let queue = queue.clone();
            let fault = fault.clone();
            let max_batch = cfg.max_batch;
            let window = cfg.batch_window;
            std::thread::Builder::new()
                .name("gemm-dispatcher".into())
                .spawn(move || {
                    Self::dispatch_loop(
                        queue, pool, backend, handles, tracer, completed, inflight, autotune,
                        accuracy, admission, fault, max_batch, window,
                    )
                })
                .map_err(|e| Error::Service(format!("spawning dispatcher: {e}")))?
        };

        let submitted_h = metrics.counter("gemm.submitted");
        let rejected_h = metrics.counter("gemm.rejected");
        Ok(GemmService {
            queue,
            admission,
            dispatcher: Some(dispatcher),
            lr_cfg: router.lowrank_config(),
            router,
            cache,
            content,
            backend,
            metrics,
            autotune,
            autotune_path: cfg.autotune.table_path.clone(),
            tracer,
            accuracy,
            accuracy_path: cfg.accuracy.table_path.clone(),
            fault,
            submitted_h,
            rejected_h,
            inflight,
            queue_depth: cfg.queue_depth,
            next_id: AtomicU64::new(1),
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed,
            _xla: xla,
        })
    }

    /// Start with defaults + CPU substrate only (tests, small tools).
    pub fn start_cpu_only() -> Result<GemmService> {
        Self::start(ServiceConfig::default())
    }

    /// Degraded boot: a *corrupt* persistence table is quarantined
    /// (renamed to `<path>.corrupt-<n>`) and the service starts with an
    /// empty table, unless `[fault] strict_boot` — or a disabled fault
    /// plane — keeps the historical fail-start behavior. I/O errors
    /// always fail start: they signal a broken disk, not a broken file,
    /// and quarantining would destroy the only copy's name for nothing.
    fn quarantine_or_fail(
        fault: &Option<Arc<FaultPlane>>,
        path: &str,
        what: &str,
        err: Error,
    ) -> Result<()> {
        let plane = match fault {
            Some(p) if !p.settings().strict_boot => p,
            _ => return Err(err),
        };
        if !matches!(err, Error::Config(_)) {
            return Err(err);
        }
        let quarantined = fault::quarantine(path)?;
        eprintln!(
            "warning: corrupt {what} at {path} quarantined to {quarantined} ({err}); \
             starting with an empty table"
        );
        plane.note_quarantined();
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn dispatch_loop(
        queue: Arc<SubmitQueue<Pending>>,
        pool: ExecPool,
        backend: Arc<Backend>,
        handles: Arc<ServiceMetrics>,
        tracer: Arc<Tracer>,
        completed: Arc<AtomicU64>,
        inflight: Arc<AtomicUsize>,
        autotune: Option<Arc<CalibrationTable>>,
        accuracy: Option<Arc<AccuracyPlane>>,
        admission: Option<Arc<Admission>>,
        fault: Option<Arc<FaultPlane>>,
        max_batch: usize,
        window: Duration,
    ) {
        let mut batcher: Batcher<Pending> = Batcher::new(max_batch, window);

        let dispatch = |batch: Vec<Pending>| {
            let backend = backend.clone();
            let handles = handles.clone();
            let tracer = tracer.clone();
            let completed = completed.clone();
            let inflight = inflight.clone();
            let autotune = autotune.clone();
            let accuracy = accuracy.clone();
            let admission = admission.clone();
            let fault = fault.clone();
            pool.execute(move || {
                let batch_size = batch.len();
                for p in batch {
                    let started = Instant::now();
                    let queue_wait = started.duration_since(p.enqueued);
                    let queue_us = queue_wait.as_micros() as u64;
                    let (m, k, n) = p.req.shape();
                    if p.plan.explored {
                        handles.explore_total.inc();
                    }
                    // Per-request tile accounting: the shard executor
                    // records each tile (and whether a stolen helper ran
                    // it) into this request's stats via the sched TLS.
                    let tile_stats = Arc::new(TileStats::default());
                    let routed = p.plan.choice.kind;
                    let (exec_result, served_kind, degraded) = {
                        let _tiles = sched::request_scope(tile_stats.clone());
                        // Scope the trace to this worker thread for the
                        // execute call, so every span opened downstream
                        // (factor/decompose/pack/tile/assemble) attaches
                        // under this request's exec span.
                        let _scope = p
                            .trace
                            .as_ref()
                            .map(|t| trace_plane::scope(t.clone(), trace_plane::ROOT_SPAN));
                        // One attempt on `kind` under its own "exec" span.
                        // With the fault plane up, the attempt runs inside
                        // catch_unwind — a panicking kernel is contained
                        // here, at the request boundary, and surfaces as a
                        // typed Error::KernelPanicked instead of killing
                        // the worker (and hanging the caller). Injection
                        // (`inject`) fires *inside* the guard so injected
                        // faults exercise exactly the containment path
                        // real ones take; the retry attempt never injects.
                        let run_kernel = |kind: KernelKind, inject: bool| {
                            let mut sp = trace_plane::span("exec");
                            sp.attr_u64("m", m as u64);
                            sp.attr_u64("k", k as u64);
                            sp.attr_u64("n", n as u64);
                            sp.attr_str("kernel", kind.id());
                            match &fault {
                                None => backend.execute_hinted(
                                    kind, &p.req.a, &p.req.b, p.req.a_id, p.req.b_id,
                                    p.plan.hints,
                                ),
                                Some(plane) => catch_unwind(AssertUnwindSafe(|| {
                                    if inject {
                                        if plane.inject_request_panic(p.id) {
                                            panic!("injected request fault (request {})", p.id);
                                        }
                                        if plane.inject_request_error(p.id, kind) {
                                            return Err(Error::Service(format!(
                                                "injected kernel error (request {})",
                                                p.id
                                            )));
                                        }
                                    }
                                    backend.execute_hinted(
                                        kind, &p.req.a, &p.req.b, p.req.a_id, p.req.b_id,
                                        p.plan.hints,
                                    )
                                }))
                                .unwrap_or_else(|_| {
                                    plane.note_panic_request();
                                    Err(Error::KernelPanicked(format!(
                                        "request {} on {}",
                                        p.id,
                                        kind.id()
                                    )))
                                }),
                            }
                        };
                        // A breaker-open reroute already happened at route
                        // time; give it its "degrade" span inside this
                        // request's tree (`routed` is the fallback then).
                        if let Some(reason) = p.plan.degraded {
                            let mut sp = trace_plane::span("degrade");
                            sp.attr_str("from", reason.from_kind().id());
                            sp.attr_str("to", routed.id());
                            sp.attr_str("reason", reason.reason_str());
                        }
                        let first = run_kernel(routed, true);
                        match &fault {
                            None => (first, routed, None),
                            Some(plane) => {
                                plane.observe(routed, first.is_ok());
                                match first {
                                    Ok(out) => (Ok(out), routed, p.plan.degraded),
                                    Err(e) => {
                                        let fallback = if plane.retry() {
                                            FaultPlane::fallback_for(routed)
                                        } else {
                                            None
                                        };
                                        match fallback {
                                            // Ladder floor (or retry off):
                                            // the typed error goes to the
                                            // caller — resolved, not hung.
                                            None => (Err(e), routed, p.plan.degraded),
                                            Some(fb) => {
                                                let reason = match &e {
                                                    Error::KernelPanicked(_) => {
                                                        DegradeReason::RetryAfterPanic {
                                                            from: routed,
                                                        }
                                                    }
                                                    _ => DegradeReason::RetryAfterError {
                                                        from: routed,
                                                    },
                                                };
                                                {
                                                    let mut sp = trace_plane::span("degrade");
                                                    sp.attr_str("from", routed.id());
                                                    sp.attr_str("to", fb.id());
                                                    sp.attr_str("reason", reason.reason_str());
                                                }
                                                let second = run_kernel(fb, false);
                                                plane.observe(fb, second.is_ok());
                                                (second, fb, Some(reason))
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    };
                    if let Some(plane) = &fault {
                        if degraded.is_some() {
                            plane.note_degraded();
                        }
                    }
                    let result = exec_result.map(|out| {
                            let elapsed = started.elapsed();
                            let exec_us = elapsed.as_micros() as u64;
                            // Float microseconds: sub-µs executions
                            // truncated through as_micros() would flatten
                            // to a weightless 0 (the histogram admits 0
                            // but it tells the reader nothing).
                            handles.exec_us.observe(elapsed.as_secs_f64() * 1e6);
                            handles.queue_us.observe(queue_wait.as_secs_f64() * 1e6);
                            handles.kernel(served_kind).inc();
                            handles.backend(out.backend).inc();
                            // A degraded retry served on a *different*
                            // kernel than the plan priced: recording its
                            // observed time against the routed kernel's
                            // prediction would poison the calibration
                            // cell, so the sample is dropped.
                            if let (Some(table), true) = (&autotune, served_kind == routed) {
                                // Calibrate against the *raw* analytic
                                // prediction: the choice's time already
                                // folds in the previous correction, and
                                // recording against a corrected value
                                // would compound the feedback loop
                                // (fixed point √ratio instead of ratio).
                                //
                                // Amortized low-rank plans are excluded:
                                // their prediction deliberately divides
                                // the decomposition charge across future
                                // reuses, while this request's observed
                                // time pays it in full — folding that
                                // ratio into the table would overprice
                                // every warm request sharing the
                                // size-class cell.
                                if !(p.plan.amortized && p.plan.choice.kind.is_lowrank()) {
                                    let raw_s =
                                        p.plan.choice.cost.time_s / p.plan.choice.calibration;
                                    let observed_s = elapsed.as_secs_f64();
                                    if let Some(corr) = table
                                        .record(p.plan.choice.kind, m, k, n, raw_s, observed_s)
                                    {
                                        handles.autotune_correction.observe(corr);
                                        handles
                                            .autotune_table_entries
                                            .observe(table.len() as f64);
                                    }
                                }
                            }
                            GemmResponse {
                                id: p.id,
                                c: out.c,
                                kernel: served_kind,
                                backend: out.backend,
                                rank: out.rank,
                                predicted_rel_error: p.plan.choice.predicted_error,
                                queue_us,
                                exec_us,
                                batch_size,
                                sched_us: p.sched_us,
                                stolen_tiles: tile_stats.stolen(),
                                degraded,
                            }
                        });
                    if result.is_err() {
                        handles.errors.inc();
                    }
                    // Record the queue span before any probe job can race
                    // to seal the trace (the seal is deferred into the
                    // probe for probed+traced requests, below).
                    if let Some(t) = &p.trace {
                        t.record_span(
                            "queue",
                            trace_plane::ROOT_SPAN,
                            t.ns_of(p.enqueued),
                            t.ns_of(started),
                            &[Attr::u64("batch_size", batch_size as u64)],
                        );
                    }
                    // Accuracy plane: hand a sampled fraction of
                    // successful requests to a background probe riding
                    // the shard pool's FIFO queue (behind all tile work,
                    // so probes never delay a serving request). The job
                    // owns clones of (a, b, c) — the response is already
                    // on its way to the caller — and, when the request is
                    // traced, ownership of the trace seal, so the "probe"
                    // span lands inside the request's own span tree.
                    let mut probe_seals_trace = false;
                    if let (Some(plane), Ok(resp)) = (&accuracy, &result) {
                        // A degraded retry served a different kernel than
                        // the plan priced — its analytic error prediction
                        // describes the routed kernel, so probing it would
                        // feed a mismatched sample into the error model.
                        if served_kind == routed && plane.sample() {
                            let plane = plane.clone();
                            let a = p.req.a.clone();
                            let b = p.req.b.clone();
                            let c = resp.c.clone();
                            let kind = p.plan.choice.kind;
                            let rank = p.plan.rank;
                            // Calibrate against the *raw* analytic error
                            // prediction (model correction divided back
                            // out) — recording a corrected value would
                            // compound the feedback loop, the same
                            // argument as the autotune table above.
                            let predicted = p.plan.choice.predicted_error as f64
                                / p.plan.choice.error_correction;
                            let tolerance = p.plan.tolerance as f64;
                            let probes = plane.settings().probes;
                            let seed = plane.probe_seed(p.id);
                            let trace = p.trace.clone();
                            let tracer = tracer.clone();
                            let job = move || {
                                let probe_start = Instant::now();
                                let est = probe_rel_error(&a, &b, &c, probes, seed);
                                let probe_end = Instant::now();
                                let probe_us = probe_end
                                    .duration_since(probe_start)
                                    .as_secs_f64()
                                    * 1e6;
                                match est {
                                    Some(measured) => {
                                        let out = plane.observe(
                                            kind, m, k, n, rank, predicted, measured,
                                            tolerance, probe_us,
                                        );
                                        if let Some(t) = &trace {
                                            t.record_span(
                                                "probe",
                                                trace_plane::ROOT_SPAN,
                                                t.ns_of(probe_start),
                                                t.ns_of(probe_end),
                                                &[
                                                    Attr::f64("measured_rel_error", out.measured),
                                                    Attr::f64("predicted_rel_error", out.predicted),
                                                    Attr::u64("violation", out.violation as u64),
                                                    Attr::u64("probes", probes as u64),
                                                ],
                                            );
                                        }
                                    }
                                    None => plane.probe_failed(),
                                }
                                if let Some(t) = &trace {
                                    tracer.finish(
                                        t,
                                        &[
                                            Attr::str("kernel", kind.id()),
                                            Attr::u64("m", m as u64),
                                            Attr::u64("k", k as u64),
                                            Attr::u64("n", n as u64),
                                        ],
                                    );
                                }
                            };
                            // With the fault plane up, the probe backlog
                            // is bounded: past PROBE_BACKLOG_CAP pending
                            // probes the sample is shed (counted) instead
                            // of queued — a probe pile-up must degrade
                            // observability, never serving memory. A
                            // panicking probe is contained at the job
                            // boundary so it cannot kill a shard worker.
                            let scheduled = match &fault {
                                Some(fplane) => {
                                    let hook = fplane.clone();
                                    let contained = move || {
                                        if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                            hook.note_panic_probe();
                                        }
                                    };
                                    let ok = backend
                                        .shard()
                                        .try_execute_background(PROBE_BACKLOG_CAP, contained);
                                    if !ok {
                                        fplane.note_probe_shed();
                                    }
                                    ok
                                }
                                None => {
                                    backend.shard().execute_background(job);
                                    true
                                }
                            };
                            // Only a probe that actually queued owns the
                            // trace seal; a shed probe hands it back to
                            // the normal seal path below.
                            probe_seals_trace = scheduled && p.trace.is_some();
                        }
                    }
                    // Seal the trace before waking the caller, so a
                    // blocked gemm() observes its own trace retained —
                    // unless a probe job took ownership of the seal (the
                    // trace then surfaces when the probe completes).
                    if let Some(t) = &p.trace {
                        if !probe_seals_trace {
                            tracer.finish(
                                t,
                                &[
                                    Attr::str("kernel", served_kind.id()),
                                    Attr::u64("m", m as u64),
                                    Attr::u64("k", k as u64),
                                    Attr::u64("n", n as u64),
                                ],
                            );
                        }
                    }
                    if let Some(adm) = &admission {
                        adm.complete(p.req.tenant, p.cost_ns);
                    }
                    completed.fetch_add(1, Ordering::Relaxed);
                    inflight.fetch_sub(1, Ordering::Relaxed);
                    // Receiver may be gone (caller timed out): fine.
                    let _ = p.respond.send(result);
                }
            });
        };

        loop {
            // Load-responsive batching (`[scheduler]` only): when the
            // in-flight backlog runs deep, holding requests for the full
            // batching window just adds latency on top of queueing — so
            // the window shrinks linearly past half depth, reaching zero
            // (flush immediately) at the admission watermark. Legacy
            // configurations keep the fixed window bit-identically.
            if let Some(adm) = &admission {
                let w = overload_window(window, inflight.load(Ordering::Relaxed), adm.depth);
                if w != batcher.window() {
                    batcher.set_window(w);
                }
            }
            // Sleep until the next batch deadline; with no batch pending,
            // block indefinitely — submit's push wakes the queue's
            // condvar, so an idle service burns no CPU (the old code
            // polled a fixed 50 ms tick here).
            match queue.pop_deadline(batcher.next_deadline()) {
                Pop::Item(p) => {
                    let (m, k, n) = p.req.shape();
                    let key = BucketKey::of(p.plan.choice.kind, m, k, n);
                    if let Some((_, batch)) = batcher.push(key, p, Instant::now()) {
                        dispatch(batch);
                    }
                }
                Pop::Timeout => {}
                Pop::Closed => break,
            }
            for (_, batch) in batcher.flush_expired(Instant::now()) {
                dispatch(batch);
            }
        }
        // Drain on shutdown so every caller gets a response.
        for (_, batch) in batcher.flush_all() {
            dispatch(batch);
        }
        pool.wait_idle();
    }

    /// Submit a request; returns the completion channel.
    ///
    /// Fails fast on shape mismatch and on backpressure — in the legacy
    /// configuration a single in-flight ≥ queue-depth check, under
    /// `[scheduler]` the full admission pipeline (drain flag → priority
    /// watermark → tenant quota → deadline pricing). Every rejection is
    /// a typed [`Error::Rejected`]; the caller decides whether to retry,
    /// shed or block.
    pub fn submit(&self, req: GemmRequest) -> Result<Receiver<Result<GemmResponse>>> {
        let sched_t0 = Instant::now();
        if !req.shape_ok() {
            return Err(Error::ShapeMismatch {
                op: "submit",
                lhs: req.a.shape(),
                rhs: req.b.shape(),
            });
        }
        let inflight = self.inflight.load(Ordering::Relaxed);
        match &self.admission {
            None => {
                if inflight >= self.queue_depth {
                    return Err(self.reject(RejectReason::QueueFull {
                        inflight,
                        depth: self.queue_depth,
                    }));
                }
            }
            Some(adm) => {
                if let Err(reason) = adm.pre_route(&req, inflight) {
                    return Err(self.reject(reason));
                }
            }
        }

        let trace = self.tracer.begin();
        let plan = {
            // Route on the caller's thread under a "route" span (the
            // router adds "fingerprint" children when the cache plane
            // hashes anonymous operands).
            let _scope = trace
                .as_ref()
                .map(|t| trace_plane::scope(t.clone(), trace_plane::ROOT_SPAN));
            let mut sp = trace_plane::span("route");
            let plan = self.router.route_serving(&req);
            sp.attr_str("kernel", plan.choice.kind.id());
            sp.attr_u64("rank", plan.rank as u64);
            plan
        };
        // Deadline pricing needs the routed plan's cost estimate, so it
        // runs after routing — but still at submit, before the request
        // consumes queue or pool time.
        let mut cost_ns = 0u64;
        if let Some(adm) = &self.admission {
            cost_ns = (plan.choice.cost.time_s.max(0.0) * 1e9) as u64;
            if let Err(reason) = adm.deadline_check(cost_ns, req.deadline) {
                return Err(self.reject(reason));
            }
            adm.admitted(&req, cost_ns, inflight);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (respond, result_rx) = channel();
        let prio = req.priority.index();
        let tenant = req.tenant;
        let pending = Pending {
            id,
            req,
            plan,
            respond,
            enqueued: Instant::now(),
            trace,
            sched_us: sched_t0.elapsed().as_micros() as u64,
            cost_ns,
        };

        self.inflight.fetch_add(1, Ordering::Relaxed);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.submitted_h.inc();
        if let Err(p) = self.queue.push(pending, prio, tenant) {
            // Queue closed: the dispatcher is shutting down. Undo the
            // accounting so drain() cannot hang on a request that will
            // never execute.
            self.inflight.fetch_sub(1, Ordering::Relaxed);
            if let Some(adm) = &self.admission {
                adm.complete(p.req.tenant, p.cost_ns);
            }
            return Err(Error::Service("dispatcher is gone".into()));
        }
        Ok(result_rx)
    }

    /// Count and type a rejection.
    fn reject(&self, reason: RejectReason) -> Error {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        self.rejected_h.inc();
        if let Some(adm) = &self.admission {
            adm.shed.inc();
        }
        Error::Rejected(reason)
    }

    /// Submit and wait for the result.
    pub fn gemm_blocking(&self, req: GemmRequest) -> Result<GemmResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| Error::Service("worker dropped the response".into()))?
    }

    /// Offline decomposition (paper §6.5): factorize `m` now under the
    /// service's low-rank config — on the same panel-parallel tile plane
    /// the cold path uses, so preloaded and on-the-fly factors agree
    /// bit-for-bit — and pin it in the cache under `id`.
    pub fn preload_factor(&self, id: MatrixId, m: &Matrix) -> Result<()> {
        let f = factorize_sharded(self.backend.shard(), m, &self.lr_cfg)?;
        self.cache.put(id, f);
        Ok(())
    }

    /// Direct (un-batched, caller-thread) execution — used by benches to
    /// measure kernels without scheduler noise.
    pub fn execute_inline(&self, req: &GemmRequest) -> Result<GemmResponse> {
        let plan = self.router.route(req);
        let started = Instant::now();
        let out = self.backend.execute_hinted(
            plan.choice.kind,
            &req.a,
            &req.b,
            req.a_id,
            req.b_id,
            plan.hints,
        )?;
        Ok(GemmResponse {
            id: 0,
            c: out.c,
            kernel: plan.choice.kind,
            backend: out.backend,
            rank: out.rank,
            predicted_rel_error: plan.choice.predicted_error,
            queue_us: 0,
            exec_us: started.elapsed().as_micros() as u64,
            batch_size: 1,
            sched_us: 0,
            stolen_tiles: 0,
            // Inline execution routes via `route()` (no breaker consult)
            // and never retries — it is a measurement path.
            degraded: None,
        })
    }

    /// Routing decision for a request without executing it.
    pub fn plan(&self, req: &GemmRequest) -> RoutePlan {
        self.router.route(req)
    }

    /// Requests admitted but not yet completed (queued + executing) —
    /// the load signal a cluster node's heartbeat reports.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Stats snapshot.
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            cache: self.cache.stats(),
            content_cache: self
                .content
                .as_ref()
                .map(|c| c.stats())
                .unwrap_or_default(),
            metrics: self.metrics.snapshot(),
            accuracy: self.accuracy.as_ref().map(|p| p.stats()),
        }
    }

    /// The metrics registry (latency histograms, kernel counters).
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// The tracing plane (flight recorder access; inert when `[trace]`
    /// is disabled).
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// The online calibration table, when `[autotune]` is enabled.
    pub fn calibration(&self) -> Option<&Arc<CalibrationTable>> {
        self.autotune.as_ref()
    }

    /// Persist the calibration table now (also happens automatically on
    /// shutdown). Returns `false` when autotuning is off or no
    /// `table_path` is configured.
    pub fn save_calibration(&self) -> Result<bool> {
        match (&self.autotune, &self.autotune_path) {
            (Some(table), Some(path)) => {
                table.save(path)?;
                Ok(true)
            }
            _ => Ok(false),
        }
    }

    /// The accuracy plane, when `[accuracy]` is enabled.
    pub fn accuracy(&self) -> Option<&Arc<AccuracyPlane>> {
        self.accuracy.as_ref()
    }

    /// The fault plane, when `[fault]` is enabled.
    pub fn fault(&self) -> Option<&Arc<FaultPlane>> {
        self.fault.as_ref()
    }

    /// Persist the calibrated error model now (also happens automatically
    /// on shutdown). Returns `false` when the accuracy plane is off or no
    /// `table_path` is configured.
    pub fn save_error_model(&self) -> Result<bool> {
        match (&self.accuracy, &self.accuracy_path) {
            (Some(plane), Some(path)) => {
                plane.model().save(path)?;
                Ok(true)
            }
            _ => Ok(false),
        }
    }

    /// The shared id-keyed factor cache.
    pub fn cache(&self) -> &Arc<FactorCache> {
        &self.cache
    }

    /// The content-addressed factor cache, when the `[cache]` plane is on.
    pub fn content_cache(&self) -> Option<&Arc<ContentCache>> {
        self.content.as_ref()
    }

    /// Block until every accepted request has completed.
    ///
    /// Under `[scheduler]` this also flips the drain flag first: new
    /// submits reject with [`RejectReason::Draining`] while in-flight
    /// work completes, so the wait cannot be starved by fresh arrivals.
    /// (The flag stays set — draining precedes shutdown.)
    pub fn drain(&self) {
        if let Some(adm) = &self.admission {
            adm.draining.store(true, Ordering::Release);
        }
        while self.inflight.load(Ordering::Relaxed) > 0 {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
}

impl Drop for GemmService {
    fn drop(&mut self) {
        // Closing the inbox stops the dispatcher after it drains.
        self.queue.close();
        if let Some(j) = self.dispatcher.take() {
            let _ = j.join();
        }
        // Persist what the instance learned so a restart warm-starts
        // (after the join: no more writers). Best-effort — shutdown must
        // not fail on a read-only filesystem.
        let _ = self.save_calibration();
        let _ = self.save_error_model();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelKind;
    use crate::linalg::Pcg64;

    #[test]
    fn overload_window_shrinks_past_half_depth() {
        let full = Duration::from_micros(1000);
        // At or below half depth: the full window, untouched.
        assert_eq!(overload_window(full, 0, 100), full);
        assert_eq!(overload_window(full, 50, 100), full);
        // Past half depth: linear shrink toward zero at full depth.
        assert_eq!(overload_window(full, 75, 100), full / 2);
        assert_eq!(overload_window(full, 100, 100), Duration::ZERO);
        // Over-full backlog clamps at zero rather than going negative.
        assert_eq!(overload_window(full, 250, 100), Duration::ZERO);
        // Degenerate depth never divides by zero.
        assert_eq!(overload_window(full, 5, 0), Duration::ZERO);
        // Monotone non-increasing in backlog.
        let mut prev = full;
        for q in 0..=120 {
            let w = overload_window(full, q, 100);
            assert!(w <= prev, "window grew at backlog {q}");
            prev = w;
        }
    }

    fn svc() -> GemmService {
        let cfg = ServiceConfig {
            workers: 2,
            max_batch: 4,
            batch_window: Duration::from_micros(100),
            ..Default::default()
        };
        GemmService::start(cfg).unwrap()
    }

    fn rand_req(n: usize, seed: u64) -> GemmRequest {
        let mut rng = Pcg64::seeded(seed);
        GemmRequest::new(
            Matrix::gaussian(n, n, &mut rng),
            Matrix::gaussian(n, n, &mut rng),
        )
    }

    #[test]
    fn blocking_gemm_is_correct() {
        let s = svc();
        let req = rand_req(48, 9);
        let exact = req.a.matmul(&req.b);
        let resp = s.gemm_blocking(req).unwrap();
        assert!(resp.c.rel_frobenius_distance(&exact) < 0.05);
        assert_eq!(s.stats().completed, 1);
    }

    #[test]
    fn many_async_submissions_complete() {
        let s = svc();
        let rxs: Vec<_> = (0..16)
            .map(|i| s.submit(rand_req(32, 100 + i)).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.c.shape(), (32, 32));
            assert!(resp.batch_size >= 1);
        }
        assert_eq!(s.stats().completed, 16);
    }

    #[test]
    fn shape_mismatch_rejected_at_submit() {
        let s = svc();
        let req = GemmRequest::new(Matrix::zeros(4, 5), Matrix::zeros(7, 4));
        assert!(s.submit(req).is_err());
    }

    #[test]
    fn preloaded_factors_hit_cache() {
        let s = svc();
        let mut rng = Pcg64::seeded(77);
        let w = Matrix::low_rank_noisy(64, 64, 5, 1e-5, &mut rng);
        s.preload_factor(42, &w).unwrap();
        assert!(s.cache().contains(42));

        let x = Matrix::gaussian(64, 64, &mut rng);
        let req = GemmRequest::new(w.clone(), x)
            .with_ids(Some(42), None)
            .with_kernel(KernelKind::LowRankAuto);
        let resp = s.gemm_blocking(req).unwrap();
        assert!(resp.rank >= 1);
        assert!(s.stats().cache.hits >= 1);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let cfg = ServiceConfig {
            workers: 1,
            queue_depth: 2,
            max_batch: 64,
            batch_window: Duration::from_millis(200), // hold batches
            ..Default::default()
        };
        let s = GemmService::start(cfg).unwrap();

        let mut rejected = 0;
        let mut rxs = Vec::new();
        for i in 0..8 {
            match s.submit(rand_req(16, 200 + i)) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected >= 1, "expected backpressure rejections");
        for rx in rxs {
            let _ = rx.recv();
        }
    }

    #[test]
    fn execute_inline_matches_blocking() {
        let s = svc();
        let req = rand_req(40, 55);
        let exact = req.a.matmul(&req.b);
        let r1 = s.execute_inline(&req).unwrap();
        assert!(r1.c.rel_frobenius_distance(&exact) < 0.05);
    }

    #[test]
    fn autotune_disabled_by_default_and_records_when_on() {
        let s = svc();
        assert!(s.calibration().is_none(), "autotune must be opt-in");
        assert!(!s.save_calibration().unwrap());

        let cfg = ServiceConfig {
            autotune: AutotuneSettings {
                enabled: true,
                epsilon: 0.0,
                ..Default::default()
            },
            ..Default::default()
        };
        let s = GemmService::start(cfg).unwrap();
        for i in 0..4 {
            s.gemm_blocking(rand_req(48, 400 + i)).unwrap();
        }
        let table = s.calibration().expect("autotune on");
        assert!(!table.is_empty(), "completed requests must be recorded");
        let summaries = s.metrics().histogram_summaries();
        assert!(summaries.contains_key("autotune.correction"));
        assert!(summaries["autotune.correction"].count >= 4);
    }

    #[test]
    fn content_cache_disabled_by_default_and_serves_when_on() {
        let s = svc();
        assert!(s.content_cache().is_none(), "cache plane must be opt-in");
        assert_eq!(s.stats().content_cache, CacheStats::default());

        let cfg = ServiceConfig {
            cache: CacheSettings {
                enabled: true,
                min_dim: 32,
                ..Default::default()
            },
            ..Default::default()
        };
        let s = GemmService::start(cfg).unwrap();
        let mut rng = Pcg64::seeded(91);
        let w = Matrix::low_rank_noisy(64, 64, 4, 1e-5, &mut rng);
        let x = Matrix::low_rank_noisy(64, 64, 4, 1e-5, &mut rng);
        let req = || {
            GemmRequest::new(w.clone(), x.clone()).with_kernel(KernelKind::LowRankFp8)
        };
        let r1 = s.gemm_blocking(req()).unwrap();
        let r2 = s.gemm_blocking(req()).unwrap();
        assert_eq!(r1.c.data(), r2.c.data(), "hit must replay the cold bits");
        let cs = s.stats().content_cache;
        assert_eq!(cs.misses, 2, "two distinct operands, two cold fills");
        assert_eq!(cs.hits, 2, "second request serves both from cache");
        assert_eq!(s.metrics().counters()["cache.hit"], 2);
    }

    #[test]
    fn amortized_misses_are_excluded_from_calibration() {
        // Autotune × cache interaction: an amortized low-rank miss's
        // prediction understates this request's cost by design, so it
        // must not seed the calibration table — only the warm (hit)
        // request, whose prediction and observation both cover just the
        // factor chain, may record.
        let cfg = ServiceConfig {
            autotune: AutotuneSettings {
                enabled: true,
                epsilon: 0.0,
                ..Default::default()
            },
            cache: CacheSettings {
                enabled: true,
                min_dim: 32,
                ..Default::default()
            },
            ..Default::default()
        };
        let s = GemmService::start(cfg).unwrap();
        let mut rng = Pcg64::seeded(93);
        let w = Matrix::low_rank_noisy(64, 64, 4, 1e-5, &mut rng);
        let x = Matrix::low_rank_noisy(64, 64, 4, 1e-5, &mut rng);
        let req = || {
            GemmRequest::new(w.clone(), x.clone()).with_kernel(KernelKind::LowRankFp8)
        };

        s.gemm_blocking(req()).unwrap();
        let table = s.calibration().expect("autotune on");
        assert!(
            table.is_empty(),
            "the amortized cold miss must not fold into the table"
        );

        s.gemm_blocking(req()).unwrap();
        assert_eq!(
            table.len(),
            1,
            "the warm hit (un-amortized plan) must record normally"
        );
    }

    #[test]
    fn invalid_cache_settings_fail_start() {
        let cfg = ServiceConfig {
            cache: CacheSettings {
                enabled: true,
                budget_mb: 0,
                ..Default::default()
            },
            ..Default::default()
        };
        assert!(GemmService::start(cfg).is_err());
    }

    #[test]
    fn traced_request_reaches_flight_recorder() {
        let s = svc();
        assert!(!s.tracer().enabled(), "tracing must be opt-in");
        s.gemm_blocking(rand_req(32, 640)).unwrap();
        assert!(s.tracer().recorder().recent().is_empty());

        let cfg = ServiceConfig {
            trace: TraceSettings {
                enabled: true,
                ..Default::default()
            },
            ..Default::default()
        };
        let s = GemmService::start(cfg).unwrap();
        s.gemm_blocking(rand_req(48, 641)).unwrap();
        let rec = s.tracer().recorder().recent();
        assert_eq!(rec.len(), 1);
        let names: Vec<&str> = rec[0].spans.iter().map(|sp| sp.name).collect();
        for required in ["request", "route", "queue", "exec"] {
            assert!(names.contains(&required), "missing span `{required}`");
        }
    }

    /// Probes run as background shard-pool jobs: poll until they land.
    fn wait_for(cond: impl Fn() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for probes");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn accuracy_disabled_by_default_and_probes_when_on() {
        let s = svc();
        assert!(s.accuracy().is_none(), "accuracy plane must be opt-in");
        assert!(s.stats().accuracy.is_none());
        assert!(!s.save_error_model().unwrap());

        let cfg = ServiceConfig {
            accuracy: AccuracySettings {
                enabled: true,
                sample_every: 1,
                probes: 4,
                ..Default::default()
            },
            ..Default::default()
        };
        let s = GemmService::start(cfg).unwrap();
        for i in 0..4 {
            s.gemm_blocking(rand_req(48, 500 + i)).unwrap();
        }
        wait_for(|| s.accuracy().unwrap().stats().probed >= 4);
        let acc = s.stats().accuracy.expect("plane on");
        assert_eq!(acc.probed, 4, "sample_every=1 probes every request");
        assert!(acc.model_cells >= 1, "probes must feed the error model");
        let snap = s.metrics().snapshot();
        assert_eq!(snap.counters["accuracy.probed"], 4);
        assert!(snap.histograms["accuracy.probe_us"].count >= 4);
        // Dense f32 serves these small requests near-exactly: no
        // violations against the default tolerance.
        assert_eq!(acc.violations, 0);
    }

    #[test]
    fn tolerance_violations_are_counted_and_modeled() {
        let cfg = ServiceConfig {
            accuracy: AccuracySettings {
                enabled: true,
                sample_every: 1,
                probes: 4,
                ..Default::default()
            },
            ..Default::default()
        };
        let s = GemmService::start(cfg).unwrap();
        // Full-rank gaussian operands forced down the low-rank path with
        // an unmeetable tolerance: the served error is large and the
        // probe must catch it.
        let mut rng = Pcg64::seeded(7);
        let req = GemmRequest::new(
            Matrix::gaussian(64, 64, &mut rng),
            Matrix::gaussian(64, 64, &mut rng),
        )
        .with_kernel(KernelKind::LowRankFp8)
        .with_tolerance(1e-6);
        s.gemm_blocking(req).unwrap();
        wait_for(|| s.accuracy().unwrap().stats().probed >= 1);
        let acc = s.stats().accuracy.unwrap();
        assert_eq!(acc.violations, 1);
        assert!(acc.violations_per_10k > 0.0);
        let snap = s.metrics().snapshot();
        assert_eq!(snap.counters["accuracy.violation"], 1);
        assert!(snap.histograms["accuracy.error.lowrank_fp8"].count >= 1);
    }

    #[test]
    fn probed_traced_request_carries_probe_span() {
        let cfg = ServiceConfig {
            trace: TraceSettings {
                enabled: true,
                ..Default::default()
            },
            accuracy: AccuracySettings {
                enabled: true,
                sample_every: 1,
                probes: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let s = GemmService::start(cfg).unwrap();
        s.gemm_blocking(rand_req(40, 643)).unwrap();
        // The trace seal is deferred into the probe job, so the flight
        // recorder sees the request only once its probe has run.
        wait_for(|| !s.tracer().recorder().recent().is_empty());
        let rec = s.tracer().recorder().recent();
        assert_eq!(rec.len(), 1);
        let names: Vec<&str> = rec[0].spans.iter().map(|sp| sp.name).collect();
        for required in ["request", "route", "queue", "exec", "probe"] {
            assert!(names.contains(&required), "missing span `{required}`");
        }
    }

    #[test]
    fn error_model_persists_across_restart() {
        let path = std::env::temp_dir().join(format!(
            "lrg-svc-errmodel-{}.json",
            std::process::id()
        ));
        let path_s = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let acc = |tp: &str| AccuracySettings {
            enabled: true,
            sample_every: 1,
            probes: 2,
            table_path: Some(tp.to_string()),
            ..Default::default()
        };
        {
            let s = GemmService::start(ServiceConfig {
                accuracy: acc(&path_s),
                ..Default::default()
            })
            .unwrap();
            s.gemm_blocking(rand_req(48, 777)).unwrap();
            wait_for(|| s.accuracy().unwrap().stats().probed >= 1);
            assert!(s.save_error_model().unwrap());
        }
        let s = GemmService::start(ServiceConfig {
            accuracy: acc(&path_s),
            ..Default::default()
        })
        .unwrap();
        assert!(
            !s.accuracy().unwrap().model().is_empty(),
            "restart must warm-load the persisted error model"
        );
        assert!(s.metrics().counters()["accuracy.warm_start_entries"] >= 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stats_carry_metrics_snapshot() {
        let s = svc();
        s.gemm_blocking(rand_req(24, 642)).unwrap();
        let stats = s.stats();
        assert_eq!(stats.metrics.counters["gemm.submitted"], 1);
        assert_eq!(stats.metrics.histograms["gemm.exec_us"].count, 1);
    }

    #[test]
    fn drain_waits_for_completion() {
        let s = svc();
        let rxs: Vec<_> = (0..6)
            .map(|i| s.submit(rand_req(24, 300 + i)).unwrap())
            .collect();
        s.drain();
        assert_eq!(s.stats().completed, 6);
        for rx in rxs {
            assert!(rx.try_recv().is_ok());
        }
    }
}
