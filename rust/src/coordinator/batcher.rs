//! Size-bucketed dynamic batcher.
//!
//! The serving-loop heart of the coordinator: requests accumulate in
//! per-bucket pens and flush to the execution pool (the legacy worker
//! pool, or the unified `[scheduler]` steal pool) when either the batch
//! is full (`max_batch`) or the oldest member has waited out the batching
//! window (`batch_window`). Buckets are keyed by (kernel kind, log2 size
//! class) so one flush hands a worker a set of *similarly shaped, same
//! kernel* requests — the GEMM analogue of vLLM's continuous batching
//! buckets. On GPU hardware a batch would fuse into one batched GEMM; on
//! the CPU substrate batching still amortizes routing and scheduling, and
//! it preserves the paper-shaped architecture.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::kernels::KernelKind;

/// Batch key: kernel kind + log2 size class of max(m, k, n).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BucketKey {
    /// Kernel this bucket collects.
    pub kind: KernelKind,
    /// floor(log2(max dim)) — shapes within 2x batch together.
    pub size_class: u32,
}

impl BucketKey {
    /// Classify a routed request.
    pub fn of(kind: KernelKind, m: usize, k: usize, n: usize) -> Self {
        let dim = m.max(k).max(n).max(1);
        BucketKey {
            kind,
            size_class: usize::BITS - 1 - dim.leading_zeros(),
        }
    }
}

/// A pen of pending items of type `T` plus its deadline bookkeeping.
struct Pen<T> {
    items: Vec<T>,
    oldest: Instant,
}

/// Generic size/time-triggered batcher. `T` is whatever the service pends
/// (kept generic so unit tests do not need full requests).
pub struct Batcher<T> {
    pens: HashMap<BucketKey, Pen<T>>,
    max_batch: usize,
    window: Duration,
}

impl<T> Batcher<T> {
    /// `max_batch` requests or `window` of age, whichever first.
    pub fn new(max_batch: usize, window: Duration) -> Self {
        Batcher {
            pens: HashMap::new(),
            max_batch: max_batch.max(1),
            window,
        }
    }

    /// Add an item; returns a full batch if this push filled the pen.
    pub fn push(&mut self, key: BucketKey, item: T, now: Instant) -> Option<(BucketKey, Vec<T>)> {
        let pen = self.pens.entry(key).or_insert_with(|| Pen {
            items: Vec::new(),
            oldest: now,
        });
        if pen.items.is_empty() {
            pen.oldest = now;
        }
        pen.items.push(item);
        if pen.items.len() >= self.max_batch {
            let items = std::mem::take(&mut pen.items);
            return Some((key, items));
        }
        None
    }

    /// Flush every pen whose oldest member has exceeded the window.
    pub fn flush_expired(&mut self, now: Instant) -> Vec<(BucketKey, Vec<T>)> {
        let mut out = Vec::new();
        for (key, pen) in self.pens.iter_mut() {
            if !pen.items.is_empty() && now.duration_since(pen.oldest) >= self.window {
                out.push((*key, std::mem::take(&mut pen.items)));
            }
        }
        out
    }

    /// Flush everything (shutdown / drain).
    pub fn flush_all(&mut self) -> Vec<(BucketKey, Vec<T>)> {
        self.pens
            .iter_mut()
            .filter(|(_, p)| !p.items.is_empty())
            .map(|(k, p)| (*k, std::mem::take(&mut p.items)))
            .collect()
    }

    /// Next deadline among non-empty pens (for the service's poll sleep).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pens
            .values()
            .filter(|p| !p.items.is_empty())
            .map(|p| p.oldest + self.window)
            .min()
    }

    /// Total queued items across pens.
    pub fn pending(&self) -> usize {
        self.pens.values().map(|p| p.items.len()).sum()
    }

    /// The current batching window.
    pub fn window(&self) -> Duration {
        self.window
    }

    /// Retarget the batching window. Takes effect for every pending and
    /// future pen deadline (deadlines are computed from `oldest + window`
    /// on demand, so shrinking the window under load flushes sooner —
    /// the graceful-degradation lever the dispatcher pulls when the
    /// submit queue runs deep).
    pub fn set_window(&mut self, window: Duration) {
        self.window = window;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(n: usize) -> BucketKey {
        BucketKey::of(KernelKind::DenseF32, n, n, n)
    }

    #[test]
    fn size_classes_group_within_2x() {
        assert_eq!(key(1024), key(1500));
        assert_ne!(key(1024), key(2048));
        assert_ne!(
            BucketKey::of(KernelKind::DenseF32, 1024, 1024, 1024),
            BucketKey::of(KernelKind::DenseFp8, 1024, 1024, 1024)
        );
    }

    #[test]
    fn fills_trigger_at_max_batch() {
        let mut b: Batcher<u32> = Batcher::new(3, Duration::from_millis(100));
        let t = Instant::now();
        assert!(b.push(key(64), 1, t).is_none());
        assert!(b.push(key(64), 2, t).is_none());
        let (k, items) = b.push(key(64), 3, t).expect("full batch");
        assert_eq!(k, key(64));
        assert_eq!(items, vec![1, 2, 3]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn window_expiry_flushes() {
        let mut b: Batcher<u32> = Batcher::new(10, Duration::from_millis(5));
        let t0 = Instant::now();
        b.push(key(64), 1, t0);
        b.push(key(128), 2, t0);
        assert!(b.flush_expired(t0).is_empty());
        let later = t0 + Duration::from_millis(6);
        let mut flushed = b.flush_expired(later);
        flushed.sort_by_key(|(k, _)| k.size_class);
        assert_eq!(flushed.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn oldest_resets_after_flush() {
        let mut b: Batcher<u32> = Batcher::new(10, Duration::from_millis(5));
        let t0 = Instant::now();
        b.push(key(64), 1, t0);
        let t1 = t0 + Duration::from_millis(6);
        assert_eq!(b.flush_expired(t1).len(), 1);
        // New item after flush starts a fresh window.
        b.push(key(64), 2, t1);
        assert!(b.flush_expired(t1 + Duration::from_millis(4)).is_empty());
        assert_eq!(b.flush_expired(t1 + Duration::from_millis(5)).len(), 1);
    }

    #[test]
    fn next_deadline_is_min_over_pens() {
        let mut b: Batcher<u32> = Batcher::new(10, Duration::from_millis(10));
        let t0 = Instant::now();
        assert!(b.next_deadline().is_none());
        b.push(key(64), 1, t0);
        b.push(key(256), 2, t0 + Duration::from_millis(3));
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
    }

    #[test]
    fn set_window_retargets_pending_deadlines() {
        let mut b: Batcher<u32> = Batcher::new(10, Duration::from_millis(10));
        let t0 = Instant::now();
        b.push(key(64), 1, t0);
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
        b.set_window(Duration::from_millis(2));
        assert_eq!(b.window(), Duration::from_millis(2));
        // The pending pen's deadline moved up with the window…
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(2)));
        // …and it now flushes at the new, shorter age.
        assert_eq!(b.flush_expired(t0 + Duration::from_millis(2)).len(), 1);
    }

    #[test]
    fn flush_all_drains() {
        let mut b: Batcher<u32> = Batcher::new(10, Duration::from_millis(10));
        let t0 = Instant::now();
        b.push(key(64), 1, t0);
        b.push(key(512), 2, t0);
        assert_eq!(b.flush_all().len(), 2);
        assert_eq!(b.pending(), 0);
    }
}
