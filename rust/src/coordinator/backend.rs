//! Kernel execution backends.
//!
//! Every routed request ends up here: [`Backend::execute`] runs the chosen
//! kernel either on an AOT-compiled **XLA artifact** (when the request's
//! shape sits on the lattice `compile/aot.py` lowered — the Pallas-kernel
//! path) or on the **native CPU substrate** (`linalg` + `fp8` + `lowrank`)
//! for everything off-lattice. This mirrors the paper's "automatic
//! fallback" and keeps one code path for arbitrary shapes.
//!
//! The numerics of the two substrates agree to float tolerance — that is
//! asserted by `rust/tests/runtime_roundtrip.rs`, which is exactly the
//! "Pallas kernel vs reference" check done once more from the Rust side.

use std::sync::Arc;

use crate::cache::{CachedFactor, ContentCache, FactorHints, Fingerprint};
use crate::config::schema::CacheSettings;
use crate::error::{Error, Result};
use crate::fp8::StorageFormat;
use crate::kernels::KernelKind;
use crate::linalg::Matrix;
use crate::lowrank::cache::MatrixId;
use crate::lowrank::factor::{LowRankConfig, LowRankFactor};
use crate::lowrank::FactorCache;
use crate::coordinator::request::BackendKind;
use crate::runtime::XlaHandle;
use crate::runtime::Manifest;
use crate::shard::{factorize_sharded, ShardExecutor, ShardPlan};
use crate::trace_plane;

/// Execution outcome details for one kernel run.
#[derive(Clone, Debug)]
pub struct ExecOutcome {
    /// The product.
    pub c: Matrix,
    /// Which substrate ran.
    pub backend: BackendKind,
    /// Rank actually used (0 = dense).
    pub rank: usize,
}

/// The executor over both substrates.
pub struct Backend {
    /// XLA executor handle + manifest (None = CPU-only mode).
    xla: Option<(XlaHandle, Arc<Manifest>)>,
    /// Factor cache shared with the router.
    cache: Arc<FactorCache>,
    /// Factorization configuration for on-the-fly (cold) decomposition.
    lr_cfg: LowRankConfig,
    /// Tile-execution plane: every CPU-substrate product routes through
    /// it, sharding across workers when the plan's gates pass and falling
    /// back to the single-threaded kernels otherwise. Under `[scheduler]`
    /// the executor runs its tiles on the coordinator's unified
    /// work-stealing pool instead of an owned one.
    shard: Arc<ShardExecutor>,
    /// Content-addressed factor cache (the `[cache]` plane) for
    /// anonymous operands; `None` = cold-factorize every anonymous
    /// operand, exactly the pre-cache behavior.
    content: Option<Arc<ContentCache>>,
    /// Factorization config for the content-cache path — `lr_cfg` with
    /// the storage optionally forced to FP8 (`[cache].fp8`). Fills and
    /// hits share it, so cached and cold results stay bit-identical.
    content_cfg: LowRankConfig,
}

impl Backend {
    /// Build a backend with a default tile plane. `xla` is optional:
    /// benches that sweep large off-lattice shapes run CPU-only.
    pub fn new(
        xla: Option<(XlaHandle, Arc<Manifest>)>,
        cache: Arc<FactorCache>,
        lr_cfg: LowRankConfig,
    ) -> Self {
        Self::with_shard(
            xla,
            cache,
            lr_cfg,
            Arc::new(ShardExecutor::new(ShardPlan::default())),
        )
    }

    /// Build a backend over an explicit (possibly shared, metrics-wired)
    /// tile executor.
    pub fn with_shard(
        xla: Option<(XlaHandle, Arc<Manifest>)>,
        cache: Arc<FactorCache>,
        lr_cfg: LowRankConfig,
        shard: Arc<ShardExecutor>,
    ) -> Self {
        Backend {
            xla,
            cache,
            content: None,
            content_cfg: lr_cfg.clone(),
            lr_cfg,
            shard,
        }
    }

    /// Attach the content-addressed factor cache (builder-style): every
    /// anonymous low-rank operand that clears the admission gate is then
    /// fetched-or-factorized through it. With `settings.fp8`, cached
    /// factors are stored FP8-encoded via the existing codecs.
    pub fn with_content_cache(
        mut self,
        content: Arc<ContentCache>,
        settings: &CacheSettings,
    ) -> Self {
        self.content_cfg = self.lr_cfg.clone();
        if settings.fp8 {
            self.content_cfg.storage = StorageFormat::Fp8(crate::fp8::Fp8Format::E4M3);
        }
        self.content = Some(content);
        self
    }

    /// The tile executor this backend runs CPU-substrate products on.
    pub fn shard(&self) -> &Arc<ShardExecutor> {
        &self.shard
    }

    /// Execute `kind` on (a, b). `a_id`/`b_id` enable id-keyed factor
    /// caching; content-addressed caching (when attached) fingerprints
    /// anonymous operands itself.
    pub fn execute(
        &self,
        kind: KernelKind,
        a: &Matrix,
        b: &Matrix,
        a_id: Option<MatrixId>,
        b_id: Option<MatrixId>,
    ) -> Result<ExecOutcome> {
        self.execute_hinted(kind, a, b, a_id, b_id, FactorHints::default())
    }

    /// [`execute`](Backend::execute) with routing-time fingerprints: the
    /// serving path hands the plan's hints through so operands hashed by
    /// the router are never hashed again here.
    pub fn execute_hinted(
        &self,
        kind: KernelKind,
        a: &Matrix,
        b: &Matrix,
        a_id: Option<MatrixId>,
        b_id: Option<MatrixId>,
        hints: FactorHints,
    ) -> Result<ExecOutcome> {
        if a.cols() != b.rows() {
            return Err(Error::ShapeMismatch {
                op: "gemm",
                lhs: a.shape(),
                rhs: b.shape(),
            });
        }
        match kind {
            KernelKind::DenseF32 => self.dense(a, b, "dense_f32", StorageFormat::F32),
            KernelKind::DenseF16 => self.dense(a, b, "dense_f16", StorageFormat::F16),
            KernelKind::DenseFp8 => self.dense(
                a,
                b,
                "dense_fp8",
                StorageFormat::Fp8(crate::fp8::Fp8Format::E4M3),
            ),
            KernelKind::LowRankFp8 | KernelKind::LowRankAuto => {
                self.lowrank(kind, a, b, a_id, b_id, hints)
            }
        }
    }

    /// Square-lattice artifact lookup: (op, n) hit iff both operands are
    /// n×n and the manifest has the op at exactly n.
    fn artifact_for(&self, op: &str, a: &Matrix, b: &Matrix, rank: usize) -> Option<String> {
        let (xla, manifest) = self.xla.as_ref()?;
        let _ = xla;
        let n = a.rows();
        if a.shape() != (n, n) || b.shape() != (n, n) {
            return None;
        }
        manifest.lookup(op, n, rank).map(|e| e.name.clone())
    }

    fn dense(
        &self,
        a: &Matrix,
        b: &Matrix,
        op: &str,
        storage: StorageFormat,
    ) -> Result<ExecOutcome> {
        if let Some(name) = self.artifact_for(op, a, b, 0) {
            let (xla, _) = self.xla.as_ref().expect("artifact_for implies xla");
            let mut outs = xla.run(&name, vec![a.clone(), b.clone()])?;
            return Ok(ExecOutcome {
                c: outs.remove(0),
                backend: BackendKind::Xla,
                rank: 0,
            });
        }
        // CPU substrate, on the tile plane: the exact f32 path shards the
        // blocked GEMM; reduced precisions round-trip storage through the
        // software codecs (f32 accumulation inside, same as the kernels)
        // and shard the resulting product. Small requests fall back to
        // the single-threaded kernels inside the executor.
        let c = match storage {
            StorageFormat::F32 => self.shard.gemm(a, b)?,
            other => self.shard.quantized_matmul(a, b, other)?,
        };
        Ok(ExecOutcome {
            c,
            backend: BackendKind::CpuSubstrate,
            rank: 0,
        })
    }

    /// Fetch a factor from a cache or factorize now (charging the cold
    /// path — this is the miss cost the router's cost model anticipated).
    /// Identified operands resolve through the id-keyed cache; anonymous
    /// ones through the content cache when one is attached and the
    /// operand clears its admission gate. Cold decompositions run the
    /// panel-parallel randomized SVD on the tile plane either way.
    fn factor_of(
        &self,
        m: &Matrix,
        id: Option<MatrixId>,
        fp: Option<Fingerprint>,
    ) -> Result<LowRankFactor> {
        let mut sp = trace_plane::span("factor");
        sp.attr_u64("rows", m.rows() as u64);
        sp.attr_u64("cols", m.cols() as u64);
        if let Some(id) = id {
            return self.cache.get_or_insert_with(id, || {
                let _d = trace_plane::span("decompose");
                factorize_sharded(&self.shard, m, &self.lr_cfg)
            });
        }
        if let Some(cc) = &self.content {
            if cc.admits(m) {
                let fp = fp.unwrap_or_else(|| Fingerprint::of(m));
                // Non-packed lookup: A-side factors never consume the
                // pre-packed Vᵀ panels, so this path must not count
                // `pack.prepacked_hit`.
                return cc.get_or_insert_with(fp, || {
                    let _d = trace_plane::span("decompose");
                    factorize_sharded(&self.shard, m, &self.content_cfg)
                });
            }
        }
        let _d = trace_plane::span("decompose");
        factorize_sharded(&self.shard, m, &self.lr_cfg)
    }

    /// [`factor_of`](Backend::factor_of) keeping the content cache's
    /// pre-packed `Vᵀ` panels (when `[cache] prepack` stores them) so the
    /// B side of a factor chain can skip the reconstruction operand's
    /// decode-and-pack. Id-keyed and cold-path factors carry no panels.
    fn factor_of_packed(
        &self,
        m: &Matrix,
        id: Option<MatrixId>,
        fp: Option<Fingerprint>,
    ) -> Result<CachedFactor> {
        let mut sp = trace_plane::span("factor");
        sp.attr_u64("rows", m.rows() as u64);
        sp.attr_u64("cols", m.cols() as u64);
        if let Some(id) = id {
            let factor = self.cache.get_or_insert_with(id, || {
                let _d = trace_plane::span("decompose");
                factorize_sharded(&self.shard, m, &self.lr_cfg)
            })?;
            return Ok(CachedFactor {
                factor,
                packed_vt: None,
            });
        }
        if let Some(cc) = &self.content {
            if cc.admits(m) {
                // Reuse the router's fingerprint; hash here only when the
                // call arrived without a plan (direct `execute`).
                let fp = fp.unwrap_or_else(|| Fingerprint::of(m));
                return cc.get_or_insert_with_packed(fp, || {
                    let _d = trace_plane::span("decompose");
                    factorize_sharded(&self.shard, m, &self.content_cfg)
                });
            }
        }
        let _d = trace_plane::span("decompose");
        Ok(CachedFactor {
            factor: factorize_sharded(&self.shard, m, &self.lr_cfg)?,
            packed_vt: None,
        })
    }

    fn lowrank(
        &self,
        kind: KernelKind,
        a: &Matrix,
        b: &Matrix,
        a_id: Option<MatrixId>,
        b_id: Option<MatrixId>,
        hints: FactorHints,
    ) -> Result<ExecOutcome> {
        // Mixed factored×dense serving paths: when exactly one operand is
        // an identified (weight) matrix, keep the other dense — never pay
        // rSVD on an activation (paper §6.5: offline decomposition is for
        // stable matrices; on-the-fly factorization of transient operands
        // is the cost the router's cold path charges).
        match (a_id, b_id) {
            (Some(_), None) => {
                let fa = self.factor_of(a, a_id, None)?;
                let rank = fa.rank();
                let c = self.shard.lowrank_matmul_dense_rhs(&fa, b)?;
                return Ok(ExecOutcome {
                    c,
                    backend: BackendKind::CpuSubstrate,
                    rank,
                });
            }
            (None, Some(_)) => {
                let fb = self.factor_of(b, b_id, None)?;
                let rank = fb.rank();
                let c = self.shard.lowrank_matmul_dense_lhs(a, &fb)?;
                return Ok(ExecOutcome {
                    c,
                    backend: BackendKind::CpuSubstrate,
                    rank,
                });
            }
            _ => {}
        }

        let fa = self.factor_of(a, a_id, hints.a)?;
        let CachedFactor {
            factor: fb,
            packed_vt: fb_packed,
        } = self.factor_of_packed(b, b_id, hints.b)?;
        let rank = fa.rank().max(fb.rank());

        // XLA path needs equal ranks on the lattice (artifacts are lowered
        // at fixed r); the CPU factor-chain handles mixed ranks natively.
        let op = match kind {
            KernelKind::LowRankFp8 => "lowrank_apply_fp8",
            _ => "lowrank_apply",
        };
        if fa.rank() == fb.rank() {
            if let Some(name) = self.artifact_for(op, a, b, fa.rank()) {
                let (xla, _) = self.xla.as_ref().expect("artifact_for implies xla");
                // Merge the rank-sized core on the CPU (r² work), ship the
                // three factor operands to the artifact.
                let u_a = fa.u_dense();
                let vt_b = fb.vt_dense();
                let core = fa.core_with(&fb)?;
                let mut outs = xla.run(&name, vec![u_a, core, vt_b])?;
                return Ok(ExecOutcome {
                    c: outs.remove(0),
                    backend: BackendKind::Xla,
                    rank,
                });
            }
        }

        let c = self
            .shard
            .lowrank_matmul_prepacked(&fa, &fb, fb_packed.as_ref())?;
        Ok(ExecOutcome {
            c,
            backend: BackendKind::CpuSubstrate,
            rank,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Pcg64;

    fn cpu_backend() -> Backend {
        Backend::new(
            None,
            Arc::new(FactorCache::new(64 << 20)),
            LowRankConfig::default(),
        )
    }

    #[test]
    fn dense_f32_matches_reference() {
        let mut rng = Pcg64::seeded(3);
        let a = Matrix::gaussian(33, 47, &mut rng);
        let b = Matrix::gaussian(47, 29, &mut rng);
        let out = cpu_backend()
            .execute(KernelKind::DenseF32, &a, &b, None, None)
            .unwrap();
        assert_eq!(out.backend, BackendKind::CpuSubstrate);
        let exact = a.matmul(&b);
        assert!(out.c.rel_frobenius_distance(&exact) < 1e-6);
    }

    #[test]
    fn fp8_dense_error_band() {
        let mut rng = Pcg64::seeded(4);
        let a = Matrix::gaussian(64, 64, &mut rng);
        let b = Matrix::gaussian(64, 64, &mut rng);
        let out = cpu_backend()
            .execute(KernelKind::DenseFp8, &a, &b, None, None)
            .unwrap();
        let exact = a.matmul(&b);
        let err = out.c.rel_frobenius_distance(&exact);
        // §5.4: fp8 quantization error is percent-level, not exact.
        assert!(err > 1e-5 && err < 0.2, "err = {err}");
    }

    #[test]
    fn lowrank_on_lowrank_matrix_is_accurate() {
        let mut rng = Pcg64::seeded(5);
        let a = Matrix::low_rank_noisy(96, 96, 6, 1e-5, &mut rng);
        let b = Matrix::low_rank_noisy(96, 96, 6, 1e-5, &mut rng);
        let be = cpu_backend();
        let out = be
            .execute(KernelKind::LowRankAuto, &a, &b, Some(11), Some(12))
            .unwrap();
        assert!(out.rank >= 1);
        let exact = a.matmul(&b);
        let err = out.c.rel_frobenius_distance(&exact);
        assert!(err < 0.05, "err = {err}");
        // Second call hits the cache.
        let _ = be
            .execute(KernelKind::LowRankAuto, &a, &b, Some(11), Some(12))
            .unwrap();
        assert!(be.cache.stats().hits >= 2);
    }

    #[test]
    fn content_cache_hit_is_bitwise_identical_to_cold() {
        let cc = Arc::new(ContentCache::new(64 << 20, 32));
        let be = Backend::new(
            None,
            Arc::new(FactorCache::new(64 << 20)),
            LowRankConfig::default(),
        )
        .with_content_cache(cc.clone(), &CacheSettings::default());

        let mut rng = Pcg64::seeded(6);
        let a = Matrix::low_rank_noisy(96, 96, 6, 1e-5, &mut rng);
        let b = Matrix::low_rank_noisy(96, 96, 6, 1e-5, &mut rng);
        // Anonymous operands: the cold call decomposes and fills the
        // content cache, the second call serves off it — bit-for-bit.
        let cold = be
            .execute(KernelKind::LowRankFp8, &a, &b, None, None)
            .unwrap();
        assert_eq!(cc.stats().entries, 2);
        let warm = be
            .execute(KernelKind::LowRankFp8, &a, &b, None, None)
            .unwrap();
        assert_eq!(cold.c.data(), warm.c.data(), "hit must replay the cold bits");
        assert_eq!(cc.stats().hits, 2);
        assert_eq!(cc.stats().misses, 2);
    }

    #[test]
    fn prepacked_content_cache_hit_is_bitwise_identical() {
        // `[cache] prepack`: the hit serves Vᵀ as ready-made kernel
        // panels. Results must match both the cold fill and a cache
        // without prepacking, bit for bit.
        let cc = Arc::new(ContentCache::new(64 << 20, 32).with_prepack(true));
        let be = Backend::new(
            None,
            Arc::new(FactorCache::new(64 << 20)),
            LowRankConfig::default(),
        )
        .with_content_cache(cc.clone(), &CacheSettings::default());

        let mut rng = Pcg64::seeded(8);
        // Large enough that the reconstruction product clears the naive
        // cutover, so the prepacked panels are actually consumed.
        let a = Matrix::low_rank_noisy(384, 384, 8, 1e-5, &mut rng);
        let b = Matrix::low_rank_noisy(384, 384, 8, 1e-5, &mut rng);
        let cold = be
            .execute(KernelKind::LowRankFp8, &a, &b, None, None)
            .unwrap();
        let warm = be
            .execute(KernelKind::LowRankFp8, &a, &b, None, None)
            .unwrap();
        assert_eq!(cold.c.data(), warm.c.data(), "hit must replay cold bits");

        let plain_cc = Arc::new(ContentCache::new(64 << 20, 32));
        let plain = Backend::new(
            None,
            Arc::new(FactorCache::new(64 << 20)),
            LowRankConfig::default(),
        )
        .with_content_cache(plain_cc, &CacheSettings::default())
        .execute(KernelKind::LowRankFp8, &a, &b, None, None)
        .unwrap();
        assert_eq!(
            plain.c.data(),
            cold.c.data(),
            "prepacked panels must not change the chain's bits"
        );
    }

    #[test]
    fn content_cache_gate_keeps_small_operands_out() {
        let cc = Arc::new(ContentCache::new(64 << 20, 512));
        let be = Backend::new(
            None,
            Arc::new(FactorCache::new(64 << 20)),
            LowRankConfig::default(),
        )
        .with_content_cache(cc.clone(), &CacheSettings::default());
        let mut rng = Pcg64::seeded(7);
        let a = Matrix::low_rank_noisy(64, 64, 4, 1e-5, &mut rng);
        let b = Matrix::low_rank_noisy(64, 64, 4, 1e-5, &mut rng);
        be.execute(KernelKind::LowRankFp8, &a, &b, None, None)
            .unwrap();
        assert_eq!(cc.stats().entries, 0, "below min_dim nothing is cached");
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let a = Matrix::zeros(4, 5);
        let b = Matrix::zeros(6, 4);
        assert!(cpu_backend()
            .execute(KernelKind::DenseF32, &a, &b, None, None)
            .is_err());
    }
}
