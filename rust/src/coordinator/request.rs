//! Request/response types for the GEMM serving API.

use std::time::Duration;

use crate::fault::DegradeReason;
use crate::kernels::KernelKind;
use crate::linalg::Matrix;
use crate::lowrank::cache::MatrixId;

/// Stable tenant identity for per-tenant fair dequeue and quotas.
pub type TenantId = u64;

/// Scheduling priority class. Under `[scheduler]` admission control,
/// priorities shed lowest-first as the backlog grows (Background gives up
/// queue room first, Interactive last) and dequeue highest-first. The
/// legacy two-pool service ignores them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive foreground traffic: dequeued first, admitted up
    /// to the full queue depth.
    Interactive,
    /// The default class — today's behavior for callers that never set a
    /// priority.
    #[default]
    Batch,
    /// Best-effort traffic: first to shed under overload.
    Background,
}

impl Priority {
    /// Lane index, 0 = most urgent.
    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
            Priority::Background => 2,
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
            Priority::Background => "background",
        }
    }
}

/// A single GEMM request: `C = A · B` plus routing hints.
///
/// `a_id`/`b_id` are stable matrix identities (e.g. a weight tensor id in
/// a model). They unlock the paper's *offline decomposition* path: factors
/// for identified matrices live in the [`crate::lowrank::FactorCache`]
/// across requests, so the low-rank path skips factorization entirely.
/// Anonymous operands (activations) are factorized on the fly — and the
/// cost model charges them for it, which is why small anonymous GEMMs
/// route to dense kernels.
#[derive(Clone, Debug)]
pub struct GemmRequest {
    /// Left operand (m × k).
    pub a: Matrix,
    /// Right operand (k × n).
    pub b: Matrix,
    /// Stable identity of A for factor caching (None = anonymous).
    pub a_id: Option<MatrixId>,
    /// Stable identity of B for factor caching (None = anonymous).
    pub b_id: Option<MatrixId>,
    /// Relative-error tolerance; None uses the service default.
    pub error_tolerance: Option<f32>,
    /// Force a specific kernel, bypassing the AutoKernelSelector.
    pub kernel: Option<KernelKind>,
    /// Will the caller accept a factored (non-materialized) result?
    /// (The "LowRank Auto" fastest path in the paper's Table 1.)
    pub factored_output_ok: bool,
    /// Scheduling priority (QoS class). Default [`Priority::Batch`]
    /// preserves the historical behavior.
    pub priority: Priority,
    /// Completion deadline, measured from `submit`. Under `[scheduler]`
    /// admission control a provably unmeetable deadline is rejected at
    /// submit time; `None` (the default) never deadline-rejects.
    pub deadline: Option<Duration>,
    /// Tenant identity for fair dequeue and per-tenant quotas. `None`
    /// (the default) is the shared anonymous tenant.
    pub tenant: Option<TenantId>,
}

impl GemmRequest {
    /// A plain anonymous request with service-default routing.
    pub fn new(a: Matrix, b: Matrix) -> Self {
        GemmRequest {
            a,
            b,
            a_id: None,
            b_id: None,
            error_tolerance: None,
            kernel: None,
            factored_output_ok: false,
            priority: Priority::default(),
            deadline: None,
            tenant: None,
        }
    }

    /// Attach stable operand identities (weights).
    pub fn with_ids(mut self, a_id: Option<MatrixId>, b_id: Option<MatrixId>) -> Self {
        self.a_id = a_id;
        self.b_id = b_id;
        self
    }

    /// Set the error tolerance.
    pub fn with_tolerance(mut self, tol: f32) -> Self {
        self.error_tolerance = Some(tol);
        self
    }

    /// Force a kernel.
    pub fn with_kernel(mut self, kind: KernelKind) -> Self {
        self.kernel = Some(kind);
        self
    }

    /// Set the scheduling priority.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Set a completion deadline (measured from `submit`).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Attach a tenant identity.
    pub fn with_tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = Some(tenant);
        self
    }

    /// GEMM shape (m, k, n).
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.a.rows(), self.a.cols(), self.b.cols())
    }

    /// Shapes compose?
    pub fn shape_ok(&self) -> bool {
        self.a.cols() == self.b.rows()
    }
}

/// Which execution substrate actually ran the kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT-compiled XLA artifact via the PJRT CPU client.
    Xla,
    /// Native Rust linalg/lowrank substrate.
    CpuSubstrate,
}

impl BackendKind {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Xla => "xla",
            BackendKind::CpuSubstrate => "cpu",
        }
    }
}

/// The completed GEMM.
#[derive(Clone, Debug)]
pub struct GemmResponse {
    /// Monotonic request id assigned by the service.
    pub id: u64,
    /// The (materialized) product.
    pub c: Matrix,
    /// Kernel that produced it.
    pub kernel: KernelKind,
    /// Execution substrate.
    pub backend: BackendKind,
    /// Rank used by the low-rank path (0 for dense kernels).
    pub rank: usize,
    /// Selector's predicted relative error.
    pub predicted_rel_error: f32,
    /// Time spent queued + batched, microseconds.
    pub queue_us: u64,
    /// Kernel execution time, microseconds.
    pub exec_us: u64,
    /// How many requests shared this batch.
    pub batch_size: usize,
    /// Time spent in admission + routing at `submit`, microseconds —
    /// the scheduling cost the caller paid before the request queued.
    pub sched_us: u64,
    /// Tiles of this request that ran inside *stolen* helper jobs on the
    /// unified scheduler. 0 on the legacy two-pool configuration (and for
    /// requests too small to shard).
    pub stolen_tiles: u64,
    /// `Some` when the fault plane served this request on a kernel lower
    /// on the degradation ladder than the routed one (breaker-open
    /// reroute, or a retry after the routed kernel failed/panicked).
    /// `kernel` above is always the kernel that actually produced `c`.
    /// Always `None` with `[fault]` disabled.
    pub degraded: Option<DegradeReason>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn builder_roundtrip() {
        let r = GemmRequest::new(Matrix::zeros(4, 6), Matrix::zeros(6, 8))
            .with_ids(Some(7), None)
            .with_tolerance(0.02)
            .with_kernel(KernelKind::DenseF32);
        assert_eq!(r.shape(), (4, 6, 8));
        assert!(r.shape_ok());
        assert_eq!(r.a_id, Some(7));
        assert_eq!(r.error_tolerance, Some(0.02));
        assert_eq!(r.kernel, Some(KernelKind::DenseF32));
        // QoS defaults preserve today's behavior.
        assert_eq!(r.priority, Priority::Batch);
        assert_eq!(r.deadline, None);
        assert_eq!(r.tenant, None);
    }

    #[test]
    fn qos_builders_roundtrip() {
        let r = GemmRequest::new(Matrix::zeros(4, 6), Matrix::zeros(6, 8))
            .with_priority(Priority::Interactive)
            .with_deadline(Duration::from_millis(5))
            .with_tenant(42);
        assert_eq!(r.priority, Priority::Interactive);
        assert_eq!(r.deadline, Some(Duration::from_millis(5)));
        assert_eq!(r.tenant, Some(42));
    }

    #[test]
    fn priority_lane_order() {
        assert_eq!(Priority::Interactive.index(), 0);
        assert_eq!(Priority::Batch.index(), 1);
        assert_eq!(Priority::Background.index(), 2);
        assert!(Priority::Interactive < Priority::Batch);
        assert_eq!(Priority::Background.name(), "background");
    }

    #[test]
    fn shape_mismatch_detected() {
        let r = GemmRequest::new(Matrix::zeros(4, 5), Matrix::zeros(6, 8));
        assert!(!r.shape_ok());
    }
}
