//! Request/response types for the GEMM serving API.

use crate::kernels::KernelKind;
use crate::linalg::Matrix;
use crate::lowrank::cache::MatrixId;

/// A single GEMM request: `C = A · B` plus routing hints.
///
/// `a_id`/`b_id` are stable matrix identities (e.g. a weight tensor id in
/// a model). They unlock the paper's *offline decomposition* path: factors
/// for identified matrices live in the [`crate::lowrank::FactorCache`]
/// across requests, so the low-rank path skips factorization entirely.
/// Anonymous operands (activations) are factorized on the fly — and the
/// cost model charges them for it, which is why small anonymous GEMMs
/// route to dense kernels.
#[derive(Clone, Debug)]
pub struct GemmRequest {
    /// Left operand (m × k).
    pub a: Matrix,
    /// Right operand (k × n).
    pub b: Matrix,
    /// Stable identity of A for factor caching (None = anonymous).
    pub a_id: Option<MatrixId>,
    /// Stable identity of B for factor caching (None = anonymous).
    pub b_id: Option<MatrixId>,
    /// Relative-error tolerance; None uses the service default.
    pub error_tolerance: Option<f32>,
    /// Force a specific kernel, bypassing the AutoKernelSelector.
    pub kernel: Option<KernelKind>,
    /// Will the caller accept a factored (non-materialized) result?
    /// (The "LowRank Auto" fastest path in the paper's Table 1.)
    pub factored_output_ok: bool,
}

impl GemmRequest {
    /// A plain anonymous request with service-default routing.
    pub fn new(a: Matrix, b: Matrix) -> Self {
        GemmRequest {
            a,
            b,
            a_id: None,
            b_id: None,
            error_tolerance: None,
            kernel: None,
            factored_output_ok: false,
        }
    }

    /// Attach stable operand identities (weights).
    pub fn with_ids(mut self, a_id: Option<MatrixId>, b_id: Option<MatrixId>) -> Self {
        self.a_id = a_id;
        self.b_id = b_id;
        self
    }

    /// Set the error tolerance.
    pub fn with_tolerance(mut self, tol: f32) -> Self {
        self.error_tolerance = Some(tol);
        self
    }

    /// Force a kernel.
    pub fn with_kernel(mut self, kind: KernelKind) -> Self {
        self.kernel = Some(kind);
        self
    }

    /// GEMM shape (m, k, n).
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.a.rows(), self.a.cols(), self.b.cols())
    }

    /// Shapes compose?
    pub fn shape_ok(&self) -> bool {
        self.a.cols() == self.b.rows()
    }
}

/// Which execution substrate actually ran the kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT-compiled XLA artifact via the PJRT CPU client.
    Xla,
    /// Native Rust linalg/lowrank substrate.
    CpuSubstrate,
}

impl BackendKind {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Xla => "xla",
            BackendKind::CpuSubstrate => "cpu",
        }
    }
}

/// The completed GEMM.
#[derive(Clone, Debug)]
pub struct GemmResponse {
    /// Monotonic request id assigned by the service.
    pub id: u64,
    /// The (materialized) product.
    pub c: Matrix,
    /// Kernel that produced it.
    pub kernel: KernelKind,
    /// Execution substrate.
    pub backend: BackendKind,
    /// Rank used by the low-rank path (0 for dense kernels).
    pub rank: usize,
    /// Selector's predicted relative error.
    pub predicted_rel_error: f32,
    /// Time spent queued + batched, microseconds.
    pub queue_us: u64,
    /// Kernel execution time, microseconds.
    pub exec_us: u64,
    /// How many requests shared this batch.
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn builder_roundtrip() {
        let r = GemmRequest::new(Matrix::zeros(4, 6), Matrix::zeros(6, 8))
            .with_ids(Some(7), None)
            .with_tolerance(0.02)
            .with_kernel(KernelKind::DenseF32);
        assert_eq!(r.shape(), (4, 6, 8));
        assert!(r.shape_ok());
        assert_eq!(r.a_id, Some(7));
        assert_eq!(r.error_tolerance, Some(0.02));
        assert_eq!(r.kernel, Some(KernelKind::DenseF32));
    }

    #[test]
    fn shape_mismatch_detected() {
        let r = GemmRequest::new(Matrix::zeros(4, 5), Matrix::zeros(6, 8));
        assert!(!r.shape_ok());
    }
}
