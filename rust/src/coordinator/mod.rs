//! The serving coordinator — the paper's system contribution, L3.
//!
//! A GEMM request router + dynamic batcher in the vLLM-router shape:
//!
//! - [`request`]: the public request/response types,
//! - [`router`]: AutoKernelSelector-driven routing (kernel, rank, cache),
//! - [`batcher`]: size-bucketed dynamic batching with a flush window,
//! - [`backend`]: kernel execution over XLA artifacts or CPU substrate,
//! - [`service`]: [`GemmService`] — queue, dispatcher, worker pool (or
//!   the unified `[scheduler]` steal pool), admission control /
//!   backpressure, metrics, offline-decomposition API.

pub mod backend;
pub mod batcher;
pub mod request;
pub mod router;
pub mod service;

pub use backend::{Backend, ExecOutcome};
pub use batcher::{Batcher, BucketKey};
pub use request::{BackendKind, GemmRequest, GemmResponse, Priority, TenantId};
pub use router::{RoutePlan, Router, RouterConfig};
pub use service::{GemmService, ServiceConfig, ServiceStats};
