//! Request routing: from a [`GemmRequest`] to an executable plan.
//!
//! The router is the serving-side face of the paper's Listing-1
//! `AutoKernelSelector`: for each request it
//!
//! 1. estimates the rank the low-rank path would use (strategy-driven),
//! 2. consults the factor cache (offline decomposition — cached weights
//!    make the low-rank path dramatically cheaper),
//! 3. asks the selector for the cheapest kernel within tolerance,
//! 4. decides the execution substrate (XLA artifact if the shape sits on
//!    the AOT lattice, native CPU substrate otherwise — the paper's
//!    "automatic fallback").

use std::sync::Arc;

use crate::autotune::{CalibrationTable, ExplorePolicy};
use crate::cache::{ContentCache, FactorHints, Fingerprint};
use crate::config::schema::{AutotuneSettings, CacheSettings};
use crate::fault::{DegradeReason, FaultPlane};
use crate::gpu_sim::profile::DeviceProfile;
use crate::kernels::{AutoKernelSelector, KernelChoice, SelectorInputs};
use crate::lowrank::cache::FactorCache;
use crate::lowrank::factor::{DecompMethod, LowRankConfig};
use crate::lowrank::rank::{select_rank, RankStrategy};
use crate::coordinator::request::GemmRequest;
use crate::shard::ShardPlan;

/// Everything a worker needs to execute one request.
#[derive(Clone, Debug)]
pub struct RoutePlan {
    /// Kernel the selector picked (or the request forced).
    pub choice: KernelChoice,
    /// Rank for the low-rank path (estimate used for routing; the actual
    /// factorization may refine it when an adaptive strategy is active).
    pub rank: usize,
    /// Were both operands' factors already cached at routing time?
    pub factors_cached: bool,
    /// The effective error tolerance applied.
    pub tolerance: f32,
    /// Did the ε-greedy autotune policy override the model's best choice
    /// (an exploration request feeding the calibration table)?
    pub explored: bool,
    /// Content-addressed fingerprints of anonymous operands (factor-cache
    /// plane), computed once here so the backend never re-hashes. Both
    /// `None` whenever the plane is off or the operands are identified.
    pub hints: FactorHints,
    /// Was the decomposition charge amortized (`decomp_amortization > 1`)
    /// in this plan's cost inputs? Amortized predictions deliberately
    /// under-state the *this-request* cost of a miss, so the autotune
    /// plane must not fold such requests into its observed/predicted
    /// calibration — the service checks this flag before recording.
    pub amortized: bool,
    /// `Some` when the fault plane rerouted this plan at route time
    /// because the preferred kernel's circuit breaker was open. `choice`
    /// already reflects the fallback kernel. Always `None` with `[fault]`
    /// disabled.
    pub degraded: Option<DegradeReason>,
}

/// Routing configuration (a distilled view of [`crate::config::AppConfig`]).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Device profile the cost model optimizes for.
    pub device: DeviceProfile,
    /// Rank strategy for the low-rank path.
    pub rank_strategy: RankStrategy,
    /// Decomposition method for on-the-fly factorization.
    pub decomp: DecompMethod,
    /// Storage precision for factors.
    pub storage: crate::fp8::StorageFormat,
    /// Tolerance when the request doesn't carry one.
    pub default_tolerance: f32,
    /// Shard plan of the serving tile-execution plane; feeds the cost
    /// model's parallel-speedup term so routing stays calibrated against
    /// the substrate that actually executes. Inside the service this is
    /// derived from `ServiceConfig::shard` (which wins over a hand-set
    /// value) — set it directly only for a standalone [`Router`].
    pub shard: ShardPlan,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            device: DeviceProfile::rtx4090(),
            rank_strategy: RankStrategy::EnergyFraction(0.99),
            decomp: DecompMethod::RandomizedSvd,
            storage: crate::fp8::StorageFormat::Fp8(crate::fp8::Fp8Format::E4M3),
            default_tolerance: 0.05,
            shard: ShardPlan::default(),
        }
    }
}

/// The router.
pub struct Router {
    selector: AutoKernelSelector,
    cfg: RouterConfig,
    cache: Arc<FactorCache>,
    /// ε-greedy exploration (autotune); `None` routes purely greedily.
    explore: Option<ExplorePolicy>,
    /// Content-addressed factor cache (the `[cache]` plane); `None` keeps
    /// routing bit-identical to the id-only world.
    content: Option<(Arc<ContentCache>, CacheSettings)>,
    /// Fault plane (the `[fault]` plane): routing consults each choice's
    /// circuit breaker and walks the degradation ladder away from tripped
    /// kernels. `None` keeps routing bit-identical.
    fault: Option<Arc<FaultPlane>>,
}

impl Router {
    /// Build a router over a shared factor cache.
    pub fn new(cfg: RouterConfig, cache: Arc<FactorCache>) -> Self {
        Router {
            selector: AutoKernelSelector::with_shard(cfg.device.clone(), cfg.shard),
            cfg,
            cache,
            explore: None,
            content: None,
            fault: None,
        }
    }

    /// Build a router with the online autotuning plane attached: the
    /// selector blends `table`'s measured corrections into its cost
    /// model, and routing explores ε-greedily so every in-tolerance
    /// kernel keeps receiving fresh calibration samples.
    pub fn with_autotune(
        cfg: RouterConfig,
        cache: Arc<FactorCache>,
        table: Arc<CalibrationTable>,
        settings: &AutotuneSettings,
    ) -> Self {
        let selector = AutoKernelSelector::with_shard(cfg.device.clone(), cfg.shard)
            .with_calibration(table);
        let explore = (settings.epsilon > 0.0)
            .then(|| ExplorePolicy::new(settings.epsilon, settings.explore_seed));
        Router {
            selector,
            cfg,
            cache,
            explore,
            content: None,
            fault: None,
        }
    }

    /// Attach the content-addressed factor cache (builder-style): routing
    /// then fingerprints anonymous operands that clear the admission
    /// gate, treats resident fingerprints as cached factors, and
    /// amortizes the decomposition charge of cacheable misses over the
    /// plane's expected reuse count.
    pub fn with_content_cache(
        mut self,
        content: Arc<ContentCache>,
        settings: CacheSettings,
    ) -> Self {
        self.content = Some((content, settings));
        self
    }

    /// Attach the accuracy plane's calibrated error model
    /// (builder-style): the selector's tolerance gate then routes on
    /// probed rather than assumed accuracy. An unprobed model is
    /// bit-identical to no model at all.
    pub fn with_error_model(mut self, model: Arc<crate::accuracy::ErrorModel>) -> Self {
        self.selector = self.selector.with_error_model(model);
        self
    }

    /// Attach the fault plane (builder-style): routing then consults the
    /// per-kernel circuit breaker — a choice (selected, explored, or
    /// forced) whose breaker is open is rerouted down the degradation
    /// ladder and the plan flagged `degraded`.
    pub fn with_fault(mut self, fault: Arc<FaultPlane>) -> Self {
        self.fault = Some(fault);
        self
    }

    /// The routing-time rank estimate for an (m, k, n) GEMM.
    ///
    /// Spectrum-dependent strategies (energy / error-bound) cannot know
    /// the true rank before factorization; for *routing* they estimate
    /// with the paper's empirical r ≈ n/16 working point (§5.5 uses
    /// r = 512 at N = 20480 ≈ n/40; n/16 is deliberately conservative so
    /// the cost model does not under-charge the low-rank path).
    pub fn rank_estimate(&self, m: usize, k: usize, n: usize) -> usize {
        let edge = m.min(k).min(n);
        match self.cfg.rank_strategy {
            RankStrategy::Fixed(_)
            | RankStrategy::FixedFraction(_)
            | RankStrategy::HardwareAware { .. } => {
                select_rank(&self.cfg.rank_strategy, m.min(k), k.min(n), &[], &self.cfg.device)
            }
            RankStrategy::EnergyFraction(_) | RankStrategy::ErrorBound(_) => {
                (edge / 16).clamp(1, edge.max(1))
            }
        }
    }

    /// The low-rank configuration workers use for on-the-fly factorization.
    pub fn lowrank_config(&self) -> LowRankConfig {
        LowRankConfig {
            rank: self.cfg.rank_strategy,
            method: self.cfg.decomp,
            storage: self.cfg.storage,
            rsvd: Default::default(),
        }
    }

    /// Shared factor cache.
    pub fn cache(&self) -> &Arc<FactorCache> {
        &self.cache
    }

    /// Route one request deterministically (pure model-best, never
    /// explores): the introspection surface (`GemmService::plan`) and
    /// un-recorded paths (`execute_inline`) must not consume the
    /// exploration RNG or return deliberately non-optimal kernels.
    pub fn route(&self, req: &GemmRequest) -> RoutePlan {
        self.route_inner(req, false)
    }

    /// Route one request for serving: like [`route`](Router::route), but
    /// the ε-greedy policy may override the model's best choice — only
    /// the serving path records observed latencies, so only it should
    /// pay for exploration.
    pub fn route_serving(&self, req: &GemmRequest) -> RoutePlan {
        self.route_inner(req, true)
    }

    fn route_inner(&self, req: &GemmRequest, may_explore: bool) -> RoutePlan {
        let (m, k, n) = req.shape();
        let rank = self.rank_estimate(m, k, n);
        let tolerance = req.error_tolerance.unwrap_or(self.cfg.default_tolerance);

        // Factor-cache plane: fingerprint fully-anonymous operands that
        // clear the admission gate (once — the backend reuses the hints).
        // Mixed requests (one identified operand) keep the anonymous side
        // dense on the execution path, so hashing it would buy nothing.
        let mut hints = FactorHints::default();
        if req.a_id.is_none() && req.b_id.is_none() {
            if let Some((cc, _)) = &self.content {
                let mut sp = crate::trace_plane::span("fingerprint");
                if cc.admits(&req.a) {
                    hints.a = Some(Fingerprint::of(&req.a));
                }
                if cc.admits(&req.b) {
                    hints.b = Some(Fingerprint::of(&req.b));
                }
                sp.attr_u64("hashed", hints.a.is_some() as u64 + hints.b.is_some() as u64);
            }
        }

        // "Cached" means: no factorization will be charged at execution
        // time. Identified operands must be resident in the id cache;
        // anonymous operands paired with an identified one stay dense
        // (the mixed factored×dense serving path) and cost nothing to
        // decompose; fully-anonymous pairs count as cached when both
        // fingerprints are resident in the content cache.
        let factors_cached = match (req.a_id, req.b_id) {
            (Some(a), Some(b)) => self.cache.contains(a) && self.cache.contains(b),
            (Some(a), None) => self.cache.contains(a),
            (None, Some(b)) => self.cache.contains(b),
            (None, None) => match (&self.content, hints.a, hints.b) {
                (Some((cc, _)), Some(af), Some(bf)) => cc.contains(af) && cc.contains(bf),
                _ => false,
            },
        };

        // Amortized-decomposition term: a miss whose factors will land in
        // a cache (the id cache for identified operands, the content
        // cache for fingerprinted ones) is priced at cold-cost /
        // amortize_over — the workload decomposes once and serves many
        // requests off the factors. One cacheable operand is enough to
        // engage the credit: for the asymmetric serving shape (large
        // reusable weight × below-gate activation) the weight dominates
        // the decomposition charge, and refusing all credit until *both*
        // operands qualify would keep the plane from ever flipping the
        // selector there. The term is coarse — it divides both operands'
        // charges — but over-crediting a below-gate operand's (cheap)
        // decomposition distorts far less than full cold pricing of the
        // resident-side one.
        let decomp_amortization = match &self.content {
            Some((_, set)) if !factors_cached => {
                let cacheable = match (req.a_id, req.b_id) {
                    (None, None) => hints.a.is_some() || hints.b.is_some(),
                    _ => true,
                };
                if cacheable {
                    set.amortize_over as f64
                } else {
                    1.0
                }
            }
            _ => 1.0,
        };

        // FP8 re-encode charge: when the content cache stores factors
        // FP8-encoded, a fingerprinted request's factors round-trip
        // through the codec (on the fill and on every hit), an error
        // source the analytic model used to leave uncharged.
        let fp8_reencode = match &self.content {
            Some((_, set)) => set.fp8 && (hints.a.is_some() || hints.b.is_some()),
            None => false,
        };

        let inp = SelectorInputs {
            m,
            k,
            n,
            error_tolerance: tolerance,
            rank,
            factors_cached,
            factored_output_ok: req.factored_output_ok,
            decomp_amortization,
            fp8_reencode,
        };

        let mut explored = false;
        let explore = if may_explore {
            self.explore.as_ref()
        } else {
            None
        };
        let choice = match req.kernel {
            Some(kind) => self.selector.estimate(kind, &inp),
            None => match explore.filter(|p| p.roll()) {
                // ε-greedy leg of the autotune loop (the roll comes
                // first, so at small ε the common exploitation path
                // pays one RNG draw and a single ranked() pass): serve
                // this request on a uniformly chosen non-best kernel,
                // restricted to kernels whose predicted error still
                // fits the tolerance — exploration trades latency for
                // calibration data, never accuracy.
                Some(policy) => {
                    let ranked = self.selector.ranked(&inp);
                    let best = AutoKernelSelector::select_from(&ranked, &inp);
                    let alternatives: Vec<KernelChoice> = ranked
                        .into_iter()
                        .filter(|c| {
                            c.kind != best.kind && c.predicted_error <= inp.error_tolerance
                        })
                        .collect();
                    match policy.choose(&alternatives) {
                        Some(alt) => {
                            explored = true;
                            alt
                        }
                        None => best,
                    }
                }
                None => self.selector.select(&inp),
            },
        };

        // Fault plane: breaker consult, serving path only — `allows`
        // advances the open-state cooldown and may admit the single
        // half-open probe, so the introspection path (`route`) must not
        // consume either. Applies to selected, explored and forced
        // kernels alike: a tripped kernel family is unhealthy no matter
        // how the request arrived at it.
        let mut degraded = None;
        let choice = match &self.fault {
            Some(plane) if may_explore => match plane.reroute(choice.kind) {
                Some((fallback, reason)) => {
                    degraded = Some(reason);
                    self.selector.estimate(fallback, &inp)
                }
                None => choice,
            },
            _ => choice,
        };

        RoutePlan {
            choice,
            rank,
            factors_cached,
            tolerance,
            explored,
            hints,
            amortized: decomp_amortization > 1.0,
            degraded,
        }
    }

    /// The content-addressed factor cache, when the `[cache]` plane is on.
    pub fn content_cache(&self) -> Option<&Arc<ContentCache>> {
        self.content.as_ref().map(|(cc, _)| cc)
    }

    /// Expose the selector (benchmarks want `ranked()`).
    pub fn selector(&self) -> &AutoKernelSelector {
        &self.selector
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelKind;
    use crate::linalg::{Matrix, Pcg64};

    fn router() -> Router {
        Router::new(RouterConfig::default(), Arc::new(FactorCache::new(64 << 20)))
    }

    fn req(n: usize) -> GemmRequest {
        let mut rng = Pcg64::seeded(1);
        GemmRequest::new(
            Matrix::gaussian(n, n, &mut rng),
            Matrix::gaussian(n, n, &mut rng),
        )
    }

    #[test]
    fn small_anonymous_requests_go_dense() {
        let r = router();
        let plan = r.route(&req(256));
        assert!(!plan.choice.kind.is_lowrank(), "got {:?}", plan.choice.kind);
    }

    #[test]
    fn kernel_override_is_respected() {
        let r = router();
        let plan = r.route(&req(64).with_kernel(KernelKind::LowRankFp8));
        assert_eq!(plan.choice.kind, KernelKind::LowRankFp8);
    }

    #[test]
    fn tight_tolerance_forces_accurate_kernel() {
        let r = router();
        let plan = r.route(&req(128).with_tolerance(1e-5));
        assert_eq!(plan.choice.kind, KernelKind::DenseF32);
    }

    #[test]
    fn cached_factors_flip_the_choice_at_scale() {
        // With both factors cached, the low-rank path skips factorization
        // and wins at sizes where the cold path would not.
        let r = router();
        let mut rng = Pcg64::seeded(2);
        let n = 4096;
        // Fake "cached" state by inserting factors under the ids.
        let a = Matrix::low_rank(64, 64, 8, &mut rng);
        let cfg = r.lowrank_config();
        let fa = crate::lowrank::factorize(&a, &cfg).unwrap();
        r.cache().put(1, fa.clone());
        r.cache().put(2, fa);

        let mut request = req(64).with_ids(Some(1), Some(2));
        request.a = Matrix::zeros(n, n);
        request.b = Matrix::zeros(n, n);
        let plan = r.route(&request);
        assert!(plan.factors_cached);
        // At n=4096 with cached factors + 5% tolerance the cost model
        // must prefer a low-rank kernel (crossover analysis, Fig. 1).
        assert!(plan.choice.kind.is_lowrank(), "got {:?}", plan.choice.kind);
    }

    #[test]
    fn rank_estimate_spectrum_free_strategies() {
        let cfg = RouterConfig {
            rank_strategy: RankStrategy::Fixed(12),
            ..Default::default()
        };
        let r = Router::new(cfg, Arc::new(FactorCache::new(1 << 20)));
        assert_eq!(r.rank_estimate(256, 256, 256), 12);
    }

    fn content_router(settings: CacheSettings) -> (Router, Arc<ContentCache>) {
        let cc = Arc::new(ContentCache::new(settings.budget_bytes(), settings.min_dim));
        let r = Router::new(RouterConfig::default(), Arc::new(FactorCache::new(1 << 20)))
            .with_content_cache(cc.clone(), settings);
        (r, cc)
    }

    fn small_settings() -> CacheSettings {
        CacheSettings {
            enabled: true,
            min_dim: 32,
            ..Default::default()
        }
    }

    #[test]
    fn content_cached_anonymous_operands_flip_routing() {
        // Anonymous operands whose fingerprints are resident route like
        // preloaded weights: no decomposition charged, low-rank wins at
        // sizes where the cold path would not.
        let (r, cc) = content_router(small_settings());
        let n = 4096;
        let mut request = req(64);
        request.a = Matrix::zeros(n, n);
        request.b = Matrix::zeros(n, n);

        let before = r.route(&request);
        assert!(!before.factors_cached);
        assert_eq!(before.hints.a.map(|f| f.shape()), Some((n, n)));

        // Pin (small) factors under the operands' fingerprints — routing
        // only consults presence, never the payload.
        let mut rng = Pcg64::seeded(21);
        let w = Matrix::low_rank(64, 64, 8, &mut rng);
        let f = crate::lowrank::factorize(&w, &r.lowrank_config()).unwrap();
        cc.put(Fingerprint::of(&request.a), f.clone());
        cc.put(Fingerprint::of(&request.b), f);

        let plan = r.route(&request);
        assert!(plan.factors_cached);
        assert!(plan.choice.kind.is_lowrank(), "got {:?}", plan.choice.kind);
    }

    #[test]
    fn admission_gate_skips_fingerprinting() {
        let (r, _) = content_router(CacheSettings {
            enabled: true,
            min_dim: 512,
            ..Default::default()
        });
        let plan = r.route(&req(128));
        assert_eq!(plan.hints, crate::cache::FactorHints::default());
        assert!(!plan.factors_cached);
    }

    #[test]
    fn mixed_requests_skip_fingerprinting() {
        // One identified operand ⇒ the anonymous side stays dense on the
        // execution path, so the router must not pay to hash it.
        let (r, _) = content_router(small_settings());
        let plan = r.route(&req(64).with_ids(Some(9), None));
        assert_eq!(plan.hints, crate::cache::FactorHints::default());
    }

    #[test]
    fn cacheable_miss_prices_amortized_decomposition() {
        // Forced low-rank kernel on an anonymous, admissible, not-yet-
        // resident pair: the content router divides the decomposition
        // charge by amortize_over, the plain router charges it in full.
        let settings = CacheSettings {
            amortize_over: 16,
            ..small_settings()
        };
        let (r, _) = content_router(settings);
        let plain = router();
        let request = req(512).with_kernel(KernelKind::LowRankFp8);
        let plan = r.route(&request);
        let full = plain.route(&request);
        assert!(plan.amortized, "cacheable miss must be flagged amortized");
        assert!(!full.amortized);
        assert!(
            plan.choice.cost.time_s < full.choice.cost.time_s,
            "amortized {} must undercut cold {}",
            plan.choice.cost.time_s,
            full.choice.cost.time_s
        );
    }

    #[test]
    fn one_cacheable_operand_is_enough_for_amortization() {
        // Asymmetric serving shape: admitted weight × below-gate
        // activation. The weight's decomposition dominates; the credit
        // must engage even though the activation never caches.
        let (r, _) = content_router(CacheSettings {
            enabled: true,
            min_dim: 256,
            ..Default::default()
        });
        let mut rng = Pcg64::seeded(31);
        let mut request = req(64).with_kernel(KernelKind::LowRankFp8);
        request.a = Matrix::gaussian(512, 512, &mut rng); // admitted
        request.b = Matrix::gaussian(512, 64, &mut rng); // below min_dim
        let plan = r.route(&request);
        assert!(plan.hints.a.is_some());
        assert!(plan.hints.b.is_none());
        assert!(plan.amortized, "one admitted operand must engage the credit");
    }

    #[test]
    fn fp8_stored_factors_charge_reencode_error() {
        // An fp8-storing content cache must surcharge the low-rank error
        // prediction of fingerprinted requests; an f32-storing one (and
        // the plain router) must not.
        let request = req(512).with_kernel(KernelKind::LowRankFp8);
        let (f32_router, _) = content_router(small_settings());
        let (fp8_router, _) = content_router(CacheSettings {
            fp8: true,
            ..small_settings()
        });
        let base = f32_router.route(&request).choice.predicted_error;
        let charged = fp8_router.route(&request).choice.predicted_error;
        assert!(
            charged > base,
            "fp8 storage must surcharge error: {charged} vs {base}"
        );
        assert_eq!(
            router().route(&request).choice.predicted_error.to_bits(),
            base.to_bits(),
            "f32-storing cache must stay bit-identical to no cache"
        );
        // Below the admission gate nothing is fingerprinted — and nothing
        // round-trips through FP8 — so no surcharge applies.
        let small = req(16).with_kernel(KernelKind::LowRankFp8);
        assert_eq!(
            fp8_router.route(&small).choice.predicted_error.to_bits(),
            router().route(&small).choice.predicted_error.to_bits()
        );
    }

    #[test]
    fn error_model_wires_into_routing() {
        let model = Arc::new(crate::accuracy::ErrorModel::new(0.5, 0));
        let r = Router::new(RouterConfig::default(), Arc::new(FactorCache::new(1 << 20)))
            .with_error_model(model.clone());
        let request = req(96).with_kernel(KernelKind::LowRankFp8);
        let before = r.route(&request);
        assert_eq!(before.choice.error_correction, 1.0);
        let raw = before.choice.predicted_error as f64;
        let (m, k, n) = request.shape();
        model.record(KernelKind::LowRankFp8, m, k, n, before.rank, raw, raw * 3.0);
        let after = r.route(&request);
        assert!((after.choice.error_correction - 3.0).abs() < 1e-9);
        assert!(after.choice.predicted_error > before.choice.predicted_error);
    }

    #[test]
    fn open_breaker_reroutes_serving_plans_only() {
        let plane = FaultPlane::new(
            &crate::config::FaultSettings {
                enabled: true,
                breaker_window: 2,
                breaker_threshold: 2,
                breaker_cooldown: 8,
                ..Default::default()
            },
            &crate::metrics::MetricsRegistry::new(),
        );
        let r = router().with_fault(plane.clone());
        let request = req(64).with_kernel(KernelKind::LowRankFp8);
        assert_eq!(r.route_serving(&request).degraded, None);
        plane.observe(KernelKind::LowRankFp8, false);
        plane.observe(KernelKind::LowRankFp8, false); // trips
        let plan = r.route_serving(&request);
        assert_eq!(plan.choice.kind, KernelKind::DenseF32);
        assert_eq!(
            plan.degraded,
            Some(DegradeReason::BreakerOpen {
                from: KernelKind::LowRankFp8
            })
        );
        // Introspection must neither reroute nor consume breaker state
        // (cooldown denials / the half-open probe slot).
        let preview = r.route(&request);
        assert_eq!(preview.choice.kind, KernelKind::LowRankFp8);
        assert_eq!(preview.degraded, None);
    }

    #[test]
    fn no_content_cache_leaves_plans_hint_free() {
        let r = router();
        let plan = r.route(&req(256));
        assert_eq!(plan.hints, crate::cache::FactorHints::default());
    }

    fn autotune_router(epsilon: f64) -> Router {
        let settings = crate::config::schema::AutotuneSettings {
            enabled: true,
            epsilon,
            ..Default::default()
        };
        Router::with_autotune(
            RouterConfig::default(),
            Arc::new(FactorCache::new(64 << 20)),
            Arc::new(crate::autotune::CalibrationTable::new(
                settings.ewma_alpha,
                settings.min_samples,
            )),
            &settings,
        )
    }

    #[test]
    fn exploration_stays_within_tolerance() {
        // ε = 1: every route explores when an in-tolerance alternative
        // exists — and the explored kernel must itself fit the tolerance.
        let r = autotune_router(1.0);
        let greedy = Router::new(RouterConfig::default(), Arc::new(FactorCache::new(1 << 20)));
        let mut explored = 0;
        for i in 0..32 {
            let request = req(64 + i);
            let best = greedy.route(&request).choice;
            let plan = r.route_serving(&request);
            if plan.explored {
                explored += 1;
                assert_ne!(plan.choice.kind, best.kind);
            }
            assert!(
                plan.choice.predicted_error <= plan.tolerance,
                "explored kernel {:?} breaks tolerance",
                plan.choice.kind
            );
        }
        assert!(explored > 0, "ε=1 must explore");
    }

    #[test]
    fn zero_epsilon_and_forced_kernels_never_explore() {
        let r = autotune_router(0.0);
        assert!(!r.route_serving(&req(128)).explored);
        let r = autotune_router(1.0);
        let plan = r.route_serving(&req(64).with_kernel(KernelKind::DenseF16));
        assert!(!plan.explored, "explicit kernel bypasses exploration");
        assert_eq!(plan.choice.kind, KernelKind::DenseF16);
    }

    #[test]
    fn tight_tolerance_leaves_nothing_to_explore() {
        // Only DenseF32 fits 1e-6; no alternative may be explored.
        let r = autotune_router(1.0);
        for _ in 0..8 {
            let plan = r.route_serving(&req(128).with_tolerance(1e-6));
            assert!(!plan.explored);
            assert_eq!(plan.choice.kind, KernelKind::DenseF32);
        }
    }

    #[test]
    fn plan_introspection_never_explores_or_consumes_rng() {
        // route() is the introspection path: deterministic even at ε=1,
        // and it must not advance the exploration RNG — the serving
        // sequence may not depend on how many previews interleaved.
        let r = autotune_router(1.0);
        let request = req(96);
        let kind = r.route(&request).choice.kind;
        for _ in 0..8 {
            let plan = r.route(&request);
            assert!(!plan.explored);
            assert_eq!(plan.choice.kind, kind);
        }
        // A second router with the same seed whose RNG was untouched by
        // previews must produce the identical serving sequence.
        let fresh = autotune_router(1.0);
        let a: Vec<_> = (0..8)
            .map(|i| r.route_serving(&req(64 + i)).choice.kind)
            .collect();
        let b: Vec<_> = (0..8)
            .map(|i| fresh.route_serving(&req(64 + i)).choice.kind)
            .collect();
        assert_eq!(a, b);
    }
}
