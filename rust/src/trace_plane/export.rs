//! Trace exporters: `chrome://tracing` JSON and indented text trees.
//!
//! Chrome trace-event format: one complete event (`"ph":"X"`) per span,
//! timestamps/durations in microseconds, `pid` fixed at 1, `tid` set to
//! the worker-thread ordinal so tile spans land on their worker's row.
//! Span attributes (plus trace/span/parent ids) go into `args`. Within a
//! trace, events are emitted in start-time order.

use std::fmt::Write as _;
use std::sync::Arc;

use super::recorder::FinishedTrace;
use super::span::{AttrValue, SpanRecord, NO_PARENT};

fn write_args(out: &mut String, trace_id: u64, s: &SpanRecord) {
    let _ = write!(
        out,
        "{{\"trace_id\":{trace_id},\"span_id\":{},\"parent_id\":{}",
        s.span_id, s.parent_id
    );
    for a in s.attrs() {
        match a.value {
            AttrValue::U64(v) => {
                let _ = write!(out, ",\"{}\":{v}", a.key);
            }
            AttrValue::F64(v) => {
                let _ = write!(out, ",\"{}\":{v:e}", a.key);
            }
            AttrValue::Str(v) => {
                let _ = write!(out, ",\"{}\":\"{v}\"", a.key);
            }
        }
    }
    out.push('}');
}

/// Render traces as a chrome trace-event JSON document
/// (`{"traceEvents": [...]}`), loadable in `chrome://tracing` or Perfetto.
pub fn chrome_trace_json(traces: &[Arc<FinishedTrace>]) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    for t in traces {
        for s in &t.spans {
            if !first {
                out.push(',');
            }
            first = false;
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"cat\":\"lowrank_gemm\",\"ph\":\"X\",\
                 \"ts\":{:.3},\"dur\":{:.3},\"pid\":1,\"tid\":{},\"args\":",
                s.name,
                s.start_ns as f64 / 1e3,
                s.duration_ns() as f64 / 1e3,
                s.worker
            );
            write_args(&mut out, t.trace_id, s);
            out.push('}');
        }
    }
    out.push_str("]}");
    out
}

/// Render one trace as an indented text tree: stage name, duration, and
/// attributes, children ordered by start time.
pub fn text_tree(t: &FinishedTrace) -> String {
    let mut out = format!(
        "trace {} — {:.3} ms, {} spans{}\n",
        t.trace_id,
        t.duration_ns as f64 / 1e6,
        t.spans.len(),
        if t.dropped_spans > 0 {
            format!(" ({} dropped)", t.dropped_spans)
        } else {
            String::new()
        }
    );
    fn children<'a>(t: &'a FinishedTrace, parent: u32) -> Vec<&'a SpanRecord> {
        // spans are already start-ordered, so this preserves start order.
        t.spans.iter().filter(|s| s.parent_id == parent).collect()
    }
    fn emit(out: &mut String, t: &FinishedTrace, s: &SpanRecord, depth: usize) {
        let _ = write!(
            out,
            "{:indent$}{} {:.3} ms [w{}]",
            "",
            s.name,
            s.duration_ns() as f64 / 1e6,
            s.worker,
            indent = depth * 2
        );
        for a in s.attrs() {
            match a.value {
                AttrValue::U64(v) => {
                    let _ = write!(out, " {}={v}", a.key);
                }
                AttrValue::F64(v) => {
                    let _ = write!(out, " {}={v:.3e}", a.key);
                }
                AttrValue::Str(v) => {
                    let _ = write!(out, " {}={v}", a.key);
                }
            }
        }
        out.push('\n');
        for c in children(t, s.span_id) {
            emit(out, t, c, depth + 1);
        }
    }
    for root in children(t, NO_PARENT) {
        emit(&mut out, t, root, 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace_plane::span::{Attr, MAX_ATTRS};

    fn record(
        span_id: u32,
        parent_id: u32,
        name: &'static str,
        start_ns: u64,
        end_ns: u64,
    ) -> SpanRecord {
        let mut attrs = [None; MAX_ATTRS];
        attrs[0] = Some(Attr::u64("n", 64));
        SpanRecord {
            span_id,
            parent_id,
            name,
            start_ns,
            end_ns,
            worker: 2,
            attrs,
        }
    }

    fn sample() -> FinishedTrace {
        FinishedTrace {
            trace_id: 9,
            duration_ns: 5000,
            dropped_spans: 0,
            spans: vec![
                record(1, 0, "request", 0, 5000),
                record(2, 1, "route", 100, 400),
                record(3, 1, "exec", 500, 4500),
                record(4, 3, "tile", 600, 2000),
            ],
        }
    }

    #[test]
    fn chrome_export_shape() {
        let j = chrome_trace_json(&[Arc::new(sample())]);
        assert!(j.starts_with("{\"traceEvents\":["));
        assert!(j.ends_with("]}"));
        assert!(j.contains("\"name\":\"route\""));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"tid\":2"));
        assert!(j.contains("\"trace_id\":9"));
        assert!(j.contains("\"n\":64"));
        // µs conversion: the exec span starts at 0.5 µs.
        assert!(j.contains("\"ts\":0.500"));
    }

    #[test]
    fn empty_export_is_valid() {
        assert_eq!(chrome_trace_json(&[]), "{\"traceEvents\":[]}");
    }

    #[test]
    fn text_tree_indents_by_depth() {
        let txt = text_tree(&sample());
        assert!(txt.contains("trace 9"));
        assert!(txt.contains("\n  request"));
        assert!(txt.contains("\n    route"));
        assert!(txt.contains("\n      tile"));
    }
}
