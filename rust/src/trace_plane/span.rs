//! Span records and the per-request span arena.
//!
//! A [`RequestTrace`] owns a fixed-size slot arena allocated once at
//! request admission. Starting a span reserves a slot with one
//! `fetch_add`; finishing it writes the completed [`SpanRecord`] into the
//! slot's `OnceLock`. Worker threads therefore publish spans without ever
//! taking a lock or allocating — the only synchronization on the hot path
//! is the cursor increment and the `OnceLock` release store. Spans past
//! `max_spans` are dropped and counted, never blocking the request.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Parent id meaning "no parent" (the root span).
pub const NO_PARENT: u32 = 0;
/// Span id of the implicit per-request root span (always slot 0).
pub const ROOT_SPAN: u32 = 1;
/// Attribute capacity per span (fixed so records stay `Copy`-sized).
pub const MAX_ATTRS: usize = 4;

/// A typed span attribute value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AttrValue {
    /// Unsigned integer (shapes, ranks, tile ids, worker ordinals).
    U64(u64),
    /// Float (tolerances, ratios).
    F64(f64),
    /// Static string (kernel ids, backend names).
    Str(&'static str),
}

/// One key/value span attribute.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Attr {
    /// Attribute key.
    pub key: &'static str,
    /// Attribute value.
    pub value: AttrValue,
}

impl Attr {
    /// Integer attribute.
    pub fn u64(key: &'static str, v: u64) -> Self {
        Attr {
            key,
            value: AttrValue::U64(v),
        }
    }

    /// Float attribute.
    pub fn f64(key: &'static str, v: f64) -> Self {
        Attr {
            key,
            value: AttrValue::F64(v),
        }
    }

    /// Static-string attribute.
    pub fn str(key: &'static str, v: &'static str) -> Self {
        Attr {
            key,
            value: AttrValue::Str(v),
        }
    }
}

/// A completed span: one timed stage of one request.
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    /// Span id (slot index + 1; [`ROOT_SPAN`] for the root).
    pub span_id: u32,
    /// Parent span id ([`NO_PARENT`] for the root).
    pub parent_id: u32,
    /// Stage name (static: "route", "pack", "tile", ...).
    pub name: &'static str,
    /// Start, nanoseconds since the tracer epoch.
    pub start_ns: u64,
    /// End, nanoseconds since the tracer epoch.
    pub end_ns: u64,
    /// Ordinal of the thread that ran the span (maps to chrome `tid`).
    pub worker: u32,
    /// Up to [`MAX_ATTRS`] key/value attributes.
    pub attrs: [Option<Attr>; MAX_ATTRS],
}

impl SpanRecord {
    /// Span duration in nanoseconds.
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    /// Iterate the set attributes.
    pub fn attrs(&self) -> impl Iterator<Item = &Attr> {
        self.attrs.iter().flatten()
    }
}

/// The span arena for one in-flight request.
pub struct RequestTrace {
    trace_id: u64,
    epoch: Instant,
    start_ns: u64,
    cursor: AtomicUsize,
    slots: Vec<OnceLock<SpanRecord>>,
    dropped: AtomicU64,
}

impl RequestTrace {
    /// New arena with `max_spans` slots; slot 0 is reserved for the root
    /// span written at finish time.
    pub(crate) fn new(trace_id: u64, epoch: Instant, max_spans: usize) -> Self {
        let max_spans = max_spans.max(2);
        let start_ns = epoch.elapsed().as_nanos() as u64;
        RequestTrace {
            trace_id,
            epoch,
            start_ns,
            cursor: AtomicUsize::new(1),
            slots: (0..max_spans).map(|_| OnceLock::new()).collect(),
            dropped: AtomicU64::new(0),
        }
    }

    /// Trace id.
    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    /// Admission time, nanoseconds since the tracer epoch.
    pub fn start_ns(&self) -> u64 {
        self.start_ns
    }

    /// Current time on this trace's clock.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Map an `Instant` onto this trace's clock.
    pub fn ns_of(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.epoch).as_nanos() as u64
    }

    /// Reserve a slot: returns `(slot, span_id)`, or `None` (counted) when
    /// the arena is full.
    pub(crate) fn claim(&self) -> Option<(usize, u32)> {
        let slot = self.cursor.fetch_add(1, Ordering::Relaxed);
        if slot >= self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        Some((slot, slot as u32 + 1))
    }

    /// Publish a completed record into its reserved slot.
    pub(crate) fn store(&self, slot: usize, rec: SpanRecord) {
        let _ = self.slots[slot].set(rec);
    }

    /// Record a span whose start/end are already known (e.g. queue wait,
    /// measured between two `Instant`s rather than via a guard).
    pub fn record_span(
        &self,
        name: &'static str,
        parent_id: u32,
        start_ns: u64,
        end_ns: u64,
        attrs: &[Attr],
    ) {
        if let Some((slot, span_id)) = self.claim() {
            let mut rec = SpanRecord {
                span_id,
                parent_id,
                name,
                start_ns,
                end_ns,
                worker: crate::metrics::thread_ordinal() as u32,
                attrs: [None; MAX_ATTRS],
            };
            for (dst, a) in rec.attrs.iter_mut().zip(attrs) {
                *dst = Some(*a);
            }
            self.store(slot, rec);
        }
    }

    /// Spans dropped because the arena filled.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Collect completed spans (slot 0 root first when present, then by
    /// start time). Unfinished slots — a span guard still alive — are
    /// skipped.
    pub(crate) fn collect(&self) -> Vec<SpanRecord> {
        let used = self.cursor.load(Ordering::Acquire).min(self.slots.len());
        let mut out: Vec<SpanRecord> = self.slots[..used]
            .iter()
            .filter_map(|s| s.get().copied())
            .collect();
        out.sort_by_key(|r| (r.start_ns, r.span_id));
        out
    }
}
