//! Request-scoped tracing: span trees, a flight recorder, and exporters.
//!
//! Layered on the serving path as follows:
//!
//! - [`Tracer::begin`] allocates one [`RequestTrace`] span arena per
//!   admitted request (or `None` when `[trace].enabled = false` — the
//!   disabled path allocates nothing and touches no numerics, preserving
//!   bitwise-identical results).
//! - [`scope`] pins the trace to the executing thread; [`span`] opens a
//!   child of the innermost live span via that thread-local context, and
//!   [`span_in`] opens a child from an explicitly captured [`ActiveCtx`]
//!   on threads that never saw the scope (the shard pool's tile workers).
//! - Finishing a span publishes its [`SpanRecord`] into the arena with a
//!   single release store — per-thread buffers flush at span end, and the
//!   hot path never takes a global lock. The only lock in the plane is
//!   one [`FlightRecorder`] mutex acquisition per *completed request*.
//! - [`export`] renders retained traces as an indented text tree or
//!   `chrome://tracing` JSON.
//!
//! Stage names emitted by the serving path: `request` (root), `route`,
//! `fingerprint`, `queue`, `exec`, `factor`, `decompose`, `pack`, `tile`
//! (one per claimed tile, labeled with its worker), `assemble`.

pub mod export;
mod recorder;
mod span;

pub use recorder::{FinishedTrace, FlightRecorder};
pub use span::{Attr, AttrValue, RequestTrace, SpanRecord, MAX_ATTRS, NO_PARENT, ROOT_SPAN};

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::config::schema::TraceSettings;
use crate::metrics::thread_ordinal;

/// Per-service tracer: hands out span arenas and owns the flight recorder.
pub struct Tracer {
    enabled: bool,
    max_spans: usize,
    epoch: Instant,
    next_trace_id: AtomicU64,
    recorder: FlightRecorder,
}

impl Tracer {
    /// Tracer configured from the `[trace]` settings.
    pub fn new(settings: &TraceSettings) -> Self {
        Tracer {
            enabled: settings.enabled,
            max_spans: settings.max_spans,
            epoch: Instant::now(),
            next_trace_id: AtomicU64::new(1),
            recorder: FlightRecorder::new(settings.ring_capacity, settings.slowest_k),
        }
    }

    /// A tracer that records nothing (`begin` always returns `None`).
    pub fn disabled() -> Self {
        Self::new(&TraceSettings::default())
    }

    /// Is span capture on?
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Open a trace for one admitted request. `None` when disabled — the
    /// caller threads the `Option` through and every span site no-ops.
    pub fn begin(&self) -> Option<Arc<RequestTrace>> {
        if !self.enabled {
            return None;
        }
        let id = self.next_trace_id.fetch_add(1, Ordering::Relaxed);
        Some(Arc::new(RequestTrace::new(id, self.epoch, self.max_spans)))
    }

    /// Seal a trace: write the root `request` span spanning admission to
    /// now, collect the span tree, and hand it to the flight recorder.
    pub fn finish(&self, trace: &Arc<RequestTrace>, attrs: &[Attr]) {
        let end_ns = trace.now_ns();
        let mut root = SpanRecord {
            span_id: ROOT_SPAN,
            parent_id: NO_PARENT,
            name: "request",
            start_ns: trace.start_ns(),
            end_ns,
            worker: thread_ordinal() as u32,
            attrs: [None; MAX_ATTRS],
        };
        for (dst, a) in root.attrs.iter_mut().zip(attrs) {
            *dst = Some(*a);
        }
        trace.store(0, root);
        self.recorder.record(FinishedTrace {
            trace_id: trace.trace_id(),
            duration_ns: end_ns.saturating_sub(trace.start_ns()),
            dropped_spans: trace.dropped(),
            spans: trace.collect(),
        });
    }

    /// The retained traces.
    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }
}

/// A trace pinned to a point in its span tree — what tile workers capture
/// before fanning out.
#[derive(Clone)]
pub struct ActiveCtx {
    /// The request's span arena.
    pub trace: Arc<RequestTrace>,
    /// Span id new children attach under.
    pub parent: u32,
}

std::thread_local! {
    static CURRENT: RefCell<Option<ActiveCtx>> = const { RefCell::new(None) };
}

/// The calling thread's active trace context, if any. Cheap (one `Arc`
/// clone), allocation-free.
pub fn current() -> Option<ActiveCtx> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Pins `trace` (at `parent`) to this thread until the guard drops,
/// restoring whatever context was active before.
#[must_use = "the scope ends when this guard drops"]
pub struct ScopeGuard {
    prev: Option<ActiveCtx>,
}

/// Enter a trace scope on the calling thread.
pub fn scope(trace: Arc<RequestTrace>, parent: u32) -> ScopeGuard {
    let prev = CURRENT.with(|c| c.borrow_mut().replace(ActiveCtx { trace, parent }));
    ScopeGuard { prev }
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

struct SpanInner {
    trace: Arc<RequestTrace>,
    slot: usize,
    span_id: u32,
    parent_id: u32,
    name: &'static str,
    start_ns: u64,
    attrs: [Option<Attr>; MAX_ATTRS],
    nattrs: usize,
    pop_tls: bool,
}

/// An open span; publishes its record when dropped. Inert (and
/// allocation-free) when no trace is active.
#[must_use = "the span ends when this guard drops"]
pub struct SpanGuard {
    inner: Option<SpanInner>,
}

/// Open a child of the innermost live span on this thread. No-op when the
/// thread has no active trace context or the span arena is full.
pub fn span(name: &'static str) -> SpanGuard {
    CURRENT.with(|cur| {
        let mut cur = cur.borrow_mut();
        let Some(ctx) = cur.as_mut() else {
            return SpanGuard { inner: None };
        };
        let Some((slot, span_id)) = ctx.trace.claim() else {
            return SpanGuard { inner: None };
        };
        let parent_id = ctx.parent;
        ctx.parent = span_id;
        SpanGuard {
            inner: Some(SpanInner {
                trace: ctx.trace.clone(),
                slot,
                span_id,
                parent_id,
                name,
                start_ns: ctx.trace.now_ns(),
                attrs: [None; MAX_ATTRS],
                nattrs: 0,
                pop_tls: true,
            }),
        }
    })
}

/// Open a child under an explicitly captured context — for pool threads
/// that never entered the scope. Does not touch thread-local state.
pub fn span_in(ctx: &ActiveCtx, name: &'static str) -> SpanGuard {
    let Some((slot, span_id)) = ctx.trace.claim() else {
        return SpanGuard { inner: None };
    };
    SpanGuard {
        inner: Some(SpanInner {
            trace: ctx.trace.clone(),
            slot,
            span_id,
            parent_id: ctx.parent,
            name,
            start_ns: ctx.trace.now_ns(),
            attrs: [None; MAX_ATTRS],
            nattrs: 0,
            pop_tls: false,
        }),
    }
}

impl SpanGuard {
    fn push(&mut self, attr: Attr) {
        if let Some(inner) = self.inner.as_mut() {
            if inner.nattrs < MAX_ATTRS {
                inner.attrs[inner.nattrs] = Some(attr);
                inner.nattrs += 1;
            }
        }
    }

    /// Attach an integer attribute (first [`MAX_ATTRS`] stick).
    pub fn attr_u64(&mut self, key: &'static str, v: u64) {
        self.push(Attr::u64(key, v));
    }

    /// Attach a float attribute.
    pub fn attr_f64(&mut self, key: &'static str, v: f64) {
        self.push(Attr::f64(key, v));
    }

    /// Attach a static-string attribute.
    pub fn attr_str(&mut self, key: &'static str, v: &'static str) {
        self.push(Attr::str(key, v));
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(inner) = self.inner.take() else {
            return;
        };
        let end_ns = inner.trace.now_ns();
        inner.trace.store(
            inner.slot,
            SpanRecord {
                span_id: inner.span_id,
                parent_id: inner.parent_id,
                name: inner.name,
                start_ns: inner.start_ns,
                end_ns,
                worker: thread_ordinal() as u32,
                attrs: inner.attrs,
            },
        );
        if inner.pop_tls {
            CURRENT.with(|cur| {
                if let Some(ctx) = cur.borrow_mut().as_mut() {
                    if ctx.parent == inner.span_id {
                        ctx.parent = inner.parent_id;
                    }
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled_tracer() -> Tracer {
        Tracer::new(&TraceSettings {
            enabled: true,
            ..Default::default()
        })
    }

    #[test]
    fn disabled_tracer_begins_nothing() {
        let t = Tracer::disabled();
        assert!(!t.enabled());
        assert!(t.begin().is_none());
        // Span sites are inert without a scope.
        let g = span("orphan");
        drop(g);
        assert!(current().is_none());
    }

    #[test]
    fn span_tree_nests_and_restores_parent() {
        let tracer = enabled_tracer();
        let trace = tracer.begin().unwrap();
        {
            let _scope = scope(trace.clone(), ROOT_SPAN);
            {
                let mut a = span("a");
                a.attr_u64("n", 7);
                {
                    let _b = span("b");
                }
                let _c = span("c");
            }
            let _d = span("d");
        }
        tracer.finish(&trace, &[Attr::str("kernel", "dense_f32")]);
        let rec = tracer.recorder().recent();
        assert_eq!(rec.len(), 1);
        let spans = &rec[0].spans;
        let by_name = |n: &str| spans.iter().find(|s| s.name == n).unwrap();
        let (root, a, b, c, d) = (
            by_name("request"),
            by_name("a"),
            by_name("b"),
            by_name("c"),
            by_name("d"),
        );
        assert_eq!(root.parent_id, NO_PARENT);
        assert_eq!(a.parent_id, root.span_id);
        assert_eq!(b.parent_id, a.span_id);
        assert_eq!(c.parent_id, a.span_id, "parent restored after b drops");
        assert_eq!(d.parent_id, root.span_id, "parent restored after a drops");
        assert_eq!(a.attrs().next().unwrap().value, AttrValue::U64(7));
        assert!(root.start_ns <= a.start_ns && a.end_ns <= root.end_ns);
        assert_eq!(rec[0].dropped_spans, 0);
    }

    #[test]
    fn span_in_attaches_from_foreign_thread() {
        let tracer = enabled_tracer();
        let trace = tracer.begin().unwrap();
        let ctx = ActiveCtx {
            trace: trace.clone(),
            parent: ROOT_SPAN,
        };
        let handle = std::thread::spawn(move || {
            let mut g = span_in(&ctx, "tile");
            g.attr_u64("worker", 3);
        });
        handle.join().unwrap();
        tracer.finish(&trace, &[]);
        let rec = tracer.recorder().recent();
        let tile = rec[0].spans.iter().find(|s| s.name == "tile").unwrap();
        assert_eq!(tile.parent_id, ROOT_SPAN);
    }

    #[test]
    fn arena_overflow_drops_and_counts() {
        let tracer = Tracer::new(&TraceSettings {
            enabled: true,
            max_spans: 4,
            ..Default::default()
        });
        let trace = tracer.begin().unwrap();
        let _scope = scope(trace.clone(), ROOT_SPAN);
        for _ in 0..10 {
            let _g = span("s");
        }
        drop(_scope);
        tracer.finish(&trace, &[]);
        let rec = tracer.recorder().recent();
        // Root + 3 children fit in 4 slots; 7 claims bounced.
        assert_eq!(rec[0].spans.len(), 4);
        assert_eq!(rec[0].dropped_spans, 7);
    }
}
