//! The flight recorder: bounded retention of completed request traces.
//!
//! One mutex acquisition per **completed request** (never per span): the
//! recorder keeps a ring of the last `capacity` traces plus a separate
//! always-retained list of the `slowest_k` by root duration, so a latency
//! spike stays inspectable long after the ring has wrapped past it.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::span::SpanRecord;

/// One fully-assembled request trace.
#[derive(Clone, Debug)]
pub struct FinishedTrace {
    /// Trace id (monotone per tracer).
    pub trace_id: u64,
    /// Root (request) span duration in nanoseconds.
    pub duration_ns: u64,
    /// Spans dropped because the per-request arena filled.
    pub dropped_spans: u64,
    /// Completed spans, root first, then by start time.
    pub spans: Vec<SpanRecord>,
}

struct Inner {
    ring: VecDeque<Arc<FinishedTrace>>,
    slowest: Vec<Arc<FinishedTrace>>,
    total: u64,
}

/// Fixed-capacity trace retention (ring + slowest-K).
pub struct FlightRecorder {
    capacity: usize,
    slowest_k: usize,
    inner: Mutex<Inner>,
}

impl FlightRecorder {
    /// Recorder holding the last `capacity` traces and the `slowest_k`
    /// slowest ever seen.
    pub fn new(capacity: usize, slowest_k: usize) -> Self {
        let capacity = capacity.max(1);
        FlightRecorder {
            capacity,
            slowest_k,
            inner: Mutex::new(Inner {
                ring: VecDeque::with_capacity(capacity),
                slowest: Vec::with_capacity(slowest_k),
                total: 0,
            }),
        }
    }

    /// Record one finished trace.
    pub fn record(&self, trace: FinishedTrace) {
        let trace = Arc::new(trace);
        let mut g = self.inner.lock().unwrap();
        g.total += 1;
        if g.ring.len() == self.capacity {
            g.ring.pop_front();
        }
        g.ring.push_back(trace.clone());
        if self.slowest_k > 0 {
            let pos = g
                .slowest
                .iter()
                .position(|t| trace.duration_ns > t.duration_ns)
                .unwrap_or(g.slowest.len());
            if pos < self.slowest_k {
                g.slowest.insert(pos, trace);
                g.slowest.truncate(self.slowest_k);
            }
        }
    }

    /// The retained recent traces, oldest first.
    pub fn recent(&self) -> Vec<Arc<FinishedTrace>> {
        self.inner.lock().unwrap().ring.iter().cloned().collect()
    }

    /// The slowest traces ever recorded, slowest first.
    pub fn slowest(&self) -> Vec<Arc<FinishedTrace>> {
        self.inner.lock().unwrap().slowest.clone()
    }

    /// Total traces ever recorded (including ones the ring evicted).
    pub fn total_recorded(&self) -> u64 {
        self.inner.lock().unwrap().total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(id: u64, dur: u64) -> FinishedTrace {
        FinishedTrace {
            trace_id: id,
            duration_ns: dur,
            dropped_spans: 0,
            spans: Vec::new(),
        }
    }

    #[test]
    fn ring_wraps_and_keeps_order() {
        let r = FlightRecorder::new(3, 0);
        for i in 0..5 {
            r.record(t(i, 100));
        }
        let ids: Vec<u64> = r.recent().iter().map(|t| t.trace_id).collect();
        assert_eq!(ids, vec![2, 3, 4]);
        assert_eq!(r.total_recorded(), 5);
        assert!(r.slowest().is_empty());
    }

    #[test]
    fn slowest_survive_ring_eviction() {
        let r = FlightRecorder::new(2, 2);
        for (i, d) in [(0u64, 50u64), (1, 900), (2, 10), (3, 400), (4, 20)] {
            r.record(t(i, d));
        }
        let ids: Vec<u64> = r.recent().iter().map(|t| t.trace_id).collect();
        assert_eq!(ids, vec![3, 4]);
        let slow: Vec<(u64, u64)> = r
            .slowest()
            .iter()
            .map(|t| (t.trace_id, t.duration_ns))
            .collect();
        assert_eq!(slow, vec![(1, 900), (3, 400)]);
    }
}
